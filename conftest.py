"""Repo-level pytest bootstrap.

Puts ``src/`` on ``sys.path`` (so the tier-1 command works without exporting
PYTHONPATH) and, when the real ``hypothesis`` package is not installed,
registers the in-repo fallback shim so the property-test modules still
collect and run.  CI installs real hypothesis from ``pyproject.toml``; the
shim only ever activates in environments that cannot install packages.
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.testing import hypothesis_fallback

hypothesis_fallback.install()
