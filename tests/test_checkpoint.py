"""BigStore decomposed delta checkpointing: supersession, quorum restore,
host failure, compaction reclaim, delta-save byte accounting."""
import numpy as np
import pytest

from repro.checkpoint.bigstore import BigStore

RUN = b"run0"


def shards_at(step, n=6, scale=1.0):
    rng = np.random.default_rng(step)
    return {f"layer{i}/w": (rng.standard_normal((4, 8)) * scale).astype(np.float32)
            for i in range(n)}


class TestSaveRestore:
    def test_roundtrip(self):
        store = BigStore(4, replication=3)
        shards = shards_at(1)
        store.save(RUN, shards, step=1)
        got = store.restore(RUN, expect=shards.keys())
        for k, v in shards.items():
            step, arr = got[k]
            assert step == 1
            np.testing.assert_array_equal(arr, v)

    def test_supersession_keeps_latest(self):
        store = BigStore(4)
        store.save(RUN, shards_at(1), step=1, delta_only=False)
        s2 = shards_at(2)
        store.save(RUN, s2, step=2, delta_only=False)
        got = store.restore(RUN)
        for k in s2:
            step, arr = got[k]
            assert step == 2
            np.testing.assert_array_equal(arr, s2[k])

    def test_delta_save_skips_unchanged(self):
        store = BigStore(4)
        shards = shards_at(1)
        r1 = store.save(RUN, shards, step=1)
        assert r1["written"] == len(shards)
        # identical content at step 2: everything skipped
        r2 = store.save(RUN, shards, step=2)
        assert r2["written"] == 0 and r2["skipped"] == len(shards)
        # change one shard only (the MoE-cold-expert pattern)
        shards2 = dict(shards)
        shards2["layer0/w"] = shards["layer0/w"] + 1
        r3 = store.save(RUN, shards2, step=3)
        assert r3["written"] == 1
        got = store.restore(RUN)
        assert got["layer0/w"][0] == 3
        assert got["layer1/w"][0] == 1  # old version still live

    def test_restore_with_dead_host(self):
        store = BigStore(5, replication=3)
        shards = shards_at(7, n=12)
        store.save(RUN, shards, step=7)
        store.kill(0)
        store.kill(3)
        got = store.restore(RUN, expect=shards.keys())
        assert len(got) == 12

    def test_restore_fails_below_quorum(self):
        store = BigStore(3, replication=2)
        shards = shards_at(1, n=8)
        store.save(RUN, shards, step=1)
        store.kill(0)
        store.kill(1)
        store.kill(2)
        with pytest.raises(RuntimeError):
            store.restore(RUN, expect=shards.keys())

    def test_revive_via_antientropy(self):
        store = BigStore(3, replication=2)
        shards = shards_at(1, n=6)
        store.save(RUN, shards, step=1)
        store.kill(1)
        store.revive(1)
        # the revived host must serve reads on its own for its keyrange
        got = store.restore(RUN, expect=shards.keys())
        assert len(got) == 6

    def test_compaction_reclaims_superseded(self):
        store = BigStore(3, replication=3)
        for step in range(1, 6):
            store.save(RUN, shards_at(step), step=step, delta_only=False)
        before = store.total_bytes()
        store.compact_all()
        after = store.total_bytes()
        assert after < before * 0.45  # 5 versions -> 1 live version
        got = store.restore(RUN)
        assert all(s == 5 for s, _ in got.values())

    def test_interrupted_save_is_safe(self):
        """A torn save never corrupts: old shard versions stay live."""
        store = BigStore(3)
        s1 = shards_at(1)
        store.save(RUN, s1, step=1)
        s2 = shards_at(2)
        # write only half of step 2's shards (crash mid-save)
        partial = dict(list(s2.items())[:3])
        store.save(RUN, partial, step=2, delta_only=False)
        got = store.restore(RUN, expect=s1.keys())
        for k in s1:
            step, arr = got[k]
            if k in partial:
                assert step == 2
            else:
                assert step == 1  # old version intact
