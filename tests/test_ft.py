"""End-to-end fault-tolerance integration: train → crash → restore →
deterministic continuation; straggler sealing; elastic resize; serving."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.runtime.ft import FTConfig, FTTrainer


def tiny_cfg():
    return smoke_config("minitron-4b").replace(
        n_layers=2, d_model=32, d_ff=64, vocab_size=97, n_heads=2,
        n_kv_heads=2, head_dim=16)


class TestFTTraining:
    def test_loss_decreases(self):
        tr = FTTrainer(tiny_cfg(), FTConfig(n_hosts=2, global_batch=8,
                                            seq_len=32, ckpt_every=100))
        losses = tr.train_steps(30)
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1

    def test_crash_restore_continues_identically(self):
        """Checkpoint/restart must reproduce the uninterrupted run exactly
        (same data stream, same state -> bit-equal losses)."""
        ft = FTConfig(n_hosts=3, global_batch=6, seq_len=16, ckpt_every=4)
        ref = FTTrainer(tiny_cfg(), ft)
        ref_losses = ref.train_steps(8)

        tr = FTTrainer(tiny_cfg(), ft)
        losses_a = tr.train_steps(4)   # checkpoint fires at step 4
        # simulated coordinator crash: rebuild trainer, restore from store
        tr2 = FTTrainer(tiny_cfg(), ft)
        tr2.store = tr.store
        step = tr2.restore()
        assert step == 4
        losses_b = tr2.train_steps(4)
        np.testing.assert_allclose(losses_a + losses_b, ref_losses, rtol=1e-5)

    def test_restore_survives_host_loss(self):
        ft = FTConfig(n_hosts=4, global_batch=8, seq_len=16, ckpt_every=2,
                      replication=3)
        tr = FTTrainer(tiny_cfg(), ft)
        tr.train_steps(2)
        tr.crash_host(1)
        tr2 = FTTrainer(tiny_cfg(), ft)
        tr2.store = tr.store
        assert tr2.restore() == 2

    def test_straggler_sealed_out(self):
        ft = FTConfig(n_hosts=4, global_batch=8, seq_len=16,
                      quorum_frac=0.5, ckpt_every=100)
        tr = FTTrainer(tiny_cfg(), ft)
        losses = tr.train_steps(3, slow_hosts={"node2": 2})
        assert all(np.isfinite(losses))
        # late duplicate delivery must be rejected (sealed step)
        from repro.train.delta_sync import DeltaAggregator, GradDelta
        agg = DeltaAggregator(["a", "b"], quorum=1)
        g = {"w": jnp.ones(2)}
        agg.offer(GradDelta("a", 0, 4, g))
        agg.seal(0)
        assert agg.offer(GradDelta("b", 0, 4, g)) is False

    def test_elastic_scale_down_continues(self):
        ft = FTConfig(n_hosts=4, global_batch=8, seq_len=16, ckpt_every=100)
        tr = FTTrainer(tiny_cfg(), ft)
        tr.train_steps(2)
        tr.elastic.fail("node3", detected_by="node0")
        losses = tr.train_steps(2)
        assert all(np.isfinite(losses))
        a = tr.elastic.current_assignment()
        assert a.dp_size == 3


class TestServing:
    def test_engine_batched_decode(self):
        from repro.serve.engine import ServeEngine
        from repro.models import build_model

        cfg = tiny_cfg().replace(kv_cache_dtype="bfloat16")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        eng = ServeEngine(cfg, params, max_batch=3, max_len=64)
        rng = np.random.default_rng(0)
        reqs = [eng.submit(rng.integers(0, cfg.vocab_size, 8),
                           max_new_tokens=5) for _ in range(5)]
        eng.run_until_drained()
        assert all(r.done for r in reqs)
        assert all(len(r.out_tokens) == 5 for r in reqs)

    def test_engine_matches_sequential_decode(self):
        """Continuous batching must not change greedy outputs."""
        from repro.serve.engine import ServeEngine
        from repro.models import build_model

        cfg = tiny_cfg().replace(kv_cache_dtype="bfloat16")
        model = build_model(cfg)
        params = model.init(jax.random.key(1))
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, cfg.vocab_size, 6) for _ in range(3)]

        # engine (batched, staggered admission)
        eng = ServeEngine(cfg, params, max_batch=2, max_len=64)
        reqs = [eng.submit(p, max_new_tokens=4) for p in prompts]
        eng.run_until_drained()

        # sequential reference
        for p, r in zip(prompts, reqs):
            logits, cache = model.prefill_step(
                params, {"tokens": jnp.asarray(p[None, :], jnp.int32)},
                max_len=64)
            toks = [int(jnp.argmax(logits[0]))]
            cl = jnp.array([len(p)], jnp.int32)
            for _ in range(3):
                logits, cache = model.decode_step(
                    params, cache, jnp.asarray([[toks[-1]]], jnp.int32), cl)
                toks.append(int(jnp.argmax(logits[0])))
                cl = cl + 1
            assert r.out_tokens == toks
