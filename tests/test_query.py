"""Property + acceptance tests for the §4.4 query subsystem.

Covers the three correctness contracts of the query engine:
* cursor resumption is exact — paging through a set yields byte-for-byte the
  one-shot scan, regardless of page size;
* query results agree with the ORSWOT ground truth (`read_full`) under
  concurrent insert/remove and partial replication;
* the batched (Pallas-dispatched) dot-visibility filter agrees with the
  scalar ``Clock.seen`` path dot-for-dot;
plus the paper's cost claim: a range query over a 100k-element bigset reads
O(result + causal metadata) bytes, not O(n).
"""
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.clusters import BigsetCluster
from repro.cluster.sim import Network
from repro.core.bigset import BigsetVnode
from repro.core.clock import Clock
from repro.core.dots import Dot
from repro.query import (Count, CursorError, Join, Membership, PlanError,
                         QueryExecutor, Range, Scan, decode_cursor,
                         encode_cursor, validate)
from repro.query.batch import BatchVisibility
from repro.storage.lsm import LsmStore

S = b"qset"
T = b"qset2"
ELEMS = [b"a", b"b", b"c", b"d", b"e", b"f", b"g", b"h", b"i", b"j"]

ops_st = st.lists(
    st.tuples(
        st.sampled_from(["add", "rem"]),
        st.integers(0, 2),
        st.sampled_from(ELEMS),
    ),
    max_size=24,
)


def apply_ops(cluster, ops, set_name=S):
    for op, coord, el in ops:
        if op == "add":
            cluster.add(set_name, el, coordinator=coord)
        else:
            cluster.remove(set_name, el, coordinator=coord)


def entries_of(orswot):
    return {e: frozenset(ds) for e, ds in orswot.entries.items()}


def result_entries(res):
    return {e: frozenset(ds) for e, ds in res.entries}


# ----------------------------------------------------------------- cursors
class TestCursors:
    def test_roundtrip(self):
        tok = encode_cursor(b"scope", b"elem")
        assert decode_cursor(tok, b"scope") == (b"elem", False)
        tok = encode_cursor(b"scope", b"elem", inclusive=True)
        assert decode_cursor(tok, b"scope") == (b"elem", True)

    def test_scope_mismatch(self):
        tok = encode_cursor(b"scope-a", b"elem")
        with pytest.raises(CursorError):
            decode_cursor(tok, b"scope-b")

    def test_corruption(self):
        with pytest.raises(CursorError):
            decode_cursor(b"!!not-base64!!", b"s")
        tok = bytearray(encode_cursor(b"s", b"elem"))
        tok[4] = (tok[4] + 1) % 128
        with pytest.raises(CursorError):
            decode_cursor(bytes(tok), b"s")

    def test_scope_components_are_delimited(self):
        """Range(b'a:b') and Range(b'a', start=b'b:') must not share scopes."""
        from repro.query.plan import cursor_scope
        assert cursor_scope(Range(b"a:b")) != cursor_scope(
            Range(b"a", start=b"b:"))
        assert cursor_scope(Scan(b"s")) != cursor_scope(Range(b"s"))

    def test_plan_validation(self):
        with pytest.raises(PlanError):
            validate(Join("bogus", S, T))
        with pytest.raises(PlanError):
            validate(Range(S, start=b"z", end=b"a"))
        with pytest.raises(PlanError):
            validate(Scan(S, page_size=0))


# ---------------------------------------------------------------- executor
class TestExecutor:
    @given(ops_st, st.integers(1, 5))
    @settings(max_examples=40, deadline=None)
    def test_cursor_resumption_equals_one_shot(self, ops, page):
        c = BigsetCluster(3)
        apply_ops(c, ops)
        for a in c.actors:
            ex = QueryExecutor(c.vnodes[a])
            one_shot = ex.execute(Range(S))
            paged, cur = [], None
            for _ in range(64):  # bounded: must terminate
                r = ex.execute(Scan(S, page_size=page, cursor=cur))
                paged.extend(r.entries)
                cur = r.cursor
                if cur is None:
                    break
            assert paged == one_shot.entries

    @given(ops_st, st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_matches_orswot_truth_under_concurrency(self, ops, seed):
        """Partial, reordered replication: every replica's query results must
        equal that replica's materialised ORSWOT (read_full) exactly."""
        net = Network(seed=seed, reorder=True)
        c = BigsetCluster(3, net=net, sync=False)
        apply_ops(c, ops)
        for _ in range(net.pending() // 2):  # deliver only half the deltas
            net.deliver_one(c._handle)
        for a in c.actors:
            vn = c.vnodes[a]
            truth = vn.read_full(S)
            ex = QueryExecutor(vn)
            scan = ex.execute(Range(S))
            assert result_entries(scan) == entries_of(truth)
            assert ex.execute(Count(S)).count == len(truth.entries)
            for el in ELEMS[:3]:
                r = ex.execute(Membership(S, el))
                assert r.present == (el in truth.entries)
                if r.present:
                    assert frozenset(r.entries[0][1]) == truth.entries[el]

    @given(ops_st)
    @settings(max_examples=30, deadline=None)
    def test_bounded_range(self, ops):
        c = BigsetCluster(3)
        apply_ops(c, ops)
        vn = c.vnodes[c.actors[0]]
        ex = QueryExecutor(vn)
        truth = sorted(vn.value(S))
        r = ex.execute(Range(S, start=b"c", end=b"g"))
        assert r.members == [e for e in truth if b"c" <= e < b"g"]
        r = ex.execute(Range(S, limit=2))
        assert r.members == truth[:2]
        assert (r.cursor is not None) == (len(truth) > 2)

    def test_limit_zero_cursor_makes_progress(self):
        vn = BigsetVnode("a")
        for el in ELEMS:
            vn.coordinate_insert(S, el)
        ex = QueryExecutor(vn)
        r = ex.execute(Range(S, limit=0))
        assert r.members == [] and r.cursor is not None
        r2 = ex.execute(Range(S, limit=3, cursor=r.cursor))
        assert r2.members == sorted(ELEMS)[:3]


# ------------------------------------------------------------------- joins
class TestJoins:
    @given(ops_st, ops_st, st.integers(1, 4))
    @settings(max_examples=40, deadline=None)
    def test_join_kinds_match_set_algebra(self, ops_l, ops_r, page):
        c = BigsetCluster(3)
        apply_ops(c, ops_l, S)
        apply_ops(c, ops_r, T)
        vn = c.vnodes[c.actors[0]]
        ex = QueryExecutor(vn)
        left, right = vn.value(S), vn.value(T)
        expected = {
            "intersect": left & right,
            "union": left | right,
            "difference": left - right,
        }
        for kind, exp in expected.items():
            assert ex.execute(Join(kind, S, T)).members == sorted(exp), kind
            paged, cur = [], None
            for _ in range(64):
                r = ex.execute(Join(kind, S, T, limit=page, cursor=cur))
                paged.extend(r.members)
                cur = r.cursor
                if cur is None:
                    break
            assert paged == sorted(exp), f"paged {kind}"


# -------------------------------------------------------- batched dot-seen
clock_st = st.lists(
    st.tuples(st.integers(0, 3), st.integers(1, 200)), max_size=30
).map(lambda ds: Clock.zero().add_dots(
    Dot(f"vnode{a}", c) for a, c in ds))

dots_st = st.lists(
    st.tuples(st.integers(0, 5), st.integers(1, 260)), max_size=60
).map(lambda ds: [Dot(f"vnode{a}", c) for a, c in ds])


class TestBatchVisibility:
    @given(clock_st, dots_st)
    @settings(max_examples=60, deadline=None)
    def test_batched_agrees_with_scalar(self, tombstone, dots):
        vis = BatchVisibility(tombstone, min_batch=1)
        batched = list(vis.seen_mask(dots))
        scalar = [tombstone.seen(d) for d in dots]
        assert batched == scalar

    def test_pallas_path_agrees_with_scalar(self):
        ts = Clock.zero().add_dots(
            [Dot("vnode0", c) for c in range(1, 40)]
            + [Dot("vnode1", c) for c in (2, 5, 70)])
        dots = [Dot("vnode0", c) for c in range(1, 80)] + \
               [Dot("vnode1", c) for c in range(1, 80)] + \
               [Dot("stranger", 3)]
        vis = BatchVisibility(ts, use_pallas=True, interpret=True, min_batch=1)
        assert list(vis.seen_mask(dots)) == [ts.seen(d) for d in dots]

    def test_executor_batched_path_on_survivor_mix(self):
        """A set big enough to cross the batching threshold, with removes."""
        vn = BigsetVnode("a")
        for i in range(400):
            vn.coordinate_insert(S, b"%05d" % i)
        for i in range(0, 400, 3):
            _, ctx = vn.is_member(S, b"%05d" % i)
            vn.coordinate_remove(S, ctx)
        truth = vn.value(S)
        res = QueryExecutor(vn).execute(Range(S))
        assert res.members == sorted(truth)
        assert res.stats.batches >= 1


# -------------------------------------------------------------- cluster path
class TestClusterQuery:
    @given(ops_st)
    @settings(max_examples=30, deadline=None)
    def test_quorum_query_equals_quorum_read(self, ops):
        c = BigsetCluster(3)
        apply_ops(c, ops)
        truth = c.read(S, r=3)
        res = c.query(Range(S), r=3, repair=False)
        assert result_entries(res) == entries_of(truth)
        assert c.query(Count(S), r=3, repair=False).count == len(truth.entries)

    def test_read_repair_replays_missing_deltas(self):
        c = BigsetCluster(3, sync=False)
        for i in range(30):
            c.add(S, b"x%03d" % i, coordinator=0)
        # partition vnode2: it misses every delta
        c.net.queue = [m for m in c.net.queue if m.dst != "vnode2"]
        c.net.deliver_all(c._handle)
        straggler = c.vnodes["vnode2"]
        assert len(straggler.value(S)) == 0
        res = c.query(Range(S), r=3)
        c.settle()  # deliver the repair deltas
        assert res.members == sorted(b"x%03d" % i for i in range(30))
        assert len(straggler.value(S)) == 30

    def test_read_repair_preserves_values(self):
        """Repaired element-keys must carry the stored payload, not b''."""
        c = BigsetCluster(3, sync=False)
        for i in range(8):
            delta = c.vnodes["vnode0"].coordinate_insert(
                S, b"k%d" % i, value=b"payload-%d" % i)
            c._replicate("vnode0", delta, delta.size_bytes())
        c.net.queue = [m for m in c.net.queue if m.dst != "vnode2"]
        c.net.deliver_all(c._handle)
        c.query(Range(S), r=3)
        c.settle()
        repaired = {e: v for e, _d, v in c.vnodes["vnode2"].fold_values(S)}
        assert repaired == {b"k%d" % i: b"payload-%d" % i for i in range(8)}

    def test_executor_join_snapshots_clock(self):
        c = BigsetCluster(3)
        apply_ops(c, [("add", 0, b"a")], S)
        apply_ops(c, [("add", 1, b"b")], T)
        vn = c.vnodes[c.actors[0]]
        res = QueryExecutor(vn).execute(Join("union", S, T))
        assert res.clock == vn.read_clock(S).join(vn.read_clock(T))

    def test_store_seek_bounds_and_limit(self):
        store = LsmStore(memtable_limit=4)
        for i in range(20):
            store.put(b"k%02d" % i, b"v%02d" % i)
        got = list(store.seek(b"k05", b"k15", limit=4))
        assert got == [(b"k%02d" % i, b"v%02d" % i) for i in range(5, 9)]
        assert [k for k, _ in store.seek(b"k18")] == [b"k18", b"k19"]

    def test_quorum_membership_and_join(self):
        c = BigsetCluster(3)
        for i in range(40):
            c.add(S, b"e%03d" % i, coordinator=i % 3)
            if i % 2 == 0:
                c.add(T, b"e%03d" % i, coordinator=i % 3)
        r = c.query(Membership(S, b"e001"), r=3)
        assert r.present and r.entries[0][0] == b"e001"
        assert not c.query(Membership(S, b"zzz"), r=3).present
        r = c.query(Join("intersect", S, T), r=3)
        assert r.members == sorted(c.value(S, r=3) & c.value(T, r=3))


# --------------------------------------------------------- IO acceptance
class TestQueryIo:
    def test_range_io_is_o_result_not_o_n(self):
        """Acceptance: range over a 100k-element bigset reads O(result +
        causal metadata) bytes (measured by the store's IoStats), not O(n)."""
        n = 100_000
        vn = BigsetVnode("a", LsmStore(memtable_limit=1 << 20))
        for i in range(n):
            vn.coordinate_insert(S, b"%08d" % i)
        vn.store.flush()  # one sorted run: queries are a bisect + scan
        ex = QueryExecutor(vn)

        meter = vn.store.meter()
        full = sum(1 for _ in vn.fold(S))
        fold_bytes = meter.delta().bytes_read
        assert full == n

        res = ex.execute(Range(S, start=b"%08d" % (n // 2), limit=100))
        assert len(res.members) == 100
        range_bytes = res.stats.bytes_read
        # o(n): two orders of magnitude under the full fold ...
        assert range_bytes * 100 < fold_bytes, (range_bytes, fold_bytes)
        # ... and absolutely result-sized: ~100 keys (~30B each) + clock +
        # tombstone metadata, far under even 1% of the fold.
        assert range_bytes < 64 * 1024, range_bytes

        probe = ex.execute(Membership(S, b"%08d" % 12345))
        assert probe.present
        assert probe.stats.bytes_read < 4 * 1024, probe.stats.bytes_read

    def test_cluster_query_io_sublinear(self):
        card = 4000
        c = BigsetCluster(3)
        for i in range(card):
            c.add(S, b"%06d" % i, coordinator=i % 3)
        c.compact_all()
        res = c.query(Range(S, start=b"%06d" % 100, limit=20), r=3)
        assert len(res.members) == 20
        # 3 replicas each pay O(result + metadata); far below one full fold
        assert res.stats.bytes_read < 48 * 1024, res.stats.bytes_read
