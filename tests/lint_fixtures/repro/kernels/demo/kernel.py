"""BS006 fixture: host-side imports leaking into a device kernel file."""
import functools

import jax
import jax.numpy as jnp
import numpy as np                           # BS006: numpy belongs in ref.py
from jax.experimental import pallas as pl

from .ref import reference_impl              # BS006: relative import


def kernel(x):
    del functools, jax, jnp, np, pl, reference_impl
    return x
