"""BS006 fixture sibling: numpy is at home in ref.py (rule scope excludes it)."""
import numpy as np


def reference_impl(x):
    return np.asarray(x)
