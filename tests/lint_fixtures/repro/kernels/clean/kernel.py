"""BS006 fixture: the device stack plus compile-time stdlib is allowed."""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def kernel(x) -> Tuple:
    del functools, math, jax, jnp, pl, pltpu
    return (x,)
