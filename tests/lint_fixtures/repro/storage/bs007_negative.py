"""BS007 negative: mutations confined to the WAL-billed entry points."""


class WalStore:
    def __init__(self):
        self.memtable = {}
        self.wal = []

    def put_batch(self, items):
        for key, value in items:
            self.wal.append((key, value))
            self.memtable[key] = value

    def flush(self):
        run = sorted(self.memtable.items())
        self.memtable = {}
        return run

    def recover(self, records):
        for key, value in records:
            self.memtable[key] = value

    def lookup(self, key):
        return self.memtable.get(key)

    def entries(self):
        return self.memtable.items()
