"""BS007 positive: memtable mutations outside the WAL-billed write path."""


class LeakyStore:
    def __init__(self):
        self.memtable = {}

    def sneak_write(self, key, value):
        self.memtable[key] = value

    def evict(self, key):
        self.memtable.pop(key, None)

    def reset(self):
        self.memtable = {}

    def merge_in(self, other):
        self.memtable.update(other)

    def forget(self, key):
        del self.memtable[key]
