"""BS007 suppressed: a justified escape hatch for crash-test backdoors."""


class BackdoorStore:
    def __init__(self):
        self.memtable = {}

    def drop_unlogged(self, key):
        self.memtable.pop(key, None)  # bigset-lint: disable=BS007 -- models losing un-WALed state in crash tests
