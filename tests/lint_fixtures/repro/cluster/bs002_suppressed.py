"""BS002 fixture: justified suppression of an unbilled send."""
from repro.cluster.sim import Network


def ping(net: Network):
    net.send("a", "b", None)  # bigset-lint: disable=BS002 -- fixture: empty control ping bills zero by design
