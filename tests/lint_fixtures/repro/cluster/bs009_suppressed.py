"""BS009 suppressed: a justified literal index in a demo harness."""


def demo_primary(cluster):
    return cluster.vnodes[0]  # bigset-lint: disable=BS009 -- single-vnode demo harness; no ring exists to route through
