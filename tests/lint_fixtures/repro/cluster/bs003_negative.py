"""BS003 fixture: same field names on provably different types are fine."""


class Ledger:
    def __init__(self):
        self.base = 0.0          # Ledger.base, not Clock.base
        self.counts = []         # Ledger.counts, not SetDigest.counts

    def bump(self):
        self.base += 1.0
        self.counts.append(self.base)


def rebase(ledger: Ledger):
    ledger.base = 0.0            # annotated param resolves to Ledger
