"""BS003 fixture: Clock/SetDigest mutation outside core/."""
from repro.core.bigset import SetDigest
from repro.core.clock import Clock


def corrupt(actor):
    c = Clock()
    c.base = {actor: 1}                      # BS003: typed receiver
    c.cloud[actor] = frozenset({3})          # BS003: item write through field
    d = SetDigest()
    d.fences = []                            # BS003: typed receiver
    return c, d


def sneaky(c):
    # receiver type unresolvable -> conservative finding
    c.base = {}                              # BS003
