"""BS008 fixture: raw per-dot cloud enumeration outside core/."""
from repro.core.clock import Clock


def fragmentation_report(clock: Clock, other: Clock):
    per_actor = {a: len(s) for a, s in clock.cloud.items()}  # BS008: .cloud
    dots = clock.all_dots()                                  # BS008: full walk
    for d in other.all_dots():                               # BS008: full walk
        per_actor[d.actor] = d.counter
    return per_actor, dots


def sneaky(c):
    # receiver type unresolvable -> conservative finding
    return sorted(c.cloud)                                   # BS008
