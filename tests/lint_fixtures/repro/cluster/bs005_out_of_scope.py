"""BS005 fixture: anti-entropy's full_sync baseline may fold (not query/serve)."""


def full_sync(vnode, set_name):
    return list(vnode.fold(set_name))        # cluster/: out of BS005 scope
