"""BS002 fixture: billed sends, and .send on non-network receivers."""
from repro.cluster.sim import Network


class Pipe:
    def send(self, item):                    # unrelated .send: fine
        return item


class Fanout:
    def __init__(self):
        self.net = Network()
        self.pipe = Pipe()

    def broadcast(self, payload, size):
        self.net.send("a", "b", payload, size)            # 4 positional
        self.net.send("a", "b", payload, size_bytes=size)  # keyword
        self.pipe.send(payload)              # receiver resolves to Pipe
