"""BS009 negative: ring-routed placement and computed vnode keys."""


def route(cluster, ring, set_name, element):
    pref = ring.preference_list(set_name, element)
    owner = pref.owners[0]            # preference lists ARE the ring's verdict
    vn = cluster.vnodes[owner]        # keyed by actor name, not position
    quorum = cluster.actors[:2]       # a slice is a quorum prefix, not an owner
    for a in cluster.actors:          # iteration never picks a position
        cluster.stores[a].sync()
    return vn, quorum


def dynamic(cluster, i):
    return cluster._actor(i)          # routed variable: the caller decided
