"""BS002 fixture: Network.send without explicit size_bytes."""
from repro.cluster.sim import Network


class Fanout:
    def __init__(self, net=None):
        self.net = net or Network()

    def broadcast(self, payload):
        # type-resolved receiver (self.net = Network()): missing size_bytes
        self.net.send("a", "b", payload)     # BS002


def relay(net, payload):
    # hint-resolved receiver (parameter named ``net``): missing size_bytes
    net.send("a", "b", payload)              # BS002
