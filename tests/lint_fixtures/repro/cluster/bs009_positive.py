"""BS009 fixture: literal vnode indexing bypasses the ring."""


class BadRouting:
    def __init__(self, cluster):
        self.cluster = cluster
        self.actors = list(cluster.actors)

    def primary(self):
        return self.cluster.vnodes[0]               # BS009: hardwired owner

    def coordinator_pair(self):
        first = self.actors[0]                      # BS009: positional owner
        last = self.cluster.actors[-1]              # BS009: negative literal
        return first, last

    def routed_by_position(self, stores):
        vn = self.cluster._actor(2)                 # BS009: literal position
        return vn, stores[1]                        # BS009: store by position
