"""BS008 fixture: run-granular surface and same-named fields elsewhere."""
from repro.core.clock import Clock


class WeatherModel:
    def __init__(self):
        self.cloud = "cumulus"   # WeatherModel.cloud, not Clock.cloud

    def forecast(self):
        return self.cloud.upper()


def sync_ranges(mine: Clock, theirs: Clock):
    # the sanctioned O(runs) surface: ranges in, ranges out
    diverged = mine.diff_runs(theirs)
    healed = theirs.add_runs(diverged)
    return healed.n_runs(), mine.subtract_clock(theirs).size_bytes()


def divergence(mine: Clock, theirs: Clock):
    # diff_dots is allowed: it materialises only the actual divergence
    return mine.diff_dots(theirs)
