"""BS008 suppressed: a justified per-dot escape for an ops dump."""
from repro.core.clock import Clock


def debug_dump(clock: Clock):
    return clock.all_dots()  # bigset-lint: disable=BS008 -- cold-path ops dump; explicitly O(events)
