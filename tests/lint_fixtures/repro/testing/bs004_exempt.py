"""BS004 fixture: testing/ support code exists to assert — exempt."""


def check_roundtrip(codec, value):
    assert codec.decode(codec.encode(value)) == value  # exempt path
