"""BS005 fixture: bounded seeks are the sanctioned query-layer surface."""


def members_in(vnode, set_name, lo, hi):
    return [e for e, _d, _v in vnode.fold_raw(set_name, start=lo, end=hi)]


def postings(vnode, set_name, index):
    return list(vnode.fold_postings(set_name, index))


def window(store, lo, hi):
    return list(store.scan(lo, hi))          # bounded scan: fine
