"""BS005 fixture: full folds from the seek-only query layer."""


def slow_members(vnode, set_name):
    return [e for e, _dot in vnode.fold(set_name)]        # BS005


def slow_count(vnode, set_name):
    return len(vnode.value(set_name))                     # BS005


def slow_everything(store):
    return list(store.scan())                             # BS005: unbounded
