"""BS004 fixture: a justified internal-invariant assert stays."""


def merge(runs):
    out = []
    for run in runs:
        assert run is not None  # bigset-lint: disable=BS004 -- fixture: internal invariant, unreachable from user input
        out.extend(run)
    return out
