"""BS004 fixture: typed exceptions survive python -O."""


def page_size_of(req):
    size = req.get("page_size", 0)
    if size <= 0:
        raise ValueError("page_size must be positive")
    return size
