"""BS004 fixture: bare asserts as validation in library code."""


def page_size_of(req):
    size = req.get("page_size", 0)
    assert size > 0, "page_size must be positive"   # BS004: stripped by -O
    return size


def decode(buf):
    assert isinstance(buf, bytes)                   # BS004
    return buf
