"""BS001 fixture: a justified line suppression silences the finding."""
import time


def default_clock():
    return time.monotonic()  # bigset-lint: disable=BS001 -- fixture: default for an injectable clock
