"""BS003 fixture: core/ is the mutation home — assignments here are legal."""
from .clock import Clock


def _rebuild(c: Clock, base, cloud):
    c.base = base                # allowed: this is core/
    c.cloud = cloud
    return c
