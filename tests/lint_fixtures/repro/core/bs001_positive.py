"""BS001 fixture: wall clocks and ambient randomness in a deterministic layer."""
import random
import time
from datetime import datetime

import numpy as np


def stamp():
    return time.time()                       # BS001: wall clock


def stamp_mono():
    return time.monotonic()                  # BS001: wall clock


def when():
    return datetime.now()                    # BS001: wall clock


def jitter():
    return random.random()                   # BS001: process-global RNG


def pick(xs):
    return random.choice(xs)                 # BS001: process-global RNG


def make_rng():
    return random.Random()                   # BS001: unseeded factory


def noise(n):
    return np.random.rand(n)                 # BS001: process-global RNG


def gen():
    return np.random.default_rng()           # BS001: unseeded factory
