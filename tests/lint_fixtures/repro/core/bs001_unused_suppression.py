"""BS001 fixture: a suppression on a clean line is itself a finding (BS000)."""


def tick(clock):
    return clock()  # bigset-lint: disable=BS001 -- fixture: nothing here triggers BS001
