"""BS000 fixture: malformed suppressions are lint debt themselves."""


def f(x):
    return x  # bigset-lint: disable=BS999 -- fixture: no such rule


def g(x):
    assert x  # bigset-lint: disable=BS004
