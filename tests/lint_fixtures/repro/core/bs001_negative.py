"""BS001 fixture: injected clocks and seeded RNGs are the sanctioned idiom."""
import random

import numpy as np


class Sim:
    def __init__(self, seed: int, clock):
        self.rng = random.Random(seed)       # seeded factory: allowed
        self.gen = np.random.default_rng(seed)
        self.clock = clock                   # injected, not read from time

    def step(self):
        # instance RNG + injected clock: deterministic given (seed, clock)
        return self.rng.random(), self.gen.random(), self.clock()
