"""LSM store + order-preserving key codec tests."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.storage.keycodec import (KIND_ELEMENT, KIND_INDEX, KeyCodecError,
                                    decode_key, encode_key, prefix_bounds,
                                    successor_bytes)
from repro.storage.lsm import LsmStore

key_part = st.one_of(
    st.binary(max_size=12), st.integers(0, 2**64 - 1), st.text(max_size=8)
)
key_tuple = st.lists(key_part, min_size=1, max_size=4).map(tuple)


def norm(t):
    return tuple(p.encode() if isinstance(p, str) else p for p in t)


class TestKeyCodec:
    @given(key_tuple)
    def test_roundtrip(self, t):
        assert decode_key(encode_key(t)) == norm(t)

    @given(st.lists(st.binary(max_size=10), min_size=2, max_size=6))
    def test_order_preserved_bytes(self, parts):
        keys = [(p,) for p in parts]
        encoded = [encode_key(k) for k in keys]
        assert sorted(range(len(keys)), key=lambda i: keys[i][0]) == sorted(
            range(len(keys)), key=lambda i: encoded[i]
        )

    @given(st.lists(st.tuples(st.binary(max_size=6), st.integers(0, 1 << 32)),
                    min_size=2, max_size=8))
    def test_order_preserved_composite(self, parts):
        encoded = [encode_key(p) for p in parts]
        assert sorted(range(len(parts)), key=lambda i: parts[i]) == sorted(
            range(len(parts)), key=lambda i: encoded[i]
        )

    def test_embedded_nulls(self):
        a = encode_key((b"a\x00b",))
        b = encode_key((b"a", b"b"))
        assert a != b and decode_key(a) == (b"a\x00b",)

    @given(st.binary(max_size=8), st.binary(max_size=8), st.binary(max_size=4))
    def test_prefix_bounds_cover_exactly_extensions(self, s, other, tail):
        """[lo, hi) of a prefix contains every extension of it and no key
        with a different component at that position."""
        prefix = (s, KIND_INDEX)
        lo, hi = prefix_bounds(prefix)
        assert lo <= encode_key(prefix + (tail,)) < hi
        assert lo <= encode_key(prefix + (tail, other, 7)) < hi
        inside = lo <= encode_key((s, KIND_ELEMENT, tail)) < hi
        assert not inside  # sibling kind stays outside
        if other != s:
            assert not lo <= encode_key((other, KIND_INDEX, tail)) < hi

    @given(st.binary(max_size=8), st.binary(min_size=1, max_size=8))
    def test_successor_bytes_is_immediate(self, b, ext):
        succ = successor_bytes(b)
        assert b < succ
        assert succ <= b + ext  # nothing fits strictly between b and b+nul


class TestKeyCodecErrors:
    """Malformed keys raise the typed ``KeyCodecError`` — never a leaked
    ``struct.error`` or a vanishing assert (the ``python -O`` smoke job
    runs these paths with asserts stripped)."""

    @pytest.mark.parametrize("bad", [
        b"\x02abc",          # int tag but only 3 payload bytes
        b"\x02",             # int tag, no payload at all
        b"\x01abc",          # string tag, never terminated
        b"\x01abc\x00",      # lone 0x00: neither terminator nor escape
        b"\x01abc\x00\x02",  # bogus escape pair
        b"\x03xyz",          # unknown tag byte
    ])
    def test_malformed_keys_raise_typed(self, bad):
        with pytest.raises(KeyCodecError):
            decode_key(bad)

    def test_keycodec_error_is_a_value_error(self):
        assert issubclass(KeyCodecError, ValueError)
        with pytest.raises(ValueError):  # pre-existing handlers still work
            decode_key(b"\x02ab")

    def test_encode_rejects_out_of_range_int(self):
        with pytest.raises(KeyCodecError):
            encode_key((1 << 64,))
        with pytest.raises(KeyCodecError):
            encode_key((-1,))

    @given(key_tuple)
    def test_truncations_never_leak_untyped(self, t):
        full = encode_key(t)
        for cut in range(len(full)):
            try:
                decode_key(full[:cut])
            except KeyCodecError:
                pass  # typed failure is the contract


class TestLsm:
    def test_put_get_delete(self):
        s = LsmStore(memtable_limit=4)
        for i in range(10):
            s.put(b"k%02d" % i, b"v%d" % i)
        assert s.get(b"k03") == b"v3"
        s.delete(b"k03")
        assert s.get(b"k03") is None
        assert len(s) == 9

    def test_scan_merges_levels(self):
        s = LsmStore(memtable_limit=3)
        for i in range(10):
            s.put(b"k%02d" % i, b"v%d" % i)
        s.put(b"k05", b"NEW")  # overwrite in memtable
        got = dict(s.scan(b"k03", b"k07"))
        assert got == {b"k03": b"v3", b"k04": b"v4", b"k05": b"NEW", b"k06": b"v6"}

    @given(st.lists(st.tuples(st.binary(min_size=1, max_size=6),
                              st.binary(max_size=6)), max_size=40))
    @settings(max_examples=60)
    def test_matches_dict_model(self, ops):
        s = LsmStore(memtable_limit=5, auto_compact_runs=3)
        model = {}
        for k, v in ops:
            s.put(k, v)
            model[k] = v
        for k, v in model.items():
            assert s.get(k) == v
        assert dict(s.scan(b"", b"\xff" * 8)) == model

    def test_compaction_filter_and_discard(self):
        s = LsmStore(memtable_limit=100)
        for i in range(10):
            s.put(b"k%d" % i, b"v")
        dropped = []
        s.compaction_filter = lambda k, v: k < b"k5"
        s.on_discard = lambda k, v: dropped.append(k)
        discarded = s.compact()
        assert len(discarded) == 5 and len(dropped) == 5
        assert s.get(b"k2") is None and s.get(b"k7") == b"v"

    def test_io_accounting_monotone(self):
        s = LsmStore()
        snap = s.stats.snapshot()
        s.put(b"abc", b"defgh")
        d = s.stats.delta(snap)
        assert d.bytes_written == 8 and d.num_writes == 1


class TestPositionalSeek:
    def _filled(self, n=600, memtable_limit=97):
        """Keys spread across several runs plus a live memtable."""
        s = LsmStore(memtable_limit=memtable_limit, auto_compact_runs=64)
        for i in range(n):
            s.put(b"k%05d" % i, b"v%05d" % i)
        return s

    def test_seek_unbounded_is_genuinely_unbounded(self):
        """Regression: hi=None must not fabricate a 24-byte upper fence —
        keys at or past ``b"\\xff" * 24`` were silently truncated."""
        s = LsmStore(memtable_limit=4)
        long_keys = [b"\xff" * 24, b"\xff" * 40, b"\xff" * 24 + b"tail"]
        for k in long_keys:
            s.put(k, b"v")
        s.put(b"plain", b"v")
        got = [k for k, _ in s.seek(b"")]
        assert got == sorted(long_keys + [b"plain"])
        # and from a lower bound inside the long-key cluster
        assert [k for k, _ in s.seek(b"\xff" * 25)] == [b"\xff" * 40]

    def test_seek_unbounded_long_element_keys(self):
        """The same regression through the element keyspace: elements whose
        encoded keys are far past 24 bytes stream in full."""
        from repro.core.bigset import BigsetVnode

        vn = BigsetVnode("a")
        elems = [b"e" * 40, b"f" * 64, b"g" * 100]
        for el in elems:
            vn.coordinate_insert(b"longset", el)
        assert [el for el, _d, _v in vn.fold_raw(b"longset")] == sorted(elems)

    def test_positional_seek_skips_without_io(self):
        """A cursor seek repositions in O(log n) and meters one seek, zero
        bytes — skipped entries are never touched."""
        s = self._filled()
        it = s.scan(b"k00000")
        assert next(it)[0] == b"k00000"
        assert next(it)[0] == b"k00001"
        snap = s.stats.snapshot()
        it.seek(b"k00500")
        d = s.stats.delta(snap)
        assert d.bytes_read == 0 and d.num_seeks == 1
        assert next(it)[0] == b"k00500"

    def test_seek_respects_upper_bound_and_levels(self):
        s = self._filled()
        s.put(b"k00510", b"NEW")  # overwrite lands in the memtable level
        it = s.scan(b"k00000", b"k00512")
        it.seek(b"k00509")
        assert list(it) == [(b"k00509", b"v00509"), (b"k00510", b"NEW"),
                            (b"k00511", b"v00511")]

    def test_cursor_snapshots_levels(self):
        """Writes issued while a cursor is open are not visible through it
        (the old per-scan memtable snapshot semantics)."""
        s = LsmStore(memtable_limit=1000)
        s.put(b"a", b"1")
        it = s.scan(b"")
        s.put(b"b", b"2")
        assert [k for k, _ in it] == [b"a"]
        assert [k for k, _ in s.scan(b"")] == [b"a", b"b"]

    def test_memtable_view_cached_until_write(self):
        """Satellite: scans reuse one bisectable sorted view — positioning
        is O(log n + page), not an O(memtable) sort per cursor."""
        s = LsmStore(memtable_limit=1000)
        for i in range(50):
            s.put(b"m%03d" % i, b"v")
        list(s.scan(b"m010", b"m015"))
        view1 = s._mem_keys
        assert view1 is not None
        list(s.scan(b"m020", b"m025"))
        assert s._mem_keys is view1  # cached: no re-sort between reads
        s.put(b"m999", b"v")
        assert s._mem_keys is None   # write invalidates
        assert [k for k, _ in s.scan(b"m998", None)] == [b"m999"]


class TestRangeStats:
    def test_single_run_exact(self):
        s = LsmStore(memtable_limit=1000)
        items = [(b"r%02d" % i, b"x" * i) for i in range(20)]
        for k, v in items:
            s.put(k, v)
        s.flush()
        rs = s.range_stats(b"r05", b"r15")
        assert rs.keys == 10
        assert rs.bytes == sum(len(k) + len(v) for k, v in items[5:15])
        assert s.range_stats(b"r00").keys == 20          # hi=None unbounded
        assert s.range_stats(b"zz").keys == 0

    def test_memtable_and_runs_combine(self):
        s = LsmStore(memtable_limit=8)
        for i in range(20):          # flushes into runs + leaves a memtable
            s.put(b"c%02d" % i, b"v")
        rs = s.range_stats(b"c00", None)
        assert rs.keys == 20 and rs.bytes == 20 * 4

    def test_run_stats_fences(self):
        s = LsmStore(memtable_limit=1000)
        for i in range(10):
            s.put(b"f%02d" % i, b"val")
        s.flush()
        (st0,) = s.run_stats()
        assert st0.key_count == 10
        assert st0.min_key == b"f00" and st0.max_key == b"f09"
        assert st0.total_bytes == 10 * 6

    def test_stats_never_meter_io(self):
        s = LsmStore(memtable_limit=16)
        for i in range(100):
            s.put(b"s%03d" % i, b"v")
        snap = s.stats.snapshot()
        s.range_stats(b"", None)
        s.run_stats()
        d = s.stats.delta(snap)
        assert d.bytes_read == 0 and d.num_seeks == 0
