"""LSM store + order-preserving key codec tests."""
from hypothesis import given, settings, strategies as st

from repro.storage.keycodec import (KIND_ELEMENT, KIND_INDEX, decode_key,
                                    encode_key, prefix_bounds,
                                    successor_bytes)
from repro.storage.lsm import LsmStore

key_part = st.one_of(
    st.binary(max_size=12), st.integers(0, 2**64 - 1), st.text(max_size=8)
)
key_tuple = st.lists(key_part, min_size=1, max_size=4).map(tuple)


def norm(t):
    return tuple(p.encode() if isinstance(p, str) else p for p in t)


class TestKeyCodec:
    @given(key_tuple)
    def test_roundtrip(self, t):
        assert decode_key(encode_key(t)) == norm(t)

    @given(st.lists(st.binary(max_size=10), min_size=2, max_size=6))
    def test_order_preserved_bytes(self, parts):
        keys = [(p,) for p in parts]
        encoded = [encode_key(k) for k in keys]
        assert sorted(range(len(keys)), key=lambda i: keys[i][0]) == sorted(
            range(len(keys)), key=lambda i: encoded[i]
        )

    @given(st.lists(st.tuples(st.binary(max_size=6), st.integers(0, 1 << 32)),
                    min_size=2, max_size=8))
    def test_order_preserved_composite(self, parts):
        encoded = [encode_key(p) for p in parts]
        assert sorted(range(len(parts)), key=lambda i: parts[i]) == sorted(
            range(len(parts)), key=lambda i: encoded[i]
        )

    def test_embedded_nulls(self):
        a = encode_key((b"a\x00b",))
        b = encode_key((b"a", b"b"))
        assert a != b and decode_key(a) == (b"a\x00b",)

    @given(st.binary(max_size=8), st.binary(max_size=8), st.binary(max_size=4))
    def test_prefix_bounds_cover_exactly_extensions(self, s, other, tail):
        """[lo, hi) of a prefix contains every extension of it and no key
        with a different component at that position."""
        prefix = (s, KIND_INDEX)
        lo, hi = prefix_bounds(prefix)
        assert lo <= encode_key(prefix + (tail,)) < hi
        assert lo <= encode_key(prefix + (tail, other, 7)) < hi
        inside = lo <= encode_key((s, KIND_ELEMENT, tail)) < hi
        assert not inside  # sibling kind stays outside
        if other != s:
            assert not lo <= encode_key((other, KIND_INDEX, tail)) < hi

    @given(st.binary(max_size=8), st.binary(min_size=1, max_size=8))
    def test_successor_bytes_is_immediate(self, b, ext):
        succ = successor_bytes(b)
        assert b < succ
        assert succ <= b + ext  # nothing fits strictly between b and b+nul


class TestLsm:
    def test_put_get_delete(self):
        s = LsmStore(memtable_limit=4)
        for i in range(10):
            s.put(b"k%02d" % i, b"v%d" % i)
        assert s.get(b"k03") == b"v3"
        s.delete(b"k03")
        assert s.get(b"k03") is None
        assert len(s) == 9

    def test_scan_merges_levels(self):
        s = LsmStore(memtable_limit=3)
        for i in range(10):
            s.put(b"k%02d" % i, b"v%d" % i)
        s.put(b"k05", b"NEW")  # overwrite in memtable
        got = dict(s.scan(b"k03", b"k07"))
        assert got == {b"k03": b"v3", b"k04": b"v4", b"k05": b"NEW", b"k06": b"v6"}

    @given(st.lists(st.tuples(st.binary(min_size=1, max_size=6),
                              st.binary(max_size=6)), max_size=40))
    @settings(max_examples=60)
    def test_matches_dict_model(self, ops):
        s = LsmStore(memtable_limit=5, auto_compact_runs=3)
        model = {}
        for k, v in ops:
            s.put(k, v)
            model[k] = v
        for k, v in model.items():
            assert s.get(k) == v
        assert dict(s.scan(b"", b"\xff" * 8)) == model

    def test_compaction_filter_and_discard(self):
        s = LsmStore(memtable_limit=100)
        for i in range(10):
            s.put(b"k%d" % i, b"v")
        dropped = []
        s.compaction_filter = lambda k, v: k < b"k5"
        s.on_discard = lambda k, v: dropped.append(k)
        discarded = s.compact()
        assert len(discarded) == 5 and len(dropped) == 5
        assert s.get(b"k2") is None and s.get(b"k7") == b"v"

    def test_io_accounting_monotone(self):
        s = LsmStore()
        snap = s.stats.snapshot()
        s.put(b"abc", b"defgh")
        d = s.stats.delta(snap)
        assert d.bytes_written == 8 and d.num_writes == 1
