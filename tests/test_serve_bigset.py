"""Serve-layer tests: wire protocol, cursor leases, backpressure, IO cost.

The four contracts of :mod:`repro.serve.bigset_service`:

* the wire codec round-trips every plan shape and rejects malformed
  envelopes with typed errors;
* pagination through the service is exact — pages concatenate to the
  one-shot result with no re-emitted and no skipped elements, even when
  backpressure rejections interleave with resumes (property-tested, runs
  under the hypothesis fallback shim);
* admission control is observable (``retry`` + retry-after hint) and a
  rejected page never invalidates its cursor lease, while idle leases
  expire and foreign sessions are refused;
* the paper's cost claim at the serve layer: each page of a 100k-element
  Scan reads O(page + causal metadata) bytes (per-page IoStats).
"""
import msgpack
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.clusters import BigsetCluster
from repro.core.bigset import BigsetVnode
from repro.index import by_element_suffix
from repro.query import (Count, IndexLookup, IndexRange, Join, LeaseError,
                         Membership, PlanError, Range, Scan, plan_from_wire,
                         plan_to_wire, unwrap_lease, wrap_lease)
from repro.serve.bigset_service import (ANON_SESSION, STATUS_ERROR, STATUS_OK,
                                        STATUS_RETRY, WIRE_VERSION,
                                        Backpressure, BigsetClient,
                                        BigsetService, ServiceConfig,
                                        ServiceError)
from repro.storage.lsm import LsmStore

S = b"srvset"
T = b"srvset2"
ELEMS = [b"a", b"b", b"c", b"d", b"e", b"f", b"g", b"h", b"i", b"j"]

ops_st = st.lists(
    st.tuples(
        st.sampled_from(["add", "rem"]),
        st.integers(0, 2),
        st.sampled_from(ELEMS),
    ),
    max_size=24,
)


def make_service(n=3, config=None):
    """Service over a fresh cluster with a test-controlled clock."""
    cluster = BigsetCluster(n)
    clk = [0.0]
    service = BigsetService(cluster, config, clock=lambda: clk[0])
    return cluster, service, BigsetClient(service), clk


def apply_ops(cluster, ops, set_name=S):
    for op, coord, el in ops:
        if op == "add":
            cluster.add(set_name, el, coordinator=coord)
        else:
            cluster.remove(set_name, el, coordinator=coord)


# ---------------------------------------------------------------- wire codec
class TestPlanWire:
    PLANS = [
        Membership(S, b"x"),
        Range(S, start=b"a", end=b"z", limit=10),
        Range(S, cursor=b"tok"),
        Count(S, start=b"b"),
        Scan(S, page_size=7),
        Join("intersect", S, T, limit=3),
        Join("union", S, T),
        Join("difference", S, T, cursor=b"tok"),
        IndexLookup(S, b"idx", b"key", limit=2),
        IndexRange(S, b"idx", start=b"a", end=b"m", limit=5, cursor=b"tok"),
    ]

    def test_roundtrip_every_shape(self):
        for plan in self.PLANS:
            assert plan_from_wire(plan_to_wire(plan)) == plan

    @given(st.binary(max_size=12), st.binary(max_size=12),
           st.integers(1, 1000))
    @settings(max_examples=40)
    def test_roundtrip_property(self, set_name, start, limit):
        plan = Range(set_name or b"s", start=start or None, limit=limit)
        assert plan_from_wire(plan_to_wire(plan)) == plan

    def test_malformed_envelopes(self):
        with pytest.raises(PlanError):
            plan_from_wire(b"\xffnot-msgpack")
        with pytest.raises(PlanError):
            plan_from_wire(msgpack.packb(["nope"]))
        with pytest.raises(PlanError):  # wrong version
            plan_from_wire(msgpack.packb([99, "scan", {"set_name": S}]))
        with pytest.raises(PlanError):  # unknown shape
            plan_from_wire(msgpack.packb([1, "explode", {}]))
        with pytest.raises(PlanError):  # unknown field
            plan_from_wire(msgpack.packb(
                [1, "scan", {"set_name": S, "hacker": 1}]))
        with pytest.raises(PlanError):  # fails plan validation
            plan_from_wire(msgpack.packb(
                [1, "scan", {"set_name": S, "page_size": -4}]))

    def test_invalid_plan_never_encodes(self):
        with pytest.raises(PlanError):
            plan_to_wire(Scan(S, page_size=0))


# -------------------------------------------------------------------- leases
class TestLeases:
    def test_wrap_roundtrip_and_binding(self):
        tok = wrap_lease(b"sess1", b"cursor-bytes")
        assert unwrap_lease(tok, b"sess1") == b"cursor-bytes"
        with pytest.raises(LeaseError):
            unwrap_lease(tok, b"sess2")
        corrupt = bytearray(tok)
        corrupt[5] = (corrupt[5] + 1) % 128
        with pytest.raises(LeaseError):
            unwrap_lease(bytes(corrupt), b"sess1")

    def test_lease_expiry(self):
        _, service, client, clk = make_service(
            config=ServiceConfig(lease_ttl=10.0))
        client.batch(S, [["add", el] for el in ELEMS])
        page = client.query(Scan(S, page_size=3))
        clk[0] += 11.0  # idle past the ttl
        with pytest.raises(LeaseError):
            client.query(Scan(S, page_size=3), cursor=page.cursor)
        # the lease table was swept, not just refused
        assert not service._leases

    def test_foreign_session_refused(self):
        _, service, client, _ = make_service()
        client.batch(S, [["add", el] for el in ELEMS])
        page = client.query(Scan(S, page_size=3))
        other = BigsetClient(service)
        assert other.session != client.session
        with pytest.raises(LeaseError):
            other.query(Scan(S, page_size=3), cursor=page.cursor)
        # the owner can still resume
        rest = client.query(Scan(S, page_size=100), cursor=page.cursor)
        assert page.members + rest.members == sorted(ELEMS)

    def test_close_session_releases_leases(self):
        _, service, client, _ = make_service(
            config=ServiceConfig(max_open_cursors=1))
        client.batch(S, [["add", el] for el in ELEMS])
        client.query(Scan(S, page_size=2))
        fresh = BigsetClient(service)
        with pytest.raises(Backpressure) as bp:
            fresh.query(Scan(S, page_size=2))
        assert bp.value.reason == "open_cursors"
        client.close()  # releases the outstanding page
        assert fresh.query(Scan(S, page_size=2)).members == ELEMS[:2]

    def test_plan_embedded_cursor_is_refused(self):
        """A raw executor cursor inside the wire plan would bypass lease
        binding, expiry, and admission accounting — the service must force
        all pagination through the lease token."""
        _, service, client, _ = make_service()
        client.batch(S, [["add", el] for el in ELEMS])
        page = client.query(Scan(S, page_size=3))
        raw_cursor = unwrap_lease(page.cursor, client.session)
        with pytest.raises(ServiceError) as err:
            client.query(Scan(S, page_size=3, cursor=raw_cursor))
        assert err.value.kind == "request"
        with pytest.raises(ServiceError):
            client.query(Range(S, cursor=raw_cursor))
        # the legitimate token path still works
        rest = client.query(Scan(S, page_size=100), cursor=page.cursor)
        assert page.members + rest.members == sorted(ELEMS)

    def test_identical_scans_hold_independent_leases(self):
        """Two byte-identical scans in one session must not share a lease:
        resuming (and thereby releasing) one must not strand the other."""
        _, service, client, _ = make_service()
        client.batch(S, [["add", el] for el in ELEMS])
        a = client.query(Scan(S, page_size=2))
        b = client.query(Scan(S, page_size=2))
        assert a.members == b.members and a.cursor != b.cursor
        a2 = client.query(Scan(S, page_size=2), cursor=a.cursor)
        b2 = client.query(Scan(S, page_size=2), cursor=b.cursor)
        assert a2.members == b2.members == sorted(ELEMS)[2:4]

    def test_session_ids_are_not_guessable(self):
        _, service, client, _ = make_service()
        other = BigsetClient(service)
        assert client.session != other.session
        assert len(client.session) >= 16  # a credential, not a counter

    def test_rejected_touch_renews_lease(self):
        """Backpressure must not starve a lease into expiry: every valid
        touch — including a rejected one — renews the deadline."""
        _, service, client, clk = make_service(
            config=ServiceConfig(byte_budget=1, budget_window=20.0,
                                 lease_ttl=10.0))
        client.batch(S, [["add", el] for el in ELEMS])
        page = client.query(Scan(S, page_size=2))      # t=0, spends budget
        clk[0] = 6.0
        with pytest.raises(Backpressure):              # renews to t=16
            client.query(Scan(S, page_size=2), cursor=page.cursor)
        clk[0] = 12.0  # past the original t=10 deadline, inside the renewal
        with pytest.raises(Backpressure):              # still leased; t=22 now
            client.query(Scan(S, page_size=2), cursor=page.cursor)
        clk[0] = 21.0  # window rolled at t=20; lease renewed at t=12 is alive
        rest = client.query(Scan(S, page_size=100), cursor=page.cursor)
        assert page.members + rest.members == sorted(ELEMS)


# -------------------------------------------------------------- backpressure
class TestBackpressure:
    def test_rejection_is_observable_on_the_wire(self):
        _, service, client, clk = make_service(
            config=ServiceConfig(byte_budget=1, budget_window=5.0))
        client.batch(S, [["add", el] for el in ELEMS])
        client.query(Scan(S, page_size=2))  # spends the window's budget
        raw = service.handle(msgpack.packb([WIRE_VERSION, "query", {
            "plan": plan_to_wire(Scan(S, page_size=2)),
            "session": client.session}]))
        version, status, body = msgpack.unpackb(raw)
        assert (version, status) == (WIRE_VERSION, STATUS_RETRY)
        assert body["reason"] == "byte_budget"
        assert 0 < body["retry_after"] <= 5.0
        assert service.rejections == 1

    def test_rejection_preserves_cursor_and_resume_is_exact(self):
        _, service, client, clk = make_service(
            config=ServiceConfig(byte_budget=1, budget_window=5.0,
                                 lease_ttl=1e9))
        client.batch(S, [["add", el] for el in ELEMS])
        one_shot = client.query(Scan(S, page_size=100)).members
        clk[0] += 5.0

        page = client.query(Scan(S, page_size=3))
        got = list(page.members)
        cursor = page.cursor
        rejections = 0
        while cursor is not None:
            try:
                page = client.query(Scan(S, page_size=3), cursor=cursor)
            except Backpressure as bp:
                rejections += 1
                clk[0] += bp.retry_after  # back off, then retry same token
                continue
            got.extend(page.members)
            cursor = page.cursor
        assert rejections > 0, "budget never engaged; test is vacuous"
        assert got == one_shot  # no re-emit, no skip across rejections

    def test_budget_window_refills(self):
        _, service, client, clk = make_service(
            config=ServiceConfig(byte_budget=1, budget_window=2.0))
        client.batch(S, [["add", el] for el in ELEMS])
        client.query(Count(S))
        with pytest.raises(Backpressure):
            client.query(Count(S))
        clk[0] += 2.0
        assert client.query(Count(S)).count == len(ELEMS)

    def test_mutations_bypass_read_budget(self):
        _, service, client, clk = make_service(
            config=ServiceConfig(byte_budget=1, budget_window=1e9))
        client.query(Count(S))
        with pytest.raises(Backpressure):
            client.query(Count(S))
        assert client.insert(S, b"still-writable")  # writes stay admitted


# --------------------------------------------------- pagination exactness
class TestServePagination:
    @given(ops_st, st.integers(1, 7))
    @settings(max_examples=20, deadline=None)
    def test_paged_scan_equals_one_shot_under_backpressure(self, ops, page):
        cluster, service, client, clk = make_service(
            config=ServiceConfig(byte_budget=600, budget_window=1.0,
                                 lease_ttl=1e9))
        apply_ops(cluster, ops)
        one_shot = cluster.query(Scan(S, page_size=10_000), r=3)

        def advance(seconds):
            clk[0] += seconds + 1e-3

        entries = []
        for pg in client.pages(Scan(S, page_size=page), r=3, sleep=advance):
            entries.extend(pg.entries)
        assert [e for e, _ in entries] == one_shot.members
        assert {e: frozenset(d) for e, d in entries} == {
            e: frozenset(d) for e, d in one_shot.entries}

    @given(ops_st, st.integers(1, 5))
    @settings(max_examples=10, deadline=None)
    def test_index_pagination_through_service(self, ops, page):
        cluster, service, client, clk = make_service()
        cluster.register_index(S, by_element_suffix(1))
        apply_ops(cluster, ops)
        one_shot = cluster.query(
            IndexRange(S, b"element_suffix:1"), r=2)
        got = []
        for pg in client.pages(IndexRange(S, b"element_suffix:1", limit=page),
                               r=2):
            assert pg.index_entries is not None
            got.extend(pg.index_entries)
        assert [(ik, el) for ik, el, _ in got] == [
            (ik, el) for ik, el, _ in one_shot.index_entries]


# ------------------------------------------------------------ write path
class TestWritePath:
    def test_insert_returns_minted_dot(self):
        cluster, _, client, _ = make_service()
        dot = client.insert(S, b"x")
        assert dot == ["vnode0", 1, 1]  # single dot rides as [actor, c, c]
        dot2 = client.insert(S, b"x")
        assert dot2 == ["vnode0", 2, 2]

    def test_membership_ctx_round_trips_into_remove(self):
        cluster, _, client, _ = make_service()
        client.batch(S, [["add", b"x"], ["add", b"y"]])
        present, ctx = client.membership(S, b"x", r=3)
        assert present and ctx
        assert client.remove(S, b"x", ctx=ctx)
        for actor in cluster.actors:  # gone on every replica
            assert cluster.vnodes[actor].value(S) == {b"y"}

    def test_stale_ctx_remove_loses_to_concurrent_readd(self):
        cluster, _, client, _ = make_service()
        client.insert(S, b"x")
        _, stale_ctx = client.membership(S, b"x")
        client.insert(S, b"x")  # concurrent re-add mints a fresh dot
        client.remove(S, b"x", ctx=stale_ctx)
        present, ctx = client.membership(S, b"x")
        assert present  # add-wins: only the observed dot was removed
        assert ctx == [["vnode0", 2, 2]]

    def test_legacy_per_dot_ctx_still_decodes(self):
        # pre-interval clients sent [[actor, counter], ...] — the service
        # must keep honouring that alongside the run-triple form
        cluster, _, client, _ = make_service()
        client.insert(S, b"x")
        assert client.remove(S, b"x", ctx=[["vnode0", 1]])
        for actor in cluster.actors:
            assert cluster.vnodes[actor].value(S) == set()

    def test_contiguous_ctx_coalesces_on_the_wire(self):
        # ten dots of one actor ship as a single run triple
        cluster, _, client, _ = make_service()
        for _ in range(10):
            client.insert(S, b"x")
        _, ctx = client.membership(S, b"x", r=3)
        assert ctx == [["vnode0", 1, 10]]
        assert client.remove(S, b"x", ctx=ctx)

    def test_batch_remove_observes_earlier_add(self):
        cluster, _, client, _ = make_service()
        results = client.batch(S, [
            ["add", b"keep"],
            ["add", b"tmp"],
            ["remove", b"tmp"],
            ["remove", b"never-there"],
        ])
        assert "dot" in results[0] and "dot" in results[1]
        assert results[2]["removed"] is True
        assert results[3]["removed"] is False
        assert cluster.value(S, r=3) == {b"keep"}

    def test_values_ride_inserts(self):
        cluster, _, client, _ = make_service()
        client.insert(S, b"doc", value=b"payload")
        vn = cluster.vnodes[cluster.actors[0]]
        assert [v for _, _, v in vn.fold_values(S)] == [b"payload"]


# ------------------------------------------------------------ wire errors
class TestWireErrors:
    def call(self, service, op, body):
        raw = service.handle(msgpack.packb([WIRE_VERSION, op, body]))
        return msgpack.unpackb(raw)

    def test_error_taxonomy(self):
        _, service, client, _ = make_service()
        v, status, body = self.call(service, "explode", {})
        assert status == STATUS_ERROR and body["error"] == "request"
        v, status, body = self.call(service, "query", {"plan": b"garbage"})
        assert status == STATUS_ERROR and body["error"] == "plan"
        v, status, body = self.call(service, "query", {
            "plan": plan_to_wire(Scan(S)), "session": b"who?"})
        assert status == STATUS_ERROR and body["error"] == "session"
        v, status, body = self.call(service, "query", {
            "plan": plan_to_wire(Scan(S)), "cursor": b"not-a-lease"})
        assert status == STATUS_ERROR and body["error"] == "lease"

    def test_bad_envelopes(self):
        _, service, _, _ = make_service()
        for raw in (b"\xff\xff", msgpack.packb("hi"),
                    msgpack.packb([2, "query", {}]),
                    msgpack.packb([1, 42, {}])):
            _, status, body = msgpack.unpackb(service.handle(raw))
            assert status == STATUS_ERROR and body["error"] == "request"

    def test_malformed_scalars_become_error_responses(self):
        """Out-of-range coordinators, bad quorums, non-bytes values: typed
        ``error`` responses, never exceptions escaping handle()."""
        _, service, _, _ = make_service(n=3)
        bad = [
            ("insert", {"set": S, "element": b"x", "coordinator": 7}),
            ("insert", {"set": S, "element": b"x", "coordinator": "zzz"}),
            ("insert", {"set": S, "element": b"x", "value": "not-bytes"}),
            ("insert", {"set": S, "element": b"x", "ctx": [["a"]]}),
            ("remove", {"set": S, "element": b"x", "coordinator": -1}),
            ("batch", {"set": S, "ops": [["add", "not-bytes"]]}),
            ("batch", {"set": S, "ops": [["add", b"x", 123]]}),
            ("query", {"plan": plan_to_wire(Scan(S)), "r": 99}),
            ("query", {"plan": plan_to_wire(Scan(S)), "r": "two"}),
        ]
        for op, body in bad:
            _, status, out = self.call(service, op, body)
            assert status == STATUS_ERROR and out["error"] == "request", (
                op, body, out)

    def test_cursor_on_non_paginating_plan(self):
        _, service, client, _ = make_service()
        client.batch(S, [["add", b"x"], ["add", b"y"]])
        page = client.query(Scan(S, page_size=1))
        assert page.cursor is not None
        with pytest.raises(PlanError):
            client.query(Membership(S, b"x"), cursor=page.cursor)

    def test_page_size_is_capped(self):
        _, service, client, _ = make_service(
            config=ServiceConfig(max_page_size=3))
        client.batch(S, [["add", el] for el in ELEMS])
        page = client.query(Scan(S, page_size=10_000))
        assert len(page.entries) == 3 and page.cursor is not None


# ---------------------------------------------------------- IO acceptance
class TestServeIo:
    def test_scan_page_io_is_o_page_not_o_n(self):
        """Acceptance: each page of a 100k-element Scan through the service
        reads O(page + causal metadata) bytes — per-page IoStats attached
        to every wire response, never O(n)."""
        n = 100_000
        page_size = 256
        cluster = BigsetCluster(1)
        vn = BigsetVnode(cluster.actors[0], LsmStore(memtable_limit=1 << 20))
        cluster.vnodes[cluster.actors[0]] = vn
        for i in range(n):
            vn.coordinate_insert(S, b"%08d" % i)
        vn.store.flush()

        meter = vn.store.meter()
        assert sum(1 for _ in vn.fold(S)) == n
        fold_bytes = meter.delta().bytes_read

        service = BigsetService(cluster)
        client = BigsetClient(service)
        seen = 0
        worst_page = 0
        for page in client.pages(Scan(S, page_size=page_size), r=1):
            assert len(page.entries) <= page_size
            seen += len(page.entries)
            worst_page = max(worst_page, page.stats["bytes_read"])
        assert seen == n
        # o(n): every page far under the full fold, and absolutely page-sized
        assert worst_page * 20 < fold_bytes, (worst_page, fold_bytes)
        assert worst_page < 64 * 1024, worst_page


class TestJoinStrategyOnTheWire:
    def test_per_page_stats_surface_planner_choice(self):
        """The planner's strategy rides the serve layer's per-page stats:
        a skewed intersect reports gallop, a forced zipper reports zipper,
        both return identical pages."""
        cluster = BigsetCluster(3)
        for i in range(400):
            cluster.add(T, b"%05d" % i, coordinator=i % 3)
        for i in range(0, 400, 40):
            cluster.add(S, b"%05d" % i, coordinator=i % 3)
        client = BigsetClient(BigsetService(cluster))
        expected = [b"%05d" % i for i in range(0, 400, 40)]

        auto = client.query(Join("intersect", S, T))
        assert auto.stats["strategy"] == "gallop"
        assert auto.members == expected
        forced = client.query(Join("intersect", S, T, strategy="zipper"))
        assert forced.stats["strategy"] == "zipper"
        assert forced.entries == auto.entries
        assert auto.stats["keys_scanned"] < forced.stats["keys_scanned"]
        # non-join shapes report no strategy
        assert client.query(Count(S)).stats["strategy"] == ""

    def test_lease_cursor_resumes_across_strategies(self):
        """A lease minted under one strategy resumes under another — the
        cursor names a position, not an algorithm."""
        cluster = BigsetCluster(3)
        for el in ELEMS:
            cluster.add(S, el, coordinator=0)
            cluster.add(T, el, coordinator=0)
        client = BigsetClient(BigsetService(cluster))
        first = client.query(Join("union", S, T, limit=4, strategy="zipper"))
        rest = client.query(Join("union", S, T, strategy="gallop"),
                            cursor=first.cursor)
        assert first.members + rest.members == sorted(ELEMS)
