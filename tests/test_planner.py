"""Cost-based join planner + seek-gallop join tests.

Four contracts:

* the chooser picks zipper for balanced sides and union, gallop past the
  skew crossover, honors forced strategies, and validates them;
* zipper and gallop return **byte-identical** entries for every join kind
  — the planner moves cost, never results;
* any cursor cut of any join, under any strategy, reassembles to the
  uncut result with single-domain dot tuples preserved (property test);
* the ISSUE acceptance: a planner-selected gallop intersect of a
  100-element set against a 100k-element set scans ≤ 4x the smaller
  side's cardinality, and the positional-seek zipper reflects skips in
  ``keys_scanned`` instead of paying O(skipped).
"""
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.clusters import BigsetCluster
from repro.core.bigset import BigsetVnode
from repro.query import (GALLOP, ZIPPER, Join, PlanError, QueryExecutor,
                         SideStats, choose_join, plan_from_wire, plan_to_wire,
                         side_stats, validate)
from repro.query.planner import gallop_drive
from repro.storage.lsm import LsmStore

S = b"plsmall"
B = b"plbig"
ELEMS = [b"a", b"b", b"c", b"d", b"e", b"f", b"g", b"h", b"i", b"j"]
KINDS = ("intersect", "union", "difference")
STRATEGIES = (None, "zipper", "gallop")

ops_st = st.lists(
    st.tuples(
        st.sampled_from(["add", "rem"]),
        st.integers(0, 2),
        st.sampled_from(ELEMS),
    ),
    max_size=20,
)


def apply_ops(cluster, ops, set_name):
    for op, coord, el in ops:
        if op == "add":
            cluster.add(set_name, el, coordinator=coord)
        else:
            cluster.remove(set_name, el, coordinator=coord)


# ------------------------------------------------------------------ chooser
class TestChooser:
    def test_balanced_sides_zipper(self):
        c = choose_join("intersect", SideStats(100, 3000), SideStats(100, 3000))
        assert c.strategy == ZIPPER

    def test_skewed_intersect_gallops_either_direction(self):
        small, big = SideStats(10, 300), SideStats(100_000, 3_000_000)
        left_small = choose_join("intersect", small, big)
        assert left_small.strategy == GALLOP and left_small.drive == "left"
        right_small = choose_join("intersect", big, small)
        assert right_small.strategy == GALLOP and right_small.drive == "right"

    def test_difference_only_drives_left(self):
        small, big = SideStats(10, 300), SideStats(100_000, 3_000_000)
        c = choose_join("difference", small, big)
        assert c.strategy == GALLOP and c.drive == "left"
        # big left side must be streamed anyway: galloping cannot help
        assert choose_join("difference", big, small).strategy == ZIPPER

    def test_union_never_gallops(self):
        small, big = SideStats(10, 300), SideStats(100_000, 3_000_000)
        assert gallop_drive("union", small, big) is None
        assert choose_join("union", small, big).strategy == ZIPPER
        # even when forced: union structurally streams both sides
        forced = choose_join("union", small, big, forced=GALLOP)
        assert forced.strategy == ZIPPER

    def test_forced_strategy_honored(self):
        small, big = SideStats(10, 300), SideStats(100_000, 3_000_000)
        assert choose_join("intersect", small, big,
                           forced=ZIPPER).strategy == ZIPPER
        assert choose_join("intersect", SideStats(5, 100), SideStats(5, 100),
                           forced=GALLOP).strategy == GALLOP

    def test_empty_sides(self):
        # both empty: nothing to gallop over
        assert choose_join("intersect", SideStats(0, 0),
                           SideStats(0, 0)).strategy == ZIPPER

    def test_strategy_validation_and_wire(self):
        with pytest.raises(PlanError):
            validate(Join("intersect", S, B, strategy="bogus"))
        plan = Join("intersect", S, B, limit=3, strategy="gallop")
        assert plan_from_wire(plan_to_wire(plan)) == plan
        # wire envelopes minted before the field existed still decode
        assert plan_from_wire(plan_to_wire(Join("union", S, B))).strategy is None

    def test_side_stats_reads_run_statistics(self):
        vn = BigsetVnode("a", LsmStore(memtable_limit=1 << 20))
        for i in range(50):
            vn.coordinate_insert(S, b"%04d" % i)
        mem = side_stats(vn.store, S)
        assert mem.keys == 50 and mem.bytes > 0  # memtable view counts too
        vn.store.flush()
        flushed = side_stats(vn.store, S)
        assert flushed.keys == 50
        assert side_stats(vn.store, b"no-such-set").keys == 0


# ------------------------------------------------------- strategy equivalence
class TestEquivalence:
    @given(ops_st, ops_st)
    @settings(max_examples=25, deadline=None)
    def test_gallop_equals_zipper_all_kinds(self, ops_l, ops_r):
        c = BigsetCluster(3)
        apply_ops(c, ops_l, S)
        apply_ops(c, ops_r, B)
        # asymmetry: bulk up the right side so the planner has real skew
        for i in range(40):
            c.add(B, b"z%03d" % i, coordinator=i % 3)
        vn = c.vnodes[c.actors[0]]
        ex = QueryExecutor(vn)
        left, right = vn.value(S), vn.value(B)
        expected = {
            "intersect": left & right,
            "union": left | right,
            "difference": left - right,
        }
        for kind in KINDS:
            results = [
                ex.execute(Join(kind, S, B, strategy=strat))
                for strat in STRATEGIES
            ]
            for res in results:
                assert res.members == sorted(expected[kind]), kind
                # entries (elements AND dot tuples) byte-identical
                assert res.entries == results[0].entries, kind

    @given(ops_st, ops_st, st.integers(1, 4))
    @settings(max_examples=25, deadline=None)
    def test_cursor_cuts_reassemble_single_domain(self, ops_l, ops_r, page):
        """Satellite: any cursor cut of any join under any strategy
        re-assembles to the uncut result, dot tuples from a single set's
        clock domain (left's when present there, else right's)."""
        c = BigsetCluster(3)
        apply_ops(c, ops_l, S)
        apply_ops(c, ops_r, B)
        for i in range(12):  # asymmetric cardinalities
            c.add(B, b"y%02d" % i, coordinator=i % 3)
        vn = c.vnodes[c.actors[0]]
        ex = QueryExecutor(vn)
        left_truth = vn.read_full(S).entries
        right_truth = vn.read_full(B).entries
        for kind in KINDS:
            uncut = ex.execute(Join(kind, S, B)).entries
            for strat in STRATEGIES:
                paged, cur = [], None
                for _ in range(64):  # bounded: must terminate
                    r = ex.execute(
                        Join(kind, S, B, limit=page, cursor=cur,
                             strategy=strat))
                    paged.extend(r.entries)
                    cur = r.cursor
                    if cur is None:
                        break
                assert paged == uncut, (kind, strat)
            for el, dots in uncut:
                domain = left_truth.get(el) or right_truth.get(el)
                assert frozenset(dots) == domain, (kind, el)

    def test_cursor_minted_under_one_strategy_resumes_under_other(self):
        c = BigsetCluster(1)
        for i in range(8):
            c.add(S, b"s%02d" % i, coordinator=0)
            c.add(B, b"s%02d" % i, coordinator=0)
        ex = QueryExecutor(c.vnodes[c.actors[0]])
        first = ex.execute(Join("intersect", S, B, limit=3, strategy="zipper"))
        rest = ex.execute(Join("intersect", S, B, limit=99, cursor=first.cursor,
                               strategy="gallop"))
        assert first.members + rest.members == [b"s%02d" % i for i in range(8)]


# --------------------------------------------------------------- acceptance
@pytest.fixture(scope="module")
def skewed_vnode():
    """100-element set vs 100k-element superset, flushed to one run."""
    n = 100_000
    vn = BigsetVnode("a", LsmStore(memtable_limit=1 << 20))
    for i in range(n):
        vn.coordinate_insert(B, b"%08d" % i)
    for i in range(0, n, 1000):  # 100 elements, all ∈ B
        vn.coordinate_insert(S, b"%08d" % i)
    vn.store.flush()
    return vn


class TestAcceptance:
    def test_planner_gallop_intersect_bounded_io(self, skewed_vnode):
        """ISSUE acceptance: planner-selected gallop intersect of 100 vs
        100k scans ≤ 4x the smaller side's cardinality."""
        ex = QueryExecutor(skewed_vnode)
        res = ex.execute(Join("intersect", S, B))
        assert res.stats.strategy == "gallop"
        assert res.members == [b"%08d" % i for i in range(0, 100_000, 1000)]
        assert res.stats.keys_scanned <= 4 * 100, res.stats.keys_scanned
        # and driving from the big side chooses the same gallop
        rev = ex.execute(Join("intersect", B, S))
        assert rev.stats.strategy == "gallop"
        assert rev.stats.keys_scanned <= 4 * 100, rev.stats.keys_scanned
        assert rev.members == res.members

    def test_all_kinds_identical_at_scale(self, skewed_vnode):
        """ISSUE acceptance: all three kinds byte-identical zipper vs
        gallop at 1:1000 skew."""
        ex = QueryExecutor(skewed_vnode)
        for kind in KINDS:
            z = ex.execute(Join(kind, S, B, strategy="zipper", limit=500))
            g = ex.execute(Join(kind, S, B, strategy="gallop", limit=500))
            assert z.entries == g.entries, kind

    def test_zipper_seek_reflects_skip(self, skewed_vnode):
        """Satellite: the zipper's seek_to gallops via positional storage
        seeks — keys_scanned stays near the small side, not O(big side)."""
        ex = QueryExecutor(skewed_vnode)
        res = ex.execute(Join("intersect", S, B, strategy="zipper"))
        assert res.members == [b"%08d" % i for i in range(0, 100_000, 1000)]
        # each of the 100 gallop rounds pays a bounded bite (steps + a
        # post-seek chunk), never the 1000-key gap it skipped
        assert res.stats.keys_scanned < 100_000 // 20, res.stats.keys_scanned

    def test_gallop_difference_bounded_io(self, skewed_vnode):
        ex = QueryExecutor(skewed_vnode)
        res = ex.execute(Join("difference", S, B))
        assert res.stats.strategy == "gallop"
        assert res.members == []  # S ⊂ B
        assert res.stats.keys_scanned <= 4 * 100, res.stats.keys_scanned


# ------------------------------------------------------------- quorum gallop
class TestQuorumGallop:
    def build(self, sync=True):
        c = BigsetCluster(3, sync=sync)
        for i in range(2000):
            c.add(B, b"%06d" % i, coordinator=i % 3)
        for i in range(0, 2000, 100):
            c.add(S, b"%06d" % i, coordinator=i % 3)
        return c

    def test_quorum_strategy_and_equivalence(self):
        c = self.build()
        for kind in KINDS:
            auto = c.query(Join(kind, S, B), r=3, repair=False)
            z = c.query(Join(kind, S, B, strategy="zipper"), r=3, repair=False)
            assert auto.entries == z.entries, kind
            if kind == "union":
                assert auto.stats.strategy == "zipper"
            else:
                assert auto.stats.strategy == "gallop"
        skew = c.query(Join("intersect", S, B), r=3, repair=False)
        full = c.query(Join("intersect", S, B, strategy="zipper"), r=3,
                       repair=False)
        assert skew.stats.keys_scanned < full.stats.keys_scanned

    def test_gallop_probe_read_repairs(self):
        """A replica missing big-side deltas gets the *probed* element-keys
        replayed: repair rides the gallop workload too."""
        c = BigsetCluster(3, sync=False)
        for i in range(200):
            c.add(B, b"%06d" % i, coordinator=0)
        for i in range(0, 200, 40):
            c.add(S, b"%06d" % i, coordinator=0)
        # partition vnode2 away from every delta so far
        c.net.queue = [m for m in c.net.queue if m.dst != "vnode2"]
        c.net.deliver_all(c._handle)
        straggler = c.vnodes["vnode2"]
        assert len(straggler.value(B)) == 0
        res = c.query(Join("intersect", S, B), r=3)
        c.settle()
        expected = [b"%06d" % i for i in range(0, 200, 40)]
        assert res.stats.strategy == "gallop"
        assert res.members == expected
        # drive side fully repaired; probe side repaired at the probed keys
        assert sorted(straggler.value(S)) == expected
        assert sorted(straggler.value(B)) == expected
