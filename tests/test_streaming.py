"""§4.4 streaming ORSWOT join: subset merges ≡ full merges, queries work."""
from hypothesis import given, settings, strategies as st

from repro.cluster.clusters import BigsetCluster
from repro.core.streaming import merge_entry, quorum_is_member, quorum_read, streaming_join
from repro.core.bigset import BigsetVnode

S = b"s"
ELEMS = [b"aa", b"bb", b"cc", b"dd", b"ee", b"ff"]

op_st = st.tuples(
    st.sampled_from(["add", "rem"]), st.integers(0, 2), st.sampled_from(ELEMS)
)
ops_st = st.lists(op_st, max_size=22)


def build_cluster(ops, sync=False):
    big = BigsetCluster(3, sync=sync)
    for kind, coord, elem in ops:
        if kind == "add":
            _, ctx = big.vnodes[big.actors[coord]].is_member(S, elem)
            big.add(S, elem, coord, ctx)
        else:
            big.remove(S, elem, coord)
    return big


class TestStreamingJoin:
    @given(ops_st)
    @settings(max_examples=50, deadline=None)
    def test_streaming_equals_full_merge(self, ops):
        big = build_cluster(ops, sync=False)
        # DON'T settle: replicas genuinely divergent
        streams = []
        fulls = []
        for a in big.actors:
            vn = big.vnodes[a]
            rs = vn.read(S)
            streams.append((rs.clock, rs.entries()))
            fulls.append(vn.read_full(S))
        via_stream = quorum_read(streams)
        via_full = fulls[0].merge(fulls[1]).merge(fulls[2])
        assert via_stream == via_full

    @given(ops_st)
    @settings(max_examples=40, deadline=None)
    def test_stream_yields_sorted_elements(self, ops):
        big = build_cluster(ops)
        streams = [
            (big.vnodes[a].read(S).clock, big.vnodes[a].read(S).entries())
            for a in big.actors
        ]
        elems = [e for e, _ in streaming_join(streams)]
        assert elems == sorted(elems)

    @given(ops_st, st.sampled_from(ELEMS))
    @settings(max_examples=50, deadline=None)
    def test_quorum_is_member_matches_quorum_read(self, ops, probe_elem):
        big = build_cluster(ops, sync=False)
        probes = []
        for a in big.actors:
            vn = big.vnodes[a]
            present, dots = vn.is_member(S, probe_elem)
            probes.append(
                (vn.read_clock(S), frozenset(dots) if present else None)
            )
        member, _ = quorum_is_member(probes)
        full = big.read(S, r=3)
        assert member == (probe_elem in full.value())

    def test_pagination_over_quorum(self):
        big = build_cluster([("add", i % 3, e) for i, e in enumerate(ELEMS)], sync=True)
        page = big.vnodes[big.actors[0]].range_query(S, b"bb", 3)
        assert page == [b"bb", b"cc", b"dd"]

    def test_merge_entry_no_dots_is_absent(self):
        from repro.core.clock import Clock

        c = Clock.zero()
        assert merge_entry([None, None], [c, c]) == frozenset()
