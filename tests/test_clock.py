"""Property tests for the BaseVV+DotCloud logical clock (paper §4.1)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.clock import Clock
from repro.core.dots import Dot

ACTORS = ["a", "b", "c", "d"]

dots_st = st.lists(
    st.tuples(st.sampled_from(ACTORS), st.integers(1, 12)).map(lambda t: Dot(*t)),
    max_size=24,
)


def clock_of(dots):
    return Clock.zero().add_dots(dots)


clock_st = dots_st.map(clock_of)


class TestBasics:
    def test_zero(self):
        z = Clock.zero()
        assert z.is_zero()
        assert not z.seen(Dot("a", 1))

    def test_increment_contiguous(self):
        c, d1 = Clock.zero().increment("a")
        assert d1 == Dot("a", 1)
        c, d2 = c.increment("a")
        assert d2 == Dot("a", 2)
        assert c.base == {"a": 2} and not c.cloud

    def test_add_gap_goes_to_cloud(self):
        c = Clock.zero().add(Dot("a", 3))
        assert c.base.get("a", 0) == 0
        assert 3 in c.cloud["a"]
        assert c.seen(Dot("a", 3)) and not c.seen(Dot("a", 1))

    def test_cloud_compresses_into_base(self):
        c = clock_of([Dot("a", 2), Dot("a", 3), Dot("a", 1)])
        assert c.base == {"a": 3} and not c.cloud

    def test_no_self_cloud_entry_invariant(self):
        # a coordinator that somehow saw its own future dot must not increment
        c = Clock.zero().add(Dot("a", 2))
        with pytest.raises(ValueError):
            c.increment("a")


class TestSemilattice:
    @given(clock_st, clock_st)
    def test_join_commutative(self, x, y):
        assert x.join(y) == y.join(x)

    @given(clock_st, clock_st, clock_st)
    @settings(max_examples=60)
    def test_join_associative(self, x, y, z):
        assert x.join(y).join(z) == x.join(y.join(z))

    @given(clock_st)
    def test_join_idempotent(self, x):
        assert x.join(x) == x

    @given(clock_st, clock_st)
    def test_join_is_lub(self, x, y):
        j = x.join(y)
        assert j.descends(x) and j.descends(y)

    @given(dots_st, dots_st)
    def test_seen_after_join(self, da, db):
        j = clock_of(da).join(clock_of(db))
        for d in da + db:
            assert j.seen(d)

    @given(clock_st, clock_st)
    def test_descends_antisymmetry(self, x, y):
        if x.descends(y) and y.descends(x):
            assert x == y


class TestSubtract:
    @given(dots_st, dots_st)
    def test_subtract_removes_exactly(self, base_dots, gone):
        c = clock_of(base_dots)
        s = c.subtract(gone)
        gone_set = set(gone)
        for d in c.all_dots():
            assert s.seen(d) == (d not in gone_set)

    @given(dots_st)
    def test_subtract_everything_is_zero(self, dots):
        c = clock_of(dots)
        assert c.subtract(c.all_dots()).is_zero()

    @given(dots_st, dots_st)
    def test_subtract_then_add_roundtrip(self, dots, gone):
        c = clock_of(dots)
        present_gone = [d for d in gone if c.seen(d)]
        s = c.subtract(gone).add_dots(present_gone)
        assert s == c


class TestDotsEnumeration:
    @given(dots_st)
    def test_all_dots_matches_seen(self, dots):
        c = clock_of(dots)
        assert set(c.all_dots()) == {d for d in set(dots) if c.seen(d)}
        # and every enumerated dot is seen
        for d in c.all_dots():
            assert c.seen(d)

    @given(dots_st)
    def test_obj_roundtrip(self, dots):
        c = clock_of(dots)
        assert Clock.from_obj(c.to_obj()) == c


class TestDiffDots:
    """diff_dots is digest subtraction — the anti-entropy divergence probe."""

    @given(clock_st, clock_st)
    @settings(max_examples=60, deadline=None)
    def test_diff_equals_set_difference(self, x, y):
        assert set(x.diff_dots(y)) == set(x.all_dots()) - set(y.all_dots())

    @given(clock_st)
    @settings(max_examples=30, deadline=None)
    def test_diff_with_self_is_empty(self, x):
        assert x.diff_dots(x) == ()

    @given(clock_st, clock_st)
    @settings(max_examples=30, deadline=None)
    def test_diff_against_join_is_empty(self, x, y):
        assert x.diff_dots(x.join(y)) == ()


class TestOracleEquivalence:
    """Model-based check: interval clock vs a plain set-of-dots oracle.

    The oracle is the dot *set* the operations are defined over in the
    paper; the interval clock must agree on every op while storing only
    (lo, hi) runs.
    """

    @given(dots_st, dots_st)
    @settings(max_examples=80, deadline=None)
    def test_ops_match_set_oracle(self, da, db):
        ox, oy = set(da), set(db)
        x, y = clock_of(da), clock_of(db)
        for d in da + db:
            assert x.seen(d) == (d in ox)
        assert set(x.join(y).all_dots()) == ox | oy
        assert set(x.subtract_clock(y).all_dots()) == ox - oy
        assert set(x.intersect(y).all_dots()) == ox & oy
        assert set(x.diff_dots(y)) == ox - oy

    @given(dots_st, dots_st)
    @settings(max_examples=60, deadline=None)
    def test_diff_runs_expands_to_diff_dots(self, da, db):
        x, y = clock_of(da), clock_of(db)
        expanded = tuple(sorted(
            Dot(a, c)
            for a, lo, hi in x.diff_runs(y)
            for c in range(lo, hi + 1)))
        assert expanded == x.diff_dots(y)

    @given(dots_st, dots_st)
    @settings(max_examples=60, deadline=None)
    def test_add_runs_absorbs_diff(self, da, db):
        # digest sync in one line: absorbing the diverged ranges converges
        x, y = clock_of(da), clock_of(db)
        assert y.add_runs(x.diff_runs(y)) == x.join(y)


class TestRunInvariants:
    """Invariant 12: per-actor runs are sorted, disjoint, non-adjacent,
    1-based, and start strictly above base+1."""

    @given(dots_st, dots_st, dots_st)
    @settings(max_examples=80, deadline=None)
    def test_canonical_after_random_ops(self, da, db, gone):
        c = clock_of(da).join(clock_of(db)).subtract(gone)
        for a, rs in c.runs.items():
            assert rs, "empty run lists must be dropped from the dict"
            prev_hi = c.base.get(a, 0)
            for lo, hi in rs:
                assert 1 <= lo <= hi
                assert lo >= prev_hi + 2, "runs must be coalesced into base/neighbour"
                prev_hi = hi

    @given(st.lists(st.tuples(st.sampled_from(ACTORS), st.integers(1, 20),
                              st.integers(0, 6)), max_size=10))
    @settings(max_examples=60, deadline=None)
    def test_add_runs_matches_add_dots(self, ranges):
        rs = [(a, lo, lo + w) for a, lo, w in ranges]
        via_runs = Clock.zero().add_runs(rs)
        via_dots = Clock.zero().add_dots(
            Dot(a, c) for a, lo, hi in rs for c in range(lo, hi + 1))
        assert via_runs == via_dots


class TestChurnCompression:
    """Serialized size is O(actors + live runs) — never O(removed dots)."""

    @given(st.integers(100, 400),
           st.lists(st.integers(1, 400), max_size=120, unique=True).map(set))
    @settings(max_examples=40, deadline=None)
    def test_size_tracks_live_runs(self, n, removed):
        removed = {r for r in removed if r <= n}
        c = Clock(base={"x": n}).subtract([Dot("x", r) for r in removed])
        live = sorted(set(range(1, n + 1)) - removed)
        spans = sum(1 for i, v in enumerate(live) if i == 0 or v != live[i - 1] + 1)
        assert c.n_runs() == spans
        assert c.size_bytes() == 24 * spans
        assert c.n_events() == len(live)

    def test_span_removal_is_o_runs(self):
        # 50k removals in one contiguous span cost one run boundary, not
        # 50k cloud entries — the paper's "hole problem", solved.
        c = Clock(base={"x": 100_000})
        hole = Clock.zero().add_runs([("x", 20_001, 70_000)])
        c2 = c.subtract_clock(hole)
        assert c2.n_runs() == 2
        assert c2.size_bytes() == 48
        assert c2.n_events() == 50_000


class TestCodecVersions:
    def test_new_obj_is_run_length(self):
        c = Clock(base={"x": 5}).add_runs([("x", 8, 12)])
        assert c.to_obj() == {"b": [("x", 5)], "r": [("x", [[8, 12]])]}

    @given(dots_st)
    @settings(max_examples=40, deadline=None)
    def test_legacy_per_dot_objs_decode(self, dots):
        c = clock_of(dots)
        cloud = sorted((a, sorted(s)) for a, s in c.cloud.items())
        legacy_msgpack = {"b": sorted(c.base.items()), "c": cloud}
        legacy_verbose = {"base": sorted(c.base.items()), "cloud": cloud}
        assert Clock.from_obj(legacy_msgpack) == c
        assert Clock.from_obj(legacy_verbose) == c
        # and re-encoding upgrades to the run-length form
        assert "r" in Clock.from_obj(legacy_msgpack).to_obj()
