"""Property tests for the BaseVV+DotCloud logical clock (paper §4.1)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.clock import Clock
from repro.core.dots import Dot

ACTORS = ["a", "b", "c", "d"]

dots_st = st.lists(
    st.tuples(st.sampled_from(ACTORS), st.integers(1, 12)).map(lambda t: Dot(*t)),
    max_size=24,
)


def clock_of(dots):
    return Clock.zero().add_dots(dots)


clock_st = dots_st.map(clock_of)


class TestBasics:
    def test_zero(self):
        z = Clock.zero()
        assert z.is_zero()
        assert not z.seen(Dot("a", 1))

    def test_increment_contiguous(self):
        c, d1 = Clock.zero().increment("a")
        assert d1 == Dot("a", 1)
        c, d2 = c.increment("a")
        assert d2 == Dot("a", 2)
        assert c.base == {"a": 2} and not c.cloud

    def test_add_gap_goes_to_cloud(self):
        c = Clock.zero().add(Dot("a", 3))
        assert c.base.get("a", 0) == 0
        assert 3 in c.cloud["a"]
        assert c.seen(Dot("a", 3)) and not c.seen(Dot("a", 1))

    def test_cloud_compresses_into_base(self):
        c = clock_of([Dot("a", 2), Dot("a", 3), Dot("a", 1)])
        assert c.base == {"a": 3} and not c.cloud

    def test_no_self_cloud_entry_invariant(self):
        # a coordinator that somehow saw its own future dot must not increment
        c = Clock.zero().add(Dot("a", 2))
        with pytest.raises(ValueError):
            c.increment("a")


class TestSemilattice:
    @given(clock_st, clock_st)
    def test_join_commutative(self, x, y):
        assert x.join(y) == y.join(x)

    @given(clock_st, clock_st, clock_st)
    @settings(max_examples=60)
    def test_join_associative(self, x, y, z):
        assert x.join(y).join(z) == x.join(y.join(z))

    @given(clock_st)
    def test_join_idempotent(self, x):
        assert x.join(x) == x

    @given(clock_st, clock_st)
    def test_join_is_lub(self, x, y):
        j = x.join(y)
        assert j.descends(x) and j.descends(y)

    @given(dots_st, dots_st)
    def test_seen_after_join(self, da, db):
        j = clock_of(da).join(clock_of(db))
        for d in da + db:
            assert j.seen(d)

    @given(clock_st, clock_st)
    def test_descends_antisymmetry(self, x, y):
        if x.descends(y) and y.descends(x):
            assert x == y


class TestSubtract:
    @given(dots_st, dots_st)
    def test_subtract_removes_exactly(self, base_dots, gone):
        c = clock_of(base_dots)
        s = c.subtract(gone)
        gone_set = set(gone)
        for d in c.all_dots():
            assert s.seen(d) == (d not in gone_set)

    @given(dots_st)
    def test_subtract_everything_is_zero(self, dots):
        c = clock_of(dots)
        assert c.subtract(c.all_dots()).is_zero()

    @given(dots_st, dots_st)
    def test_subtract_then_add_roundtrip(self, dots, gone):
        c = clock_of(dots)
        present_gone = [d for d in gone if c.seen(d)]
        s = c.subtract(gone).add_dots(present_gone)
        assert s == c


class TestDotsEnumeration:
    @given(dots_st)
    def test_all_dots_matches_seen(self, dots):
        c = clock_of(dots)
        assert set(c.all_dots()) == {d for d in set(dots) if c.seen(d)}
        # and every enumerated dot is seen
        for d in c.all_dots():
            assert c.seen(d)

    @given(dots_st)
    def test_obj_roundtrip(self, dots):
        c = clock_of(dots)
        assert Clock.from_obj(c.to_obj()) == c


class TestDiffDots:
    """diff_dots is digest subtraction — the anti-entropy divergence probe."""

    @given(clock_st, clock_st)
    @settings(max_examples=60, deadline=None)
    def test_diff_equals_set_difference(self, x, y):
        assert set(x.diff_dots(y)) == set(x.all_dots()) - set(y.all_dots())

    @given(clock_st)
    @settings(max_examples=30, deadline=None)
    def test_diff_with_self_is_empty(self, x):
        assert x.diff_dots(x) == ()

    @given(clock_st, clock_st)
    @settings(max_examples=30, deadline=None)
    def test_diff_against_join_is_empty(self, x, y):
        assert x.diff_dots(x.join(y)) == ()
