"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes and dtypes, plus vclock dense/sparse agreement."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core import vclock
from repro.core.clock import Clock
from repro.core.dots import Dot
from repro.kernels.decode_attention import decode_attention_pallas, decode_attention_ref
from repro.kernels.dot_seen import dot_seen_pallas, dot_seen_ref
from repro.kernels.flash_attention import attention_ref, flash_attention_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas, mamba_scan_ref, mamba_step_ref

RNG = np.random.default_rng(0)


# --------------------------------------------------------------------- vclock
ACTORS4 = ["a", "b", "c", "d"]
IDX4 = {a: i for i, a in enumerate(ACTORS4)}


def _sparse(dots):
    return Clock.zero().add_dots(Dot(ACTORS4[a], c) for a, c in dots)


class TestVClock:
    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 90)), max_size=40))
    @settings(max_examples=60, deadline=None)
    def test_dense_seen_matches_sparse(self, dots):
        sparse = _sparse(dots)
        dense = vclock.from_clock(sparse, IDX4, 4)
        probe_a = np.array([a for a, _ in dots] + [0, 1, 2, 3], np.int32)
        probe_c = np.array([c for _, c in dots] + [1, 64, 90, 128], np.int32)
        got = np.asarray(vclock.dots_seen(dense, jnp.asarray(probe_a), jnp.asarray(probe_c)))
        want = np.array([sparse.seen(Dot(ACTORS4[a], int(c)))
                         for a, c in zip(probe_a, probe_c)])
        assert (got == want).all()

    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 120)), max_size=40))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_sparse_dense_sparse(self, dots):
        sparse = _sparse(dots)
        dense = vclock.from_clock(sparse, IDX4, 4)
        assert vclock.to_clock(dense, ACTORS4) == sparse

    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 100)), max_size=30),
           st.lists(st.tuples(st.integers(0, 3), st.integers(1, 100)), max_size=30))
    @settings(max_examples=40, deadline=None)
    def test_dense_join_matches_sparse(self, d1, d2):
        s1, s2 = _sparse(d1), _sparse(d2)
        j = vclock.join(vclock.from_clock(s1, IDX4, 4),
                        vclock.from_clock(s2, IDX4, 4))
        assert vclock.to_clock(j, ACTORS4) == s1.join(s2)

    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 100)), max_size=30),
           st.lists(st.tuples(st.integers(0, 3), st.integers(1, 100)), max_size=30))
    @settings(max_examples=40, deadline=None)
    def test_dense_subtract_intersect_match_sparse(self, d1, d2):
        s1, s2 = _sparse(d1), _sparse(d2)
        a = vclock.from_clock(s1, IDX4, 4)
        b = vclock.from_clock(s2, IDX4, 4)
        assert vclock.to_clock(vclock.subtract(a, b), ACTORS4) == s1.subtract_clock(s2)
        assert vclock.to_clock(vclock.intersect(a, b), ACTORS4) == s1.intersect(s2)

    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 80)), max_size=25),
           st.lists(st.tuples(st.integers(0, 3), st.integers(1, 80)),
                    min_size=1, max_size=25))
    @settings(max_examples=40, deadline=None)
    def test_dense_add_dots_matches_sparse(self, base, extra):
        sparse = _sparse(base)
        dense = vclock.from_clock(sparse, IDX4, 4)
        added = vclock.add_dots(
            dense,
            jnp.asarray([a for a, _ in extra], jnp.int32),
            jnp.asarray([c for _, c in extra], jnp.int32))
        want = sparse.add_dots(Dot(ACTORS4[a], c) for a, c in extra)
        assert vclock.to_clock(added, ACTORS4) == want

    def test_subtract_is_origin_free(self):
        # Subtraction punches holes *below the base* — the old windowed
        # bitmap could not represent that without a scalar fallback.
        s1 = Clock.zero().add_dots(Dot("a", c) for c in range(1, 41))
        s2 = Clock.zero().add_dots(Dot("a", c) for c in (2, 9, 40))
        d = vclock.subtract(vclock.from_clock(s1, IDX4, 4),
                            vclock.from_clock(s2, IDX4, 4))
        assert vclock.to_clock(d, ACTORS4) == s1.subtract_clock(s2)
        assert int(vclock.popcount(d).sum()) == 37

    def test_no_window_cap(self):
        # A single run covers an arbitrarily wide span at constant cost.
        wide = Clock(base={"a": 1_000_000})
        dense = vclock.from_clock(wide, IDX4, 4)
        assert dense.n_runs == 1
        got = vclock.dots_seen(dense,
                               jnp.zeros(3, jnp.int32),
                               jnp.array([1, 999_999, 1_000_001], jnp.int32))
        assert np.asarray(got).tolist() == [True, True, False]


# ------------------------------------------------------------------- dot_seen
def _random_dense(n_actors, n_runs, hi, rng):
    """Random canonical interval arrays plus the sparse oracle."""
    names = [f"v{i}" for i in range(n_actors)]
    n_dots = n_actors * n_runs * 2
    sparse = Clock.zero().add_dots(
        Dot(names[int(a)], int(c))
        for a, c in zip(rng.integers(0, n_actors, n_dots),
                        rng.integers(1, hi, n_dots)))
    idx = {a: i for i, a in enumerate(names)}
    return vclock.from_clock(sparse, idx, n_actors), sparse, names


class TestDotSeenKernel:
    @pytest.mark.parametrize("n_actors,n_runs,n_dots,block_n", [
        (4, 8, 64, 32),
        (16, 32, 1000, 256),
        (128, 16, 4096, 1024),
        (3, 2, 17, 64),     # ragged: pad path
    ])
    def test_matches_ref(self, n_actors, n_runs, n_dots, block_n):
        dense, sparse, names = _random_dense(n_actors, n_runs, n_runs * 40, RNG)
        actors = jnp.asarray(RNG.integers(0, n_actors, n_dots), jnp.int32)
        counters = jnp.asarray(RNG.integers(1, n_runs * 40 + 80, n_dots), jnp.int32)
        got = dot_seen_pallas(dense.starts, dense.ends, actors, counters,
                              block_n=block_n)
        want = dot_seen_ref(dense.starts, dense.ends, actors, counters)
        assert (np.asarray(got) == np.asarray(want)).all()
        oracle = np.array([sparse.seen(Dot(names[int(a)], int(c)))
                           for a, c in zip(np.asarray(actors), np.asarray(counters))])
        assert (np.asarray(got) == oracle).all()

    def test_extremes(self):
        # Large counters stay exact through the f32 one-hot gather (< 2^24).
        starts = jnp.array([[1, 128], [1, 0]], jnp.int32)
        ends = jnp.array([[100, 128], [16_000_000, 0]], jnp.int32)
        actors = jnp.array([0, 0, 0, 1, 1], jnp.int32)
        counters = jnp.array([128, 127, 101, 16_000_000, 16_000_001], jnp.int32)
        got = dot_seen_pallas(starts, ends, actors, counters, block_n=32)
        assert np.asarray(got).tolist() == [True, False, False, True, False]


# ------------------------------------------------------------------ clock_ops
class TestClockOpsKernels:
    @pytest.mark.parametrize("n_actors,n_runs", [(4, 16), (8, 64), (13, 25)])
    def test_pallas_matches_ref_and_oracle(self, n_actors, n_runs):
        from repro.kernels.clock_ops import intersect, join, popcount, subtract

        rng = np.random.default_rng(n_actors * 100 + n_runs)
        da, sa, names = _random_dense(n_actors, n_runs, n_runs * 20, rng)
        db, sb, _ = _random_dense(n_actors, n_runs, n_runs * 20, rng)
        for op, sparse_want in [
            (join, sa.join(sb)),
            (subtract, sa.subtract_clock(sb)),
            (intersect, sa.intersect(sb)),
        ]:
            got_p = op(da, db, use_pallas=True, interpret=True)
            got_r = op(da, db, use_pallas=False)
            assert (np.asarray(got_p.starts) == np.asarray(got_r.starts)).all()
            assert (np.asarray(got_p.ends) == np.asarray(got_r.ends)).all()
            assert vclock.to_clock(got_p, names) == sparse_want

    def test_popcount(self):
        from repro.kernels.clock_ops import popcount

        dense, sparse, names = _random_dense(6, 12, 300, np.random.default_rng(7))
        got = np.asarray(popcount(dense, use_pallas=True, interpret=True))
        want = np.asarray(popcount(dense, use_pallas=False))
        assert (got == want).all()
        assert int(got.sum()) == sparse.n_events()


# ------------------------------------------------------------ flash attention
class TestFlashAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,T,D,dtype", [
        (1, 2, 2, 128, 64, jnp.float32),
        (2, 4, 2, 256, 64, jnp.float32),   # GQA group 2
        (1, 8, 1, 128, 128, jnp.float32),  # MQA-ish
        (1, 2, 2, 256, 128, jnp.bfloat16),
    ])
    def test_causal_matches_ref(self, B, Hq, Hkv, T, D, dtype):
        q = jnp.asarray(RNG.standard_normal((B, Hq, T, D)), dtype)
        k = jnp.asarray(RNG.standard_normal((B, Hkv, T, D)), dtype)
        v = jnp.asarray(RNG.standard_normal((B, Hkv, T, D)), dtype)
        got = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_kv=64)
        want = attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                        atol=tol, rtol=tol)

    @pytest.mark.parametrize("window", [64, 128, 999])
    def test_sliding_window(self, window):
        B, H, T, D = 1, 2, 256, 64
        q = jnp.asarray(RNG.standard_normal((B, H, T, D)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((B, H, T, D)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((B, H, T, D)), jnp.float32)
        got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                     block_q=64, block_kv=64)
        want = attention_ref(q, k, v, causal=True, window=window)
        assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)

    def test_noncausal(self):
        B, H, T, D = 1, 1, 128, 64
        q = jnp.asarray(RNG.standard_normal((B, H, T, D)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((B, H, T, D)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((B, H, T, D)), jnp.float32)
        got = flash_attention_pallas(q, k, v, causal=False, block_q=64, block_kv=64)
        want = attention_ref(q, k, v, causal=False)
        assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ decode attention
class TestDecodeAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,S,D,dtype", [
        (2, 4, 4, 256, 64, jnp.float32),
        (1, 8, 2, 512, 64, jnp.float32),   # GQA group 4
        (2, 4, 1, 256, 128, jnp.bfloat16),
    ])
    def test_matches_ref(self, B, Hq, Hkv, S, D, dtype):
        q = jnp.asarray(RNG.standard_normal((B, Hq, D)), dtype)
        k = jnp.asarray(RNG.standard_normal((B, Hkv, S, D)), dtype)
        v = jnp.asarray(RNG.standard_normal((B, Hkv, S, D)), dtype)
        lens = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
        got = decode_attention_pallas(q, k, v, lens, block_kv=128)
        want = decode_attention_ref(q, k, v, lens)
        tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
        assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                        atol=tol, rtol=tol)

    def test_windowed_decode(self):
        B, Hq, Hkv, S, D = 1, 4, 2, 512, 64
        q = jnp.asarray(RNG.standard_normal((B, Hq, D)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((B, Hkv, S, D)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((B, Hkv, S, D)), jnp.float32)
        lens = jnp.array([400], jnp.int32)
        got = decode_attention_pallas(q, k, v, lens, window=128, block_kv=128)
        want = decode_attention_ref(q, k, v, lens, window=128)
        assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- mamba scan
class TestMambaScan:
    @pytest.mark.parametrize("B,T,Dm,N,chunk,block_d", [
        (1, 64, 32, 8, 32, 32),
        (2, 128, 64, 16, 64, 32),
        (1, 96, 48, 16, 32, 16),
    ])
    def test_matches_ref(self, B, T, Dm, N, chunk, block_d):
        x = jnp.asarray(RNG.standard_normal((B, T, Dm)), jnp.float32)
        delta = jnp.asarray(np.abs(RNG.standard_normal((B, T, Dm))) * 0.1, jnp.float32)
        A = -jnp.asarray(np.abs(RNG.standard_normal((Dm, N))) + 0.1, jnp.float32)
        Bm = jnp.asarray(RNG.standard_normal((B, T, N)), jnp.float32)
        Cm = jnp.asarray(RNG.standard_normal((B, T, N)), jnp.float32)
        Dp = jnp.asarray(RNG.standard_normal(Dm), jnp.float32)
        got = mamba_scan_pallas(x, delta, A, Bm, Cm, Dp, chunk=chunk, block_d=block_d)
        want, _ = mamba_scan_ref(x, delta, A, Bm, Cm, Dp)
        assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)

    def test_step_continues_scan(self):
        """Decode step after a prefill scan equals one longer scan."""
        B, T, Dm, N = 1, 32, 16, 8
        x = jnp.asarray(RNG.standard_normal((B, T + 1, Dm)), jnp.float32)
        delta = jnp.asarray(np.abs(RNG.standard_normal((B, T + 1, Dm))) * 0.1, jnp.float32)
        A = -jnp.asarray(np.abs(RNG.standard_normal((Dm, N))) + 0.1, jnp.float32)
        Bm = jnp.asarray(RNG.standard_normal((B, T + 1, N)), jnp.float32)
        Cm = jnp.asarray(RNG.standard_normal((B, T + 1, N)), jnp.float32)
        Dp = jnp.asarray(RNG.standard_normal(Dm), jnp.float32)
        y_full, _ = mamba_scan_ref(x, delta, A, Bm, Cm, Dp)
        y_pre, h = mamba_scan_ref(x[:, :T], delta[:, :T], A, Bm[:, :T], Cm[:, :T], Dp)
        y_step, _ = mamba_step_ref(x[:, T], delta[:, T], A, Bm[:, T], Cm[:, T], Dp, h)
        assert_allclose(np.asarray(y_step), np.asarray(y_full[:, T]), atol=1e-5, rtol=1e-5)
