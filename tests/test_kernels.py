"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes and dtypes, plus vclock dense/sparse agreement."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core import vclock
from repro.core.clock import Clock
from repro.core.dots import Dot
from repro.kernels.clock_ops import kernel as ck, ref as cr
from repro.kernels.decode_attention import decode_attention_pallas, decode_attention_ref
from repro.kernels.dot_seen import dot_seen_pallas, dot_seen_ref
from repro.kernels.flash_attention import attention_ref, flash_attention_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas, mamba_scan_ref, mamba_step_ref

RNG = np.random.default_rng(0)


# --------------------------------------------------------------------- vclock
class TestVClock:
    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 90)), max_size=40))
    @settings(max_examples=60, deadline=None)
    def test_dense_seen_matches_sparse(self, dots):
        actors = ["a", "b", "c", "d"]
        sparse = Clock.zero().add_dots(Dot(actors[a], c) for a, c in dots)
        dense = vclock.from_clock(sparse, {a: i for i, a in enumerate(actors)}, 4, 4)
        probe_a = np.array([a for a, _ in dots] + [0, 1, 2, 3], np.int32)
        probe_c = np.array([c for _, c in dots] + [1, 64, 90, 128], np.int32)
        got = np.asarray(vclock.dots_seen(dense, jnp.asarray(probe_a), jnp.asarray(probe_c)))
        want = np.array([sparse.seen(Dot(actors[a], int(c)))
                         for a, c in zip(probe_a, probe_c)])
        assert (got == want).all()

    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 120)), max_size=40))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_sparse_dense_sparse(self, dots):
        actors = ["a", "b", "c", "d"]
        sparse = Clock.zero().add_dots(Dot(actors[a], c) for a, c in dots)
        dense = vclock.from_clock(sparse, {a: i for i, a in enumerate(actors)}, 4, 4)
        assert vclock.to_clock(dense, actors) == sparse

    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 100)), max_size=30),
           st.lists(st.tuples(st.integers(0, 3), st.integers(1, 100)), max_size=30))
    @settings(max_examples=40, deadline=None)
    def test_dense_join_matches_sparse(self, d1, d2):
        actors = ["a", "b", "c", "d"]
        idx = {a: i for i, a in enumerate(actors)}
        s1 = Clock.zero().add_dots(Dot(actors[a], c) for a, c in d1)
        s2 = Clock.zero().add_dots(Dot(actors[a], c) for a, c in d2)
        j = vclock.join(vclock.from_clock(s1, idx, 4, 4),
                        vclock.from_clock(s2, idx, 4, 4))
        assert vclock.to_clock(j, actors) == s1.join(s2)

    def test_compress_folds_prefix(self):
        dense = vclock.zero(2, 2)
        dense = vclock.add_dots(dense, jnp.array([0] * 40, jnp.int32),
                                jnp.arange(1, 41, dtype=jnp.int32))
        c = vclock.compress(dense)
        assert int(c.origin[0]) == 40 and int(c.origin[1]) == 0
        assert int(c.bits.sum()) == 0

    def test_compress_stops_at_gap(self):
        dense = vclock.zero(1, 2)
        cs = jnp.array([1, 2, 3, 5, 6], jnp.int32)
        dense = vclock.add_dots(dense, jnp.zeros(5, jnp.int32), cs)
        c = vclock.compress(dense)
        assert int(c.origin[0]) == 3
        got = vclock.dots_seen(c, jnp.zeros(6, jnp.int32),
                               jnp.array([1, 2, 3, 4, 5, 6], jnp.int32))
        assert np.asarray(got).tolist() == [True, True, True, False, True, True]


# ------------------------------------------------------------------- dot_seen
class TestDotSeenKernel:
    @pytest.mark.parametrize("n_actors,n_words,n_dots,block_n", [
        (4, 8, 64, 32),
        (16, 32, 1000, 256),
        (128, 64, 4096, 1024),
        (3, 2, 17, 64),     # ragged: pad path
    ])
    def test_matches_ref(self, n_actors, n_words, n_dots, block_n):
        origin = jnp.asarray(RNG.integers(0, 50, n_actors), jnp.int32)
        bits = jnp.asarray(
            RNG.integers(0, 1 << 32, (n_actors, n_words), dtype=np.uint64)
            .astype(np.uint32))
        actors = jnp.asarray(RNG.integers(0, n_actors, n_dots), jnp.int32)
        counters = jnp.asarray(RNG.integers(1, n_words * 32 + 80, n_dots), jnp.int32)
        got = dot_seen_pallas(origin, bits, actors, counters, block_n=block_n)
        want = dot_seen_ref(origin, bits, actors, counters)
        assert (np.asarray(got) == np.asarray(want)).all()

    def test_extremes(self):
        origin = jnp.array([0, 1000], jnp.int32)
        bits = jnp.zeros((2, 4), jnp.uint32).at[0, 3].set(0x80000000)
        actors = jnp.array([0, 0, 1, 1], jnp.int32)
        counters = jnp.array([128, 127, 1000, 1001], jnp.int32)
        got = dot_seen_pallas(origin, bits, actors, counters, block_n=32)
        assert np.asarray(got).tolist() == [True, False, True, False]


# ------------------------------------------------------------------ clock_ops
class TestClockOpsKernels:
    @pytest.mark.parametrize("a_shape", [(4, 16), (8, 512), (13, 100)])
    def test_join_subtract_popcount(self, a_shape):
        a = jnp.asarray(RNG.integers(0, 1 << 32, a_shape, dtype=np.uint64).astype(np.uint32))
        b = jnp.asarray(RNG.integers(0, 1 << 32, a_shape, dtype=np.uint64).astype(np.uint32))
        assert (np.asarray(ck.join_pallas(a, b)) == np.asarray(cr.join_ref(a, b))).all()
        assert (np.asarray(ck.subtract_pallas(a, b)) == np.asarray(cr.subtract_ref(a, b))).all()
        assert (np.asarray(ck.popcount_pallas(a)) == np.asarray(cr.popcount_ref(a))).all()


# ------------------------------------------------------------ flash attention
class TestFlashAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,T,D,dtype", [
        (1, 2, 2, 128, 64, jnp.float32),
        (2, 4, 2, 256, 64, jnp.float32),   # GQA group 2
        (1, 8, 1, 128, 128, jnp.float32),  # MQA-ish
        (1, 2, 2, 256, 128, jnp.bfloat16),
    ])
    def test_causal_matches_ref(self, B, Hq, Hkv, T, D, dtype):
        q = jnp.asarray(RNG.standard_normal((B, Hq, T, D)), dtype)
        k = jnp.asarray(RNG.standard_normal((B, Hkv, T, D)), dtype)
        v = jnp.asarray(RNG.standard_normal((B, Hkv, T, D)), dtype)
        got = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_kv=64)
        want = attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                        atol=tol, rtol=tol)

    @pytest.mark.parametrize("window", [64, 128, 999])
    def test_sliding_window(self, window):
        B, H, T, D = 1, 2, 256, 64
        q = jnp.asarray(RNG.standard_normal((B, H, T, D)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((B, H, T, D)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((B, H, T, D)), jnp.float32)
        got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                     block_q=64, block_kv=64)
        want = attention_ref(q, k, v, causal=True, window=window)
        assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)

    def test_noncausal(self):
        B, H, T, D = 1, 1, 128, 64
        q = jnp.asarray(RNG.standard_normal((B, H, T, D)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((B, H, T, D)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((B, H, T, D)), jnp.float32)
        got = flash_attention_pallas(q, k, v, causal=False, block_q=64, block_kv=64)
        want = attention_ref(q, k, v, causal=False)
        assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ decode attention
class TestDecodeAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,S,D,dtype", [
        (2, 4, 4, 256, 64, jnp.float32),
        (1, 8, 2, 512, 64, jnp.float32),   # GQA group 4
        (2, 4, 1, 256, 128, jnp.bfloat16),
    ])
    def test_matches_ref(self, B, Hq, Hkv, S, D, dtype):
        q = jnp.asarray(RNG.standard_normal((B, Hq, D)), dtype)
        k = jnp.asarray(RNG.standard_normal((B, Hkv, S, D)), dtype)
        v = jnp.asarray(RNG.standard_normal((B, Hkv, S, D)), dtype)
        lens = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
        got = decode_attention_pallas(q, k, v, lens, block_kv=128)
        want = decode_attention_ref(q, k, v, lens)
        tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
        assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                        atol=tol, rtol=tol)

    def test_windowed_decode(self):
        B, Hq, Hkv, S, D = 1, 4, 2, 512, 64
        q = jnp.asarray(RNG.standard_normal((B, Hq, D)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((B, Hkv, S, D)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((B, Hkv, S, D)), jnp.float32)
        lens = jnp.array([400], jnp.int32)
        got = decode_attention_pallas(q, k, v, lens, window=128, block_kv=128)
        want = decode_attention_ref(q, k, v, lens, window=128)
        assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- mamba scan
class TestMambaScan:
    @pytest.mark.parametrize("B,T,Dm,N,chunk,block_d", [
        (1, 64, 32, 8, 32, 32),
        (2, 128, 64, 16, 64, 32),
        (1, 96, 48, 16, 32, 16),
    ])
    def test_matches_ref(self, B, T, Dm, N, chunk, block_d):
        x = jnp.asarray(RNG.standard_normal((B, T, Dm)), jnp.float32)
        delta = jnp.asarray(np.abs(RNG.standard_normal((B, T, Dm))) * 0.1, jnp.float32)
        A = -jnp.asarray(np.abs(RNG.standard_normal((Dm, N))) + 0.1, jnp.float32)
        Bm = jnp.asarray(RNG.standard_normal((B, T, N)), jnp.float32)
        Cm = jnp.asarray(RNG.standard_normal((B, T, N)), jnp.float32)
        Dp = jnp.asarray(RNG.standard_normal(Dm), jnp.float32)
        got = mamba_scan_pallas(x, delta, A, Bm, Cm, Dp, chunk=chunk, block_d=block_d)
        want, _ = mamba_scan_ref(x, delta, A, Bm, Cm, Dp)
        assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)

    def test_step_continues_scan(self):
        """Decode step after a prefill scan equals one longer scan."""
        B, T, Dm, N = 1, 32, 16, 8
        x = jnp.asarray(RNG.standard_normal((B, T + 1, Dm)), jnp.float32)
        delta = jnp.asarray(np.abs(RNG.standard_normal((B, T + 1, Dm))) * 0.1, jnp.float32)
        A = -jnp.asarray(np.abs(RNG.standard_normal((Dm, N))) + 0.1, jnp.float32)
        Bm = jnp.asarray(RNG.standard_normal((B, T + 1, N)), jnp.float32)
        Cm = jnp.asarray(RNG.standard_normal((B, T + 1, N)), jnp.float32)
        Dp = jnp.asarray(RNG.standard_normal(Dm), jnp.float32)
        y_full, _ = mamba_scan_ref(x, delta, A, Bm, Cm, Dp)
        y_pre, h = mamba_scan_ref(x[:, :T], delta[:, :T], A, Bm[:, :T], Cm[:, :T], Dp)
        y_step, _ = mamba_step_ref(x[:, T], delta[:, T], A, Bm[:, T], Cm[:, T], Dp, h)
        assert_allclose(np.asarray(y_step), np.asarray(y_full[:, T]), atol=1e-5, rtol=1e-5)
