"""Extra property coverage: data determinism, BigStore random histories,
vclock window edges, aggregator invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.checkpoint.bigstore import BigStore
from repro.core import vclock
from repro.core.clock import Clock
from repro.core.dots import Dot
from repro.train.data import DataConfig, SyntheticLM


class TestDataPipeline:
    def test_deterministic_and_seekable(self):
        cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=4, seed=3)
        d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
        b1 = d1.batch(7)
        b2 = d2.batch(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        # different steps differ
        assert not np.array_equal(b1["tokens"], d1.batch(8)["tokens"])

    def test_host_sharding_partitions(self):
        cfg = DataConfig(vocab_size=101, seq_len=8, global_batch=8, seed=0)
        d = SyntheticLM(cfg)
        full = d.batch(3)["tokens"]
        parts = [d.batch(3, host=h, n_hosts=4)["tokens"] for h in range(4)]
        assert all(p.shape[0] == 2 for p in parts)

    def test_learnable_signal(self):
        """Tokens are not uniform: a bigram model beats chance."""
        cfg = DataConfig(vocab_size=64, seq_len=256, global_batch=8, seed=1)
        toks = SyntheticLM(cfg).batch(0)["tokens"]
        # unigram entropy < log2(vocab) by a margin
        _, counts = np.unique(toks, return_counts=True)
        p = counts / counts.sum()
        h = -(p * np.log2(p)).sum()
        assert h < np.log2(64) - 0.5


save_hist = st.lists(
    st.tuples(st.integers(0, 5),            # shard id to touch
              st.booleans()),               # full save vs delta
    min_size=1, max_size=12)


class TestBigStoreProps:
    @given(save_hist, st.integers(0, 3))
    @settings(max_examples=30, deadline=None)
    def test_latest_version_wins_any_history(self, hist, kill):
        store = BigStore(4, replication=3)
        latest = {}
        rng = np.random.default_rng(0)
        shards = {f"s{i}": rng.standard_normal(4).astype(np.float32)
                  for i in range(6)}
        for step, (sid, full) in enumerate(hist, start=1):
            shards[f"s{sid}"] = shards[f"s{sid}"] + 1.0
            latest[f"s{sid}"] = (step, shards[f"s{sid}"].copy())
            store.save(b"r", dict(shards), step=step)
        for k in shards:
            latest.setdefault(k, (1, shards[k]))
        store.kill(kill)
        got = store.restore(b"r", expect=shards.keys())
        for k, (step, arr) in latest.items():
            np.testing.assert_array_equal(got[k][1], arr)

    @given(save_hist)
    @settings(max_examples=20, deadline=None)
    def test_compaction_never_changes_restore(self, hist):
        store = BigStore(3, replication=3)
        rng = np.random.default_rng(1)
        shards = {f"s{i}": rng.standard_normal(3).astype(np.float32)
                  for i in range(4)}
        for step, (sid, _) in enumerate(hist, start=1):
            shards[f"s{sid % 4}"] = shards[f"s{sid % 4}"] + 1.0
            store.save(b"r", dict(shards), step=step)
        before = store.restore(b"r")
        store.compact_all()
        after = store.restore(b"r")
        assert set(before) == set(after)
        for k in before:
            np.testing.assert_array_equal(before[k][1], after[k][1])


class TestVClockIntervals:
    @given(st.lists(st.integers(1, 127), min_size=1, max_size=30))
    @settings(max_examples=40, deadline=None)
    def test_interval_roundtrip_vs_sparse(self, counters):
        sparse = Clock.zero().add_dots(Dot("x", c) for c in counters)
        dense = vclock.from_clock(sparse, {"x": 0}, 1)
        assert vclock.to_clock(dense, ["x"]) == sparse
        # canonical form: one array slot per run, not per dot
        assert dense.n_runs == sparse.n_runs()

    def test_subtract_matches_sparse(self):
        s1 = Clock.zero().add_dots(Dot("x", c) for c in (1, 2, 3, 5, 9))
        s2 = Clock.zero().add_dots(Dot("x", c) for c in (2, 9))
        d1 = vclock.from_clock(s1, {"x": 0}, 1)
        d2 = vclock.from_clock(s2, {"x": 0}, 1)
        diff = vclock.subtract(d1, d2)
        assert vclock.to_clock(diff, ["x"]) == s1.subtract([Dot("x", 2), Dot("x", 9)])

    def test_subtract_origin_free_across_bases(self):
        # Holes punched below either base — no alignment precondition.
        s1 = Clock(base={"x": 50}).add_dots([Dot("x", 60)])
        s2 = Clock(base={"x": 10}).add_dots(
            [Dot("x", 20), Dot("x", 21), Dot("x", 60)])
        d1 = vclock.from_clock(s1, {"x": 0}, 1)
        d2 = vclock.from_clock(s2, {"x": 0}, 1)
        diff = vclock.subtract(d1, d2)
        assert vclock.to_clock(diff, ["x"]) == s1.subtract_clock(s2)
        assert int(vclock.popcount(diff).sum()) == 50 - 10 - 2

    def test_densify_100k_contiguous_is_o_runs(self):
        """Regression: densifying a 100k-dot clock must not expand per dot.

        The old bitmap path walked ``all_dots()`` in Python (100k iterations
        and a 100k-bit window); the interval form carries one (lo, hi) pair
        per run, so the dense arrays stay O(runs) no matter how many events
        the clock covers.
        """
        big = Clock(base={"x": 100_000}).add_dots(
            [Dot("x", 100_005), Dot("y", 7)])
        dense = vclock.from_clock(big, {"x": 0, "y": 1}, 2)
        assert dense.starts.size <= 4          # 2 actors x <=2 run slots
        assert int(vclock.popcount(dense).sum()) == 100_002
        assert vclock.to_clock(dense, ["x", "y"]) == big
