"""ORSWOT semantics + delta-ORSWOT equivalence (paper §2-3 baselines)."""
from hypothesis import given, settings, strategies as st

from repro.core.delta_orswot import delta_add, delta_remove, group_deltas, join_delta
from repro.core.orswot import Orswot

ACTORS = ["a", "b", "c"]
ELEMS = [b"x", b"y", b"z", b"w"]

# an op is (kind, actor, element)
op_st = st.tuples(
    st.sampled_from(["add", "rem"]), st.sampled_from(ACTORS), st.sampled_from(ELEMS)
)
ops_st = st.lists(op_st, max_size=30)


def apply_ops_local(replicas, ops):
    """Each op executes at its actor's replica; no replication."""
    for kind, actor, elem in ops:
        i = ACTORS.index(actor)
        s = replicas[i]
        if kind == "add":
            replicas[i] = s.add(actor, elem)
        else:
            replicas[i] = s.remove(elem, s.context_of(elem))
    return replicas


class TestSemantics:
    def test_add_then_remove(self):
        s = Orswot.new().add("a", b"x")
        assert b"x" in s.value()
        s = s.remove(b"x", s.context_of(b"x"))
        assert b"x" not in s.value()

    def test_add_wins_over_concurrent_remove(self):
        base = Orswot.new().add("a", b"x")
        # replica b removes (observed), replica c concurrently re-adds
        b_side = base.remove(b"x", base.context_of(b"x"))
        c_side = base.add("c", b"x")
        merged = b_side.merge(c_side)
        assert b"x" in merged.value()  # add-wins

    def test_unobserved_remove_is_noop(self):
        a = Orswot.new().add("a", b"x")
        b = Orswot.new()  # hasn't seen the add
        b = b.remove(b"x", b.context_of(b"x"))
        assert b"x" in a.merge(b).value()

    def test_readd_after_remove(self):
        s = Orswot.new().add("a", b"x")
        s = s.remove(b"x", s.context_of(b"x"))
        s = s.add("a", b"x")
        assert b"x" in s.value()


class TestMergeLattice:
    @given(ops_st, ops_st)
    @settings(max_examples=80)
    def test_merge_commutative(self, ops1, ops2):
        r = apply_ops_local([Orswot.new()] * 3, ops1 + ops2)
        a, b = r[0], r[1]
        assert a.merge(b) == b.merge(a)

    @given(ops_st)
    @settings(max_examples=80)
    def test_merge_idempotent(self, ops):
        r = apply_ops_local([Orswot.new()] * 3, ops)
        for s in r:
            assert s.merge(s) == s

    @given(ops_st)
    @settings(max_examples=60)
    def test_merge_associative(self, ops):
        a, b, c = apply_ops_local([Orswot.new()] * 3, ops)
        assert a.merge(b).merge(c) == a.merge(b.merge(c))

    @given(ops_st, st.randoms(use_true_random=False))
    @settings(max_examples=60)
    def test_convergence_any_merge_order(self, ops, rng):
        replicas = apply_ops_local([Orswot.new()] * 3, ops)
        order = list(range(3))
        rng.shuffle(order)
        x = replicas[order[0]].merge(replicas[order[1]]).merge(replicas[order[2]])
        y = replicas[2].merge(replicas[0]).merge(replicas[1])
        assert x == y


class TestDeltaEquivalence:
    """§3: delta replication must be semantically identical to full-state."""

    @given(ops_st)
    @settings(max_examples=80)
    def test_delta_stream_equals_full_state(self, ops):
        full = Orswot.new()
        via_deltas = Orswot.new()
        deltas = []
        for kind, actor, elem in ops:
            if kind == "add":
                full2, d = delta_add(full, actor, elem)
            else:
                full2, d = delta_remove(full, elem, full.context_of(elem))
            full = full2
            deltas.append(d)
            via_deltas = join_delta(via_deltas, d)
        assert via_deltas.value() == full.value()
        assert via_deltas == full

    @given(ops_st)
    @settings(max_examples=50)
    def test_delta_groups_and_duplication(self, ops):
        full = Orswot.new()
        deltas = []
        for kind, actor, elem in ops:
            if kind == "add":
                full, d = delta_add(full, actor, elem)
            else:
                full, d = delta_remove(full, elem, full.context_of(elem))
            deltas.append(d)
        group = group_deltas(deltas)
        # applying the group twice (duplication) converges to the same value
        s = join_delta(join_delta(Orswot.new(), group), group)
        assert s.value() == full.value()
