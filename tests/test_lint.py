"""bigset-lint: golden fixture runs per rule, engine semantics, self-check.

The fixture tree under ``tests/lint_fixtures/repro/`` mirrors the package
layout (``core/``, ``cluster/``, ``query/``, ``storage/``, ``kernels/``,
``testing/``)
so the *shipped* config — with its real layer scoping — is what the
golden tests exercise: every rule has a positive, a negative, a
suppressed, and (via BS000) an unused-/malformed-suppression case.

The self-check pins the acceptance criterion: ``src/repro`` lints clean
under the shipped config, and every committed suppression is used and
justified (an unused or bare one would itself be a finding).
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (DEFAULT_CONFIG, META_RULE, RULES, LintConfig,
                            render_json, run_lint)
from repro.analysis.__main__ import main as lint_main
from repro.analysis.engine import package_rel

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "lint_fixtures"
SRC = REPO / "src" / "repro"

#: fixture file (relative to FIXTURES) -> exact [(rule, line), ...] expected
GOLDEN = {
    "repro/core/bs001_positive.py": [
        ("BS001", 10), ("BS001", 14), ("BS001", 18), ("BS001", 22),
        ("BS001", 26), ("BS001", 30), ("BS001", 34), ("BS001", 38),
    ],
    "repro/core/bs001_negative.py": [],
    "repro/core/bs001_suppressed.py": [],
    "repro/core/bs001_unused_suppression.py": [(META_RULE, 5)],
    "repro/core/bs000_bad_suppressions.py": [(META_RULE, 5), (META_RULE, 9)],
    "repro/core/bs003_home.py": [],
    "repro/cluster/bs002_positive.py": [("BS002", 11), ("BS002", 16)],
    "repro/cluster/bs002_negative.py": [],
    "repro/cluster/bs002_suppressed.py": [],
    "repro/cluster/bs003_positive.py": [
        ("BS003", 8), ("BS003", 9), ("BS008", 9), ("BS003", 11),
        ("BS003", 17),
    ],
    "repro/cluster/bs003_negative.py": [],
    "repro/cluster/bs005_out_of_scope.py": [],
    "repro/cluster/bs008_positive.py": [
        ("BS008", 6), ("BS008", 7), ("BS008", 8), ("BS008", 15),
    ],
    "repro/cluster/bs008_negative.py": [],
    "repro/cluster/bs008_suppressed.py": [],
    "repro/cluster/bs009_positive.py": [
        ("BS009", 10), ("BS009", 13), ("BS009", 14), ("BS009", 18),
        ("BS009", 19),
    ],
    "repro/cluster/bs009_negative.py": [],
    "repro/cluster/bs009_suppressed.py": [],
    "repro/query/bs004_positive.py": [("BS004", 6), ("BS004", 11)],
    "repro/query/bs004_negative.py": [],
    "repro/query/bs004_suppressed.py": [],
    "repro/testing/bs004_exempt.py": [],
    "repro/query/bs005_positive.py": [
        ("BS005", 5), ("BS005", 9), ("BS005", 13),
    ],
    "repro/query/bs005_negative.py": [],
    "repro/kernels/demo/kernel.py": [("BS006", 6), ("BS006", 9)],
    "repro/kernels/demo/ref.py": [],
    "repro/kernels/clean/kernel.py": [],
    "repro/storage/bs007_positive.py": [
        ("BS007", 9), ("BS007", 12), ("BS007", 15), ("BS007", 18),
        ("BS007", 21),
    ],
    "repro/storage/bs007_negative.py": [],
    "repro/storage/bs007_suppressed.py": [],
}


class TestGoldenFixtures:
    @pytest.fixture(scope="class")
    def fixture_result(self):
        return run_lint([str(FIXTURES)])

    def test_every_fixture_matches_golden(self, fixture_result):
        got: dict = {rel: [] for rel in GOLDEN}
        for f in fixture_result.findings:
            rel = Path(f.path).relative_to(FIXTURES).as_posix()
            assert rel in GOLDEN, f"finding in unexpected file: {f.render()}"
            got[rel].append((f.rule, f.line))
        for rel, expected in GOLDEN.items():
            assert got[rel] == expected, (
                f"{rel}: expected {expected}, got {got[rel]}")

    def test_fixture_file_inventory_is_complete(self, fixture_result):
        on_disk = {p.relative_to(FIXTURES).as_posix()
                   for p in FIXTURES.rglob("*.py")}
        assert on_disk == set(GOLDEN)
        assert fixture_result.files_checked == len(GOLDEN)

    def test_suppressions_counted(self, fixture_result):
        # bs001_suppressed + bs002_suppressed + bs004_suppressed
        # + bs007_suppressed + bs008_suppressed + bs009_suppressed
        # + the justification-less (still applied) one in bs000_bad_*
        assert fixture_result.suppressed == 7

    def test_all_rules_ran(self, fixture_result):
        assert fixture_result.rules == (
            "BS001", "BS002", "BS003", "BS004", "BS005", "BS006", "BS007",
            "BS008", "BS009")
        assert set(RULES) == set(fixture_result.rules)


class TestSelfCheck:
    """Acceptance: the shipped tree is clean under the shipped config."""

    def test_src_repro_is_clean(self):
        result = run_lint([str(SRC)])
        assert result.ok, "\n" + "\n".join(f.render() for f in result.findings)
        assert result.files_checked > 100
        # the committed suppressions are real, used, and justified
        assert result.suppressed >= 3

    def test_reintroduced_violation_fails(self, tmp_path):
        # the acceptance criterion's regression direction: put one of the
        # fixture violations back into a package-shaped tree and the run
        # must go red again
        bad = tmp_path / "repro" / "query" / "regression.py"
        bad.parent.mkdir(parents=True)
        bad.write_text("def f(vnode, s):\n    return list(vnode.fold(s))\n")
        result = run_lint([str(tmp_path)])
        assert [f.rule for f in result.findings] == ["BS005"]


class TestEngineSemantics:
    def test_package_rel(self):
        assert package_rel(Path("src/repro/core/clock.py")) == "core/clock.py"
        assert package_rel(
            Path("tests/lint_fixtures/repro/kernels/demo/kernel.py")
        ) == "kernels/demo/kernel.py"
        assert package_rel(Path("elsewhere/mod.py")) == "elsewhere/mod.py"

    def test_docstring_mention_is_not_a_suppression(self, tmp_path):
        # only COMMENT tokens count: the engine's own docs describe the
        # syntax without registering (and thus without going stale-unused)
        f = tmp_path / "repro" / "core" / "doc.py"
        f.parent.mkdir(parents=True)
        f.write_text('"""Use `# bigset-lint: disable=BS001 -- why`."""\n')
        assert run_lint([str(tmp_path)]).ok

    def test_suppression_only_covers_its_line(self, tmp_path):
        f = tmp_path / "repro" / "core" / "twolines.py"
        f.parent.mkdir(parents=True)
        f.write_text(
            "import time\n"
            "a = time.time()  # bigset-lint: disable=BS001 -- test escape\n"
            "b = time.time()\n")
        result = run_lint([str(f)])
        assert [(x.rule, x.line) for x in result.findings] == [("BS001", 3)]
        assert result.suppressed == 1

    def test_select_and_ignore(self):
        only4 = run_lint([str(FIXTURES)],
                         DEFAULT_CONFIG.with_rules(select=frozenset({"BS004"})))
        assert only4.rules == ("BS004",)
        assert {f.rule for f in only4.findings} <= {"BS004", META_RULE}
        # narrowing must not flag other rules' suppressions as unused
        assert not any("unused suppression of BS001" in f.message
                       for f in only4.findings)
        no4 = run_lint([str(FIXTURES)],
                       DEFAULT_CONFIG.with_rules(ignore=frozenset({"BS004"})))
        assert "BS004" not in no4.rules
        assert not any(f.rule == "BS004" for f in no4.findings)

    def test_syntax_error_is_a_finding(self, tmp_path):
        f = tmp_path / "broken.py"
        f.write_text("def f(:\n")
        result = run_lint([str(f)])
        assert len(result.findings) == 1
        assert result.findings[0].rule == META_RULE
        assert "could not parse" in result.findings[0].message

    def test_config_is_data(self):
        cfg = LintConfig(deterministic_layers=("query/",))
        result = run_lint([str(FIXTURES / "repro" / "core")], cfg)
        assert not any(f.rule == "BS001" for f in result.findings)


class TestCli:
    def test_exit_codes_and_json(self, tmp_path):
        out = tmp_path / "lint.json"
        assert lint_main([str(FIXTURES), "--json-out", str(out)]) == 1
        doc = json.loads(out.read_text())
        assert doc["version"] == 1 and doc["ok"] is False
        assert len(doc["findings"]) == 39
        assert doc["rules"] == list(RULES)
        assert lint_main([str(SRC)]) == 0
        assert lint_main(["--list-rules"]) == 0

    def test_module_entrypoint(self):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", str(SRC / "analysis"),
             "--format", "json"],
            capture_output=True, text=True,
            env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
        assert proc.returncode == 0, proc.stderr
        doc = json.loads(proc.stdout)
        assert doc["ok"] is True

    def test_json_report_roundtrips(self):
        result = run_lint([str(FIXTURES / "repro" / "kernels")])
        doc = json.loads(json.dumps(render_json(result)))
        assert [f["rule"] for f in doc["findings"]] == ["BS006", "BS006"]
