"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes and finiteness (deliverable f)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import build_model

ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, rng, B=2, T=16):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T + 1)),
                                   jnp.int32)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["encoder_frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_positions, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = smoke_config(arch)
        model = build_model(cfg)
        rng = np.random.default_rng(0)
        state = model.init_train_state(jax.random.key(0))
        batch = make_batch(cfg, rng)
        loss0 = model.loss_fn(state.params, batch)
        assert np.isfinite(float(loss0)), f"{arch}: non-finite initial loss"
        step = jax.jit(model.train_step)
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        # one more step must change the loss (optimizer actually applied)
        state, m2 = step(state, batch)
        assert float(m2["loss"]) != float(metrics["loss"])
        assert int(m2["step"]) == 2

    def test_prefill_then_decode(self, arch):
        cfg = smoke_config(arch)
        model = build_model(cfg)
        rng = np.random.default_rng(1)
        params = model.init(jax.random.key(1))
        B, T = 2, 16
        batch = make_batch(cfg, rng, B, T)
        prompt = batch["tokens"][:, :T]
        pf_batch = dict(batch, tokens=prompt)
        logits, cache = model.prefill_step(params, pf_batch)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        # decode cache shapes must admit continuation; re-init a decode cache
        # of capacity T+4 and replay the prompt via decode for equivalence
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        cache_len = jnp.full((B,), T, jnp.int32)
        d_logits, _ = model.decode_step(params, cache, nxt, cache_len)
        assert d_logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(d_logits, np.float32)).all()


class TestDecodeConsistency:
    """Token-by-token decode must match teacher-forced forward."""

    @pytest.mark.parametrize("arch", ["gemma-7b", "gemma3-27b",
                                      "falcon-mamba-7b",
                                      "jamba-1.5-large-398b",
                                      "granite-moe-1b-a400m"])
    def test_decode_matches_forward(self, arch):
        cfg = smoke_config(arch).replace(kv_cache_dtype="bfloat16")
        if cfg.n_experts:
            # dropless capacity: capacity dropping is shape-dependent (a
            # full-sequence pass drops over-capacity tokens that a 1-token
            # decode keeps), so exact equivalence needs cf >= E/K
            cfg = cfg.replace(
                capacity_factor=cfg.n_experts / cfg.experts_per_token)
        model = build_model(cfg)
        rng = np.random.default_rng(2)
        params = model.init(jax.random.key(2))
        B, T = 1, 12
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)

        # teacher-forced logits at the last position
        from repro.models.transformer import forward
        from repro.models.model import _logits
        hid, _, _ = forward(params, cfg, tokens, mode="train",
                            _return_hidden=True)
        want = _logits(params, cfg, hid[:, -1:, :])[:, 0]

        # prefill T-1 then decode token T-1
        logits_p, cache = model.prefill_step(params, {"tokens": tokens[:, :T - 1]})
        got, _ = model.decode_step(params, cache, tokens[:, T - 1:T],
                                   jnp.full((B,), T - 1, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=2e-2, rtol=2e-2)


class TestConfigs:
    @pytest.mark.parametrize("arch", ALL_ARCHS)
    def test_full_config_matches_assignment(self, arch):
        cfg = get_config(arch)
        expected = {
            "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
            "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
            "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
            "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
            "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
            "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
            "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
            "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
            "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
            "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        }[arch]
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == expected

    def test_param_counts_plausible(self):
        # order-of-magnitude sanity for the billion-scale archs
        assert 6e9 < get_config("gemma-7b").n_params() < 10e9
        assert 3e9 < get_config("minitron-4b").n_params() < 6e9
        assert 250e9 < get_config("grok-1-314b").n_params() < 380e9
        assert 330e9 < get_config("jamba-1.5-large-398b").n_params() < 480e9
        assert 100e9 < get_config("mistral-large-123b").n_params() < 150e9
        g = get_config("granite-moe-1b-a400m")
        assert 0.8e9 < g.n_params() < 2e9
        assert g.n_active_params() < 0.6e9

    def test_layer_patterns(self):
        j = get_config("jamba-1.5-large-398b")
        kinds = [j.layer_kind(i) for i in range(8)]
        assert [m for m, _ in kinds].count("attn") == 1
        assert kinds[4][0] == "attn"
        assert [f for _, f in kinds].count("moe") == 4
        g = get_config("gemma3-27b")
        kg = [g.layer_kind(i)[0] for i in range(6)]
        assert kg == ["attn_local"] * 5 + ["attn_global"]
