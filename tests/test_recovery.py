"""Durability and crash recovery: WAL framing, group commit, crash injection.

Three layers of the prefix-durability invariant (invariant 11,
acknowledged ⇒ durable):

* **Codec** — CRC framing makes any truncation of the log decode to an
  exact record prefix; a torn tail is discarded, never replayed.
* **Store** — a crash at an arbitrary seeded kill point (WAL byte offset,
  mid-flush, mid-compaction) loses exactly the unacknowledged tail:
  ``recover()`` on fresh state restores every batch with
  ``seq <= commit_seq`` from durable media alone.
* **Cluster** — ``BigsetCluster.crash()/restart()``: WAL replay brings the
  acknowledged prefix back *before any network traffic*, and scheduled
  anti-entropy (``tick()``) heals the unacknowledged tail from peers,
  dot-bounded (post-heal ticks are skipped without folding a single key).

All strategies stay inside the ``repro.testing.hypothesis_fallback``
surface (integers / lists / tuples / binary / sampled_from / randoms), so
the suite runs identically on the CI leg without hypothesis installed.
"""
from __future__ import annotations

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cluster.clusters import BigsetCluster, VnodeDown
from repro.cluster.sim import Network
from repro.obs.trace import Tracer
from repro.query import plan as qp
from repro.storage import (CrashError, CrashPoint, DurableMedia, LsmStore,
                           WalError)
from repro.storage.wal import decode_wal, encode_wal_record

S = b"people"


def key(i: int) -> bytes:
    return b"k%04d" % i


def batches_to_wal(batches) -> bytes:
    return b"".join(
        encode_wal_record(seq, items)
        for seq, items in enumerate(batches, start=1))


# --------------------------------------------------------------------- codec
class TestWalCodec:
    @given(st.lists(
        st.lists(st.tuples(st.binary(max_size=12), st.binary(max_size=24)),
                 max_size=4),
        max_size=6))
    @settings(max_examples=40, deadline=None)
    def test_roundtrip(self, batches):
        records, torn = decode_wal(batches_to_wal(batches))
        assert torn == 0
        assert [list(r.items) for r in records] == batches
        assert [r.seq for r in records] == list(range(1, len(batches) + 1))
        assert sum(r.nbytes for r in records) == len(batches_to_wal(batches))

    @given(st.integers(min_value=0, max_value=600), st.randoms())
    @settings(max_examples=50, deadline=None)
    def test_any_truncation_decodes_to_a_record_prefix(self, cut, rng):
        batches = [
            [(bytes([rng.randrange(256)]) * rng.randrange(1, 8),
              bytes([rng.randrange(256)]) * rng.randrange(0, 12))
             for _ in range(rng.randrange(3))]
            for _ in range(rng.randrange(1, 8))
        ]
        wal = batches_to_wal(batches)
        full, _ = decode_wal(wal)
        cut = min(cut, len(wal))
        records, torn = decode_wal(wal[:cut])
        # exact prefix property: whole records below the cut, nothing else
        assert records == full[:len(records)]
        consumed = sum(r.nbytes for r in records)
        assert consumed <= cut and torn == cut - consumed
        if torn == 0 and cut == len(wal):
            assert len(records) == len(full)

    def test_corrupt_byte_stops_replay_at_the_frame(self):
        wal = batches_to_wal([[(b"a", b"1")], [(b"b", b"2")], [(b"c", b"3")]])
        first, _ = decode_wal(wal)
        # flip one byte inside the second record's body
        pos = first[0].nbytes + first[1].nbytes - 1
        bad = wal[:pos] + bytes([wal[pos] ^ 0xFF]) + wal[pos + 1:]
        records, torn = decode_wal(bad)
        assert [r.seq for r in records] == [1]
        assert torn == len(wal) - first[0].nbytes


# --------------------------------------------------------------------- store
def fresh_recover(media: DurableMedia, **kw) -> "tuple[LsmStore, object]":
    store = LsmStore(media=media, **kw)
    return store, store.recover()


class TestDurableStore:
    def test_group_commit_issues_fewer_fsyncs_than_batches(self):
        media = DurableMedia()
        store = LsmStore(media=media, group_depth=8)
        for i in range(20):
            store.put(key(i), b"v")
        assert store.stats.num_fsyncs == 2        # 20 batches, depth 8
        assert store.commit_seq == 16             # acked = fsynced prefix
        store.sync()
        assert store.stats.num_fsyncs == 3 and store.commit_seq == 20
        assert media.wal_fsyncs == 3

    def test_volatile_store_has_no_wal_accounting(self):
        store = LsmStore()
        for i in range(50):
            store.put(key(i), b"v")
        assert store.commit_seq == 50             # trivially acked
        assert store.stats.bytes_wal == 0
        assert store.stats.num_fsyncs == 0

    @given(st.integers(min_value=1, max_value=10),
           st.integers(min_value=1, max_value=40))
    @settings(max_examples=30, deadline=None)
    def test_acked_prefix_survives_an_unsynced_crash(self, depth, n):
        media = DurableMedia()
        store = LsmStore(media=media, group_depth=depth)
        for i in range(n):
            store.put(key(i), b"v%d" % i)
        acked = store.commit_seq
        assert n - acked < depth                  # tail bounded by the group
        media.crash()                             # drops the unsynced buffer
        recovered, res = fresh_recover(media, group_depth=depth)
        assert res.batches_replayed + res.batches_skipped == acked
        assert res.torn_bytes == 0
        for i in range(n):
            expected = b"v%d" % i if i < acked else None
            assert recovered.get(key(i)) == expected
        assert recovered.commit_seq == acked == recovered._seq

    @given(st.integers(min_value=0, max_value=4000))
    @settings(max_examples=30, deadline=None)
    def test_crash_at_arbitrary_wal_offset(self, offset):
        """Seeded kill point at any byte of the log: replay restores exactly
        the acknowledged batches, the torn record is discarded."""
        media = DurableMedia()
        media.schedule_crash(CrashPoint(wal_bytes=offset))
        store = LsmStore(media=media, group_depth=1)
        acked = 0
        crashed = False
        for i in range(40):
            try:
                store.put(key(i), b"v%d" % i)
                acked = store.commit_seq
            except CrashError:
                crashed = True
                break
        media.crash()
        recovered, res = fresh_recover(media)
        assert res.batches_replayed == acked
        if crashed:
            assert len(media.wal) <= offset       # truncated at the kill point
        for i in range(40):
            expected = b"v%d" % i if i < acked else None
            assert recovered.get(key(i)) == expected

    def test_empty_wal_recovers_to_an_empty_store(self):
        store, res = fresh_recover(DurableMedia())
        assert res.batches_replayed == res.batches_skipped == 0
        assert res.segments == 0 and res.torn_bytes == 0
        assert len(store) == 0 and store.commit_seq == 0
        # the recovered store is fully writable
        store.put(b"a", b"1")
        assert store.get(b"a") == b"1"

    def test_torn_final_record_is_discarded(self):
        media = DurableMedia()
        store = LsmStore(media=media, group_depth=100)
        for i in range(10):
            store.put(key(i), b"v%d" % i)
        # tear the fsync 5 bytes short of the full buffer
        media.schedule_crash(
            CrashPoint(wal_bytes=len(media.wal) + media.wal_pending() - 5))
        with pytest.raises(CrashError):
            store.sync()
        media.crash()
        recovered, res = fresh_recover(media)
        assert res.torn_bytes > 0
        assert res.batches_replayed == 9          # record 10 was torn
        assert recovered.get(key(8)) == b"v8"
        assert recovered.get(key(9)) is None

    def test_wal_records_below_horizon_replay_idempotently(self):
        """A durable flush captures WAL'd batches in a segment; the stale
        records still in the log are skipped on replay — and billed zero
        recovery bytes (byte-billed once, at the original append)."""
        media = DurableMedia()
        store = LsmStore(media=media, group_depth=1, memtable_limit=6)
        for i in range(10):                       # flush fires at batch 6
            store.put(key(i), b"v%d" % i)
        media.crash()
        recovered, res = fresh_recover(media)
        assert res.segments == 1 and res.horizon == 6
        # records 1-5 still sit in the log below the horizon and are
        # skipped; record 6 was dropped from the unsynced buffer by the
        # flush that captured it; 7-10 replay
        assert res.batches_skipped == 5
        assert res.batches_replayed == 4
        replayed_bytes = res.bytes_replayed
        assert recovered.stats.bytes_recovered == replayed_bytes
        for i in range(10):
            assert recovered.get(key(i)) == b"v%d" % i
        # recovery is deterministic: a second fresh store sees the same
        again, res2 = fresh_recover(media)
        assert res2 == res
        assert dict(again.scan()) == dict(recovered.scan())

    def test_crash_before_flush_segment_publishes(self):
        media = DurableMedia()
        store = LsmStore(media=media, group_depth=100)
        for i in range(4):
            store.put(key(i), b"v%d" % i)
        store.sync()                              # acked: 4
        for i in range(4, 8):
            store.put(key(i), b"v%d" % i)         # unsynced tail
        media.schedule_crash(CrashPoint(file_writes=1))
        with pytest.raises(CrashError):
            store.flush()                         # dies writing the segment
        media.crash()
        recovered, res = fresh_recover(media)
        assert res.segments == 0                  # old (empty) manifest wins
        assert res.batches_replayed == 4          # exactly the acked prefix
        assert recovered.get(key(3)) == b"v3"
        assert recovered.get(key(4)) is None

    def test_crash_between_segment_and_manifest(self):
        media = DurableMedia()
        store = LsmStore(media=media, group_depth=100)
        for i in range(4):
            store.put(key(i), b"v%d" % i)
        store.sync()
        media.schedule_crash(CrashPoint(file_writes=2))
        with pytest.raises(CrashError):
            store.flush()                         # segment lands, manifest dies
        media.crash()
        recovered, res = fresh_recover(media)
        # the orphan segment is invisible without its manifest: durable
        # state is still old-manifest + WAL, i.e. the acknowledged prefix
        assert res.segments == 0
        assert res.batches_replayed == 4
        assert dict(recovered.scan()) == {key(i): b"v%d" % i for i in range(4)}

    def test_mid_compaction_crash_preserves_precompaction_state(self):
        media = DurableMedia()
        store = LsmStore(media=media, group_depth=1)
        for i in range(10):
            store.put(key(i), b"v%d" % i)
        store.flush()                             # seg + manifest: 2 publishes
        for i in range(10, 15):
            store.put(key(i), b"v%d" % i)
        before = dict(store.scan())
        # compact() = inner flush (2 publishes) then the merged segment (3rd)
        media.schedule_crash(CrashPoint(file_writes=3))
        with pytest.raises(CrashError):
            store.compact()
        media.crash()
        recovered, res = fresh_recover(media)
        assert dict(recovered.scan()) == before
        assert res.segments == 2                  # pre-merge manifest rules

    def test_crash_on_wal_reset_after_compaction_manifest(self):
        """The compaction manifest landed but the WAL reset did not: every
        surviving WAL record sits at or below the new horizon and must be
        skipped (replaying would resurrect filter-discarded keys)."""
        media = DurableMedia()
        store = LsmStore(media=media, group_depth=1)
        for i in range(8):
            store.put(key(i), b"v%d" % i)
        before = dict(store.scan())
        # inner flush (2 publishes) + merged segment (3) + manifest (4),
        # then the WAL reset is the 5th
        media.schedule_crash(CrashPoint(file_writes=5))
        with pytest.raises(CrashError):
            store.compact()
        media.crash()
        recovered, res = fresh_recover(media)
        assert res.segments == 1                  # the merged run
        assert res.batches_replayed == 0
        assert res.batches_skipped == 8 and res.bytes_replayed == 0
        assert dict(recovered.scan()) == before

    def test_recover_guards(self):
        with pytest.raises(WalError):
            LsmStore().recover()                  # no durable media
        media = DurableMedia()
        store = LsmStore(media=media)
        store.put(b"a", b"1")
        with pytest.raises(WalError):
            store.recover()                       # not a fresh store

    def test_legacy_clock_payloads_roundtrip_through_recovery(self):
        """``KIND_CLOCK`` records written by the pre-interval per-dot codec
        replay through the WAL, decode, serve reads, and re-encode in the
        run-length form on the next write."""
        import msgpack

        from repro.core.bigset import (BigsetVnode, clock_key, element_key,
                                       tombstone_key)
        from repro.core.clock import Clock
        from repro.core.dots import Dot

        # Pre-refactor replica state: set-clock base {a: 2} + cloud {4, 5}
        # (gap at 3), tombstone cloud {4} — element y@(a,4) was removed.
        legacy_clock = msgpack.packb({"b": [["a", 2]], "c": [["a", [4, 5]]]})
        legacy_ts = msgpack.packb({"b": [], "c": [["a", [4]]]})
        media = DurableMedia()
        old = LsmStore(media=media)
        old.put(clock_key(S), legacy_clock)
        old.put(tombstone_key(S), legacy_ts)
        old.put(element_key(S, b"x", Dot("a", 2)), b"")
        old.put(element_key(S, b"z", Dot("a", 5)), b"")
        old.sync()
        media.crash()

        store, res = fresh_recover(media)
        assert res.batches_replayed == 4 and res.torn_bytes == 0
        vn = BigsetVnode("b", store)
        assert vn.value(S) == {b"x", b"z"}
        clk = Clock.from_obj(msgpack.unpackb(store.get(clock_key(S)),
                                             strict_map_key=False))
        assert clk.seen(Dot("a", 5)) and not clk.seen(Dot("a", 3))

        # a write through the recovered vnode upgrades the record in place
        vn.coordinate_insert(S, b"w")
        upgraded = msgpack.unpackb(store.get(clock_key(S)),
                                   strict_map_key=False)
        assert "r" in upgraded and "c" not in upgraded
        store.sync()
        media.crash()
        store2, _ = fresh_recover(media)
        assert BigsetVnode("b", store2).value(S) == {b"w", b"x", b"z"}


# ------------------------------------------------------------------- cluster
def run_writes(clusters, lo, hi, coordinators=(0, 1, 2)):
    for i in range(lo, hi):
        c = coordinators[i % len(coordinators)]
        for cluster in clusters:
            cluster.add(S, key(i), coordinator=c, value=b"v%d" % i)


def heal(big: BigsetCluster, ctrl: BigsetCluster, ticks: int = 80) -> int:
    """Tick until every replica matches the control cluster; returns ticks."""
    for t in range(ticks):
        if all(big.vnodes[a].value(S) == ctrl.vnodes[a].value(S)
               for a in big.actors):
            return t
        big.tick()
        big.settle()
    raise AssertionError("anti-entropy did not heal within budget")


class TestClusterCrashRecovery:
    def test_kill_mid_batch_restart_heal_matches_no_crash_run(self):
        """The acceptance path: a seeded kill point tears vnode0's WAL
        mid-batch; restart replays the acknowledged prefix from durable
        media alone, one tick heals the tail, and the healed stores are
        byte-identical to a control cluster that never crashed."""
        big = BigsetCluster(3, durable=True, group_depth=4)
        ctrl = BigsetCluster(3, durable=True, group_depth=4)
        run_writes([big, ctrl], 0, 30)
        media = big.media["vnode0"]
        # arm the kill point 3 bytes short of the next fsync's end: the
        # fsync that crosses it tears the durable log mid-record
        media.schedule_crash(
            CrashPoint(wal_bytes=len(media.wal) + media.wal_pending() + 40))
        crashed_at = None
        for i in range(30, 40):
            try:
                big.add(S, key(i), coordinator=0, value=b"v%d" % i)
            except CrashError:
                crashed_at = i
                break
        assert crashed_at is not None
        big.crash(0)
        # the op that died mid-commit was never replicated: drop it from
        # the control run too, then keep writing through live coordinators
        run_writes([ctrl], 30, crashed_at)
        run_writes([big, ctrl], crashed_at + 1, 40, coordinators=(1, 2))
        ctrl.add(S, key(crashed_at), coordinator=1,
                 value=b"v%d" % crashed_at)
        big.add(S, key(crashed_at), coordinator=1, value=b"v%d" % crashed_at)

        rec = big.restart(0)
        assert rec.batches_replayed > 0           # WAL replay did the bulk
        before = big.ae_stats().keys_scanned
        ticks = heal(big, ctrl)
        # dot-bounded heal: the sync shipped the missing tail, and once
        # converged further ticks skip at O(causal metadata) — zero folds
        stats = big.ae_stats()
        assert stats.keys_shipped >= 1
        scanned_after_heal = stats.keys_scanned
        skipped_before = stats.rounds_skipped
        big.tick()
        assert big.ae_stats().keys_scanned == scanned_after_heal
        assert big.ae_stats().rounds_skipped > skipped_before
        # byte-identical stores: same live keys, same values, every replica
        for a in big.actors:
            assert (dict(big.vnodes[a].store.scan())
                    == dict(ctrl.vnodes[a].store.scan()))

    @given(st.integers(min_value=50, max_value=8000))
    @settings(max_examples=12, deadline=None)
    def test_every_acked_write_survives_restart_before_any_sync(self, offset):
        """WAL replay alone (no anti-entropy) restores every add() that
        returned: group_depth=1 acknowledges each batch at its own fsync,
        so only the op killed mid-commit may be missing."""
        big = BigsetCluster(3, durable=True, group_depth=1)
        media = big.media["vnode0"]
        media.schedule_crash(CrashPoint(wal_bytes=offset))
        acked = []
        for i in range(60):
            try:
                big.add(S, key(i), coordinator=i % 3, value=b"v%d" % i)
                acked.append(i)
            except CrashError:
                break
        big.crash(0)
        big.restart(0)
        vn = big.vnodes["vnode0"]
        present = vn.value(S)
        for i in acked:
            assert key(i) in present, f"acknowledged write {i} lost"

    @given(st.integers(min_value=0, max_value=2 ** 32 - 1))
    @settings(max_examples=10, deadline=None)
    def test_recovery_plus_digest_sync_converges_on_lossy_networks(self, seed):
        net = Network(seed=seed, drop_prob=0.25, dup_prob=0.25, reorder=True)
        big = BigsetCluster(3, net=net, sync=False, durable=True,
                            group_depth=4)
        run_writes([big], 0, 24)
        big.settle()
        big.crash(0)
        run_writes([big], 24, 32, coordinators=(1, 2))
        big.settle()
        big.restart(0)
        for _ in range(20):
            big.tick(budget=3)
            big.settle()
        vns = [big.vnodes[a] for a in big.actors]
        assert vns[0].value(S) == vns[1].value(S) == vns[2].value(S)
        # every write acknowledged by a *live* coordinator survived
        assert vns[0].value(S) == {key(i) for i in range(32)}

    def test_restart_under_traffic_with_nonquorum_crash(self):
        """A non-quorum replica crash leaves the write and query paths
        fully available; tick()-driven sync catches the replica up after
        restart (the ROADMAP's 'node restarts under traffic' scenario)."""
        big = BigsetCluster(3, durable=True, group_depth=4)
        ctrl = BigsetCluster(3, durable=True, group_depth=4)
        run_writes([big, ctrl], 0, 12)
        big.crash(2)                              # vnode2: outside the quorum
        crashed_rounds_before = big.ae_stats().rounds_crashed
        for i in range(12, 24):
            for cluster in (big, ctrl):
                cluster.add(S, key(i), coordinator=i % 2, value=b"v%d" % i)
            if i % 4 == 0:
                big.tick()                        # AE keeps running mid-crash
                res = big.query(qp.Scan(S, page_size=50))
                assert len(res.entries) == i + 1
        # rounds touching the dead member were counted, not attempted
        assert big.ae_stats().rounds_crashed > crashed_rounds_before
        with pytest.raises(VnodeDown):
            big.add(S, b"down", coordinator=2)
        rec = big.restart(2)
        assert rec.batches_replayed > 0
        heal(big, ctrl)
        for a in big.actors:
            assert big.vnodes[a].value(S) == ctrl.vnodes[a].value(S)

    def test_crashed_replica_drops_queued_traffic(self):
        big = BigsetCluster(3, sync=False, durable=True, group_depth=1)
        big.add(S, b"x")                          # replication still queued
        dropped_before = big.net.msgs_dropped
        big.crash(1)
        big.settle()                              # vnode1's copy evaporates
        assert big.net.msgs_dropped > dropped_before
        big.restart(1)
        assert big.vnodes["vnode1"].value(S) == set()
        big.tick()
        big.settle()
        assert big.vnodes["vnode1"].value(S) == {b"x"}

    def test_recovery_span_reports_replay(self):
        tracer = Tracer()
        big = BigsetCluster(3, durable=True, group_depth=2, tracer=tracer)
        run_writes([big], 0, 10)
        big.crash(0)
        rec = big.restart(0)
        spans = [s for s in tracer.spans if s.name == "storage.recover"]
        assert len(spans) == 1
        attrs = spans[0].attrs
        assert attrs["actor"] == "vnode0"
        assert attrs["batches_replayed"] == rec.batches_replayed
        assert attrs["torn_bytes"] == rec.torn_bytes

    def test_fault_api_guards(self):
        volatile = BigsetCluster(3)
        with pytest.raises(RuntimeError):
            volatile.crash(0)
        big = BigsetCluster(3, durable=True)
        with pytest.raises(RuntimeError):
            big.restart(0)                        # not crashed
        big.crash(0)
        big.crash(0)                              # idempotent
        with pytest.raises(VnodeDown):
            big.query(qp.Scan(S, page_size=10), r=3)  # quorum unreachable
        big.restart(0)
        assert "vnode0" in big.vnodes

    def test_restarted_vnode_reregisters_indexes(self):
        from repro.index.spec import by_value_prefix

        big = BigsetCluster(3, durable=True, group_depth=1)
        spec = by_value_prefix(1)
        big.register_index(S, spec)
        run_writes([big], 0, 8)
        big.crash(0)
        big.restart(0)
        # the recovered replica serves index queries: postings were durable
        # with their element-keys, and the spec re-registered on restart
        res = big.query(qp.IndexLookup(S, spec.name, b"v"), r=3)
        assert len(res.entries) == 8
        big.add(S, b"zz", coordinator=0, value=b"v99")
        res = big.query(qp.IndexLookup(S, spec.name, b"v"), r=3)
        assert len(res.entries) == 9
