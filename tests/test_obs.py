"""Observability stack: tracing, metrics, exporters, and the stats op.

The load-bearing invariants, in test order:

* **One tree per request.**  A traced quorum query yields a single span
  tree rooted at the serve layer covering every downstream layer —
  coordinator, per-replica coverage, storage, the visibility kernel,
  network deliveries, read repair — and stays a tree (zero orphans)
  under drop/duplicate/reorder delivery.
* **Determinism under injected clocks.**  Fake clocks make span
  durations and histogram contents exact, and two identical runs
  produce identical traces.
* **Disabled ⇒ zero behavior change.**  The default NULL_TRACER wraps
  no payloads: wire traffic is byte-identical with tracing off
  (ARCHITECTURE invariant 10), and ``Network.send`` refuses un-billed
  non-empty payloads so wire accounting cannot silently read zero.
"""
import json

import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.clusters import BigsetCluster, TracedPayload
from repro.cluster.sim import Network
from repro.obs.export import (span_trees, spans_to_chrome, spans_to_jsonl,
                              tree_names)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               lift_dispatch_stats, lift_network)
from repro.obs.trace import NULL_TRACER, TraceContext, Tracer
from repro.query.plan import Membership, Scan
from repro.serve.bigset_service import (BigsetClient, BigsetService,
                                        ServiceConfig)

SET = b"obs_set"


def ticking_clock(step=1.0, start=0.0):
    """Deterministic monotonic clock: advances ``step`` per call."""
    state = [start]

    def clk():
        state[0] += step
        return state[0]

    return clk


def build_traced(net=None, tracer=None, n=3):
    tr = tracer or Tracer(clock=ticking_clock())
    cluster = BigsetCluster(n, net=net, sync=True, tracer=tr)
    service = BigsetService(cluster, clock=ticking_clock(step=0.001))
    client = BigsetClient(service)
    return tr, cluster, service, client


# =============================================================== trace layer
class TestTracer:
    def test_injected_clock_exact_durations(self):
        tr = Tracer(clock=ticking_clock())
        with tr.span("outer"):
            with tr.span("inner"):
                pass
        inner, outer = tr.spans  # finish order: inner first
        assert (inner.start, inner.end, inner.duration) == (2.0, 3.0, 1.0)
        assert (outer.start, outer.end, outer.duration) == (1.0, 4.0, 3.0)

    def test_implicit_and_explicit_parenting(self):
        tr = Tracer(clock=ticking_clock())
        with tr.span("root") as root:
            with tr.span("child") as child:
                pass
            # explicit context parenting — the network-crossing idiom
            remote = tr.finish(tr.start("remote", parent=root.context()))
        assert child.trace_id == root.trace_id
        assert child.parent_id == root.span_id
        assert remote.parent_id == root.span_id
        assert root.parent_id is None

    def test_error_attr_on_exception(self):
        tr = Tracer(clock=ticking_clock())
        with pytest.raises(ValueError):
            with tr.span("boom"):
                raise ValueError("x")
        (sp,) = tr.spans
        assert sp.attrs["error"] == "ValueError"
        assert sp.end is not None  # finished even on the raise path

    def test_identical_runs_identical_trees(self):
        def run():
            tr = Tracer(clock=ticking_clock())
            with tr.span("a"):
                with tr.span("b"):
                    tr.finish(tr.start("c"))
            return [(s.name, s.trace_id, s.span_id, s.parent_id, s.start,
                     s.end) for s in tr.spans]

        assert run() == run()

    def test_null_tracer_records_nothing(self):
        with NULL_TRACER.span("anything") as sp:
            sp.set(huge=list(range(100)))
        assert NULL_TRACER.spans == []
        assert not NULL_TRACER.enabled
        assert sp.attrs == {}  # set() was a no-op


# ==================================================================== metrics
class TestMetrics:
    def test_counter_monotonic(self):
        c = Counter()
        c.inc()
        c.inc(4)
        assert c.value == 5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_histogram_deterministic_buckets(self):
        h = Histogram(buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.0, 1.5, 3.0, 100.0):
            h.observe(v)
        # bisect_left: upper bounds inclusive; last slot is overflow
        assert h.counts == [2, 1, 1, 1]
        assert h.count == 5
        h2 = Histogram(buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.0, 1.5, 3.0, 100.0):
            h2.observe(v)
        assert h2.snapshot() == h.snapshot()

    def test_histogram_rejects_unsorted(self):
        with pytest.raises(ValueError):
            Histogram(buckets=(2.0, 1.0))

    def test_registry_kind_and_bucket_conflicts(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")
        reg.histogram("h", buckets=(1.0, 2.0))
        with pytest.raises(ValueError):
            reg.histogram("h", buckets=(1.0, 3.0))
        # get-or-create is idempotent
        assert reg.counter("x") is reg.counter("x")

    def test_lift_network_and_dispatch(self):
        reg = MetricsRegistry()
        net = Network()
        net.send("a", "b", b"payload", 7)
        lift_network(reg, net)
        lift_dispatch_stats(reg)
        snap = reg.snapshot()
        assert snap["net.bytes_sent"]["value"] == 7
        assert snap["net.msgs_sent"]["value"] == 1
        assert "kernels.dot_seen.launches" in snap
        assert "kernels.dot_seen.rows" in snap


# ============================================================== wire billing
class TestWireBilling:
    def test_send_requires_billing_nonempty(self):
        net = Network()
        with pytest.raises(ValueError):
            net.send("a", "b", b"not empty", 0)

    def test_send_allows_empty_control_payloads(self):
        net = Network()
        net.send("a", "b", None, 0)
        net.send("a", "b", b"", 0)
        assert net.msgs_sent == 2 and net.bytes_sent == 0


# ===================================================== end-to-end span trees
def diverge(cluster, elements):
    """Insert ``elements`` on vnode0 only — quorum queries must read-repair."""
    for el in elements:
        cluster.vnodes["vnode0"].coordinate_insert(SET, el, ())


class TestTracedQuery:
    def test_single_tree_covers_every_layer(self):
        """The acceptance check: one traced quorum query exports ONE span
        tree covering serve -> executor -> storage -> kernel -> network ->
        read-repair."""
        tr, cluster, service, client = build_traced()
        client.batch(SET, [["add", b"r%02d" % i] for i in range(5)])
        diverge(cluster, [b"x%02d" % i for i in range(3)])
        tr.clear()  # keep only the query's spans

        page = client.query(Scan(SET, page_size=100))
        assert len(page.entries) == 8

        spans = tr.drain()
        trees = span_trees(spans)
        assert len(trees) == 1, "one request, one trace"
        (tree,) = trees.values()
        assert tree["orphans"] == []
        assert [r.name for r in tree["roots"]] == ["serve.request"]

        names = tree_names(spans)
        assert names["serve.request"] == 1
        assert names["cluster.query"] == 1          # executor scatter
        assert names["replica.coverage"] == 2       # majority quorum of 3
        assert names["storage.scan"] == 2           # one per covered replica
        assert names["kernel.dot_seen"] == 1        # per-query summary
        assert names["query.read_repair"] == 3      # one per replayed element
        assert names["net.deliver"] == 3            # each replay delivered

    def test_read_repair_spans_carry_replay_counts(self):
        tr, cluster, service, client = build_traced()
        client.batch(SET, [["add", b"a"]])
        diverge(cluster, [b"solo"])
        tr.clear()
        client.query(Scan(SET, page_size=100))
        repairs = [s for s in tr.spans if s.name == "query.read_repair"]
        assert len(repairs) == 1
        assert repairs[0].attrs["replayed"] == 1
        assert repairs[0].attrs["element"] == b"solo"
        # its net.deliver child parents on it, not on the query span
        delivers = [s for s in tr.spans if s.name == "net.deliver"]
        assert {d.parent_id for d in delivers} == {repairs[0].span_id}

    def test_membership_query_tree(self):
        tr, cluster, service, client = build_traced()
        client.batch(SET, [["add", b"present"]])
        tr.clear()
        page = client.query(Membership(SET, b"present"))
        assert page.present
        names = tree_names(tr.spans)
        assert names["serve.request"] == 1
        assert names["cluster.query"] == 1
        assert names["replica.coverage"] == 2

    @given(st.sampled_from([0.0, 0.15, 0.3]), st.sampled_from([0.0, 0.2]),
           st.booleans(), st.integers(0, 2 ** 16))
    @settings(max_examples=25, deadline=None)
    def test_tree_integrity_under_lossy_delivery(self, drop, dup, reorder,
                                                 seed):
        """Property: every replica sub-span of a traced quorum query parents
        under the coordinator root across drop/duplicate/reorder schedules —
        lossy delivery loses leaves, never tree integrity."""
        net = Network(seed=seed, drop_prob=drop, dup_prob=dup,
                      reorder=reorder)
        tr, cluster, service, client = build_traced(net=net)
        client.batch(SET, [["add", b"e%02d" % i] for i in range(6)])
        tr.clear()
        client.query(Scan(SET, page_size=100))

        trees = span_trees(tr.spans)
        assert len(trees) == 1
        (tree,) = trees.values()
        assert tree["orphans"] == []
        assert [r.name for r in tree["roots"]] == ["serve.request"]
        names = tree_names(tr.spans)
        # the synchronous skeleton is delivery-independent ...
        assert names["cluster.query"] == 1
        assert names["replica.coverage"] == 2
        assert names["storage.scan"] == 2
        assert names["kernel.dot_seen"] == 1
        # ... and whatever repair traffic was delivered landed in-tree
        assert names.get("net.deliver", 0) + len(tree["orphans"]) == \
            sum(1 for s in tr.spans if s.name == "net.deliver")

    def test_antientropy_round_spans(self):
        tr, cluster, service, client = build_traced()
        client.batch(SET, [["add", b"a"], ["add", b"b"]])
        tr.clear()
        assert cluster.tick(budget=1) == 1
        names = {s.name for s in tr.spans}
        assert {"ae.round", "ae.pull", "net.deliver"} <= names
        trees = span_trees(tr.spans)
        for tree in trees.values():
            assert tree["orphans"] == []

    def test_converged_pair_zero_fold_with_tracing(self):
        """Tracing on must not disturb the PR-5 zero-fold property: a
        converged pair syncs from digests alone (no keys folded)."""
        tr, cluster, service, client = build_traced()
        client.batch(SET, [["add", b"e%02d" % i] for i in range(8)])
        cluster.settle()
        cluster.tick(budget=4)
        stats = cluster.ae_stats()
        assert stats.keys_scanned == 0
        assert stats.rounds_skipped > 0
        assert any(s.name == "ae.round" for s in tr.spans)


# ============================================================ disabled = noop
class TestDisabledNoop:
    def workload(self, tracer):
        net = Network(seed=42)
        cluster = BigsetCluster(3, net=net, sync=True, tracer=tracer)
        service = BigsetService(cluster, clock=ticking_clock(step=0.001))
        client = BigsetClient(service)
        client.batch(SET, [["add", b"w%02d" % i] for i in range(10)])
        client.batch(SET, [["remove", b"w03"]])
        page = client.query(Scan(SET, page_size=100))
        return net, [e for e, _ in page.entries]

    def test_wire_traffic_byte_identical(self):
        """Invariant 10: tracing disabled is a strict no-op — the disabled
        run ships byte-identical traffic because payloads are never
        wrapped, and the traced run bills identical sizes because the
        TracedPayload context rides outside ``size_bytes``."""
        net_off, entries_off = self.workload(None)  # NULL_TRACER default
        net_on, entries_on = self.workload(Tracer(clock=ticking_clock()))
        assert entries_off == entries_on
        assert net_off.bytes_sent == net_on.bytes_sent
        assert net_off.msgs_sent == net_on.msgs_sent

    def test_disabled_cluster_never_wraps_payloads(self):
        captured = []
        net = Network()
        orig = net.send

        def spy(src, dst, payload, size_bytes):
            captured.append(payload)
            orig(src, dst, payload, size_bytes)

        net.send = spy
        cluster = BigsetCluster(3, net=net, sync=True)  # tracing off
        cluster.add(SET, b"el")
        cluster.query(Scan(SET, page_size=10))
        assert captured and not any(
            isinstance(p, TracedPayload) for p in captured)


# ================================================================= exporters
class TestExporters:
    def make_spans(self):
        tr, cluster, service, client = build_traced()
        client.batch(SET, [["add", b"a"], ["add", b"b"]])
        diverge(cluster, [b"c"])
        tr.clear()
        client.query(Scan(SET, page_size=100))
        return tr.drain()

    def test_jsonl_round_trip(self):
        spans = self.make_spans()
        lines = spans_to_jsonl(spans).splitlines()
        assert len(lines) == len(spans)
        parsed = [json.loads(ln) for ln in lines]
        ids = {p["span_id"] for p in parsed}
        for p in parsed:
            assert p["parent_id"] is None or p["parent_id"] in ids
        assert any(p["name"] == "serve.request" for p in parsed)

    def test_chrome_trace_round_trip(self):
        """The CI smoke check in library form: a Chrome trace-event export
        re-parses into >= 1 complete span tree."""
        spans = self.make_spans()
        doc = json.loads(json.dumps(spans_to_chrome(spans)))
        events = doc["traceEvents"]
        assert len(events) == len(spans)
        ids = {e["args"]["span_id"] for e in events}
        roots = 0
        for e in events:
            assert e["ph"] == "X"
            assert e["dur"] >= 0
            parent = e["args"]["parent_id"]
            assert parent is None or parent in ids
            roots += parent is None
        assert roots >= 1

    def test_bytes_attrs_are_json_safe(self):
        tr = Tracer(clock=ticking_clock())
        tr.finish(tr.start("s", set_name=b"\xff\xfe", pair=[b"a", b"b"]))
        doc = json.loads(spans_to_jsonl(tr.spans))
        assert doc["attrs"]["pair"] == ["a", "b"]
        assert isinstance(doc["attrs"]["set_name"], str)


# ================================================================== stats op
class TestStatsOp:
    def test_stats_snapshot_node_and_session(self):
        tr, cluster, service, client = build_traced()
        client.batch(SET, [["add", b"s%02d" % i] for i in range(4)])
        client.query(Scan(SET, page_size=100))
        out = client.stats()
        node, session = out["node"], out["session"]
        for name in ("storage.bytes_read", "net.bytes_sent",
                     "kernels.dot_seen.launches", "antientropy.rounds",
                     "serve.sessions", "query.bytes_read"):
            assert name in node, name
        assert node["serve.requests"]["type"] == "counter"
        assert node["serve.requests"]["value"] >= 3  # batch, query, stats
        assert node["serve.request_seconds"]["type"] == "histogram"
        assert node["serve.request_seconds"]["count"] >= 3
        assert session["mutations"] == 4
        assert session["pages"] == 1
        assert session["bytes_read"] > 0

    def test_session_stats_isolated_per_session(self):
        tr, cluster, service, client_a = build_traced()
        client_b = BigsetClient(service)
        client_a.batch(SET, [["add", b"a"]])
        client_b.batch(SET, [["add", b"b"], ["add", b"c"]])
        assert client_a.stats()["session"]["mutations"] == 1
        assert client_b.stats()["session"]["mutations"] == 2

    def test_metrics_deterministic_under_injected_clocks(self):
        def run():
            tr, cluster, service, client = build_traced()
            client.batch(SET, [["add", b"d%02d" % i] for i in range(3)])
            client.query(Scan(SET, page_size=100))
            snap = service.metrics.snapshot()
            # dispatch gauges track a process-global ledger — not a
            # per-run quantity, so exclude them from the equality check
            return {k: v for k, v in snap.items()
                    if not k.startswith("kernels.")}

        assert run() == run()
