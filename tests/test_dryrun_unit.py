"""Dry-run machinery unit tests (collective parsing, rules, specs) — the
full 512-device sweep runs via launch/dryrun.py; here we validate the
analysis plumbing on synthetic HLO and a subprocess smoke cell."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.launch.dryrun import parse_collectives
from repro.launch.mesh import HW

HLO = """
  %all-reduce.1 = f32[32,64]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[4,2]<=[2,4]T(1,0), use_global_device_ids=true, to_apply=%add
  %ag = bf16[128,256]{1,0} all-gather(%p0), channel_id=2, replica_groups=[16,16]<=[256], dimensions={0}
  %rs = bf16[8,256]{1,0} reduce-scatter(%p1), channel_id=3, replica_groups=[16,16]<=[256], to_apply=%add
  %cp = f32[64]{0} collective-permute(%p2), source_target_pairs={{0,1}}
  %aa = bf16[4,4]{1,0} all-to-all(%p3), replica_groups={{0,1,2,3}}
"""


class TestCollectiveParse:
    def test_parses_all_ops(self):
        colls = parse_collectives(HLO)
        ops = sorted(c["op"] for c in colls)
        assert ops == ["all-gather", "all-reduce", "all-to-all",
                       "collective-permute", "reduce-scatter"]

    def test_ring_cost_model(self):
        colls = {c["op"]: c for c in parse_collectives(HLO)}
        ar = colls["all-reduce"]
        assert ar["group"] == 2
        assert ar["result_bytes"] == 32 * 64 * 4
        assert ar["moved_bytes"] == pytest.approx(2 * 32 * 64 * 4 * 0.5)
        ag = colls["all-gather"]
        assert ag["group"] == 16
        assert ag["moved_bytes"] == pytest.approx(128 * 256 * 2 * 15 / 16)
        rs = colls["reduce-scatter"]
        assert rs["moved_bytes"] == pytest.approx(8 * 256 * 2 * 15)
        assert colls["all-to-all"]["group"] == 4

    def test_hw_constants(self):
        assert HW["peak_flops_bf16"] == 197e12
        assert HW["hbm_bw"] == 819e9


class TestArtifacts:
    ART = Path(__file__).resolve().parents[1] / "benchmarks" / "artifacts" / "dryrun"

    def test_existing_artifacts_are_wellformed(self):
        if not self.ART.exists():
            pytest.skip("no dry-run artifacts yet")
        recs = [json.loads(p.read_text()) for p in self.ART.glob("*.json")]
        if not recs:
            pytest.skip("no dry-run artifacts yet")
        for r in recs:
            assert "arch" in r and "shape" in r
            if "skipped" in r:
                continue
            rl = r["roofline"]
            assert rl["t_compute_s"] >= 0 and rl["t_memory_s"] > 0
            assert rl["dominant"] in ("compute", "memory", "collective")
            assert 0 <= rl["roofline_fraction"] <= 1.2
