"""Anti-entropy, handoff, and convergence under adversarial delivery.

The paper defers AE to future work; DESIGN.md documents our protocol.  These
tests are the proof obligations: replicas converge to equal read values
under message drop/duplication/reordering once AE runs, removals propagate
even after the remover has *compacted* the removal away, and handoff moves
a set wholesale to a fresh vnode.
"""
from hypothesis import given, settings, strategies as st

from repro.cluster.antientropy import handoff, survivors_digest, sync, trim_tombstone
from repro.cluster.clusters import BigsetCluster
from repro.cluster.sim import Network
from repro.core.bigset import BigsetVnode

S = b"s"
ELEMS = [b"a1", b"b2", b"c3", b"d4"]

op_st = st.tuples(
    st.sampled_from(["add", "rem"]), st.integers(0, 2), st.sampled_from(ELEMS)
)
ops_st = st.lists(op_st, max_size=20)


def run_ops(big, ops):
    for kind, coord, elem in ops:
        if kind == "add":
            _, ctx = big.vnodes[big.actors[coord]].is_member(S, elem)
            big.add(S, elem, coord, ctx)
        else:
            big.remove(S, elem, coord)


class TestSync:
    def test_basic_bidirectional(self):
        a, b = BigsetVnode("a"), BigsetVnode("b")
        a.coordinate_insert(S, b"x")
        b.coordinate_insert(S, b"y")
        sync(a, b, S)
        assert a.value(S) == b.value(S) == {b"x", b"y"}

    def test_removal_propagates_after_compaction(self):
        """The hard case: remover compacted, tombstone subtracted, yet the
        removal must still reach the peer (via survivor inference)."""
        a, b = BigsetVnode("a"), BigsetVnode("b")
        d = a.coordinate_insert(S, b"x")
        b.replica_insert(d)
        _, ctx = a.is_member(S, b"x")
        a.coordinate_remove(S, ctx)
        a.compact()
        assert a.read_tombstone(S).is_zero()  # removal info only in SC+absence
        sync(b, a, S)
        assert b.value(S) == set()

    def test_no_resurrection(self):
        """A removed element must not come back via AE from a stale peer."""
        a, b = BigsetVnode("a"), BigsetVnode("b")
        d = a.coordinate_insert(S, b"x")
        b.replica_insert(d)
        _, ctx = a.is_member(S, b"x")
        a.coordinate_remove(S, ctx)
        a.compact()
        sync(a, b, S)  # stale b syncs with a
        assert a.value(S) == set() and b.value(S) == set()

    def test_concurrent_adds_both_survive(self):
        a, b = BigsetVnode("a"), BigsetVnode("b")
        a.coordinate_insert(S, b"x")
        b.coordinate_insert(S, b"x")
        sync(a, b, S)
        assert a.value(S) == b.value(S) == {b"x"}
        # both dots survive (concurrent adds, neither superseded)
        assert len(list(a.fold(S))) == 2

    @given(ops_st)
    @settings(max_examples=40, deadline=None)
    def test_pairwise_sync_converges(self, ops):
        big = BigsetCluster(3, sync=False)  # ops never replicated
        run_ops(big, ops)
        big.net.queue.clear()  # drop ALL replication traffic
        vns = list(big.vnodes.values())
        for _ in range(2):  # two rounds of ring gossip
            sync(vns[0], vns[1], S)
            sync(vns[1], vns[2], S)
            sync(vns[2], vns[0], S)
        vals = [vn.value(S) for vn in vns]
        assert vals[0] == vals[1] == vals[2]

    @given(ops_st, st.integers(0, 2**32 - 1))
    @settings(max_examples=30, deadline=None)
    def test_converges_under_drop_dup_reorder(self, ops, seed):
        net = Network(seed=seed, drop_prob=0.3, dup_prob=0.3, reorder=True)
        big = BigsetCluster(3, net=net, sync=False)
        run_ops(big, ops)
        big.settle()  # deliver what survived (reordered, duplicated)
        vns = list(big.vnodes.values())
        for _ in range(2):
            sync(vns[0], vns[1], S)
            sync(vns[1], vns[2], S)
            sync(vns[2], vns[0], S)
        assert vns[0].value(S) == vns[1].value(S) == vns[2].value(S)


class TestHandoff:
    def test_handoff_to_empty_vnode(self):
        a = BigsetVnode("a")
        for e in ELEMS:
            a.coordinate_insert(S, e)
        _, ctx = a.is_member(S, ELEMS[0])
        a.coordinate_remove(S, ctx)
        fresh = BigsetVnode("z")
        handoff(a, fresh, S)
        assert fresh.value(S) == a.value(S) == set(ELEMS[1:])

    def test_handoff_idempotent(self):
        a = BigsetVnode("a")
        a.coordinate_insert(S, b"x")
        fresh = BigsetVnode("z")
        assert handoff(a, fresh, S) == 1
        assert handoff(a, fresh, S) == 0  # second transfer writes nothing
        assert fresh.value(S) == {b"x"}


class TestTombstoneHygiene:
    def test_trim_unbacked_tombstone_dots(self):
        a, b = BigsetVnode("a"), BigsetVnode("b")
        d = a.coordinate_insert(S, b"x")
        # b tombstones the dot via a remove ctx without ever having the key
        from repro.core.bigset import RemoveDelta

        b.replica_insert(d)
        _, ctx = b.is_member(S, b"x")
        b.coordinate_remove(S, ctx)
        b.compact()
        assert b.read_tombstone(S).is_zero()
        trim_tombstone(b, S)
        assert b.read_tombstone(S).is_zero()

    def test_survivors_digest_compresses(self):
        vn = BigsetVnode("a")
        for i in range(100):
            vn.coordinate_insert(S, b"e%03d" % i)
        dig = survivors_digest(vn, S)
        # 100 contiguous dots from one actor -> a single base VV entry
        assert dig.base == {"a": 100} and not dig.cloud
