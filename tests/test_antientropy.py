"""Anti-entropy, handoff, and convergence under adversarial delivery.

The paper defers AE to future work; DESIGN.md documents our protocol.  These
tests are the proof obligations: replicas converge to equal read values
under message drop/duplication/reordering once AE runs, removals propagate
even after the remover has *compacted* the removal away, and handoff moves
a set wholesale to a fresh vnode.
"""
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.antientropy import (build_digest_reply, full_sync, handoff,
                                       survivors_digest, sync, sync_pull,
                                       trim_tombstone)
from repro.cluster.clusters import BigsetCluster
from repro.cluster.sim import DeliveryBudget, Network
from repro.core.bigset import BigsetVnode
from repro.query.plan import Range

S = b"s"
ELEMS = [b"a1", b"b2", b"c3", b"d4"]

op_st = st.tuples(
    st.sampled_from(["add", "rem"]), st.integers(0, 2), st.sampled_from(ELEMS)
)
ops_st = st.lists(op_st, max_size=20)


def run_ops(big, ops):
    for kind, coord, elem in ops:
        if kind == "add":
            _, ctx = big.vnodes[big.actors[coord]].is_member(S, elem)
            big.add(S, elem, coord, ctx)
        else:
            big.remove(S, elem, coord)


class TestSync:
    def test_basic_bidirectional(self):
        a, b = BigsetVnode("a"), BigsetVnode("b")
        a.coordinate_insert(S, b"x")
        b.coordinate_insert(S, b"y")
        sync(a, b, S)
        assert a.value(S) == b.value(S) == {b"x", b"y"}

    def test_removal_propagates_after_compaction(self):
        """The hard case: remover compacted, tombstone subtracted, yet the
        removal must still reach the peer (via survivor inference)."""
        a, b = BigsetVnode("a"), BigsetVnode("b")
        d = a.coordinate_insert(S, b"x")
        b.replica_insert(d)
        _, ctx = a.is_member(S, b"x")
        a.coordinate_remove(S, ctx)
        a.compact()
        assert a.read_tombstone(S).is_zero()  # removal info only in SC+absence
        sync(b, a, S)
        assert b.value(S) == set()

    def test_no_resurrection(self):
        """A removed element must not come back via AE from a stale peer."""
        a, b = BigsetVnode("a"), BigsetVnode("b")
        d = a.coordinate_insert(S, b"x")
        b.replica_insert(d)
        _, ctx = a.is_member(S, b"x")
        a.coordinate_remove(S, ctx)
        a.compact()
        sync(a, b, S)  # stale b syncs with a
        assert a.value(S) == set() and b.value(S) == set()

    def test_concurrent_adds_both_survive(self):
        a, b = BigsetVnode("a"), BigsetVnode("b")
        a.coordinate_insert(S, b"x")
        b.coordinate_insert(S, b"x")
        sync(a, b, S)
        assert a.value(S) == b.value(S) == {b"x"}
        # both dots survive (concurrent adds, neither superseded)
        assert len(list(a.fold(S))) == 2

    @given(ops_st)
    @settings(max_examples=40, deadline=None)
    def test_pairwise_sync_converges(self, ops):
        big = BigsetCluster(3, sync=False)  # ops never replicated
        run_ops(big, ops)
        big.net.queue.clear()  # drop ALL replication traffic
        vns = list(big.vnodes.values())
        for _ in range(2):  # two rounds of ring gossip
            sync(vns[0], vns[1], S)
            sync(vns[1], vns[2], S)
            sync(vns[2], vns[0], S)
        vals = [vn.value(S) for vn in vns]
        assert vals[0] == vals[1] == vals[2]

    @given(ops_st, st.integers(0, 2**32 - 1))
    @settings(max_examples=30, deadline=None)
    def test_converges_under_drop_dup_reorder(self, ops, seed):
        net = Network(seed=seed, drop_prob=0.3, dup_prob=0.3, reorder=True)
        big = BigsetCluster(3, net=net, sync=False)
        run_ops(big, ops)
        big.settle()  # deliver what survived (reordered, duplicated)
        vns = list(big.vnodes.values())
        for _ in range(2):
            sync(vns[0], vns[1], S)
            sync(vns[1], vns[2], S)
            sync(vns[2], vns[0], S)
        assert vns[0].value(S) == vns[1].value(S) == vns[2].value(S)


class TestHandoff:
    def test_handoff_to_empty_vnode(self):
        a = BigsetVnode("a")
        for e in ELEMS:
            a.coordinate_insert(S, e)
        _, ctx = a.is_member(S, ELEMS[0])
        a.coordinate_remove(S, ctx)
        fresh = BigsetVnode("z")
        handoff(a, fresh, S)
        assert fresh.value(S) == a.value(S) == set(ELEMS[1:])

    def test_handoff_idempotent(self):
        a = BigsetVnode("a")
        a.coordinate_insert(S, b"x")
        fresh = BigsetVnode("z")
        assert handoff(a, fresh, S) == 1
        assert handoff(a, fresh, S) == 0  # second transfer writes nothing
        assert fresh.value(S) == {b"x"}


class TestTombstoneHygiene:
    def test_trim_unbacked_tombstone_dots(self):
        a, b = BigsetVnode("a"), BigsetVnode("b")
        d = a.coordinate_insert(S, b"x")
        # b tombstones the dot via a remove ctx without ever having the key
        from repro.core.bigset import RemoveDelta

        b.replica_insert(d)
        _, ctx = b.is_member(S, b"x")
        b.coordinate_remove(S, ctx)
        b.compact()
        assert b.read_tombstone(S).is_zero()
        trim_tombstone(b, S)
        assert b.read_tombstone(S).is_zero()

    def test_survivors_digest_compresses(self):
        vn = BigsetVnode("a")
        for i in range(100):
            vn.coordinate_insert(S, b"e%03d" % i)
        dig = survivors_digest(vn, S)
        # 100 contiguous dots from one actor -> a single base VV entry
        assert dig.base == {"a": 100} and not dig.cloud


class TestDigestSync:
    """The digest ladder: skip-when-converged at O(causal metadata), fold
    only diverged subranges otherwise, same convergence as the full fold."""

    def _pair(self, n=400, bucket_limit=64):
        a = BigsetVnode("a", digest_bucket_limit=bucket_limit)
        b = BigsetVnode("b", digest_bucket_limit=bucket_limit)
        for i in range(n):
            b.replica_insert(a.coordinate_insert(S, b"e%05d" % i))
        return a, b

    def test_converged_round_zero_element_folds(self):
        """Regression: a converged pair's sync round must not fold element
        keys at all — digest bytes only (num_seeks counts every fold/scan
        positioning, so zero seeks == zero folds)."""
        a, b = self._pair()
        sync(a, b, S)  # idempotent warm-up (already converged)
        seeks = (a.store.stats.num_seeks, b.store.stats.num_seeks)
        r1 = sync_pull(a, b, S)
        r2 = sync_pull(b, a, S)
        assert r1.skipped and r2.skipped
        assert r1.keys_scanned == 0 == r2.keys_scanned
        assert (a.store.stats.num_seeks, b.store.stats.num_seeks) == seeks

    def test_diverged_sync_scans_only_diverged_subranges(self):
        a, b = self._pair(n=2000, bucket_limit=64)
        k = 20
        for i in range(k):  # contiguous divergent writes at a only
            a.coordinate_insert(S, b"zz%04d" % i)
        reply = build_digest_reply(
            a, S, b.read_clock(S), survivors_digest(b, S))
        assert len(reply.missing) == k            # ships exactly O(k) keys
        assert reply.keys_scanned < 2000 // 4     # not the whole set
        sync(a, b, S)
        assert a.value(S) == b.value(S)
        assert sync_pull(b, a, S).skipped         # and now it's digest-only

    def test_sync_converges_removals_without_resurrect(self):
        a, b = self._pair(n=50)
        _, ctx = a.is_member(S, b"e00007")
        a.coordinate_remove(S, ctx)
        a.compact()  # removal only visible via clock + absence
        sync(a, b, S)
        assert a.value(S) == b.value(S)
        assert b"e00007" not in b.value(S)

    @given(ops_st)
    @settings(max_examples=25, deadline=None)
    def test_digest_sync_equals_full_sync(self, ops):
        def converge(sync_fn):
            big = BigsetCluster(3, sync=False)
            run_ops(big, ops)
            big.net.queue.clear()
            vns = list(big.vnodes.values())
            for _ in range(2):
                sync_fn(vns[0], vns[1], S)
                sync_fn(vns[1], vns[2], S)
                sync_fn(vns[2], vns[0], S)
            return [vn.value(S) for vn in vns]
        digest_vals = converge(sync)
        full_vals = converge(full_sync)
        assert digest_vals == full_vals
        assert digest_vals[0] == digest_vals[1] == digest_vals[2]


class TestScheduledAntiEntropy:
    """tick() closes the loop: repair hits prioritise, baseline round-robin
    converges everyone (including replicas no read quorum ever touches),
    and every message rides the lossy simulated network."""

    def test_non_quorum_replica_converges_via_ticks(self):
        big = BigsetCluster(3, sync=False)
        for e in ELEMS:
            big.add(S, e)
        big.remove(S, ELEMS[0])
        big.net.queue.clear()          # replicas 1, 2 never saw replication
        big.query(Range(S, None, None), r=2)   # read repair heals the quorum
        big.settle()
        assert big.ae_stats().repair_hits > 0
        assert big.vnodes["vnode2"].value(S) == frozenset()  # outside quorum
        for _ in range(4):
            big.tick()
            big.settle()
        expect = set(ELEMS[1:])
        assert all(vn.value(S) == expect for vn in big.vnodes.values())
        assert big.ae_stats().keys_shipped >= len(expect)

    def test_repair_hits_feed_and_decay(self):
        big = BigsetCluster(3, sync=False)
        big.add(S, b"x")
        big.net.queue.clear()
        big.query(Range(S, None, None), r=2)
        big.settle()
        hot = big.scheduler.hot_pairs()
        assert hot and hot[0][0] == S and hot[0][1] == ("vnode0", "vnode1")
        assert big.scheduler.next_rounds(budget=1) == [(S, "vnode0", "vnode1")]
        for _ in range(8):  # quiescent: no new hits, scores cool off
            big.scheduler.next_rounds(budget=0)
        assert not big.scheduler.hot_pairs()

    def test_converged_cluster_ticks_are_digest_only(self):
        big = BigsetCluster(3)
        for e in ELEMS:
            big.add(S, e)
        big.tick()  # joins any straggling clock state
        before = [big.vnodes[a].store.stats.num_seeks for a in big.actors]
        big.tick(budget=3)
        s = big.ae_stats()
        assert s.rounds_skipped > 0
        assert [big.vnodes[a].store.stats.num_seeks
                for a in big.actors] == before
        assert s.keys_scanned == 0

    @given(ops_st, st.integers(0, 2**32 - 1))
    @settings(max_examples=25, deadline=None)
    def test_ticks_converge_under_drop_dup_reorder(self, ops, seed):
        net = Network(seed=seed, drop_prob=0.25, dup_prob=0.25, reorder=True)
        big = BigsetCluster(3, net=net, sync=False)
        run_ops(big, ops)
        big.settle()  # deliver what survived (reordered, duplicated)
        for _ in range(14):
            big.tick(budget=3)
            big.settle()
        vns = list(big.vnodes.values())
        assert vns[0].value(S) == vns[1].value(S) == vns[2].value(S)


class TestSyncPathBugfixes:
    def test_deliver_all_raises_on_budget_with_leftovers(self):
        """Silently returning with queued traffic made settle() lie."""
        net = Network()
        for i in range(5):
            net.send("a", "b", i, 8)
        with pytest.raises(DeliveryBudget):
            net.deliver_all(lambda m: None, max_steps=3)
        assert net.pending() == 2  # leftovers stay queued, not dropped

    def test_repair_skips_dot_without_donor_payload(self):
        """A repair that cannot source the value must skip the dot (and
        count it) rather than fabricate an empty payload that downstream
        replica_insert would index."""
        from repro.core.bigset import element_key

        big = BigsetCluster(3, sync=False)
        d = big.add(S, b"x", value=b"payload")
        big.net.queue.clear()
        # sabotage: the donor's key vanishes between stream and repair
        big.vnodes["vnode0"].store.delete(element_key(S, b"x", d.dot))
        clocks = [big.vnodes[a].read_clock(S) for a in big.actors]
        per_stream = [frozenset([d.dot]), None, None]
        big._repair(S, b"x", [d.dot], per_stream, clocks, big.actors)
        assert big.net.pending() == 0          # nothing fabricated
        assert big.ae_stats().repair_no_donor == 1

    def test_apply_reply_skips_trim_when_tombstone_unchanged(self):
        a, b = BigsetVnode("a"), BigsetVnode("b")
        b.replica_insert(a.coordinate_insert(S, b"x"))
        calls = []
        orig_put = b.store.put

        def counting_put(key, value):
            calls.append(key)
            return orig_put(key, value)

        b.store.put = counting_put
        full_sync(a, b, S)  # converged full sync: tombstones untouched
        # trim_tombstone writes via store.put; no trim means no put calls
        assert calls == []
