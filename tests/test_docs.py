"""Documentation is executable: the cookbook runs green, links resolve.

CI has a dedicated docs job running the same runners from the command
line; this module puts them in tier-1 too, so a change that breaks a
documented request (or renames a file a doc points at) fails the ordinary
test suite, not just a separate pipeline.
"""
import importlib.util
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"


def _load(name: str):
    spec = importlib.util.spec_from_file_location(name, DOCS / f"{name}.py")
    module = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(module)
    return module


def test_query_cookbook_executes_green(capsys):
    runner = _load("run_cookbook")
    blocks = runner.run_file(DOCS / "QUERY_COOKBOOK.md")
    # one block per documented feature: setup, 7 plan shapes, backpressure,
    # write path, raw envelope — shrinking this page needs a deliberate edit
    assert blocks >= 11


def test_markdown_links_resolve():
    checker = _load("check_links")
    files = checker.collect([REPO / "README.md", DOCS])
    assert len(files) >= 3
    broken = {str(f): checker.broken_links(f) for f in files}
    assert not {f: b for f, b in broken.items() if b}


def test_architecture_names_real_modules():
    """Every `src/...` path ARCHITECTURE.md cites must exist."""
    import re

    text = (DOCS / "ARCHITECTURE.md").read_text()
    cited = set(re.findall(r"`(src/[\w/.]+?\.py)`", text))
    cited |= {p.rstrip("/") for p in re.findall(r"`(src/[\w/]+/)`", text)}
    assert cited, "ARCHITECTURE.md cites no modules?"
    missing = [p for p in sorted(cited) if not (REPO / p).exists()]
    assert not missing, missing
