"""Secondary indexes under CRDT clocks, plus the write-path/stat bugfixes.

The index consistency argument is one line — *a posting is live iff its dot
is live* — so the tests drive it from every side: postings against
brute-force extractor truth under concurrent ops and partial replication,
removes making postings invisible with zero index writes, compaction
discarding dead postings in the same pass as their element-keys, cursor
resumption across a compaction, quorum merge + read repair, and the paper's
cost claim extended to index scans: O(matches + causal metadata) bytes.

Also covers this PR's satellite fixes: byte-idempotent redelivery of
deltas, `QueryStats` accounting for Count/Membership, and the
`decode_element_key` hard error (exception-based, so it still fails under
``python -O``).
"""
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.clusters import BigsetCluster
from repro.cluster.sim import Network
from repro.core.bigset import (BigsetVnode, decode_element_key, element_key,
                               clock_key)
from repro.core.dots import Dot
from repro.index import (IndexSpec, by_element_suffix, by_field, by_value,
                         decode_posting_key, index_range, posting_key)
from repro.query import (Count, IndexLookup, IndexRange, Membership,
                         PlanError, QueryExecutor, Range, Scan, validate)
from repro.storage.lsm import LsmStore

S = b"iset"
ELEMS = [b"ant", b"bee", b"cat", b"cow", b"dog", b"eel", b"fox", b"gnu"]
# index on the first element byte: a coarse, collision-rich extractor that
# exercises grouping (many elements per index key)
HEAD = IndexSpec(b"head", lambda el, v: (el[:1],))

ops_st = st.lists(
    st.tuples(
        st.sampled_from(["add", "rem"]),
        st.integers(0, 2),
        st.sampled_from(ELEMS),
    ),
    max_size=24,
)


def apply_ops(cluster, ops, set_name=S):
    for op, coord, el in ops:
        if op == "add":
            cluster.add(set_name, el, coordinator=coord,
                        value=b"v:" + el)
        else:
            cluster.remove(set_name, el, coordinator=coord)


def index_truth(vn, spec, set_name=S):
    """Brute force: (index_key, element) groups with their surviving dots."""
    dots_of = {}
    groups = set()
    for el, dot, v in vn.fold_values(set_name):
        dots_of.setdefault(el, set()).add(dot)
        for ik in spec.keys(el, v):
            groups.add((ik, el))
    return sorted(
        (ik, el, tuple(sorted(dots_of[el]))) for ik, el in groups)


# ------------------------------------------------------------ posting truth
class TestIndexCorrectness:
    @given(ops_st)
    @settings(max_examples=40, deadline=None)
    def test_index_scan_matches_extractor_truth(self, ops):
        c = BigsetCluster(3)
        c.register_index(S, HEAD)
        apply_ops(c, ops)
        for a in c.actors:
            vn = c.vnodes[a]
            res = QueryExecutor(vn).execute(IndexRange(S, HEAD.name))
            assert res.index_entries == index_truth(vn, HEAD)

    @given(ops_st, st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_under_partial_reordered_replication(self, ops, seed):
        net = Network(seed=seed, reorder=True)
        c = BigsetCluster(3, net=net, sync=False)
        c.register_index(S, HEAD)
        apply_ops(c, ops)
        for _ in range(net.pending() // 2):
            net.deliver_one(c._handle)
        for a in c.actors:
            vn = c.vnodes[a]
            res = QueryExecutor(vn).execute(IndexRange(S, HEAD.name))
            assert res.index_entries == index_truth(vn, HEAD)

    @given(ops_st)
    @settings(max_examples=25, deadline=None)
    def test_backfill_equals_write_path(self, ops):
        """Registering after the writes must index exactly what registering
        before them would have."""
        before, after = BigsetCluster(3), BigsetCluster(3)
        before.register_index(S, HEAD)
        apply_ops(before, ops)
        apply_ops(after, ops)
        after.register_index(S, HEAD)
        for a in before.actors:
            r_b = QueryExecutor(before.vnodes[a]).execute(
                IndexRange(S, HEAD.name))
            r_a = QueryExecutor(after.vnodes[a]).execute(
                IndexRange(S, HEAD.name))
            assert r_b.index_entries == r_a.index_entries

    def test_reregistration_replaces_extractor_postings(self):
        """Last registration wins in storage too: postings from a replaced
        extractor are reconciled away, and same-spec re-registration is a
        storage no-op."""
        vn = BigsetVnode("a")
        vn.register_index(S, IndexSpec(b"i", lambda el, v: (b"OLD-" + el[:1],)))
        vn.coordinate_insert(S, b"ant", value=b"x")
        vn.coordinate_insert(S, b"bee", value=b"y")
        vn.register_index(S, IndexSpec(b"i", lambda el, v: (b"NEW-" + el[:1],)))
        res = QueryExecutor(vn).execute(IndexRange(S, b"i"))
        assert [(ik, el) for ik, el, _ in res.index_entries] == [
            (b"NEW-a", b"ant"), (b"NEW-b", b"bee")]
        before = vn.store.stats.snapshot()
        assert vn.register_index(
            S, IndexSpec(b"i", lambda el, v: (b"NEW-" + el[:1],))) == 0
        assert vn.store.stats.delta(before).bytes_written == 0

    def test_multi_valued_and_field_extractors(self):
        import msgpack
        vn = BigsetVnode("a")
        vn.register_index(S, IndexSpec(b"tags", lambda el, v: v.split(b",")))
        vn.register_index(S, by_field(b"color"))
        vn.coordinate_insert(S, b"e1", value=b"hot,new")
        vn.coordinate_insert(
            b"docs", b"d1", value=msgpack.packb({b"color": b"red"}))
        vn.register_index(b"docs", by_field(b"color"))
        ex = QueryExecutor(vn)
        assert ex.execute(IndexLookup(S, b"tags", b"hot")).members == [b"e1"]
        assert ex.execute(IndexLookup(S, b"tags", b"new")).members == [b"e1"]
        assert ex.execute(
            IndexLookup(b"docs", b"field:color", b"red")).members == [b"d1"]

    def test_plan_validation(self):
        with pytest.raises(PlanError):
            validate(IndexLookup(S, b"", b"k"))
        with pytest.raises(PlanError):
            validate(IndexRange(S, b"i", start=b"z", end=b"a"))
        with pytest.raises(PlanError):
            validate(IndexRange(S, b"i", limit=-1))


# ----------------------------------------------------- liveness == dot life
class TestPostingLiveness:
    def test_remove_hides_posting_without_index_write(self):
        """Acceptance: a concurrent remove makes the posting invisible with
        zero index writes — the posting physically stays until compaction."""
        c = BigsetCluster(3)
        c.register_index(S, HEAD)
        for el in ELEMS:
            c.add(S, el, value=b"v:" + el)
        vn = c.vnodes["vnode1"]  # not the coordinator: remove is "remote"
        lo, hi = index_range(S, HEAD.name)

        def postings():
            return [k for k, _ in vn.store.seek(lo, hi)]

        before = postings()
        w_before = vn.store.stats.snapshot()
        c.remove(S, b"cat", coordinator=2)  # concurrent remove, elsewhere
        w = vn.store.stats.delta(w_before)
        # the remove delta is clock-only: the posting keyspace is untouched
        assert postings() == before
        assert w.bytes_written < 300, w.bytes_written  # two small clocks
        res = QueryExecutor(vn).execute(IndexLookup(S, HEAD.name, b"c"))
        assert res.members == [b"cow"]  # cat gone, though its posting remains
        # compaction discards the posting and its element-key together
        vn.compact()
        assert len(postings()) == len(before) - 1
        assert vn.store.get(element_key(S, b"cat", Dot("vnode0", 3))) in (
            None,)  # element keyspace cleaned in the same pass
        res = QueryExecutor(vn).execute(IndexLookup(S, HEAD.name, b"c"))
        assert res.members == [b"cow"]

    @given(ops_st)
    @settings(max_examples=20, deadline=None)
    def test_compaction_never_changes_results(self, ops):
        c = BigsetCluster(3)
        c.register_index(S, HEAD)
        apply_ops(c, ops)
        for a in c.actors:
            vn = c.vnodes[a]
            ex = QueryExecutor(vn)
            pre = ex.execute(IndexRange(S, HEAD.name)).index_entries
            vn.compact()
            assert ex.execute(IndexRange(S, HEAD.name)).index_entries == pre
            # every surviving posting backs a surviving element-key dot
            ts = vn.read_tombstone(S)
            lo, hi = index_range(S, HEAD.name)
            for k, _ in vn.store.seek(lo, hi):
                *_rest, dot = decode_posting_key(k)
                assert not ts.seen(dot)

    def test_cursor_resumes_across_compaction(self):
        """Satellite: postings survive cursor resumption across compaction."""
        vn = BigsetVnode("a", LsmStore(memtable_limit=16))
        vn.register_index(S, HEAD)
        for i in range(60):
            vn.coordinate_insert(S, b"%c%03d" % (97 + i % 5, i))
        for i in range(0, 60, 4):
            _, ctx = vn.is_member(S, b"%c%03d" % (97 + i % 5, i))
            vn.coordinate_remove(S, ctx)
        ex = QueryExecutor(vn)
        one_shot = ex.execute(IndexRange(S, HEAD.name)).index_entries
        paged, cur = [], None
        for page in range(64):
            r = ex.execute(IndexRange(S, HEAD.name, limit=7, cursor=cur))
            paged.extend(r.index_entries)
            cur = r.cursor
            vn.compact()  # compact between every page
            if cur is None:
                break
        assert paged == one_shot

    @given(ops_st, st.integers(1, 5))
    @settings(max_examples=25, deadline=None)
    def test_paged_equals_one_shot(self, ops, page):
        c = BigsetCluster(3)
        c.register_index(S, HEAD)
        apply_ops(c, ops)
        ex = QueryExecutor(c.vnodes["vnode0"])
        one_shot = ex.execute(IndexRange(S, HEAD.name)).index_entries
        paged, cur = [], None
        for _ in range(64):
            r = ex.execute(IndexRange(S, HEAD.name, limit=page, cursor=cur))
            paged.extend(r.index_entries)
            cur = r.cursor
            if cur is None:
                break
        assert paged == one_shot

    def test_limit_zero_cursor_makes_progress(self):
        vn = BigsetVnode("a")
        vn.register_index(S, HEAD)
        for el in ELEMS:
            vn.coordinate_insert(S, el)
        ex = QueryExecutor(vn)
        r = ex.execute(IndexRange(S, HEAD.name, limit=0))
        assert r.entries == [] and r.cursor is not None
        r2 = ex.execute(IndexRange(S, HEAD.name, limit=3, cursor=r.cursor))
        assert r2.members == sorted(ELEMS)[:3]


# ------------------------------------------------------------- cluster path
class TestClusterIndexQuery:
    @given(ops_st)
    @settings(max_examples=20, deadline=None)
    def test_quorum_index_equals_local_truth(self, ops):
        c = BigsetCluster(3)
        c.register_index(S, HEAD)
        apply_ops(c, ops)
        res = c.query(IndexRange(S, HEAD.name), r=3, repair=False)
        assert res.index_entries == index_truth(c.vnodes["vnode0"], HEAD)

    def test_read_repair_rebuilds_missing_postings(self):
        """A straggler that missed every delta gets element-keys replayed by
        an index query; replica_insert re-derives its postings from them."""
        c = BigsetCluster(3, sync=False)
        c.register_index(S, HEAD)
        for i in range(24):
            c.add(S, b"x%03d" % i, coordinator=0, value=b"p%d" % i)
        c.net.queue = [m for m in c.net.queue if m.dst != "vnode2"]
        c.net.deliver_all(c._handle)
        straggler = c.vnodes["vnode2"]
        assert len(straggler.value(S)) == 0
        res = c.query(IndexLookup(S, HEAD.name, b"x"), r=3)
        c.settle()
        assert res.members == [b"x%03d" % i for i in range(24)]
        # the straggler now answers the same index query locally
        local = QueryExecutor(straggler).execute(
            IndexLookup(S, HEAD.name, b"x"))
        assert local.members == [b"x%03d" % i for i in range(24)]
        # and its repaired element-keys carry the original values
        assert {v for _e, _d, v in straggler.fold_values(S)} == {
            b"p%d" % i for i in range(24)}

    def test_quorum_keeps_concurrent_dots_across_index_keys(self):
        """A replica holding the element under a *different* index key must
        still contribute its dots to the merge — quorum index entries carry
        the same causal context a Range query would return."""
        c = BigsetCluster(3, sync=False)
        c.register_index(S, by_value())
        d1 = c.vnodes["vnode0"].coordinate_insert(S, b"el", value=b"v1")
        d2 = c.vnodes["vnode0"].coordinate_insert(S, b"el", value=b"v2")
        c.vnodes["vnode1"].replica_insert(d2)  # vnode1 never sees d1
        res = c.query(IndexLookup(S, b"value", b"v1"), r=2, repair=False)
        truth = c.query(Range(S), r=2, repair=False)
        assert res.entries == truth.entries  # == [(b"el", (d1.dot, d2.dot))]
        assert set(res.entries[0][1]) == {d1.dot, d2.dot}

    def test_antientropy_sync_rebuilds_value_postings(self):
        """Anti-entropy ships values with missing keys, so a synced replica
        re-derives value-dependent postings (not extractor-of-b'')."""
        from repro.cluster.antientropy import sync
        a, b = BigsetVnode("a"), BigsetVnode("b")
        for vn in (a, b):
            vn.register_index(S, by_value())
        for i in range(12):
            a.coordinate_insert(S, b"e%02d" % i, value=b"bucket%d" % (i % 3))
        sync(a, b, S)
        got = QueryExecutor(b).execute(IndexLookup(S, b"value", b"bucket1"))
        assert got.members == [b"e%02d" % i for i in range(12) if i % 3 == 1]
        # quorum merge over (a, b) must not kill any live entry
        c = BigsetCluster(3)
        c.vnodes["vnode0"], c.vnodes["vnode1"] = a, b
        res = c.query(IndexRange(S, b"value"), r=2, repair=False)
        assert res.index_entries == index_truth(a, by_value())


# ------------------------------------------------- satellite: redelivery
class TestRedeliveryIdempotence:
    @given(ops_st)
    @settings(max_examples=30, deadline=None)
    def test_redelivered_deltas_are_byte_idempotent(self, ops):
        """Satellite: at-least-once delivery must not re-write clocks — the
        second apply of any settled delta is an exact storage no-op."""
        a, b = BigsetVnode("a"), BigsetVnode("b", LsmStore(
            memtable_limit=1 << 20))  # no flush: byte accounting is exact
        b.register_index(S, HEAD)
        deltas = []
        for op, _c, el in ops:
            if op == "add":
                deltas.append(a.coordinate_insert(S, el, value=b"v:" + el))
            else:
                present, ctx = a.is_member(S, el)
                if present:
                    deltas.append(a.coordinate_remove(S, ctx))
        from repro.core.bigset import InsertDelta
        for d in deltas:  # first delivery, in order
            if isinstance(d, InsertDelta):
                b.replica_insert(d)
            else:
                b.replica_remove(d)
        before = b.store.stats.snapshot()
        size = b.store.approximate_bytes()
        for d in deltas:  # full redelivery
            if isinstance(d, InsertDelta):
                assert b.replica_insert(d) is False
            else:
                b.replica_remove(d)
        delta = b.store.stats.delta(before)
        assert delta.bytes_written == 0, delta
        assert delta.num_writes == 0, delta
        assert b.store.approximate_bytes() == size

    def test_fresh_ctx_still_writes(self):
        """The skip must not swallow genuinely new causal information."""
        a, b = BigsetVnode("a"), BigsetVnode("b")
        d1 = a.coordinate_insert(S, b"x")
        _, ctx = a.is_member(S, b"x")
        d2 = a.coordinate_insert(S, b"x", ctx=ctx)  # replace
        b.replica_insert(d2)  # replace arrives first: ctx pre-empts d1
        assert b.replica_insert(d1) is False  # d1 must never materialise
        assert b.value(S) == {b"x"}
        assert len(list(b.fold(S))) == 1  # only d2's key


# ----------------------------------------- satellite: stats + decode errors
class TestStatsAndDecode:
    def test_count_reports_emitted(self):
        c = BigsetCluster(3)
        for el in ELEMS:
            c.add(S, el)
        ex = QueryExecutor(c.vnodes["vnode0"])
        r = ex.execute(Count(S))
        assert r.count == len(ELEMS)
        assert r.stats.elements_emitted == len(ELEMS)
        rc = c.query(Count(S), r=3)
        assert rc.stats.elements_emitted == len(ELEMS)

    def test_membership_miss_records_probe(self):
        c = BigsetCluster(3)
        c.add(S, b"ant")
        ex = QueryExecutor(c.vnodes["vnode0"])
        hit = ex.execute(Membership(S, b"ant"))
        miss = ex.execute(Membership(S, b"zzz"))
        assert hit.stats.keys_probed == 1
        assert miss.stats.keys_probed == 1  # the probed key is accounted
        assert c.query(Membership(S, b"zzz"), r=3).stats.keys_probed == 3

    def test_decode_element_key_rejects_other_kinds(self):
        vn = BigsetVnode("a")
        vn.register_index(S, HEAD)
        vn.coordinate_insert(S, b"ant")
        with pytest.raises(ValueError):
            decode_element_key(clock_key(S))
        with pytest.raises(ValueError):
            decode_element_key(posting_key(S, HEAD.name, b"a", b"ant",
                                           Dot("a", 1)))
        with pytest.raises(ValueError):
            decode_posting_key(element_key(S, b"ant", Dot("a", 1)))
        # round-trip still exact for real keys
        k = element_key(S, b"ant", Dot("a", 1))
        assert decode_element_key(k) == (S, b"ant", Dot("a", 1))


# ------------------------------------------------------------ IO acceptance
class TestIndexIo:
    def test_index_scan_io_is_o_matches_not_o_n(self):
        """Acceptance: an index query over a 100k-element set with a
        selective predicate reads O(matches + causal metadata) bytes."""
        n = 100_000
        vn = BigsetVnode("a", LsmStore(memtable_limit=1 << 20))
        vn.register_index(S, by_element_suffix(3))  # 1000 buckets of 100
        for i in range(n):
            vn.coordinate_insert(S, b"%08d" % i)
        vn.store.flush()
        ex = QueryExecutor(vn)

        meter = vn.store.meter()
        assert sum(1 for _ in vn.fold(S)) == n
        fold_bytes = meter.delta().bytes_read

        res = ex.execute(IndexLookup(S, b"element_suffix:3", b"042"))
        assert len(res.members) == 100
        assert res.members == [b"%05d042" % i for i in range(100)]
        # o(n): far under the full fold, and absolutely match-sized
        assert res.stats.bytes_read * 20 < fold_bytes, (
            res.stats.bytes_read, fold_bytes)
        assert res.stats.bytes_read < 64 * 1024, res.stats.bytes_read

        # a bounded IndexRange pays for two buckets, not the index
        res = ex.execute(IndexRange(S, b"element_suffix:3",
                                    start=b"042", end=b"044"))
        assert len(res.members) == 200
        assert res.stats.bytes_read < 128 * 1024, res.stats.bytes_read

    def test_cluster_index_io_sublinear(self):
        card = 3000
        c = BigsetCluster(3)
        c.register_index(S, by_element_suffix(2))  # 100 buckets of 30
        for i in range(card):
            c.add(S, b"%06d" % i, coordinator=i % 3)
        c.compact_all()
        res = c.query(IndexLookup(S, b"element_suffix:2", b"42"), r=3)
        assert len(res.members) == 30
        # 3 replicas each pay O(matches + metadata)
        assert res.stats.bytes_read < 96 * 1024, res.stats.bytes_read
