"""Partitioned placement: ring properties, routed clusters, handoff.

The acceptance surface of the placement refactor:

* the ring is deterministic, minimally-moving rendezvous placement;
* a partitioned cluster's ``query()`` results are identical to the
  unpartitioned cluster's under drop/dup/reorder (same elements, same
  values, same page boundaries — dots differ only in which owner minted
  them);
* a ring-epoch bump converges via digest handoff shipping only the moved
  partitions' data + causal metadata, with zero element folds for
  unmoved partitions;
* crash/restart during handoff loses no acknowledged writes;
* storage actually partitions: each vnode stores ~factor/n of the set.
"""
from hypothesis import given, settings, strategies as st

from repro.cluster.clusters import BigsetCluster, Ring, VnodeDown
from repro.cluster.placement import (DEFAULT_PARTITIONS, partition_set,
                                     plan_coverage, split_partition_set)
from repro.cluster.sim import Network
from repro.query.plan import Count, IndexLookup, Membership, Range, Scan
from repro.query.planner import side_stats

S = b"users"
ACTORS8 = [f"v{i}" for i in range(8)]


def elems(n, prefix=b"el"):
    return [prefix + b"%05d" % i for i in range(n)]


# --------------------------------------------------------------- ring units
class TestRing:
    def test_placement_is_deterministic(self):
        r1 = Ring.build(ACTORS8, factor=3, seed=7)
        r2 = Ring.build(list(ACTORS8), factor=3, seed=7)
        assert r1 == r2
        assert all(r1.owners(p) == r2.owners(p) for p in r1.partitions())
        assert r1.partition(S, b"x") == r2.partition(S, b"x")

    def test_seed_changes_placement(self):
        a = Ring.build(ACTORS8, factor=3, seed=0)
        b = Ring.build(ACTORS8, factor=3, seed=1)
        assert any(a.owners(p) != b.owners(p) for p in a.partitions())

    def test_owners_and_fallbacks_partition_the_actors(self):
        ring = Ring.build(ACTORS8, factor=3)
        for pid in ring.partitions():
            owners, rest = ring.owners(pid), ring.fallbacks(pid)
            assert len(owners) == 3
            assert not set(owners) & set(rest)
            assert set(owners) | set(rest) == set(ACTORS8)

    def test_minimal_movement_on_join(self):
        """Rendezvous: adding a vnode moves only the partitions where the
        newcomer out-scores an incumbent — about factor/(n+1) of them —
        and every move gains exactly the newcomer."""
        old = Ring.build(ACTORS8, factor=3)
        new = old.with_actors(ACTORS8 + ["v8"])
        delta = old.delta_to(new)
        assert delta.old_epoch == 0 and delta.new_epoch == 1
        assert 0 < len(delta.moves) < DEFAULT_PARTITIONS
        for move in delta.moves:
            assert move.joined == ("v8",)
            assert len(move.left) == 1
            assert set(move.survivors()) == set(move.old_owners) - set(
                move.left)
        # expected ~ 64 * 3/9 ≈ 21 moved partitions; allow generous slack
        assert len(delta.moves) <= DEFAULT_PARTITIONS // 2

    def test_unmoved_partitions_keep_owner_order(self):
        old = Ring.build(ACTORS8, factor=3)
        new = old.with_actors(ACTORS8 + ["v8"])
        moved = set(old.delta_to(new).moved_pids())
        for pid in old.partitions():
            if pid not in moved:
                assert old.owners(pid) == new.owners(pid)

    def test_full_ring_is_degenerate(self):
        ring = Ring.full(["a", "b", "c"])
        assert ring.full_replication and ring.n_partitions == 1
        assert ring.partition(S, b"anything") == 0
        assert ring.owners(0) == ("a", "b", "c")  # ORDER preserved
        assert ring.storage_set(S, 0) == S        # passthrough
        assert ring.write_quorum() == 2

    def test_pset_codec_round_trips(self):
        pset = partition_set(S, 37)
        assert split_partition_set(pset) == (S, 37)
        assert split_partition_set(S) == (S, None)
        # partition sets sort outside the application's own namespace
        assert pset.startswith(S + b"\x00")

    def test_coverage_minimises_vnode_footprint(self):
        ring = Ring.build(ACTORS8, factor=3)
        cover = plan_coverage(ring, S, ACTORS8, r=2)
        assert len(cover.assignments) == DEFAULT_PARTITIONS
        assert all(len(actors) == 2 for _p, _s, actors in cover.assignments)
        # every assignment draws from the partition's owners
        for pid, pset, actors in cover.assignments:
            assert set(actors) <= set(ring.owners(pid))
            assert pset == ring.storage_set(S, pid)

    def test_coverage_raises_vnode_down_with_payload(self):
        ring = Ring.build(ACTORS8, factor=3)
        # find a partition and kill enough of its owners to break quorum
        victims = ring.owners(0)[:2]
        live = [a for a in ACTORS8 if a not in victims]
        try:
            plan_coverage(ring, S, live, r=2, pids=[0])
        except VnodeDown as e:
            assert e.vnode in victims
            assert e.set_name == S
        else:
            raise AssertionError("expected VnodeDown")

    def test_coverage_rejects_r_above_factor(self):
        ring = Ring.build(ACTORS8, factor=3)
        try:
            plan_coverage(ring, S, ACTORS8, r=4, pids=[0])
        except ValueError as e:
            assert "replication factor" in str(e)
        else:
            raise AssertionError("expected ValueError")


# ------------------------------------------- partitioned == unpartitioned
def apply_ops(cluster, ops):
    for kind, i, coord in ops:
        el = b"el%02d" % i
        if kind == "add":
            cluster.add(S, el, coordinator=coord % cluster.n,
                        value=b"v" + el)
        else:
            cluster.remove(S, el, coordinator=coord % cluster.n)


ops_st = st.lists(
    st.tuples(st.sampled_from(["add", "remove"]), st.integers(0, 24),
              st.integers(0, 7)),
    min_size=1, max_size=40)


class TestPartitionedEquivalence:
    @given(ops_st, st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_results_match_unpartitioned_under_faults(self, ops, seed):
        """Same ops through a faulty network on both topologies; after
        convergence every query shape answers identically."""
        full = BigsetCluster(
            3, net=Network(seed=seed, dup_prob=0.2, reorder=True))
        part = BigsetCluster(
            ring=Ring.build(ACTORS8, factor=3),
            net=Network(seed=seed, dup_prob=0.2, reorder=True))
        apply_ops(full, ops)
        apply_ops(part, ops)
        full.settle()
        part.settle()
        fr = full.query(Scan(S, page_size=100), repair=False)
        pr = part.query(Scan(S, page_size=100), repair=False)
        assert pr.members == fr.members
        assert pr.count == fr.count
        assert (part.query(Count(S), repair=False).count
                == full.query(Count(S), repair=False).count)
        for i in (0, 7, 19):
            el = b"el%02d" % i
            assert (part.query(Membership(S, el), repair=False).present
                    == full.query(Membership(S, el), repair=False).present)

    @staticmethod
    def apply_ops_ctx(cluster, ops):
        """Ops with *client-provided* remove contexts (§4.3.2): the ctx is
        the dots of the element's own prior adds, so the outcome is pure
        set algebra — identical on any topology under any delivery."""
        ctxs = {}
        for kind, i, coord in ops:
            el = b"el%02d" % i
            if kind == "add":
                d = cluster.add(S, el, coordinator=coord % cluster.n,
                                value=b"v" + el)
                ctxs.setdefault(el, []).append(d.dot)
            else:
                ctx = ctxs.pop(el, None)
                if ctx:
                    cluster.remove(S, el, coordinator=coord % cluster.n,
                                   ctx=ctx)

    @given(ops_st, st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_dropped_deltas_heal_via_quorum_and_ticks(self, ops, seed):
        """Drops leave replicas divergent; quorum reads stay correct and
        anti-entropy ticks converge the partitioned cluster to the same
        answer as a fault-free unpartitioned one."""
        oracle = BigsetCluster(3)
        part = BigsetCluster(
            ring=Ring.build(ACTORS8, factor=3),
            net=Network(seed=seed, drop_prob=0.3, reorder=True), sync=False)
        self.apply_ops_ctx(oracle, ops)
        self.apply_ops_ctx(part, ops)
        part.settle()
        for _ in range(40):
            part.tick()
        truth = oracle.query(Range(S), repair=False)
        got = part.query(Range(S), repair=False)
        assert got.members == truth.members

    def test_pagination_boundaries_identical(self):
        full = BigsetCluster(3)
        part = BigsetCluster(ring=Ring.build(ACTORS8, factor=3))
        for el in elems(30):
            full.add(S, el)
            part.add(S, el)
        cur_f = cur_p = None
        for _ in range(10):
            pf = full.query(Scan(S, page_size=7, cursor=cur_f))
            pp = part.query(Scan(S, page_size=7, cursor=cur_p))
            assert pp.members == pf.members
            assert (pp.cursor is None) == (pf.cursor is None)
            cur_f, cur_p = pf.cursor, pp.cursor
            if cur_f is None:
                break
        assert cur_f is None

    def test_coverage_surfaced_in_stats(self):
        part = BigsetCluster(ring=Ring.build(ACTORS8, factor=3))
        part.add(S, b"x")
        res = part.query(Membership(S, b"x"))
        assert res.stats.coverage == "epoch=0;partitions=1;vnodes=2;r=2"
        res = part.query(Range(S))
        assert res.stats.coverage == (
            f"epoch=0;partitions={DEFAULT_PARTITIONS};vnodes=7;r=2")

    def test_index_queries_fan_in_across_partitions(self):
        from repro.index.spec import by_value_prefix

        full = BigsetCluster(3)
        part = BigsetCluster(ring=Ring.build(ACTORS8, factor=3))
        spec = by_value_prefix(2, name=b"pfx")
        for c in (full, part):
            c.register_index(S, spec)
            for i, el in enumerate(elems(20)):
                c.add(S, el, value=b"%02d-payload" % (i % 4))
        res_f = full.query(IndexLookup(S, b"pfx", b"01"))
        res_p = part.query(IndexLookup(S, b"pfx", b"01"))
        assert ([(ik, el) for ik, el, _ in res_p.index_entries]
                == [(ik, el) for ik, el, _ in res_f.index_entries])


# ------------------------------------------------------------ ring change
class TestHandoff:
    def _loaded_cluster(self, n_elems=120, **kw):
        c = BigsetCluster(ring=Ring.build(ACTORS8, factor=3), **kw)
        for el in elems(n_elems):
            c.add(S, el, value=b"v:" + el)
        return c

    def drain(self, c, ticks=30):
        for _ in range(ticks):
            c.tick(budget=0)
            if not (c.ring_state()["handoffs_pending"]
                    or c.ring_state()["retires_pending"]):
                break

    def test_epoch_bump_ships_only_moved_partitions(self):
        c = self._loaded_cluster()
        before = c.query(Scan(S, page_size=500)).members
        shipped0 = c.ae_stats().keys_shipped
        scanned0 = c.ae_stats().keys_scanned
        delta = c.add_vnode("v8")
        moved = set(delta.moved_pids())
        # every scheduled task concerns a moved partition — nothing else
        assert {t.pid for t in c._handoffs} <= moved
        assert {t.pid for t in c._retires} <= moved
        self.drain(c)
        assert c.ring_state()["handoffs_pending"] == 0
        assert c.ring_state()["retires_pending"] == 0
        # wire cost: exactly the surviving keys of moved partitions were
        # shipped (each to the one gaining owner), zero for unmoved ones
        old = Ring.build(ACTORS8, factor=3)
        moved_keys = sum(
            1 for el in elems(120) if old.partition(S, el) in moved)
        assert c.ae_stats().keys_shipped - shipped0 == moved_keys
        # donor folds touched only moved partitions: the scan ledger grew
        # by O(moved keys), not O(total keys)
        assert c.ae_stats().keys_scanned - scanned0 <= 2 * moved_keys + len(
            moved)
        # results identical across the epoch bump
        assert c.query(Scan(S, page_size=500)).members == before

    def test_leaver_copy_retired_only_after_domination(self):
        c = self._loaded_cluster()
        delta = c.add_vnode("v8")
        move = next(m for m in delta.moves
                    if any(c.ring.partition(S, el) == m.pid
                           for el in elems(120)))
        pset = c.ring.storage_set(S, move.pid)
        leaver = move.left[0]
        assert side_stats(c.vnodes[leaver].store, pset).keys > 0
        self.drain(c)
        # handoff done: the new owner dominates, the leaver's copy is gone
        assert side_stats(c.vnodes[leaver].store, pset).keys == 0
        assert side_stats(c.vnodes["v8"].store, pset).keys > 0
        assert c.ae_stats().handoff_retired == len(c._retires)

    def test_epoch_retires_and_cursors_fall_forward(self):
        c = self._loaded_cluster(n_elems=40)
        page1 = c.query(Scan(S, page_size=15), ring_epoch=0)
        c.add_vnode("v8")
        self.drain(c)
        assert c.ring_state()["serveable_epochs"] == [1]
        # the pinned epoch 0 is retired: the cursor re-plans under epoch 1
        # and resumes from the same element boundary
        page2 = c.query(Scan(S, page_size=100, cursor=page1.cursor),
                        ring_epoch=0)
        assert "epoch=1" in page2.stats.coverage
        assert page1.members + page2.members == elems(40)

    def test_crash_restart_during_handoff_loses_nothing(self):
        c = self._loaded_cluster(durable=True)
        c.sync_all()  # acknowledgement barrier: all 120 writes durable
        c.add_vnode("v8")
        c.tick(budget=0)   # partial handoff under way
        c.crash("v8")      # the joiner dies mid-pull
        for _ in range(3):
            c.tick(budget=0)   # tasks skip the crashed joiner
        c.restart("v8")
        self.drain(c)
        assert c.ring_state()["handoffs_pending"] == 0
        assert c.query(Scan(S, page_size=500)).members == elems(120)

    def test_donor_crash_during_handoff_loses_nothing(self):
        c = self._loaded_cluster(durable=True)
        c.sync_all()
        delta = c.add_vnode("v8")
        donors = {t.src for t in c._handoffs}
        victim = sorted(donors)[0]
        c.crash(victim)
        for _ in range(5):
            c.tick(budget=0)   # pulls from the crashed donor are skipped
        c.restart(victim)
        self.drain(c, ticks=40)
        assert c.ring_state()["handoffs_pending"] == 0
        assert c.ring_state()["retires_pending"] == 0
        assert c.query(Scan(S, page_size=500)).members == elems(120)
        assert delta.new_epoch == c.ring.epoch

    def test_writes_during_handoff_survive(self):
        """Writes landing while partitions move are never lost: they go to
        the NEW ring's owners, and handoff completion is clock descent —
        the donor's whole history, not a snapshot."""
        c = self._loaded_cluster()
        c.add_vnode("v8")
        c.tick(budget=0)
        late = [b"late%02d" % i for i in range(20)]
        for el in late:
            c.add(S, el)
        self.drain(c)
        got = c.query(Scan(S, page_size=500)).members
        assert got == sorted(elems(120) + late)


# ------------------------------------------------------- sloppy placement
class TestHintedHandoff:
    def test_write_routes_around_crashed_owner(self):
        c = BigsetCluster(ring=Ring.build(ACTORS8, factor=3), durable=True)
        c.add(S, b"seed")
        pref = c.ring.preference_list(S, b"target")
        victim = pref.owners[0]
        c.crash(victim)
        # coordinate from a live vnode: hinted handoff routes *replicas*
        # around the crashed owner, a dead coordinator still refuses
        alive = next(i for i, a in enumerate(c.actors) if a != victim)
        c.add(S, b"target", value=b"val", coordinator=alive)
        assert c.ae_stats().hints_recorded == 1
        # quorum reads stay available around the crash
        assert c.query(Membership(S, b"target")).present
        c.restart(victim)
        for _ in range(6):
            c.tick(budget=0)
        assert c.ae_stats().hints_resolved == 1
        assert c.ring_state()["hints_pending"] == 0
        # the returned owner holds the element locally now
        pset = c.ring.storage_set(S, pref.pid)
        assert c.vnodes[victim].is_member(pset, b"target")[0]
        # and the fallback's parked copy was retired after domination
        fallback = next(a for a in pref.fallbacks
                        if side_stats(c.vnodes[a].store, pset).keys == 0)
        assert fallback is not None

    def test_vnode_down_when_no_owner_or_fallback(self):
        actors = ["a", "b", "c"]
        c = BigsetCluster(ring=Ring.build(actors, factor=3), durable=True)
        c.add(S, b"x", coordinator=1)
        for v in actors[1:]:
            c.crash(v)
        # entry vnode "a" is alive but partitions whose owners are all
        # crashed (factor==n: no fallbacks) must refuse the write loudly
        try:
            for i in range(50):
                c.add(S, b"probe%02d" % i, coordinator=0)
        except VnodeDown as e:
            assert e.vnode in actors
            assert e.set_name == S
        else:
            raise AssertionError("expected VnodeDown")

    def test_crashed_coordinator_raises_with_payload(self):
        c = BigsetCluster(ring=Ring.build(ACTORS8, factor=3), durable=True)
        c.add(S, b"x")
        c.crash(0)
        try:
            c.add(S, b"y", coordinator=0)
        except VnodeDown as e:
            assert e.vnode == "v0"
            assert e.set_name == S
        else:
            raise AssertionError("expected VnodeDown")


# ----------------------------------------------------------- storage bound
class TestStoragePartitioning:
    def test_per_vnode_storage_is_fractional(self):
        """8 vnodes / factor 3: each vnode stores ~3/8 of the elements
        (the full-replication baseline stores all of them everywhere)."""
        n = 400
        c = BigsetCluster(ring=Ring.build(ACTORS8, factor=3))
        for el in elems(n):
            c.add(S, el, value=b"payload:" + el)
        per_vnode = []
        for a in c.actors:
            keys = sum(
                side_stats(c.vnodes[a].store, c.ring.storage_set(S, pid)).keys
                for pid in c.ring.partitions())
            per_vnode.append(keys)
        assert sum(per_vnode) == 3 * n  # factor copies in total, no more
        # balanced-ish: nobody stores more than ~60% above the 3/8 mean
        assert max(per_vnode) <= 1.6 * (3 * n / 8)
