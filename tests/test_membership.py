"""Membership CRDT + elastic assignment tests."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.membership import GossipCluster, MembershipView
from repro.cluster.placement import Ring
from repro.cluster.sim import Network
from repro.runtime.elastic import ElasticController, derive_assignment


class TestMembership:
    def test_bootstrap_converges(self):
        c = GossipCluster(5)
        c.settle()
        assert c.converged()
        assert c.views()[0] == frozenset(f"node{i}" for i in range(5))

    def test_leave_propagates(self):
        c = GossipCluster(4)
        c.settle()
        c.node_leaves("node2")
        c.settle()
        assert c.converged()
        assert "node2" not in c.views()[0]

    def test_eject_straggler(self):
        c = GossipCluster(4)
        c.settle()
        c.eject("node0", "node3")
        c.settle()
        assert "node3" not in c.views()[0]

    def test_rejoin_after_eject_wins(self):
        """Add-wins: a node re-joining concurrently with its ejection stays."""
        c = GossipCluster(3)
        c.settle()
        # concurrent: node0 ejects node2 (based on observed state) while
        # node2 re-announces itself
        eject_delta = c.nodes["node0"].leave("node2")
        rejoin_delta = c.nodes["node2"].join()
        for nid in c.nodes:
            c.nodes[nid].apply(eject_delta)
            c.nodes[nid].apply(rejoin_delta)
        assert all("node2" in v for v in c.views())

    @given(st.lists(st.tuples(st.sampled_from(["join", "leave"]),
                              st.integers(0, 5)), max_size=12),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_converges_under_lossy_gossip(self, events, seed):
        net = Network(seed=seed, drop_prob=0.4, reorder=True)
        c = GossipCluster(3, net=net)
        c.settle()
        extant = {f"node{i}" for i in range(3)}
        for kind, i in events:
            nid = f"xnode{i}"
            if kind == "join" and nid not in extant:
                c.node_joins(nid)
                extant.add(nid)
            elif kind == "leave" and nid in extant:
                c.node_leaves(nid)
                extant.discard(nid)
        c.settle()
        c.anti_entropy_round()   # repairs dropped deltas
        c.anti_entropy_round()
        assert c.converged()


class TestIncarnation:
    """A node's incarnation is the dot-context of its own entry: each
    rejoin mints a fresh dot, so views can tell a restarted node from a
    stale sighting of its previous life."""

    def test_rejoin_bumps_incarnation(self):
        v = MembershipView("a")
        v.apply(v.join())
        inc1 = v.incarnation("a")
        v.apply(v.leave())
        assert v.incarnation("a") == ()
        v.apply(v.join())
        inc2 = v.incarnation("a")
        assert inc2 != inc1
        # the new incarnation causally follows the ejected one
        assert max(d.counter for d in inc2) > max(d.counter for d in inc1)

    def test_eject_then_rejoin_wins_everywhere(self):
        """Eject-then-rejoin: the rejoin's fresh dot is unseen by the
        ejection's context, so add-wins keeps the node in every view."""
        c = GossipCluster(3)
        c.settle()
        eject = c.nodes["node0"].leave("node2")
        rejoin = c.nodes["node2"].join()
        # deliver in both orders: converged result must be identical
        c.nodes["node1"].apply(eject)
        c.nodes["node1"].apply(rejoin)
        c.nodes["node0"].apply(rejoin)
        c.nodes["node2"].apply(eject)
        assert c.nodes["node1"].is_member("node2")
        assert c.nodes["node0"].is_member("node2")
        assert c.nodes["node2"].is_member("node2")
        # and the surviving incarnation is exactly the rejoin's dot
        new_inc = c.nodes["node1"].incarnation("node2")
        assert any(d.counter > 1 for d in new_inc)

    def test_concurrent_join_leave_converge(self):
        """Two views diverge on a concurrent join and leave; a pairwise
        merge lands both on the same member set."""
        a, b = MembershipView("a"), MembershipView("b")
        b.apply(a.join("seed"))  # both start observing the seed node
        da = a.join()          # a adds itself
        db = b.join()          # b adds itself
        a.apply(db)
        b.apply(da)
        dl = a.leave("seed")   # a ejects the seed...
        dj = b.join("seed")    # ...while b concurrently re-adds it
        a.apply(dj)
        b.apply(dl)
        assert a.members() == b.members()
        assert "seed" in a.members()  # add-wins


class TestDataParallelGroups:
    def test_groups_cover_alive_set(self):
        c = GossipCluster(5)
        c.settle()
        groups = c.nodes["node0"].data_parallel_groups(2)
        flat = [n for g in groups for n in g]
        assert sorted(flat) == sorted(c.nodes["node0"].members())
        assert all(len(g) <= 2 for g in groups)

    def test_groups_stable_across_converged_views(self):
        """Pure function of members(): every converged view computes the
        identical grouping, whatever order its deltas arrived in."""
        c = GossipCluster(4)
        c.settle()
        c.node_joins("xnode9")
        c.node_leaves("node1")
        c.settle()
        c.anti_entropy_round()
        assert c.converged()
        expected = c.nodes["node0"].data_parallel_groups(3)
        assert all(v.data_parallel_groups(3) == expected
                   for v in c.nodes.values())

    def test_join_perturbs_only_downstream_groups(self):
        v = MembershipView("a")
        for n in ["a", "b", "c", "d", "e", "f"]:
            v.apply(v.join(n))
        before = v.data_parallel_groups(2)
        v.apply(v.join("zz"))  # sorts last: earlier groups unchanged
        after = v.data_parallel_groups(2)
        assert after[:len(before)] == before
        assert after[-1] == ("zz",)

    def test_group_size_validated(self):
        v = MembershipView("a")
        with pytest.raises(ValueError):
            v.data_parallel_groups(0)


class TestRingFromMembership:
    def test_ring_consumes_alive_set(self):
        c = GossipCluster(5)
        c.settle()
        ring = Ring.from_members(c.nodes["node0"], factor=3)
        assert set(ring.actors) == c.nodes["node0"].members()
        # every converged view builds the identical ring
        assert all(Ring.from_members(v, factor=3) == ring
                   for v in c.nodes.values())

    def test_ring_shrinks_with_membership(self):
        c = GossipCluster(3)
        c.settle()
        c.node_leaves("node2")
        c.settle()
        ring = Ring.from_members(c.nodes["node0"], factor=3)
        assert "node2" not in ring.actors
        assert ring.factor == 2  # capped at the surviving member count


class TestElastic:
    def test_assignment_partitions_batch(self):
        a = derive_assignment(frozenset({"a", "b", "c"}), 8, epoch=1)
        slices = sorted(a.batch_slices.values())
        assert slices[0][0] == 0 and slices[-1][1] == 8
        covered = sum(hi - lo for lo, hi in slices)
        assert covered == 8

    def test_scale_down_reassigns(self):
        ctl = ElasticController(4, global_batch=8)
        a1 = ctl.current_assignment()
        assert a1.dp_size == 4
        a2 = ctl.fail("node1", detected_by="node0")
        assert a2.dp_size == 3
        assert "node1" not in a2.hosts
        assert sum(hi - lo for lo, hi in a2.batch_slices.values()) == 8

    def test_scale_up(self):
        ctl = ElasticController(2, global_batch=6)
        a = ctl.scale_up("node9")
        assert a.dp_size == 3 and "node9" in a.hosts
