"""Membership CRDT + elastic assignment tests."""
from hypothesis import given, settings, strategies as st

from repro.cluster.membership import GossipCluster, MembershipView
from repro.cluster.sim import Network
from repro.runtime.elastic import ElasticController, derive_assignment


class TestMembership:
    def test_bootstrap_converges(self):
        c = GossipCluster(5)
        c.settle()
        assert c.converged()
        assert c.views()[0] == frozenset(f"node{i}" for i in range(5))

    def test_leave_propagates(self):
        c = GossipCluster(4)
        c.settle()
        c.node_leaves("node2")
        c.settle()
        assert c.converged()
        assert "node2" not in c.views()[0]

    def test_eject_straggler(self):
        c = GossipCluster(4)
        c.settle()
        c.eject("node0", "node3")
        c.settle()
        assert "node3" not in c.views()[0]

    def test_rejoin_after_eject_wins(self):
        """Add-wins: a node re-joining concurrently with its ejection stays."""
        c = GossipCluster(3)
        c.settle()
        # concurrent: node0 ejects node2 (based on observed state) while
        # node2 re-announces itself
        eject_delta = c.nodes["node0"].leave("node2")
        rejoin_delta = c.nodes["node2"].join()
        for nid in c.nodes:
            c.nodes[nid].apply(eject_delta)
            c.nodes[nid].apply(rejoin_delta)
        assert all("node2" in v for v in c.views())

    @given(st.lists(st.tuples(st.sampled_from(["join", "leave"]),
                              st.integers(0, 5)), max_size=12),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_converges_under_lossy_gossip(self, events, seed):
        net = Network(seed=seed, drop_prob=0.4, reorder=True)
        c = GossipCluster(3, net=net)
        c.settle()
        extant = {f"node{i}" for i in range(3)}
        for kind, i in events:
            nid = f"xnode{i}"
            if kind == "join" and nid not in extant:
                c.node_joins(nid)
                extant.add(nid)
            elif kind == "leave" and nid in extant:
                c.node_leaves(nid)
                extant.discard(nid)
        c.settle()
        c.anti_entropy_round()   # repairs dropped deltas
        c.anti_entropy_round()
        assert c.converged()


class TestElastic:
    def test_assignment_partitions_batch(self):
        a = derive_assignment(frozenset({"a", "b", "c"}), 8, epoch=1)
        slices = sorted(a.batch_slices.values())
        assert slices[0][0] == 0 and slices[-1][1] == 8
        covered = sum(hi - lo for lo, hi in slices)
        assert covered == 8

    def test_scale_down_reassigns(self):
        ctl = ElasticController(4, global_batch=8)
        a1 = ctl.current_assignment()
        assert a1.dp_size == 4
        a2 = ctl.fail("node1", detected_by="node0")
        assert a2.dp_size == 3
        assert "node1" not in a2.hosts
        assert sum(hi - lo for lo, hi in a2.batch_slices.values()) == 8

    def test_scale_up(self):
        ctl = ElasticController(2, global_batch=6)
        a = ctl.scale_up("node9")
        assert a.dp_size == 3 and "node9" in a.hosts
