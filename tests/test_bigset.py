"""Bigset semantics: Algorithms 1 & 2, removes, compaction, queries, and the
paper's §5 claim — bigset ≅ Riak ORSWOT sets, property-tested."""
from hypothesis import given, settings, strategies as st

from repro.cluster.clusters import BigsetCluster, DeltaCluster, RiakSetCluster
from repro.cluster.sim import Network
from repro.core.bigset import BigsetVnode
from repro.core.dots import Dot

S = b"s"
ELEMS = [b"ant", b"bee", b"cat", b"dog", b"eel"]

op_st = st.tuples(
    st.sampled_from(["add", "rem"]),
    st.integers(0, 2),  # coordinator replica
    st.sampled_from(ELEMS),
)
ops_st = st.lists(op_st, max_size=25)


class TestSingleVnode:
    def test_insert_and_read(self):
        vn = BigsetVnode("a")
        vn.coordinate_insert(S, b"x")
        vn.coordinate_insert(S, b"y")
        assert vn.value(S) == {b"x", b"y"}

    def test_remove_requires_context(self):
        vn = BigsetVnode("a")
        vn.coordinate_insert(S, b"x")
        _, ctx = vn.is_member(S, b"x")
        vn.coordinate_remove(S, ctx)
        assert vn.value(S) == set()

    def test_duplicate_delta_is_noop(self):
        a, b = BigsetVnode("a"), BigsetVnode("b")
        d = a.coordinate_insert(S, b"x")
        assert b.replica_insert(d) is True
        assert b.replica_insert(d) is False  # idempotent
        assert b.value(S) == {b"x"}

    def test_write_reads_only_clocks(self):
        """§4.3: write IO must not grow with cardinality."""
        vn = BigsetVnode("a")
        for i in range(50):
            vn.coordinate_insert(S, b"elem%d" % i)
        before = vn.store.stats.snapshot()
        vn.coordinate_insert(S, b"one-more")
        d = vn.store.stats.delta(before)
        # clocks are tiny; a full-set read would be thousands of bytes
        assert d.bytes_read < 300
        assert d.bytes_written < 400

    def test_is_member_and_range(self):
        vn = BigsetVnode("a")
        for e in ELEMS:
            vn.coordinate_insert(S, e)
        assert vn.is_member(S, b"cat")[0]
        assert not vn.is_member(S, b"cow")[0]
        assert vn.range_query(S, b"bee", 3) == [b"bee", b"cat", b"dog"]

    def test_streaming_batches_ordered(self):
        vn = BigsetVnode("a")
        for e in reversed(ELEMS):
            vn.coordinate_insert(S, e)
        rs = vn.read(S, batch_size=2)
        got = [e for batch in rs.batches() for e, _ in batch]
        assert got == sorted(ELEMS)


class TestCompaction:
    def test_compaction_discards_and_trims(self):
        vn = BigsetVnode("a")
        for e in ELEMS:
            vn.coordinate_insert(S, e)
        _, ctx = vn.is_member(S, b"cat")
        vn.coordinate_remove(S, ctx)
        assert not vn.read_tombstone(S).is_zero()
        discarded = vn.compact()
        assert [d for ds in discarded.values() for d in ds]  # dropped the key
        assert vn.read_tombstone(S).is_zero()  # §4.3.3: tombstone shrank
        assert vn.value(S) == set(ELEMS) - {b"cat"}

    def test_read_value_invariant_under_compaction(self):
        vn = BigsetVnode("a")
        for i, e in enumerate(ELEMS * 3):
            vn.coordinate_insert(S, e)
            if i % 2 == 0:
                _, ctx = vn.is_member(S, e)
                vn.coordinate_remove(S, ctx)
        before = vn.value(S)
        vn.compact()
        assert vn.value(S) == before

    def test_superseded_adds_compact_away(self):
        """Re-adding an element with its read context supersedes old dots."""
        vn = BigsetVnode("a")
        vn.coordinate_insert(S, b"x")
        _, ctx = vn.is_member(S, b"x")
        vn.coordinate_insert(S, b"x", ctx)  # replacing add
        lo_count_before = len(list(vn.fold(S)))
        vn.compact()
        keys = list(vn.fold(S))
        assert len(keys) == 1  # one surviving dot for x
        assert vn.value(S) == {b"x"}


class TestClusterEquivalence:
    """Paper §5: 'bigset and Riak sets are semantically equivalent'."""

    @given(ops_st)
    @settings(max_examples=60, deadline=None)
    def test_bigset_equals_riak_sets(self, ops):
        big = BigsetCluster(3)
        riak = RiakSetCluster(3)
        for kind, coord, elem in ops:
            if kind == "add":
                # clients read-then-write: supply the observed context
                _, ctx = big.vnodes[big.actors[coord]].is_member(S, elem)
                big.add(S, elem, coord, ctx)
                riak.add(S, elem, coord)
            else:
                big.remove(S, elem, coord)
                riak.remove(S, elem, coord)
        assert big.value(S, r=3) == riak.value(S, r=3)

    @given(ops_st)
    @settings(max_examples=40, deadline=None)
    def test_bigset_equals_delta_sets(self, ops):
        big = BigsetCluster(3)
        delta = DeltaCluster(3)
        for kind, coord, elem in ops:
            if kind == "add":
                _, ctx = big.vnodes[big.actors[coord]].is_member(S, elem)
                big.add(S, elem, coord, ctx)
                delta.add(S, elem, coord)
            else:
                big.remove(S, elem, coord)
                delta.remove(S, elem, coord)
        assert big.value(S, r=3) == delta.value(S, r=3)

    @given(ops_st)
    @settings(max_examples=40, deadline=None)
    def test_equivalence_survives_compaction(self, ops):
        big = BigsetCluster(3)
        riak = RiakSetCluster(3)
        for i, (kind, coord, elem) in enumerate(ops):
            if kind == "add":
                _, ctx = big.vnodes[big.actors[coord]].is_member(S, elem)
                big.add(S, elem, coord, ctx)
                riak.add(S, elem, coord)
            else:
                big.remove(S, elem, coord)
                riak.remove(S, elem, coord)
            if i % 7 == 3:
                big.compact_all()
        big.compact_all()
        assert big.value(S, r=3) == riak.value(S, r=3)

    @given(ops_st, st.integers(1, 3))
    @settings(max_examples=40, deadline=None)
    def test_quorum_reads_consistent(self, ops, r):
        big = BigsetCluster(3)
        for kind, coord, elem in ops:
            if kind == "add":
                big.add(S, elem, coord)
            else:
                big.remove(S, elem, coord)
        # synchronous replication -> any quorum returns the full value
        assert big.value(S, r=r) == big.value(S, r=3)


class TestConcurrencySemantics:
    def test_concurrent_add_remove_add_wins(self):
        big = BigsetCluster(3, sync=False)  # manual delivery
        big.add(S, b"x", 0)
        big.settle()
        # concurrent: replica1 removes x, replica2 re-adds x
        _, ctx = big.vnodes[big.actors[1]].is_member(S, b"x")
        big.remove(S, b"x", 1, ctx)
        _, ctx2 = big.vnodes[big.actors[2]].is_member(S, b"x")
        big.add(S, b"x", 2, ctx2)
        big.settle()
        for r in (1, 2, 3):
            assert b"x" in big.value(S, r=r)

    def test_remove_of_unseen_add_preempts(self):
        """§4.3.2: if the adds were unseen they never get added."""
        from repro.core.bigset import RemoveDelta

        a, b = BigsetVnode("a"), BigsetVnode("b")
        delta = a.coordinate_insert(S, b"x")
        # b learns of the removal (via a client ctx) before the add delta
        b.replica_remove(RemoveDelta(S, (delta.dot,)))
        b.replica_insert(delta)  # late add arrives
        assert b.value(S) == set()
