"""Bigset semantics: Algorithms 1 & 2, removes, compaction, queries, and the
paper's §5 claim — bigset ≅ Riak ORSWOT sets, property-tested."""
from hypothesis import given, settings, strategies as st

from repro.cluster.clusters import BigsetCluster, DeltaCluster, RiakSetCluster
from repro.cluster.sim import Network
from repro.core.bigset import BigsetVnode
from repro.core.dots import Dot

S = b"s"
ELEMS = [b"ant", b"bee", b"cat", b"dog", b"eel"]

op_st = st.tuples(
    st.sampled_from(["add", "rem"]),
    st.integers(0, 2),  # coordinator replica
    st.sampled_from(ELEMS),
)
ops_st = st.lists(op_st, max_size=25)


class TestSingleVnode:
    def test_insert_and_read(self):
        vn = BigsetVnode("a")
        vn.coordinate_insert(S, b"x")
        vn.coordinate_insert(S, b"y")
        assert vn.value(S) == {b"x", b"y"}

    def test_remove_requires_context(self):
        vn = BigsetVnode("a")
        vn.coordinate_insert(S, b"x")
        _, ctx = vn.is_member(S, b"x")
        vn.coordinate_remove(S, ctx)
        assert vn.value(S) == set()

    def test_duplicate_delta_is_noop(self):
        a, b = BigsetVnode("a"), BigsetVnode("b")
        d = a.coordinate_insert(S, b"x")
        assert b.replica_insert(d) is True
        assert b.replica_insert(d) is False  # idempotent
        assert b.value(S) == {b"x"}

    def test_write_reads_only_clocks(self):
        """§4.3: write IO must not grow with cardinality."""
        vn = BigsetVnode("a")
        for i in range(50):
            vn.coordinate_insert(S, b"elem%d" % i)
        before = vn.store.stats.snapshot()
        vn.coordinate_insert(S, b"one-more")
        d = vn.store.stats.delta(before)
        # clocks are tiny; a full-set read would be thousands of bytes
        assert d.bytes_read < 300
        assert d.bytes_written < 400

    def test_is_member_and_range(self):
        vn = BigsetVnode("a")
        for e in ELEMS:
            vn.coordinate_insert(S, e)
        assert vn.is_member(S, b"cat")[0]
        assert not vn.is_member(S, b"cow")[0]
        assert vn.range_query(S, b"bee", 3) == [b"bee", b"cat", b"dog"]

    def test_streaming_batches_ordered(self):
        vn = BigsetVnode("a")
        for e in reversed(ELEMS):
            vn.coordinate_insert(S, e)
        rs = vn.read(S, batch_size=2)
        got = [e for batch in rs.batches() for e, _ in batch]
        assert got == sorted(ELEMS)


class TestCompaction:
    def test_compaction_discards_and_trims(self):
        vn = BigsetVnode("a")
        for e in ELEMS:
            vn.coordinate_insert(S, e)
        _, ctx = vn.is_member(S, b"cat")
        vn.coordinate_remove(S, ctx)
        assert not vn.read_tombstone(S).is_zero()
        discarded = vn.compact()
        assert [d for ds in discarded.values() for d in ds]  # dropped the key
        assert vn.read_tombstone(S).is_zero()  # §4.3.3: tombstone shrank
        assert vn.value(S) == set(ELEMS) - {b"cat"}

    def test_read_value_invariant_under_compaction(self):
        vn = BigsetVnode("a")
        for i, e in enumerate(ELEMS * 3):
            vn.coordinate_insert(S, e)
            if i % 2 == 0:
                _, ctx = vn.is_member(S, e)
                vn.coordinate_remove(S, ctx)
        before = vn.value(S)
        vn.compact()
        assert vn.value(S) == before

    def test_superseded_adds_compact_away(self):
        """Re-adding an element with its read context supersedes old dots."""
        vn = BigsetVnode("a")
        vn.coordinate_insert(S, b"x")
        _, ctx = vn.is_member(S, b"x")
        vn.coordinate_insert(S, b"x", ctx)  # replacing add
        lo_count_before = len(list(vn.fold(S)))
        vn.compact()
        keys = list(vn.fold(S))
        assert len(keys) == 1  # one surviving dot for x
        assert vn.value(S) == {b"x"}


class TestClusterEquivalence:
    """Paper §5: 'bigset and Riak sets are semantically equivalent'."""

    @given(ops_st)
    @settings(max_examples=60, deadline=None)
    def test_bigset_equals_riak_sets(self, ops):
        big = BigsetCluster(3)
        riak = RiakSetCluster(3)
        for kind, coord, elem in ops:
            if kind == "add":
                # clients read-then-write: supply the observed context
                _, ctx = big.vnodes[big.actors[coord]].is_member(S, elem)
                big.add(S, elem, coord, ctx)
                riak.add(S, elem, coord)
            else:
                big.remove(S, elem, coord)
                riak.remove(S, elem, coord)
        assert big.value(S, r=3) == riak.value(S, r=3)

    @given(ops_st)
    @settings(max_examples=40, deadline=None)
    def test_bigset_equals_delta_sets(self, ops):
        big = BigsetCluster(3)
        delta = DeltaCluster(3)
        for kind, coord, elem in ops:
            if kind == "add":
                _, ctx = big.vnodes[big.actors[coord]].is_member(S, elem)
                big.add(S, elem, coord, ctx)
                delta.add(S, elem, coord)
            else:
                big.remove(S, elem, coord)
                delta.remove(S, elem, coord)
        assert big.value(S, r=3) == delta.value(S, r=3)

    @given(ops_st)
    @settings(max_examples=40, deadline=None)
    def test_equivalence_survives_compaction(self, ops):
        big = BigsetCluster(3)
        riak = RiakSetCluster(3)
        for i, (kind, coord, elem) in enumerate(ops):
            if kind == "add":
                _, ctx = big.vnodes[big.actors[coord]].is_member(S, elem)
                big.add(S, elem, coord, ctx)
                riak.add(S, elem, coord)
            else:
                big.remove(S, elem, coord)
                riak.remove(S, elem, coord)
            if i % 7 == 3:
                big.compact_all()
        big.compact_all()
        assert big.value(S, r=3) == riak.value(S, r=3)

    @given(ops_st, st.integers(1, 3))
    @settings(max_examples=40, deadline=None)
    def test_quorum_reads_consistent(self, ops, r):
        big = BigsetCluster(3)
        for kind, coord, elem in ops:
            if kind == "add":
                big.add(S, elem, coord)
            else:
                big.remove(S, elem, coord)
        # synchronous replication -> any quorum returns the full value
        assert big.value(S, r=r) == big.value(S, r=3)


class TestConcurrencySemantics:
    def test_concurrent_add_remove_add_wins(self):
        big = BigsetCluster(3, sync=False)  # manual delivery
        big.add(S, b"x", 0)
        big.settle()
        # concurrent: replica1 removes x, replica2 re-adds x
        _, ctx = big.vnodes[big.actors[1]].is_member(S, b"x")
        big.remove(S, b"x", 1, ctx)
        _, ctx2 = big.vnodes[big.actors[2]].is_member(S, b"x")
        big.add(S, b"x", 2, ctx2)
        big.settle()
        for r in (1, 2, 3):
            assert b"x" in big.value(S, r=r)

    def test_remove_of_unseen_add_preempts(self):
        """§4.3.2: if the adds were unseen they never get added."""
        from repro.core.bigset import RemoveDelta

        a, b = BigsetVnode("a"), BigsetVnode("b")
        delta = a.coordinate_insert(S, b"x")
        # b learns of the removal (via a client ctx) before the add delta
        b.replica_remove(RemoveDelta(S, (delta.dot,)))
        b.replica_insert(delta)  # late add arrives
        assert b.value(S) == set()


class TestSetDigest:
    """The maintained per-set digest must track the fold-based truth exactly
    — anti-entropy's skip decision and subrange location both hang off it."""

    def _apply(self, big, ops):
        for kind, coord, elem in ops:
            if kind == "add":
                big.add(S, elem, coord)
            else:
                big.remove(S, elem, coord)

    @given(ops_st)
    @settings(max_examples=30, deadline=None)
    def test_survivors_digest_matches_fold(self, ops):
        from repro.core.clock import Clock

        big = BigsetCluster(3)
        self._apply(big, ops)
        for compacted in (False, True):
            if compacted:
                big.compact_all()
            for vn in big.vnodes.values():
                fold = Clock.zero().add_dots(d for _e, d in vn.fold(S))
                assert vn.survivors_digest(S) == fold, compacted

    def test_adoption_of_prepopulated_store(self):
        """A vnode handed an already-written store folds once to adopt, then
        its digest is exact — and that fold is background volume, not
        foreground read IO."""
        from repro.core.clock import Clock

        vn = BigsetVnode("a")
        for i in range(50):
            vn.coordinate_insert(S, b"e%03d" % i)
        _, ctx = vn.is_member(S, b"e000")
        vn.coordinate_remove(S, ctx)
        truth = Clock.zero().add_dots(d for _e, d in vn.fold(S))

        adopted = BigsetVnode("z", vn.store)
        before = adopted.store.stats.snapshot()
        assert adopted.survivors_digest(S) == truth
        delta = adopted.store.stats.delta(before)
        assert delta.num_seeks == 0
        assert delta.bytes_compacted > 0  # adoption billed as background

    def test_bucket_splits_bound_location(self):
        vn = BigsetVnode("a", digest_bucket_limit=32)
        for i in range(512):
            vn.coordinate_insert(S, b"%05d" % i)
        dig = vn._digest(S)
        assert len(dig.buckets) > 4  # fences actually formed
        assert dig.key_count() == 512
        # locating one dot names one narrow fenced subrange, not the set
        ranges = vn.digest_ranges(S, [Dot("a", 500)])
        assert len(ranges) == 1
        lo, hi = ranges[0]
        n_in = sum(1 for _ in vn.fold_raw(S, start=lo, end=hi))
        assert n_in <= 64

    def test_adoption_counts_exact_despite_midstream_splits(self):
        """Adopting a store bigger than one bucket triggers splits whose
        disk folds already place not-yet-adopted keys; re-adding them must
        be idempotent (dot sets AND counts)."""
        vn = BigsetVnode("a")
        for i in range(1000):
            vn.coordinate_insert(S, b"k%04d" % i)
        from repro.core.clock import Clock

        adopted = BigsetVnode("z", vn.store, digest_bucket_limit=64)
        dig = adopted._digest(S)
        assert dig.key_count() == 1000
        assert sum(dig.counts) == 1000
        # and the *total* digest lost nothing to the fold/adoption race —
        # a dropped dot here would make digest sync tombstone live keys
        truth = Clock.zero().add_dots(d for _e, d in vn.fold(S))
        assert adopted.survivors_digest(S) == truth

    def test_unsplittable_bucket_backs_off(self):
        """A bucket whose keys all share one element cannot split; its
        threshold must back off instead of re-folding on every write."""
        vn = BigsetVnode("a", digest_bucket_limit=8)
        for _ in range(9):  # overflow: split attempt fails, limit doubles
            _, ctx = vn.is_member(S, b"hot")
            vn.coordinate_insert(S, b"hot", ctx=ctx)
        dig = vn._digest(S)
        assert len(dig.buckets) == 1 and dig.limits[0] > 8
        before = vn.store.stats.bytes_compacted
        for _ in range(6):  # under the raised limit: no fold per write
            _, ctx = vn.is_member(S, b"hot")
            vn.coordinate_insert(S, b"hot", ctx=ctx)
        assert vn.store.stats.bytes_compacted == before

    def test_survivors_digest_cached_between_state_changes(self):
        vn = BigsetVnode("a")
        vn.coordinate_insert(S, b"x")
        vn.coordinate_insert(S, b"y")
        _, ctx = vn.is_member(S, b"x")
        vn.coordinate_remove(S, ctx)  # non-zero tombstone: cacheable path
        first = vn.survivors_digest(S)
        assert vn.survivors_digest(S) is first  # no re-enumeration
        vn.coordinate_insert(S, b"z")           # state change invalidates
        assert vn.survivors_digest(S) is not first

    def test_compact_drops_unbacked_tombstone_dots(self):
        """A remove redelivered after compaction re-tombstones a dot whose
        key is long gone; the next compaction must shed it (sync's trim is
        skipped when a reply leaves the tombstone unchanged, so compaction
        is the guaranteed hygiene point)."""
        vn = BigsetVnode("a")
        vn.coordinate_insert(S, b"x")
        _, ctx = vn.is_member(S, b"x")
        delta = vn.coordinate_remove(S, ctx)
        vn.compact()                      # key discarded, tombstone zeroed
        assert vn.read_tombstone(S).is_zero()
        vn.replica_remove(delta)          # dup delivery: unbacked dot returns
        assert not vn.read_tombstone(S).is_zero()
        vn.compact()
        assert vn.read_tombstone(S).is_zero()
