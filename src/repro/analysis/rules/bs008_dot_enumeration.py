"""BS008 — no raw per-dot cloud enumeration outside ``core/``.

Invariant 12 is the interval-clock bound: every clock operation and every
serialized clock costs O(interval runs) — causal metadata — never
O(events) or O(removed dots).  The run-granular surface
(``iter_runs``/``diff_runs``/``add_runs``/``subtract_clock``/
``intersect``/``n_runs``/``size_bytes``) preserves that bound; the
per-dot surface exists for core internals, tests, and oracles only.
One ``clock.all_dots()`` loop in cluster or serve code would quietly
re-introduce the O(fragmentation) cost the refactor removed — correct
answers, paper-breaking asymptotics.

Flagged, outside the mutation home (``core/``): reads of the ``.cloud``
compatibility property (it *materialises* per-actor frozensets from the
runs) and calls to ``.all_dots()``.  When the receiver provably has some
other type the access is fine; unresolved receivers are flagged
conservatively (suppress with a justification if the name is a
coincidence).  ``diff_dots`` stays sanctioned: it enumerates only the
actual divergence, already materialised from run subtraction.
"""
from __future__ import annotations

import ast

from .base import Rule, register


@register
class DotEnumerationRule(Rule):
    id = "BS008"
    title = "no raw per-dot cloud enumeration outside core/"
    invariant = "invariant 12 (clock cost is bounded by interval runs)"

    def applies(self) -> bool:
        return not self.ctx.rel.startswith(self.ctx.config.mutation_home)

    # ------------------------------------------------------------- visitors
    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if (isinstance(func, ast.Attribute)
                and func.attr in self.ctx.config.dot_enumeration_calls
                and self._clock_receiver(func.value)):
            self.report(func, f".{func.attr}() outside "
                              f"{self.ctx.config.mutation_home} — enumerates "
                              f"every dot; use the run-granular surface "
                              f"(iter_runs/diff_runs/add_runs, invariant 12)")
            # the callee Attribute is handled; still walk args etc.
            for child in ast.iter_child_nodes(node):
                if child is not func:
                    self.visit(child)
            self.visit(func.value)
            return
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if (node.attr in self.ctx.config.dot_enumeration_fields
                and self._clock_receiver(node.value)):
            self.report(node, f".{node.attr} outside "
                              f"{self.ctx.config.mutation_home} — the per-dot "
                              f"cloud view is O(events) to materialise; use "
                              f"iter_runs()/n_runs() (invariant 12)")
        self.generic_visit(node)

    # -------------------------------------------------------------- checks
    def _clock_receiver(self, value: ast.AST) -> bool:
        recv_type = self.ctx.resolver.infer_type(value)
        return recv_type is None or recv_type == "Clock"
