"""BS003 — ``Clock``/``SetDigest`` internals are mutated only in ``core/``.

The clock is documented as *purely functional* (every operation returns a
new clock) and the digest's structures are maintained solely by the write
path — invariants 2, 3, and 9 all assume no other layer reaches in and
attribute-assigns their fields.  ``Clock.zero()`` is even a shared
singleton: one ``clock.base = {...}`` outside ``core/`` could corrupt
every empty clock in the process.

Flagged, outside the mutation home (``core/``): plain, augmented, and
annotated assignments — including item assignment through the field,
``clock.cloud[a] = ...`` — to any protected field
(``Clock.base/cloud``, ``SetDigest.fences/buckets/...``).  When the
receiver's type resolves to something *else* the assignment is fine;
when it cannot be resolved at all the rule stays conservative and flags
(suppress with a justification if the name is a coincidence).
"""
from __future__ import annotations

import ast
from typing import Optional

from .base import Rule, register


@register
class ClockMutationRule(Rule):
    id = "BS003"
    title = "no Clock/SetDigest attribute assignment outside core/"
    invariant = "invariants 2, 3, 9 (functional clocks, write-path digests)"

    def applies(self) -> bool:
        return not self.ctx.rel.startswith(self.ctx.config.mutation_home)

    # ------------------------------------------------------------- visitors
    def visit_Assign(self, node: ast.Assign) -> None:
        for target in node.targets:
            self._check_target(target)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_target(node.target)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._check_target(node.target)
        self.generic_visit(node)

    # -------------------------------------------------------------- checks
    def _check_target(self, target: ast.AST) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._check_target(elt)
            return
        attr = self._protected_attr(target)
        if attr is None:
            return
        owners = [t for t, fields in self.ctx.config.protected_fields.items()
                  if attr.attr in fields]
        recv_type = self.ctx.resolver.infer_type(attr.value)
        if recv_type is not None and recv_type not in owners:
            return  # provably some other type's field
        certainty = (f"{recv_type}.{attr.attr}" if recv_type
                     else f".{attr.attr} (receiver type unresolved; field "
                          f"belongs to {'/'.join(owners)})")
        self.report(attr, f"assignment to {certainty} outside "
                          f"{self.ctx.config.mutation_home} — clocks and "
                          f"digests are mutated only by their own layer")

    def _protected_attr(self, target: ast.AST) -> Optional[ast.Attribute]:
        """The protected Attribute being written, unwrapping item writes
        (``x.cloud[a] = ...`` assigns *through* field ``cloud``)."""
        if isinstance(target, ast.Subscript):
            target = target.value
        if isinstance(target, ast.Attribute):
            fields = self.ctx.config.protected_fields.values()
            if any(target.attr in fs for fs in fields):
                return target
        return None
