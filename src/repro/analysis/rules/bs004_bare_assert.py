"""BS004 — no bare ``assert`` in library code (``python -O`` strips them).

CI runs an assert-stripped smoke job (``python -O``): any ``assert`` used
to validate inputs or guard a precondition silently vanishes there, and
the invalid state flows on — exactly how ``decode_element_key`` once
decoded clock keys into garbage dots (fixed in PR 2 by raising).
Validation must raise a typed exception (``ValueError``, ``PlanError``,
``KeyCodecError`` …); internal sanity checks that genuinely may be
compiled out can be suppressed with a justification.

Test-support code (``testing/``) is exempt: it exists to assert.
"""
from __future__ import annotations

import ast

from .base import Rule, register


@register
class BareAssertRule(Rule):
    id = "BS004"
    title = "library code raises typed exceptions, not assert"
    invariant = "CI `python -O` smoke discipline"

    def applies(self) -> bool:
        return not self.ctx.rel.startswith(
            tuple(self.ctx.config.assert_exempt))

    def visit_Assert(self, node: ast.Assert) -> None:
        self.report(node, "bare assert is stripped under python -O — raise "
                          "a typed exception so the -O smoke job exercises "
                          "the real error path")
        self.generic_visit(node)
