"""Rule plumbing: the registry, the base visitor, and findings.

A rule is one :class:`ast.NodeVisitor` subclass with a stable ``id``
(``BS###``), a one-line ``title``, and the architecture invariant it
enforces (``invariant`` — the number in docs/ARCHITECTURE.md, or a CI
discipline).  Rules see one file at a time through a
:class:`~repro.analysis.engine.FileContext` that carries the parsed
tree, the package-relative path for scoping, the shared
:class:`~repro.analysis.resolve.Resolver`, and the active config.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, Type

#: meta rule id used by the engine itself: parse failures, unknown rule
#: ids in suppressions, unused suppressions, missing justifications
META_RULE = "BS000"


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # as given on the command line / to run_lint
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


RULES: Dict[str, Type["Rule"]] = {}


def register(cls: Type["Rule"]) -> Type["Rule"]:
    if cls.id in RULES:
        raise ValueError(f"duplicate rule id {cls.id}")
    RULES[cls.id] = cls
    return cls


class Rule(ast.NodeVisitor):
    id: str = ""
    title: str = ""
    invariant: str = ""

    def __init__(self, ctx):
        self.ctx = ctx

    def applies(self) -> bool:
        """Path scoping: return False to skip this file entirely."""
        return True

    def run(self) -> None:
        if self.applies():
            self.visit(self.ctx.tree)

    def report(self, node: ast.AST, message: str) -> None:
        self.ctx.report(self.id, node, message)
