"""BS001 — no wall clocks or ambient randomness in deterministic layers.

The simulation, the property tests, and invariant 10's "identical runs
emit identical trees / byte-identical traffic" claims all rest on the
deterministic layers reading **only injected** clocks and RNGs.  A
``time.time()`` or module-level ``random.random()`` sneaking into
``core/``/``cluster/``/``query/``/``storage/``/``obs/``/``serve/``
breaks reproducibility invisibly: tests still pass, but two runs stop
being comparable.

Flagged: references to wall/monotonic clock functions (``time.time``,
``time.monotonic``, ``time.perf_counter``, ``datetime.now`` …), the
process-global RNGs (``random.*``, ``numpy.random.*``), ambient entropy
(``os.urandom``, ``uuid.uuid1/4``, ``secrets.*``), and seeded-RNG
factories called with **no** seed (``random.Random()``).

Allowed: seeded factories — ``random.Random(seed)``,
``numpy.random.default_rng(seed)`` — and everything under ``jax.random``
(key-passing, explicit by construction).
"""
from __future__ import annotations

import ast

from .base import Rule, register

WALL_CLOCK = frozenset({
    "time.time", "time.time_ns",
    "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "time.process_time", "time.process_time_ns",
    "time.clock_gettime",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
})

AMBIENT_ENTROPY = frozenset({"os.urandom", "uuid.uuid1", "uuid.uuid4"})
ENTROPY_PREFIXES = ("secrets.",)

#: factories that *capture* a seed: fine when called with one
SEEDED_FACTORIES = frozenset({
    "random.Random",
    "numpy.random.default_rng", "numpy.random.RandomState",
})

GLOBAL_RNG_PREFIXES = ("random.", "numpy.random.")


@register
class WallClockRule(Rule):
    id = "BS001"
    title = "deterministic layers read only injected clocks/RNGs"
    invariant = "determinism substrate (invariants 9–10, cluster sim)"

    def __init__(self, ctx):
        super().__init__(ctx)
        self._consumed = set()  # func nodes already judged by visit_Call

    def applies(self) -> bool:
        return self.ctx.rel.startswith(
            tuple(self.ctx.config.deterministic_layers))

    def visit_Call(self, node: ast.Call) -> None:
        dotted = self.ctx.resolver.dotted(node.func)
        if dotted in SEEDED_FACTORIES:
            self._consumed.add(id(node.func))
            if not node.args and not node.keywords:
                self.report(node, f"unseeded {dotted}() — pass an explicit "
                                  f"seed so runs are reproducible")
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        self._check(node)
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        self._check(node)

    def _check(self, node: ast.AST) -> None:
        if id(node) in self._consumed:
            return
        dotted = self.ctx.resolver.dotted(node)
        if dotted is None:
            return
        if dotted in WALL_CLOCK:
            self.report(node, f"wall-clock read {dotted} in a deterministic "
                              f"layer — inject a clock instead")
        elif dotted in AMBIENT_ENTROPY or dotted.startswith(ENTROPY_PREFIXES):
            self.report(node, f"ambient entropy {dotted} in a deterministic "
                              f"layer — inject a seeded Rng instead")
        elif dotted.startswith(GLOBAL_RNG_PREFIXES) \
                and dotted not in SEEDED_FACTORIES:
            self.report(node, f"process-global RNG {dotted} in a "
                              f"deterministic layer — use an injected, "
                              f"seeded Random/Generator instance")
