"""BS002 — every ``Network.send`` call site bills explicit wire bytes.

``Network.bytes_sent`` feeds the paper's wire-cost comparisons (§3, §5)
and the ``net.*`` metrics; one call site passing a default or missing
``size_bytes`` silently zeroes a whole benchmark column (the PR 6 bug
class: a zero-billed send made anti-entropy traffic look free).  The
runtime guard in ``cluster/sim.py`` rejects non-empty payloads billed at
zero, but only when that path *executes* — this rule moves the check to
the call site, statically.

A send is compliant when it passes four positional arguments
(``src, dst, payload, size_bytes``) or an explicit ``size_bytes=``
keyword.  Receivers are recognised by resolved type (``Network``) or,
when unresolvable, by the conventional attribute names ``net`` /
``network``.
"""
from __future__ import annotations

import ast

from .base import Rule, register
from ..resolve import terminal_name


@register
class BilledSendRule(Rule):
    id = "BS002"
    title = "Network.send call sites pass an explicit size_bytes"
    invariant = "wire-cost accounting (§3/§5 tables, net.* metrics)"

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute) and func.attr == "send":
            recv_type = self.ctx.resolver.infer_type(func.value)
            hinted = terminal_name(func.value) in \
                self.ctx.config.network_attr_hints
            if recv_type in self.ctx.config.network_types \
                    or (recv_type is None and hinted):
                if not self._bills_size(node):
                    self.report(node, "Network.send without an explicit "
                                      "size_bytes — unbilled wire traffic "
                                      "zeroes the §3/§5 byte comparisons")
        self.generic_visit(node)

    @staticmethod
    def _bills_size(node: ast.Call) -> bool:
        if any(kw.arg == "size_bytes" for kw in node.keywords):
            return True
        if any(kw.arg is None for kw in node.keywords):
            return True  # **kwargs: give the benefit of the doubt
        if any(isinstance(a, ast.Starred) for a in node.args):
            return True  # *args: cannot count statically
        return len(node.args) >= 4
