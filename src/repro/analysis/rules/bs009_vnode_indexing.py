"""BS009 — no direct vnode indexing outside ``cluster/placement.py``.

Partitioned placement (invariant 13) makes "which vnode holds this?" a
ring question: owners come from ``Ring.preference_list`` /
``plan_coverage``, never from a position in a vnode list.  A literal
``self.vnodes[0]`` or ``_actor(2)`` hardwires an owner that a ring-epoch
bump may move — correct today, silently wrong after the next handoff,
and invisible to the coverage accounting the wire-billing claims rest
on.  The placement module itself is the one home allowed to turn
positions into identities (it *defines* the ranking); everywhere else,
indexing a vnode collection is only sanctioned with a computed key (an
actor name, a routed variable) — literal integer positions are flagged.

Flagged, outside ``placement_home``: subscripts of receivers named in
``vnode_collections`` (``vnodes`` / ``actors`` / ``stores``) with a
literal-int index, and calls to the routing helpers in
``vnode_route_calls`` (``_actor`` / ``_coordinator``) passing a literal
int.  Slices (``actors[:r]``) and computed keys stay clean — quorum
prefixes and name-keyed lookups are not placement decisions.
"""
from __future__ import annotations

import ast

from .base import Rule, register


@register
class VnodeIndexingRule(Rule):
    id = "BS009"
    title = "no direct vnode indexing outside cluster/placement.py"
    invariant = "invariant 13 (all routing goes through the ring)"

    def applies(self) -> bool:
        return self.ctx.rel != self.ctx.config.placement_home

    # ------------------------------------------------------------- visitors
    def visit_Subscript(self, node: ast.Subscript) -> None:
        name = self._collection_name(node.value)
        if (name in self.ctx.config.vnode_collections
                and self._literal_int(node.slice) is not None):
            self.report(
                node,
                f"literal index into .{name} — placement belongs to the "
                f"ring ({self.ctx.config.placement_home}); route via "
                f"Ring.preference_list/plan_coverage (invariant 13)")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        name = (func.attr if isinstance(func, ast.Attribute)
                else func.id if isinstance(func, ast.Name) else None)
        if name in self.ctx.config.vnode_route_calls and any(
                self._literal_int(a) is not None for a in node.args):
            self.report(
                node,
                f"{name}() with a literal vnode position — hardwires an "
                f"owner the ring may move; pass a routed actor "
                f"(invariant 13)")
        self.generic_visit(node)

    # -------------------------------------------------------------- checks
    @staticmethod
    def _collection_name(value: ast.AST):
        if isinstance(value, ast.Attribute):
            return value.attr
        if isinstance(value, ast.Name):
            return value.id
        return None

    @staticmethod
    def _literal_int(node: ast.AST):
        """The int a literal (possibly negated) index denotes, else None."""
        if (isinstance(node, ast.UnaryOp)
                and isinstance(node.op, (ast.USub, ast.UAdd))):
            node = node.operand
        if (isinstance(node, ast.Constant) and isinstance(node.value, int)
                and not isinstance(node.value, bool)):
            return node.value
        return None
