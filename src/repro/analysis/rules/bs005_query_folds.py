"""BS005 — ``query/`` and ``serve/`` seek; they never full-fold.

Invariant 4 is the paper's §4.4 promise: a query costs O(result + causal
metadata), because every plan positions the LSM iterator and stops at
its range end.  The full-fold entry points on the vnode —
``fold``/``fold_values`` (whole-set streams), ``read_full``/``value``
(materialise the set) — exist for tests, checkpoints, and anti-entropy's
baseline, and one call from the query or serve layer would quietly turn
a seek-priced plan into an O(n) scan that still returns the right
answer.  The bounded entry points (``fold_raw``, ``fold_postings``,
``element_cursor``, ``store.seek(lo, hi)``) are the sanctioned surface.

Also flagged: ``.scan()`` called with no bounds — the storage layer's
everything-iterator.
"""
from __future__ import annotations

import ast

from .base import Rule, register


@register
class QueryFoldRule(Rule):
    id = "BS005"
    title = "query/serve layers never call full-fold storage entry points"
    invariant = "invariants 4 and 7 (queries seek, never fold)"

    def applies(self) -> bool:
        return self.ctx.rel.startswith(
            tuple(self.ctx.config.seek_only_layers))

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute):
            if func.attr in self.ctx.config.fold_denylist:
                self.report(node, f"full-fold entry point .{func.attr}() in "
                                  f"a seek-only layer — use fold_raw/"
                                  f"fold_postings/element_cursor with bounds "
                                  f"(invariant 4)")
            elif func.attr == "scan" and not node.args and not node.keywords:
                self.report(node, "unbounded .scan() in a seek-only layer — "
                                  "pass [lo, hi) bounds or use seek()")
        self.generic_visit(node)
