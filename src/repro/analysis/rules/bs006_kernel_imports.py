"""BS006 — device kernel files import only the device stack.

``kernels/*/kernel.py`` is the Pallas-lowered device code; its siblings
hold everything host-side (``ref.py``: the numpy reference the tests
diff against; ``ops.py``: dispatch, padding, ledgers).  A ``numpy``
import inside ``kernel.py`` is the classic smell that host logic leaked
into the traced path — it either breaks lowering outright or, worse,
runs at trace time and bakes host values into the compiled kernel.

Allowed roots: ``jax`` (which includes ``jax.numpy`` and
``jax.experimental.pallas``) plus compile-time stdlib
(``functools``/``typing``/``math``/``__future__``).  Relative imports
are flagged too: a kernel reaching into its own package is pulling host
helpers across the device boundary.
"""
from __future__ import annotations

import ast
from fnmatch import fnmatch

from .base import Rule, register


@register
class KernelImportRule(Rule):
    id = "BS006"
    title = "kernels/*/kernel.py imports only jax/pallas (+compile-time stdlib)"
    invariant = "device/host split of the kernel packages"

    def applies(self) -> bool:
        return fnmatch(self.ctx.rel, self.ctx.config.kernel_glob)

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self._check_root(node, alias.name.split(".")[0])

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.level > 0:
            self.report(node, "relative import in a device kernel file — "
                              "host-side helpers belong in ops.py/ref.py")
            return
        self._check_root(node, (node.module or "").split(".")[0])

    def _check_root(self, node: ast.AST, root: str) -> None:
        if root in self.ctx.config.kernel_allowed_roots:
            return
        hint = (" (host-side numpy belongs in ref.py)"
                if root == "numpy" else "")
        self.report(node, f"import of {root!r} in a device kernel file — "
                          f"only {'/'.join(sorted(self.ctx.config.kernel_allowed_roots))} "
                          f"may cross the device boundary{hint}")
