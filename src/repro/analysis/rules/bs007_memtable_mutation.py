"""BS007 — ``storage/`` memtables are mutated only by WAL-billed entry points.

Invariant 11 (acknowledged ⇒ durable) holds because every memtable write
is framed into the WAL *in the same entry point* that applies it:
``put_batch`` (append + group commit), ``flush`` (swaps in a fresh dict
after publishing the durable segment), ``recover`` (replays the durable
WAL), and construction.  A memtable mutation anywhere else in the
storage layer is state a crash cannot replay — silently un-durable data
that no test would catch until a restart loses it.

Flagged, inside ``storage/`` but outside the configured entry points
(matched by *enclosing function name*, so helpers must route through the
write path rather than rename themselves around the rule): item and
attribute assignment to a ``memtable`` (including through-subscript
writes and tuple-unpacking targets), ``del``, augmented assignment, and
the mutating dict methods (``pop``/``clear``/``update``/``setdefault``/
``popitem``).  Reads are free.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from .base import Rule, register

_MUTATING_CALLS = frozenset({"pop", "clear", "update", "setdefault",
                             "popitem"})


@register
class MemtableMutationRule(Rule):
    id = "BS007"
    title = "storage/ memtable writes flow through WAL-billed entry points"
    invariant = "invariant 11 (acknowledged => durable)"

    def __init__(self, ctx):
        super().__init__(ctx)
        self._funcs: List[str] = []

    def applies(self) -> bool:
        return self.ctx.rel.startswith(self.ctx.config.memtable_layer)

    # ---------------------------------------------------------- func stack
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._funcs.append(node.name)
        self.generic_visit(node)
        self._funcs.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _allowed_here(self) -> bool:
        return bool(self._funcs) and (
            self._funcs[-1] in self.ctx.config.memtable_entrypoints)

    # ------------------------------------------------------------- visitors
    def visit_Assign(self, node: ast.Assign) -> None:
        for target in node.targets:
            self._check_target(target)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_target(node.target)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._check_target(node.target)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for target in node.targets:
            self._check_target(target)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if (isinstance(func, ast.Attribute)
                and func.attr in _MUTATING_CALLS
                and self._memtable_attr(func.value) is not None
                and not self._allowed_here()):
            self._flag(func, f"memtable.{func.attr}(...)")
        self.generic_visit(node)

    # -------------------------------------------------------------- checks
    def _check_target(self, target: ast.AST) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._check_target(elt)
            return
        written = target
        if isinstance(written, ast.Subscript):
            written = written.value  # memtable[k] = v writes *through* it
        if self._memtable_attr(written) is None:
            return
        if self._allowed_here():
            return
        kind = ("memtable[...]" if isinstance(target, ast.Subscript)
                else "memtable rebind")
        self._flag(written, kind)

    def _memtable_attr(self, node: ast.AST) -> Optional[ast.AST]:
        """The node naming a memtable: ``x.memtable`` or a bare ``memtable``."""
        if isinstance(node, ast.Attribute) and node.attr == "memtable":
            return node
        if isinstance(node, ast.Name) and node.id == "memtable":
            return node
        return None

    def _flag(self, node: ast.AST, what: str) -> None:
        where = self._funcs[-1] if self._funcs else "<module>"
        allowed = "/".join(sorted(self.ctx.config.memtable_entrypoints))
        self.report(node, f"{what} mutated in {where}() — storage memtables "
                          f"change only inside {allowed} (WAL-billed write "
                          f"path), anything else is un-replayable state")
