"""The bigset-lint rule pack.

Importing this package registers every rule in :data:`RULES` (keyed by
``BS###`` id).  Adding a rule = adding a module here that decorates its
class with :func:`register`; the roadmap's interval-clock and
partitioned-placement work is expected to land rules the same way.
"""
from .base import META_RULE, RULES, Finding, Rule, register

from . import (bs001_wallclock, bs002_billed_send, bs003_clock_mutation,
               bs004_bare_assert, bs005_query_folds, bs006_kernel_imports,
               bs007_memtable_mutation, bs008_dot_enumeration,
               bs009_vnode_indexing)  # noqa: F401 (import = registration)

__all__ = ["META_RULE", "RULES", "Finding", "Rule", "register"]
