"""``python -m repro.analysis [paths...]`` — run bigset-lint.

Exit status: 0 clean, 1 findings, 2 usage error.  ``--json-out`` writes
the machine-readable report beside whatever lands in the log, so CI gets
both the human lines and the artifact from one invocation.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from .config import DEFAULT_CONFIG
from .engine import run_lint
from .report import render_human, render_json_text, render_rule_list
from .rules import RULES


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="bigset-lint: AST-level enforcement of the architecture "
                    "invariants (docs/ARCHITECTURE.md § Static analysis).")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directory trees to lint (default: src)")
    parser.add_argument("--format", choices=("human", "json"), default="human")
    parser.add_argument("--json-out", metavar="PATH",
                        help="also write the JSON report to PATH")
    parser.add_argument("--select", metavar="IDS",
                        help="comma list of rule ids to run (default: all)")
    parser.add_argument("--ignore", metavar="IDS", default="",
                        help="comma list of rule ids to skip")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule pack and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        print(render_rule_list())
        return 0

    def _ids(spec: str):
        ids = frozenset(s.strip() for s in spec.split(",") if s.strip())
        unknown = ids - set(RULES)
        if unknown:
            parser.error(f"unknown rule id(s): {', '.join(sorted(unknown))}")
        return ids

    config = DEFAULT_CONFIG.with_rules(
        select=_ids(args.select) if args.select else None,
        ignore=_ids(args.ignore) if args.ignore else frozenset())

    result = run_lint(args.paths, config)
    print(render_json_text(result) if args.format == "json"
          else render_human(result))
    if args.json_out:
        with open(args.json_out, "w") as fh:
            fh.write(render_json_text(result))
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
