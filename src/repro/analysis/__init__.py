"""bigset-lint: project-specific static analysis over Python's ``ast``.

The architecture's invariants (docs/ARCHITECTURE.md) are discipline the
code cannot locally see — writes read only clocks, queries seek and
never fold, every ``Network.send`` bills wire bytes, disabled tracing
leaves traffic byte-identical.  This package turns the enforceable
subset into CI-gated rules:

========  ==========================================================
BS001     deterministic layers read only injected clocks/RNGs
BS002     ``Network.send`` call sites pass an explicit ``size_bytes``
BS003     ``Clock``/``SetDigest`` fields mutated only in ``core/``
BS004     library code raises typed exceptions, not bare ``assert``
BS005     ``query/``/``serve/`` never call full-fold entry points
BS006     ``kernels/*/kernel.py`` imports only the device stack
BS007     ``storage/`` memtables mutate only in WAL-billed entry points
========  ==========================================================

Run it: ``python -m repro.analysis src`` (exit 1 on findings).  Silence
a deliberate exception at its line, justification required::

    ... # bigset-lint: disable=BS001 -- injectable default; tests inject

Programmatic use: :func:`run_lint` returns a :class:`LintResult`; the
per-rule ``NodeVisitor``s share one import/symbol
:class:`~repro.analysis.resolve.Resolver` per file, and new rules
register by decorating a :class:`~repro.analysis.rules.Rule` subclass
with :func:`~repro.analysis.rules.register`.
"""
from .config import DEFAULT_CONFIG, LintConfig
from .engine import FileContext, LintResult, lint_file, run_lint
from .report import render_human, render_json, render_json_text
from .rules import META_RULE, RULES, Finding, Rule, register

__all__ = [
    "DEFAULT_CONFIG", "LintConfig", "FileContext", "LintResult",
    "lint_file", "run_lint", "render_human", "render_json",
    "render_json_text", "META_RULE", "RULES", "Finding", "Rule", "register",
]
