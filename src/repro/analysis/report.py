"""Finding reporters: human one-line-per-finding, and JSON for CI.

The JSON document is what the CI lint job uploads as an artifact; its
shape is stable (``version`` bumps on change) so downstream tooling can
trend finding counts without scraping the log.
"""
from __future__ import annotations

import json
from typing import Any, Dict

from .engine import LintResult
from .rules import RULES

JSON_VERSION = 1


def render_human(result: LintResult) -> str:
    lines = [f.render() for f in result.findings]
    verdict = "clean" if result.ok else f"{len(result.findings)} finding(s)"
    lines.append(
        f"bigset-lint: {verdict} — {result.files_checked} file(s), "
        f"{len(result.rules)} rule(s), {result.suppressed} suppressed")
    return "\n".join(lines)


def render_json(result: LintResult) -> Dict[str, Any]:
    return {
        "version": JSON_VERSION,
        "ok": result.ok,
        "files_checked": result.files_checked,
        "rules": list(result.rules),
        "suppressed": result.suppressed,
        "findings": [
            {"rule": f.rule, "path": f.path, "line": f.line,
             "col": f.col, "message": f.message}
            for f in result.findings
        ],
    }


def render_json_text(result: LintResult) -> str:
    return json.dumps(render_json(result), indent=1)


def render_rule_list() -> str:
    """``--list-rules``: id, scope-defining invariant, one-line title."""
    lines = []
    for rid in sorted(RULES):
        rule = RULES[rid]
        lines.append(f"{rid}  {rule.title}")
        lines.append(f"       enforces: {rule.invariant}")
    return "\n".join(lines)
