"""The lint driver: file discovery, suppressions, and rule execution.

One pass per file: parse once, build one shared
:class:`~repro.analysis.resolve.Resolver`, run every in-scope rule over
the tree, then apply suppressions.

Suppression syntax (line-scoped, justification **required**)::

    self.clock = time.monotonic  # bigset-lint: disable=BS001 -- injectable default; tests inject a fake

A suppression that names an unknown rule, lacks the ``-- why`` tail, or
suppresses nothing on its line is itself a finding (``BS000``) — stale
escapes rot into silent holes otherwise, so the engine treats them as
lint debt too.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .config import DEFAULT_CONFIG, LintConfig
from .resolve import Resolver
from .rules import META_RULE, RULES, Finding

_SUPPRESS_RE = re.compile(
    r"#\s*bigset-lint:\s*disable=([A-Za-z0-9_,\s]+?)"
    r"(?:--\s*(.*?))?\s*$")


@dataclass
class Suppression:
    line: int
    rules: Tuple[str, ...]
    justification: str
    used: set = field(default_factory=set)


@dataclass
class FileContext:
    """Everything a rule sees about the file under analysis."""
    path: str            # as reported in findings
    rel: str             # package-relative path, for config scoping
    tree: ast.Module
    resolver: Resolver
    config: LintConfig
    findings: List[Finding] = field(default_factory=list)

    def report(self, rule_id: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule_id, self.path,
            getattr(node, "lineno", 0), getattr(node, "col_offset", 0),
            message))


@dataclass
class LintResult:
    findings: List[Finding]
    files_checked: int
    rules: Tuple[str, ...]        # rule ids that ran
    suppressed: int = 0           # findings silenced by used suppressions

    @property
    def ok(self) -> bool:
        return not self.findings


def package_rel(path: Path) -> str:
    """``path`` relative to its enclosing ``repro`` package directory.

    ``src/repro/core/clock.py`` -> ``core/clock.py``;
    ``tests/lint_fixtures/repro/core/x.py`` -> ``core/x.py`` — the same
    scoped config lints both the real tree and the test fixtures.  A path
    with no ``repro`` ancestor scopes by its own parts.
    """
    parts = path.parts
    for i in range(len(parts) - 2, -1, -1):
        if parts[i] == "repro":
            return "/".join(parts[i + 1:])
    return path.as_posix()


def iter_python_files(paths: Sequence[str]) -> Iterable[Path]:
    seen = set()
    for raw in paths:
        p = Path(raw)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            if f not in seen:
                seen.add(f)
                yield f


def parse_suppressions(source: str, active_rules: Sequence[str]
                       ) -> Tuple[Dict[int, Suppression], List[Tuple[int, str]]]:
    """Line -> suppression, plus (line, message) syntax problems.

    Tokenizes rather than greps, so only genuine ``#`` comments count — a
    docstring *describing* the syntax is not a suppression.
    """
    table: Dict[int, Suppression] = {}
    problems: List[Tuple[int, str]] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError):
        return table, problems  # the parse finding already covers this file
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        lineno = tok.start[0]
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        justification = (m.group(2) or "").strip()
        for r in rules:
            if r not in RULES and r != META_RULE:
                problems.append(
                    (lineno, f"suppression names unknown rule {r!r}"))
        if not justification:
            problems.append(
                (lineno, "suppression without a justification — append "
                         "'-- why this is safe'"))
        table[lineno] = Suppression(lineno, rules, justification)
    return table, problems


def lint_file(path: Path, config: LintConfig,
              rule_ids: Sequence[str]) -> Tuple[List[Finding], int]:
    """Lint one file; returns (findings, suppressed_count)."""
    display = str(path)
    try:
        source = path.read_text(encoding="utf-8")
        tree = ast.parse(source, filename=display)
    except (SyntaxError, UnicodeDecodeError, OSError) as exc:
        line = getattr(exc, "lineno", 0) or 0
        return [Finding(META_RULE, display, line, 0,
                        f"could not parse: {exc}")], 0

    ctx = FileContext(display, package_rel(path), tree, Resolver(tree), config)
    for rid in rule_ids:
        RULES[rid](ctx).run()

    suppressions, problems = parse_suppressions(source, rule_ids)
    kept: List[Finding] = []
    suppressed = 0
    for finding in ctx.findings:
        sup = suppressions.get(finding.line)
        if sup is not None and finding.rule in sup.rules:
            sup.used.add(finding.rule)
            suppressed += 1
        else:
            kept.append(finding)
    for line, msg in problems:
        kept.append(Finding(META_RULE, display, line, 0, msg))
    for sup in suppressions.values():
        for rid in sup.rules:
            # only judge unusedness for rules that actually ran: a narrowed
            # --select must not make every other suppression look stale
            if rid in rule_ids and rid not in sup.used:
                kept.append(Finding(
                    META_RULE, display, sup.line, 0,
                    f"unused suppression of {rid} — nothing on this line "
                    f"triggers it; delete the escape"))
    return kept, suppressed


def run_lint(paths: Sequence[str],
             config: Optional[LintConfig] = None) -> LintResult:
    """Run the active rule pack over ``paths`` (files or directory trees)."""
    config = config or DEFAULT_CONFIG
    rule_ids = tuple(rid for rid in sorted(RULES) if config.runs(rid))
    findings: List[Finding] = []
    files = 0
    suppressed = 0
    for path in iter_python_files(paths):
        files += 1
        got, sup = lint_file(path, config, rule_ids)
        findings.extend(got)
        suppressed += sup
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return LintResult(findings, files, rule_ids, suppressed)
