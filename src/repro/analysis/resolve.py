"""Shared symbol and import resolution for the rule pack.

One :class:`Resolver` is built per file and handed to every rule, so each
rule answers two questions without owning its own scope analysis:

* :meth:`Resolver.dotted` — what fully-qualified module path does this
  ``Name``/``Attribute`` chain denote?  (``np.random.rand`` resolves
  through ``import numpy as np`` to ``numpy.random.rand``; a chain rooted
  in a local variable resolves to ``None``.)
* :meth:`Resolver.infer_type` — what class does this expression hold?
  Resolution is deliberately shallow but covers the codebase's idioms:
  constructor calls (``Network()``), classmethod factories
  (``Clock.zero()``), ``a or Network()`` defaults, annotated parameters
  (``net: Network``), annotated/constructed locals, and ``self.x``
  attributes assigned or annotated anywhere in the enclosing class.

Unknown stays unknown (``None``) — rules choose how conservative to be.
"""
from __future__ import annotations

import ast
from typing import Dict, Optional, Tuple


def terminal_name(node: ast.AST) -> Optional[str]:
    """The last identifier of a ``Name``/``Attribute`` chain (else None)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _annotation_type(ann: Optional[ast.AST]) -> Optional[str]:
    """Class name out of a simple annotation (``Network``, ``sim.Network``,
    ``Optional[Network]`` is *not* unwrapped — shallow on purpose)."""
    name = terminal_name(ann) if ann is not None else None
    if name and name[:1].isupper():
        return name
    return None


class Resolver:
    def __init__(self, tree: ast.Module):
        self.tree = tree
        #: local alias -> dotted module/attr path ("np" -> "numpy")
        self.imports: Dict[str, str] = {}
        #: module-level class definitions in this file
        self.classes: set = set()
        #: (class name, attr) -> type name, from self.<attr> = / : annotations
        self.class_attr_types: Dict[Tuple[str, str], str] = {}
        #: id(function node) -> {local name: type name}
        self.func_local_types: Dict[int, Dict[str, str]] = {}
        #: id(node) -> parent node, for enclosing-scope lookup
        self.parents: Dict[int, ast.AST] = {}
        self._build(tree)

    # ------------------------------------------------------------- building
    def _build(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parents[id(child)] = node
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    self.imports[alias.asname or alias.name.split(".")[0]] = (
                        alias.name if alias.asname else alias.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                for alias in node.names:
                    full = f"{node.module}.{alias.name}" if node.module else alias.name
                    self.imports[alias.asname or alias.name] = full
            elif isinstance(node, ast.ImportFrom):
                # relative import: unresolvable module path, but the bound
                # name may still be a class (".clock" -> "Clock")
                for alias in node.names:
                    if alias.name[:1].isupper():
                        self.classes.add(alias.asname or alias.name)
            elif isinstance(node, ast.ClassDef):
                self.classes.add(node.name)
                self._index_class(node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._index_function(node)

    def _index_class(self, cls: ast.ClassDef) -> None:
        for node in ast.walk(cls):
            target = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                target, value = node.targets[0], node.value
            elif isinstance(node, ast.AnnAssign):
                target, value = node.target, node.value
            else:
                continue
            if not (isinstance(target, ast.Attribute)
                    and isinstance(target.value, ast.Name)
                    and target.value.id == "self"):
                continue
            typ = (_annotation_type(node.annotation)
                   if isinstance(node, ast.AnnAssign) else None)
            typ = typ or self._expr_type(value)
            if typ:
                self.class_attr_types.setdefault((cls.name, target.attr), typ)

    def _index_function(self, fn) -> None:
        locals_: Dict[str, str] = {}
        args = fn.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs
                  + ([args.vararg] if args.vararg else [])
                  + ([args.kwarg] if args.kwarg else [])):
            typ = _annotation_type(a.annotation)
            if typ:
                locals_[a.arg] = typ
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                typ = self._expr_type(node.value)
                if typ:
                    locals_[node.targets[0].id] = typ
            elif isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name):
                typ = _annotation_type(node.annotation) \
                    or self._expr_type(node.value)
                if typ:
                    locals_[node.target.id] = typ
        self.func_local_types[id(fn)] = locals_

    def _expr_type(self, expr: Optional[ast.AST]) -> Optional[str]:
        """Type of a constructing expression, else None."""
        if expr is None:
            return None
        if isinstance(expr, ast.Call):
            return self._ctor_name(expr.func)
        if isinstance(expr, ast.BoolOp):  # ``net or Network()`` defaults
            for operand in expr.values:
                typ = self._expr_type(operand)
                if typ:
                    return typ
        return None

    def _ctor_name(self, func: ast.AST) -> Optional[str]:
        """Class name a call constructs: ``Network(...)``, ``sim.Network(...)``,
        and classmethod factories like ``Clock.zero()``."""
        dotted = self.dotted(func)
        segs = dotted.split(".") if dotted else []
        if not segs:
            name = terminal_name(func)
            if isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name) \
                    and func.value.id in self.classes:
                return func.value.id  # LocalClass.factory()
            if name and name in self.classes:
                return name
            return name if name and name[:1].isupper() else None
        # rightmost Capitalized segment is the class; trailing lowercase
        # segments are factory methods on it
        for seg in reversed(segs):
            if seg[:1].isupper():
                return seg
        return None

    # -------------------------------------------------------------- queries
    def dotted(self, node: ast.AST) -> Optional[str]:
        """Fully-resolved dotted path of a Name/Attribute chain rooted in an
        import, else None (local receivers are *not* module references)."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.imports.get(node.id)
        if root is None:
            return None
        parts.append(root)
        return ".".join(reversed(parts))

    def enclosing(self, node: ast.AST, kinds) -> Optional[ast.AST]:
        cur = self.parents.get(id(node))
        while cur is not None:
            if isinstance(cur, kinds):
                return cur
            cur = self.parents.get(id(cur))
        return None

    def infer_type(self, expr: ast.AST) -> Optional[str]:
        """Best-effort class name held by ``expr`` (see module docstring)."""
        if isinstance(expr, ast.Name) and expr.id == "self":
            cls = self.enclosing(expr, ast.ClassDef)
            if cls is not None:
                return cls.name
        if isinstance(expr, ast.Name):
            fn = self.enclosing(expr, (ast.FunctionDef, ast.AsyncFunctionDef))
            while fn is not None:
                typ = self.func_local_types.get(id(fn), {}).get(expr.id)
                if typ:
                    return typ
                fn = self.enclosing(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
            return None
        if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name) \
                and expr.value.id == "self":
            cls = self.enclosing(expr, ast.ClassDef)
            if cls is not None:
                return self.class_attr_types.get((cls.name, expr.attr))
            return None
        return self._expr_type(expr)
