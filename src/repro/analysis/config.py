"""bigset-lint configuration: which rules run, and where.

Every scoping decision the rule pack makes is data here, not code in the
rules: the deterministic layers BS001 patrols, the protected field sets
BS003 guards, the storage entry points BS005 forbids, the import
allowlist BS006 grants kernel files.  Paths are matched against the
location of a file *inside* the ``repro`` package (``core/clock.py``,
``kernels/dot_seen/kernel.py``), so the same config lints the installed
tree and the test fixtures alike.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import FrozenSet, Mapping, Optional, Tuple


@dataclass(frozen=True)
class LintConfig:
    # ----------------------------------------------------------- rule choice
    #: run only these rule ids (None = every registered rule)
    select: Optional[FrozenSet[str]] = None
    #: never run these rule ids
    ignore: FrozenSet[str] = frozenset()

    # ------------------------------------------------------------ BS001 scope
    #: layers whose behaviour must be reproducible from injected inputs:
    #: identical seeds/clocks must yield identical traffic, trees, and bytes
    deterministic_layers: Tuple[str, ...] = (
        "core/", "cluster/", "query/", "storage/", "obs/", "serve/",
    )

    # ------------------------------------------------------------ BS002 types
    #: receiver types whose ``.send`` must bill explicit wire bytes
    network_types: FrozenSet[str] = frozenset({"Network"})
    #: receiver *names* treated as networks when the type cannot be resolved
    network_attr_hints: FrozenSet[str] = frozenset({"net", "network"})

    # ----------------------------------------------------------- BS003 fields
    #: type -> fields that only ``mutation_home`` may attribute-assign
    protected_fields: Mapping[str, Tuple[str, ...]] = field(
        default_factory=lambda: {
            "Clock": ("base", "cloud", "runs"),
            "SetDigest": ("bucket_limit", "fences", "buckets", "counts",
                          "limits", "_total", "_pend_add", "_pend_sub",
                          "_surv"),
        })
    #: the one layer allowed to mutate those fields (their defining home)
    mutation_home: str = "core/"

    # ------------------------------------------------------------ BS004 scope
    #: paths where bare ``assert`` is tolerated (test support only)
    assert_exempt: Tuple[str, ...] = ("testing/",)

    # ------------------------------------------------------------ BS005 scope
    #: layers bound by invariant 4 ("queries seek, never fold")
    seek_only_layers: Tuple[str, ...] = ("query/", "serve/")
    #: full-fold entry points those layers must never call
    fold_denylist: FrozenSet[str] = frozenset(
        {"fold", "fold_values", "read_full", "value"})

    # ----------------------------------------------------------- BS006 scope
    #: glob (against the package-relative path) naming device-kernel files
    kernel_glob: str = "kernels/*/kernel.py"
    #: top-level modules a kernel file may import; everything else —
    #: including host-side numpy — belongs in the sibling ``ref.py``/``ops.py``
    kernel_allowed_roots: FrozenSet[str] = frozenset(
        {"__future__", "jax", "functools", "typing", "math"})

    # ----------------------------------------------------------- BS007 scope
    #: the layer whose memtables are WAL-guarded (invariant 11)
    memtable_layer: str = "storage/"
    #: functions allowed to mutate a ``memtable``: the WAL-billed write
    #: path, the flush/recovery lifecycle, and construction — everything
    #: else would apply state a crash could not replay
    memtable_entrypoints: FrozenSet[str] = frozenset(
        {"__init__", "put_batch", "flush", "recover"})

    # ----------------------------------------------------------- BS008 scope
    #: Clock members that materialise the raw per-dot cloud; outside
    #: ``mutation_home`` only the run-granular surface is sanctioned
    dot_enumeration_fields: FrozenSet[str] = frozenset({"cloud"})
    #: Clock methods that enumerate every dot (``diff_dots`` stays allowed:
    #: it materialises only the actual divergence)
    dot_enumeration_calls: FrozenSet[str] = frozenset({"all_dots"})

    # ----------------------------------------------------------- BS009 scope
    #: the one module allowed to turn positions into vnode identities
    placement_home: str = "cluster/placement.py"
    #: collection names whose literal-int subscripts are placement
    #: decisions (``self.vnodes[0]`` hardwires an owner the ring may move)
    vnode_collections: FrozenSet[str] = frozenset(
        {"vnodes", "actors", "stores"})
    #: routing helpers that must not be fed literal vnode positions
    vnode_route_calls: FrozenSet[str] = frozenset({"_actor", "_coordinator"})

    # ------------------------------------------------------------------ misc
    def runs(self, rule_id: str) -> bool:
        if rule_id in self.ignore:
            return False
        return self.select is None or rule_id in self.select

    def with_rules(self, select: Optional[FrozenSet[str]] = None,
                   ignore: Optional[FrozenSet[str]] = None) -> "LintConfig":
        kw = {}
        if select is not None:
            kw["select"] = frozenset(select)
        if ignore is not None:
            kw["ignore"] = frozenset(ignore)
        return replace(self, **kw)


DEFAULT_CONFIG = LintConfig()
