"""Minimal stand-in for ``hypothesis`` when the real package is absent.

The test suite is written against real hypothesis (declared in
``pyproject.toml``; CI installs it).  Some execution environments cannot
install packages, so ``conftest.py`` calls :func:`install` to register this
module under ``sys.modules['hypothesis']`` **only if** the real package is
missing — it never shadows a genuine install.

Scope is exactly the API surface the suite uses: ``@given`` with positional
strategies (bound to the rightmost parameters, as hypothesis does),
``@settings(max_examples=..., deadline=...)``, and the strategy constructors
``integers, lists, tuples, sampled_from, binary, text, booleans, one_of,
randoms, just, none`` plus ``.map``/``.filter``.  Examples are drawn from a
seeded PRNG (deterministic per test), with no shrinking: on failure the
falsifying example is attached to the exception message instead.
"""
from __future__ import annotations

import functools
import inspect
import random
import string
import sys
import types
import zlib
from typing import Any, Callable, Optional, Sequence

DEFAULT_MAX_EXAMPLES = 25
_SETTINGS_ATTR = "_hypofb_settings"


class Unsatisfied(Exception):
    """Raised by :func:`assume` to discard the current example."""


def assume(condition: Any) -> bool:
    if not condition:
        raise Unsatisfied()
    return True


class HealthCheck:
    """Placeholder mirroring ``hypothesis.HealthCheck`` member names."""

    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.data_too_large, cls.filter_too_much]


class settings:
    """Decorator recording per-test run options (a subset of hypothesis')."""

    def __init__(self, max_examples: Optional[int] = None, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn: Callable) -> Callable:
        setattr(fn, _SETTINGS_ATTR, self)
        return fn


# ------------------------------------------------------------------ strategies
class SearchStrategy:
    """Base strategy: subclasses draw one value from an RNG."""

    def example(self, rng: random.Random) -> Any:  # pragma: no cover - abstract
        raise NotImplementedError

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return _Mapped(self, fn)

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        return _Filtered(self, pred)


class _Mapped(SearchStrategy):
    def __init__(self, inner: SearchStrategy, fn: Callable):
        self.inner, self.fn = inner, fn

    def example(self, rng):
        return self.fn(self.inner.example(rng))


class _Filtered(SearchStrategy):
    def __init__(self, inner: SearchStrategy, pred: Callable):
        self.inner, self.pred = inner, pred

    def example(self, rng):
        for _ in range(100):
            v = self.inner.example(rng)
            if self.pred(v):
                return v
        raise Unsatisfied()


class _Lambda(SearchStrategy):
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def just(value) -> SearchStrategy:
    return _Lambda(lambda rng: value)


def none() -> SearchStrategy:
    return just(None)


def integers(min_value: int = -(1 << 16), max_value: int = 1 << 16) -> SearchStrategy:
    return _Lambda(lambda rng: rng.randint(min_value, max_value))


def booleans() -> SearchStrategy:
    return _Lambda(lambda rng: rng.random() < 0.5)


def sampled_from(seq: Sequence) -> SearchStrategy:
    seq = list(seq)
    return _Lambda(lambda rng: seq[rng.randrange(len(seq))])


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return _Lambda(lambda rng: tuple(s.example(rng) for s in strategies))


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: Optional[int] = None, unique: bool = False) -> SearchStrategy:
    hi = (min_size + 10) if max_size is None else max_size

    def draw(rng):
        n = rng.randint(min_size, hi)
        out = [elements.example(rng) for _ in range(n)]
        if unique:
            seen, uniq = set(), []
            for v in out:
                if v not in seen:
                    seen.add(v)
                    uniq.append(v)
            out = uniq
        return out

    return _Lambda(draw)


def binary(min_size: int = 0, max_size: Optional[int] = None) -> SearchStrategy:
    hi = (min_size + 8) if max_size is None else max_size
    return _Lambda(
        lambda rng: bytes(rng.getrandbits(8)
                          for _ in range(rng.randint(min_size, hi)))
    )


_TEXT_ALPHABET = string.ascii_letters + string.digits + "_- "


def text(alphabet: Optional[str] = None, min_size: int = 0,
         max_size: Optional[int] = None) -> SearchStrategy:
    chars = alphabet or _TEXT_ALPHABET
    hi = (min_size + 8) if max_size is None else max_size
    return _Lambda(
        lambda rng: "".join(chars[rng.randrange(len(chars))]
                            for _ in range(rng.randint(min_size, hi)))
    )


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    flat = strategies[0] if len(strategies) == 1 and isinstance(
        strategies[0], (list, tuple)) else strategies
    return _Lambda(lambda rng: flat[rng.randrange(len(flat))].example(rng))


def randoms(use_true_random: bool = False, note_method_calls: bool = False) -> SearchStrategy:
    return _Lambda(lambda rng: random.Random(rng.getrandbits(64)))


# ----------------------------------------------------------------------- given
def given(*strategies: SearchStrategy, **kw_strategies: SearchStrategy) -> Callable:
    """Bind positional strategies to the rightmost test parameters.

    Mirrors hypothesis' binding rule so ``@given(s1, s2)`` works on both
    plain functions and methods (``self`` stays a caller argument).
    """

    def decorate(fn: Callable) -> Callable:
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        n_pos = len(strategies)
        remaining = params[: len(params) - n_pos] if n_pos else list(params)
        remaining = [p for p in remaining if p.name not in kw_strategies]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, _SETTINGS_ATTR, None) or getattr(
                fn, _SETTINGS_ATTR, None)
            max_examples = (
                cfg.max_examples if cfg is not None and cfg.max_examples
                else DEFAULT_MAX_EXAMPLES
            )
            seed0 = zlib.crc32(fn.__qualname__.encode())
            ran = 0
            attempt = 0
            while ran < max_examples and attempt < max_examples * 5:
                rng = random.Random(seed0 * 1_000_003 + attempt)
                attempt += 1
                try:
                    drawn = [s.example(rng) for s in strategies]
                    kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                except Unsatisfied:
                    continue
                try:
                    fn(*args, *drawn, **kw, **kwargs)
                except Unsatisfied:
                    continue
                except Exception as e:
                    detail = ", ".join(repr(d) for d in drawn)
                    e.args = (
                        (f"{e.args[0] if e.args else e!r} "
                         f"[hypothesis-fallback falsifying example #{attempt - 1}: "
                         f"({detail})]"),
                    ) + e.args[1:]
                    raise
                ran += 1
            if ran == 0:
                # mirror real hypothesis: a strategy rejecting every example
                # must fail loudly, not pass vacuously
                raise Unsatisfied(
                    f"{fn.__qualname__}: every generated example was rejected "
                    f"({attempt} attempts)")

        # Hide strategy-bound parameters from pytest's fixture resolution.
        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper

    return decorate


# --------------------------------------------------------------------- install
def install() -> None:
    """Register this shim as ``hypothesis`` if the real package is absent."""
    if "hypothesis" in sys.modules:
        return
    try:
        import importlib.util

        if importlib.util.find_spec("hypothesis") is not None:
            return  # real hypothesis available; never shadow it
    except (ImportError, ValueError):  # pragma: no cover - defensive
        pass

    this = sys.modules[__name__]
    pkg = types.ModuleType("hypothesis")
    pkg.given = given
    pkg.settings = settings
    pkg.assume = assume
    pkg.HealthCheck = HealthCheck
    pkg.example = lambda *a, **k: (lambda fn: fn)  # @example(...) is a no-op
    pkg.__version__ = "0.0-fallback"
    pkg.__fallback__ = this

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in (
        "integers", "lists", "tuples", "sampled_from", "binary", "text",
        "booleans", "one_of", "randoms", "just", "none",
    ):
        setattr(st_mod, name, getattr(this, name))
    st_mod.SearchStrategy = SearchStrategy

    pkg.strategies = st_mod
    sys.modules["hypothesis"] = pkg
    sys.modules["hypothesis.strategies"] = st_mod
