"""Bigset query service — the serve layer over :class:`BigsetCluster`.

The paper's trade-off is that decomposition costs full-set reads but "is
mitigated by enabling queries on sets"; PR 1/2 built those queries and this
module serves them: a request/response layer that accepts wire-encoded
query plans (msgpack, versioned envelope — :func:`repro.query.plan.
plan_to_wire`), dispatches them through ``BigsetCluster.query()``, and
streams results back as **cursor-paginated pages** with per-page
:class:`~repro.query.executor.QueryStats` attached.  Like a delta on the
write path, a page on the read path costs O(page + causal metadata) bytes,
never O(n) — asserted in ``tests/test_serve_bigset.py``.

Three serve-layer concerns live here, deliberately outside the query
engine:

* **Admission control / backpressure** — a bounded in-flight budget, by
  outstanding pages (open cursor leases) and by bytes (a sliding window
  fed from per-query IoStats via the :class:`~repro.cluster.clusters.
  ClusterSession` hook).  Overload gets an explicit ``RetryAfter``-style
  rejection (status ``"retry"`` + seconds hint), **never** a dropped or
  invalidated cursor: a client resumes the same token after backing off.
* **Cursor leases** — raw executor cursors are never handed out.  Each
  page's resume token is wrapped (:func:`repro.query.cursor.wrap_lease`)
  binding it to the issuing session, and the service tracks a per-lease
  deadline: any valid touch (even a rejected one) renews it, idle leases
  expire and are swept, and a foreign session's token is refused.
* **Write path** — insert / remove / batch mutate with causal-context
  round-tripping: an insert answers with its minted dot, a membership
  query answers with the element's surviving dots, and a remove accepts
  exactly those wire dots back as its observed-remove context (§4.3.2).

Transport is deliberately abstract: :meth:`BigsetService.handle` maps one
request byte-string to one response byte-string, so any socket server,
RPC framework, or in-process test can carry the protocol.
"""
from __future__ import annotations

import secrets
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import msgpack

from ..cluster.clusters import BigsetCluster, ClusterSession
from ..core.clock import runs_from_counters
from ..core.dots import Dot, DotList
from ..obs.metrics import (MetricsRegistry, lift_ae_stats,
                           lift_dispatch_stats, lift_io_stats, lift_network,
                           lift_query_stats)
from ..obs.trace import NULL_TRACER, Tracer
from ..query import cursor as query_cursor
from ..query.cursor import LeaseError, unwrap_lease, wrap_lease
from ..query.executor import QueryResult
from ..query.plan import Plan, PlanError, plan_from_wire, plan_to_wire

WIRE_VERSION = 1
ANON_SESSION = b""  # implicit session for clients that never open one

STATUS_OK = "ok"
STATUS_RETRY = "retry"
STATUS_ERROR = "error"


class ServiceError(Exception):
    """A request the service refused; ``kind`` keys the wire error body."""

    def __init__(self, kind: str, message: str):
        super().__init__(message)
        self.kind = kind


class Backpressure(Exception):
    """Client-side surfacing of a ``retry`` response (admission rejected)."""

    def __init__(self, reason: str, retry_after: float):
        super().__init__(f"backpressured ({reason}): retry in {retry_after:.3f}s")
        self.reason = reason
        self.retry_after = retry_after


@dataclass(frozen=True)
class ServiceConfig:
    """Serve-layer knobs; defaults suit an in-process demo cluster."""

    byte_budget: int = 4 << 20      # bytes_read served per budget window
    budget_window: float = 1.0      # seconds before the byte budget refills
    max_open_cursors: int = 64      # outstanding pages across all sessions
    lease_ttl: float = 30.0         # idle seconds before a cursor lease dies
    retry_after: float = 0.05       # hint when rejected on open cursors
    max_page_size: int = 10_000     # page_size/limit cap per request
    default_r: Optional[int] = None  # quorum size (None = majority)


# ----------------------------------------------------------------- wire dots
def dots_to_wire(dots: Sequence[Dot]) -> List[List]:
    """Run-compressed causal context: ``[[actor, lo, hi], ...]``.

    Contiguous counters per actor coalesce into one triple, so a ctx stays
    O(interval runs) on the wire however many dots it covers.  A single dot
    rides as ``[actor, c, c]``.
    """
    by_actor: dict = {}
    for d in dots:
        by_actor.setdefault(d.actor, []).append(d.counter)
    out: List[List] = []
    for a in sorted(by_actor, key=repr):
        for lo, hi in runs_from_counters(by_actor[a]):
            out.append([a, lo, hi])
    return out


def dots_from_wire(wire) -> DotList:
    """Decode a wire ctx — run triples or the legacy per-dot 2-lists."""
    try:
        out: List[Dot] = []
        for item in wire or ():
            if len(item) == 2:          # legacy [actor, counter]
                a, c = item
                out.append(Dot(a, int(c)))
            else:
                a, lo, hi = item
                lo, hi = int(lo), int(hi)
                if lo > hi:
                    raise ValueError(f"empty run [{lo}, {hi}]")
                out.extend(Dot(a, c) for c in range(lo, hi + 1))
        return tuple(out)
    except (TypeError, ValueError) as e:
        raise ServiceError("request", f"malformed dot list: {e}") from None


@dataclass
class _Lease:
    session: bytes
    deadline: float
    # the ring epoch the page's coverage plan ran under: a resume re-plans
    # under the same ring so pagination never straddles two placements; a
    # retired epoch falls forward to the current ring (cursors are element
    # boundaries, so the page resumes from the same element regardless)
    epoch: Optional[int] = None


@dataclass
class _Session:
    tokens: Set[bytes] = field(default_factory=set)


class _Accounting(ClusterSession):
    """The cluster-session hook feeding admission control from IoStats."""

    def __init__(self, service: "BigsetService"):
        self._svc = service

    def observe_query(self, plan, result: QueryResult) -> None:
        self._svc._window_bytes += result.stats.bytes_read
        self._svc.pages_served += 1

    def observe_mutation(self, delta) -> None:
        self._svc.mutations_applied += 1


class BigsetService:
    """One service front-end over one :class:`BigsetCluster`.

    ``clock`` is injectable (monotonic seconds) so tests drive lease expiry
    and budget-window refills deterministically.
    """

    def __init__(
        self,
        cluster: BigsetCluster,
        config: Optional[ServiceConfig] = None,
        clock: Callable[[], float] = time.monotonic,  # bigset-lint: disable=BS001 -- default for the *injectable* lease/budget clock; tests inject a fake
        tracer: Optional[Tracer] = None,
        metrics: Optional[MetricsRegistry] = None,
    ):
        self.cluster = cluster
        self.config = config or ServiceConfig()
        self._clock = clock
        self._acct = _Accounting(self)
        self._sessions: Dict[bytes, _Session] = {ANON_SESSION: _Session()}
        self._leases: Dict[bytes, _Lease] = {}
        self._lease_seq = 0  # nonce: identical cursors get distinct tokens
        self._window_start = clock()
        self._window_bytes = 0
        # observability: the tracer defaults to the CLUSTER's, so serve
        # spans and cluster/replica/network spans land in one tree; the
        # registry is the node-wide joined view the ``stats`` op snapshots
        self.tracer = tracer or getattr(cluster, "tracer", None) or \
            NULL_TRACER
        self.metrics = metrics or MetricsRegistry()
        self._session_stats: Dict[bytes, Dict[str, int]] = {}
        # observability counters (benchmarks read these)
        self.pages_served = 0
        self.mutations_applied = 0
        self.rejections = 0

    # -------------------------------------------------------------- transport
    def handle(self, request: bytes) -> bytes:
        """One wire request in, one wire response out (the whole protocol).

        Every decodable request runs inside a ``serve.request`` root span
        (op + final status), so the span trees of everything downstream —
        cluster coordinator, per-replica coverage, storage, kernel,
        network, read repair — hang off one serve-layer root per request.
        Request latency lands in the ``serve.request_seconds`` histogram,
        driven by the injectable service clock (deterministic in tests).
        """
        try:
            op, body = self._decode_request(request)
        except ServiceError as e:
            self.metrics.counter("serve.requests_undecodable").inc()
            return msgpack.packb([WIRE_VERSION, STATUS_ERROR,
                                  {"error": e.kind, "message": str(e)}])
        t0 = self._clock()
        with self.tracer.span("serve.request", op=op) as sp:
            try:
                status, out = self._dispatch(op, body)
            except Backpressure as bp:
                self.rejections += 1
                self.metrics.counter("serve.rejections").inc()
                status, out = STATUS_RETRY, {
                    "reason": bp.reason, "retry_after": bp.retry_after}
            except ServiceError as e:
                status, out = STATUS_ERROR, {
                    "error": e.kind, "message": str(e)}
            except (PlanError, LeaseError, query_cursor.CursorError) as e:
                kind = ("plan" if isinstance(e, PlanError)
                        else "lease" if isinstance(e, LeaseError)
                        else "cursor")
                status, out = STATUS_ERROR, {
                    "error": kind, "message": str(e)}
            sp.set(status=status)
        self.metrics.counter("serve.requests").inc()
        self.metrics.counter(f"serve.requests.{op}").inc()
        self.metrics.histogram("serve.request_seconds").observe(
            self._clock() - t0)
        return msgpack.packb([WIRE_VERSION, status, out])

    def _decode_request(self, request: bytes) -> Tuple[str, dict]:
        try:
            envelope = msgpack.unpackb(request)
        except Exception as e:
            raise ServiceError("request", f"undecodable request: {e}") from None
        if not (isinstance(envelope, (list, tuple)) and len(envelope) == 3):
            raise ServiceError("request", f"malformed envelope: {envelope!r}")
        version, op, body = envelope
        if version != WIRE_VERSION:
            raise ServiceError("request", f"unsupported wire version {version!r}")
        if not isinstance(op, str) or not isinstance(body, dict):
            raise ServiceError("request", "envelope needs a str op and map body")
        return op, body

    def _dispatch(self, op: str, body: dict) -> Tuple[str, dict]:
        if op == "open_session":
            return STATUS_OK, self._open_session()
        if op == "close_session":
            return STATUS_OK, self._close_session(body)
        if op == "query":
            return STATUS_OK, self._query(body)
        if op == "insert":
            return STATUS_OK, self._insert(body)
        if op == "remove":
            return STATUS_OK, self._remove(body)
        if op == "batch":
            return STATUS_OK, self._batch(body)
        if op == "stats":
            return STATUS_OK, self._stats(body)
        raise ServiceError("request", f"unknown op {op!r}")

    # --------------------------------------------------------------- sessions
    def _open_session(self) -> dict:
        # unguessable: the id is the session's only credential — a
        # predictable one would let any client close (or probe) a
        # neighbor's session and destroy its cursor leases
        sid = b"s" + secrets.token_hex(16).encode()  # bigset-lint: disable=BS001 -- the session id is a credential: unguessability beats replayability, and nothing downstream branches on its value
        self._sessions[sid] = _Session()
        return {"session": sid}

    def _close_session(self, body: dict) -> dict:
        sid = body.get("session", ANON_SESSION)
        sess = self._sessions.pop(sid, None)
        if sess is None:
            raise ServiceError("session", f"unknown session {sid!r}")
        for token in sess.tokens:
            self._leases.pop(token, None)
        self._session_stats.pop(sid, None)
        if sid == ANON_SESSION:  # the anon session is a fixture: recreate
            self._sessions[ANON_SESSION] = _Session()
        return {"closed": True, "released": len(sess.tokens)}

    def _session(self, body: dict) -> Tuple[bytes, _Session]:
        sid = body.get("session", ANON_SESSION)
        sess = self._sessions.get(sid)
        if sess is None:
            raise ServiceError("session", f"unknown session {sid!r}")
        return sid, sess

    # -------------------------------------------------------------- admission
    def _sweep(self, now: float) -> None:
        dead = [t for t, l in self._leases.items() if l.deadline <= now]
        for token in dead:
            lease = self._leases.pop(token)
            sess = self._sessions.get(lease.session)
            if sess is not None:
                sess.tokens.discard(token)

    def _admit(self, now: float, resuming: bool) -> None:
        """Admission control: raise :class:`Backpressure` instead of working.

        The byte budget is a window counter fed by ``_Accounting`` from
        per-query IoStats; once spent, queries are rejected until the
        window rolls.  The page budget bounds *outstanding* cursors — a
        resume never counts against it (it replaces its own lease), so
        backpressure can never strand a paginated scan midway.
        """
        if now - self._window_start >= self.config.budget_window:
            self._window_start = now
            self._window_bytes = 0
        if self._window_bytes >= self.config.byte_budget:
            remaining = self.config.budget_window - (now - self._window_start)
            raise Backpressure("byte_budget", max(remaining, 0.001))
        if not resuming and len(self._leases) >= self.config.max_open_cursors:
            raise Backpressure("open_cursors", self.config.retry_after)

    # ----------------------------------------------------------------- query
    def _query(self, body: dict) -> dict:
        sid, sess = self._session(body)
        wire_plan = body.get("plan")
        if not isinstance(wire_plan, bytes):
            raise ServiceError("request", "query needs a wire-encoded plan")
        plan = plan_from_wire(wire_plan)
        if getattr(plan, "cursor", None) is not None:
            # a raw executor cursor inside the plan would bypass lease
            # binding, expiry, AND admission accounting — pagination over
            # the wire goes through the lease token, full stop
            raise ServiceError(
                "request", "resume via the lease token, not plan.cursor")
        plan = self._cap_page(plan)
        now = self._clock()
        self._sweep(now)

        token = body.get("cursor")
        pinned: Optional[int] = None
        if token is not None:
            plan = self._resume(plan, token, sid, now)
            pinned = self._leases[token].epoch
        self._admit(now, resuming=token is not None)

        # cursor leases pin the ring epoch their plan ran under; a fresh
        # query plans under the current ring.  ring_for resolves a retired
        # or unknown pinned epoch forward, and the *resolved* epoch is what
        # the next page's lease pins.
        ring_epoch = self.cluster.ring_for(pinned).epoch
        r = self._quorum(body)
        repair = bool(body.get("repair", True))
        res = self.cluster.query(plan, r=r, repair=repair, session=self._acct,
                                 ring_epoch=ring_epoch)
        lift_query_stats(self.metrics, res.stats)
        self._note(sid, pages=1, bytes_read=res.stats.bytes_read,
                   elements=res.stats.elements_emitted,
                   kernel_launches=res.stats.kernel_launches)

        out = self._result_to_wire(res)
        if token is not None:
            self._release(token)
        if res.cursor is not None:
            out["cursor"] = self._mint(sid, sess, res.cursor, now,
                                       epoch=ring_epoch)
        return out

    def _cap_page(self, plan: Plan) -> Plan:
        cap = self.config.max_page_size
        if getattr(plan, "page_size", None) is not None and plan.page_size > cap:
            return replace(plan, page_size=cap)
        if getattr(plan, "limit", None) is not None and plan.limit > cap:
            return replace(plan, limit=cap)
        return plan

    def _resume(self, plan: Plan, token, sid: bytes, now: float) -> Plan:
        """Swap a lease token for the raw cursor it wraps, renewing it.

        Validation order matters: binding (is this your token?) before
        liveness (is it still leased?) before admission — so a rejected
        page both renews its lease and leaves it resumable.
        """
        if not isinstance(token, bytes):
            raise ServiceError("request", "cursor must be a lease token")
        raw = unwrap_lease(token, sid)
        lease = self._leases.get(token)
        if lease is None or lease.session != sid:
            raise LeaseError("cursor lease expired or unknown")
        lease.deadline = now + self.config.lease_ttl  # any valid touch renews
        try:
            return replace(plan, cursor=raw)
        except TypeError:
            raise PlanError(
                f"plan {type(plan).__name__} does not paginate") from None

    def _mint(self, sid: bytes, sess: _Session, raw_cursor: bytes,
              now: float, epoch: Optional[int] = None) -> bytes:
        self._lease_seq += 1
        token = wrap_lease(sid, raw_cursor, nonce=self._lease_seq)
        self._leases[token] = _Lease(sid, now + self.config.lease_ttl,
                                     epoch=epoch)
        sess.tokens.add(token)
        return token

    def _release(self, token: bytes) -> None:
        lease = self._leases.pop(token, None)
        if lease is not None:
            sess = self._sessions.get(lease.session)
            if sess is not None:
                sess.tokens.discard(token)

    # ----------------------------------------------------------------- stats
    def _note(self, sid: bytes, **deltas: int) -> None:
        """Accumulate per-session usage (the ``stats`` op's session view)."""
        acc = self._session_stats.setdefault(sid, {})
        for k, v in deltas.items():
            acc[k] = acc.get(k, 0) + v

    def _stats(self, body: dict) -> dict:
        """Metrics snapshot: the whole stack joined into one response.

        ``node`` lifts every layer's stat struct — storage IoStats
        (cluster-wide), anti-entropy ledger, simulated-network wire
        counters, Pallas dispatch ledger, serve admission state — into the
        uniformly named registry and snapshots it.  ``session`` is the
        calling session's own usage.  Like every response, the envelope is
        msgpack: a remote dashboard needs nothing but this op.
        """
        sid, _sess = self._session(body)
        reg = self.metrics
        lift_io_stats(reg, self.cluster.io_stats())
        if hasattr(self.cluster, "ae_stats"):
            lift_ae_stats(reg, self.cluster.ae_stats())
        lift_network(reg, self.cluster.net)
        lift_dispatch_stats(reg)
        reg.gauge("serve.pages_served").set(self.pages_served)
        reg.gauge("serve.mutations_applied").set(self.mutations_applied)
        reg.gauge("serve.open_cursors").set(len(self._leases))
        reg.gauge("serve.sessions").set(len(self._sessions))
        out = {"node": reg.snapshot(),
               "session": dict(self._session_stats.get(sid, {}))}
        if hasattr(self.cluster, "ring_state"):
            ring = self.cluster.ring_state()
            reg.gauge("cluster.ring_epoch").set(ring["epoch"])
            out["node"] = reg.snapshot()
            out["ring"] = ring
        return out

    def _result_to_wire(self, res: QueryResult) -> dict:
        out: dict = {
            "entries": [[el, dots_to_wire(dots)] for el, dots in res.entries],
            "cursor": None,
            "stats": dict(vars(res.stats)),
        }
        if res.present is not None:
            out["present"] = res.present
        if res.count is not None:
            out["count"] = res.count
        if res.index_entries is not None:
            out["index_entries"] = [
                [ik, el, dots_to_wire(dots)]
                for ik, el, dots in res.index_entries]
        return out

    # ----------------------------------------------------- request validation
    # every remote-controlled scalar is checked here so a malformed request
    # becomes an ``error`` response, never an exception escaping handle()
    def _coordinator(self, body: dict) -> int:
        c = body.get("coordinator", 0)
        if not isinstance(c, int) or not 0 <= c < self.cluster.n:
            raise ServiceError(
                "request",
                f"coordinator must be an int in [0, {self.cluster.n})")
        return c

    def _quorum(self, body: dict) -> Optional[int]:
        r = body.get("r", self.config.default_r)
        # quorum sizes are bounded by the ring's replication factor (== n
        # under the degenerate full-replication ring), not the vnode count
        max_r = getattr(getattr(self.cluster, "ring", None), "factor",
                        self.cluster.n)
        if r is not None and (
                not isinstance(r, int) or not 1 <= r <= max_r):
            raise ServiceError(
                "request", f"r must be an int in [1, {max_r}]")
        return r

    @staticmethod
    def _value(raw) -> bytes:
        if not isinstance(raw, bytes):
            raise ServiceError("request", "value must be bytes")
        return raw

    # ------------------------------------------------------------- write path
    def _insert(self, body: dict) -> dict:
        set_name, element = self._set_element(body)
        self._note(body.get("session", ANON_SESSION), mutations=1)
        delta = self.cluster.add(
            set_name, element,
            coordinator=self._coordinator(body),
            ctx=dots_from_wire(body.get("ctx")),
            value=self._value(body.get("value", b"")),
            session=self._acct)
        return {"element": element, "dot": dots_to_wire([delta.dot])[0]}

    def _remove(self, body: dict) -> dict:
        set_name, element = self._set_element(body)
        self._note(body.get("session", ANON_SESSION), mutations=1)
        ctx = body.get("ctx")
        delta = self.cluster.remove(
            set_name, element,
            coordinator=self._coordinator(body),
            ctx=dots_from_wire(ctx) if ctx is not None else None,
            session=self._acct)
        return {"removed": delta is not None,
                "ctx": dots_to_wire(delta.ctx) if delta is not None else []}

    def _batch(self, body: dict) -> dict:
        set_name = body.get("set")
        ops = body.get("ops")
        if not isinstance(set_name, bytes) or not isinstance(ops, list):
            raise ServiceError("request", "batch needs a set and an op list")
        coordinator = self._coordinator(body)
        parsed: List[Tuple] = []
        for op in ops:
            if not (isinstance(op, (list, tuple)) and len(op) >= 2):
                raise ServiceError("request", f"malformed batch op {op!r}")
            kind, element = op[0], op[1]
            if not isinstance(element, bytes):
                raise ServiceError("request", "batch elements must be bytes")
            if kind == "add":
                value = self._value(op[2]) if len(op) > 2 else b""
                ctx = dots_from_wire(op[3]) if len(op) > 3 else ()
                parsed.append(("add", element, value, ctx))
            elif kind == "remove":
                ctx = dots_from_wire(op[2]) if len(op) > 2 else None
                parsed.append(("remove", element, ctx))
            else:
                raise ServiceError("request", f"unknown batch op {kind!r}")
        self._note(body.get("session", ANON_SESSION), mutations=len(parsed))
        deltas = self.cluster.mutate(
            set_name, parsed, coordinator=coordinator, session=self._acct)
        results = []
        for delta in deltas:
            if delta is None:
                results.append({"removed": False})
            elif hasattr(delta, "dot"):
                results.append({"dot": dots_to_wire([delta.dot])[0]})
            else:
                results.append({"removed": True, "ctx": dots_to_wire(delta.ctx)})
        return {"results": results}

    @staticmethod
    def _set_element(body: dict) -> Tuple[bytes, bytes]:
        set_name, element = body.get("set"), body.get("element")
        if not isinstance(set_name, bytes) or not isinstance(element, bytes):
            raise ServiceError("request", "mutation needs bytes set and element")
        return set_name, element


# -------------------------------------------------------------------- client
@dataclass
class Page:
    """One decoded query response page."""

    entries: List[Tuple[bytes, DotList]]
    cursor: Optional[bytes]        # lease token; more pages exist iff not None
    stats: dict                    # per-page QueryStats (ints, plus the join
                                   # "strategy" the planner executed)
    present: Optional[bool] = None
    count: Optional[int] = None
    index_entries: Optional[List[Tuple[bytes, bytes, DotList]]] = None

    @property
    def members(self) -> List[bytes]:
        return [el for el, _ in self.entries]


class BigsetClient:
    """Thin wire-speaking client: every call round-trips through
    :meth:`BigsetService.handle` bytes, exactly as a remote client would.

    Pagination state is one lease token; :meth:`pages` iterates a paginated
    plan to exhaustion, backing off on ``retry`` responses via the
    injectable ``sleep`` (tests pass a fake-clock advancer).
    """

    def __init__(self, service: BigsetService):
        self._service = service
        self._session: Optional[bytes] = None

    # ------------------------------------------------------------- transport
    _ERROR_TYPES = {
        "plan": PlanError,
        "lease": LeaseError,
        "cursor": query_cursor.CursorError,
    }

    def _call(self, op: str, body: dict) -> dict:
        response = self._service.handle(
            msgpack.packb([WIRE_VERSION, op, body]))
        version, status, out = msgpack.unpackb(response)
        if version != WIRE_VERSION:
            raise ServiceError("response", f"wire version {version!r}")
        if status == STATUS_RETRY:
            raise Backpressure(out["reason"], out["retry_after"])
        if status == STATUS_ERROR:
            # re-hydrate the typed errors the service serialized, so client
            # code catches the same exceptions an in-process caller would
            exc = self._ERROR_TYPES.get(out["error"])
            if exc is not None:
                raise exc(out["message"])
            raise ServiceError(out["error"], out["message"])
        return out

    @property
    def session(self) -> bytes:
        if self._session is None:
            self._session = self._call("open_session", {})["session"]
        return self._session

    def close(self) -> None:
        if self._session is not None:
            self._call("close_session", {"session": self._session})
            self._session = None

    # ---------------------------------------------------------------- queries
    def query(self, plan: Plan, r: Optional[int] = None,
              cursor: Optional[bytes] = None) -> Page:
        """One page.  Raises :class:`Backpressure` on admission rejection —
        the cursor (ours or the one passed in) stays valid for a retry."""
        body = {"plan": plan_to_wire(plan), "session": self.session}
        if r is not None:
            body["r"] = r
        if cursor is not None:
            body["cursor"] = cursor
        out = self._call("query", body)
        return Page(
            entries=[(el, dots_from_wire(dots))
                     for el, dots in out["entries"]],
            cursor=out.get("cursor"),
            stats=out.get("stats", {}),
            present=out.get("present"),
            count=out.get("count"),
            index_entries=[
                (ik, el, dots_from_wire(dots))
                for ik, el, dots in out["index_entries"]]
            if out.get("index_entries") is not None else None,
        )

    def pages(self, plan: Plan, r: Optional[int] = None,
              sleep: Callable[[float], None] = time.sleep,
              max_retries: int = 64):
        """Iterate every page of a paginated plan, riding out backpressure."""
        cursor = None
        while True:
            retries = 0
            while True:
                try:
                    page = self.query(plan, r=r, cursor=cursor)
                    break
                except Backpressure as bp:
                    retries += 1
                    if retries > max_retries:
                        raise
                    sleep(bp.retry_after)
            yield page
            cursor = page.cursor
            if cursor is None:
                return

    def stats(self) -> dict:
        """Node-wide + this-session metrics snapshot (the ``stats`` op).

        ``out["node"]`` is the registry snapshot — uniformly named
        ``storage.* / antientropy.* / net.* / kernels.* / serve.* /
        query.*`` metrics; ``out["session"]`` is this session's usage.
        """
        return self._call("stats", {"session": self.session})

    def membership(self, set_name: bytes, element: bytes,
                   r: Optional[int] = None) -> Tuple[bool, List[List]]:
        """(present, wire ctx) — the ctx feeds straight into :meth:`remove`."""
        from ..query.plan import Membership

        page = self.query(Membership(set_name, element), r=r)
        ctx = dots_to_wire(page.entries[0][1]) if page.entries else []
        return bool(page.present), ctx

    # -------------------------------------------------------------- mutations
    def insert(self, set_name: bytes, element: bytes, value: bytes = b"",
               ctx: Optional[List[List]] = None) -> List:
        body = {"set": set_name, "element": element, "value": value,
                "session": self.session}
        if ctx:
            body["ctx"] = ctx
        return self._call("insert", body)["dot"]

    def remove(self, set_name: bytes, element: bytes,
               ctx: Optional[List[List]] = None) -> bool:
        body = {"set": set_name, "element": element, "session": self.session}
        if ctx is not None:
            body["ctx"] = ctx
        return self._call("remove", body)["removed"]

    def batch(self, set_name: bytes, ops: List[List]) -> List[dict]:
        return self._call("batch", {"set": set_name, "ops": ops,
                                    "session": self.session})["results"]
