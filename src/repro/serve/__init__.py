from .bigset_service import (Backpressure, BigsetClient, BigsetService, Page,
                             ServiceConfig, ServiceError)
from .engine import Request, ServeEngine

__all__ = [
    "Backpressure", "BigsetClient", "BigsetService", "Page", "Request",
    "ServeEngine", "ServiceConfig", "ServiceError",
]
