"""Batched serving engine: continuous-batching decode over a shared cache.

Request lifecycle: enqueue → prefill (one jit'd call per admission wave,
writing into the slot's pre-allocated max-length cache) → step the whole
active batch with one fused decode step per token → stream tokens out →
free the slot on EOS/limit.  Greedy or temperature sampling.

Single-host execution here; the decode step is the same function the
dry-run lowers for the 256/512-chip meshes, so the sharded path is
covered by launch/dryrun.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import build_model
from ..models.transformer import init_decode_cache


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # int32[T]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 256, temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.rng = jax.random.key(seed)
        self.cache = init_decode_cache(cfg, max_batch, max_len)
        self.cache_len = jnp.zeros((max_batch,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self._decode = jax.jit(self.model.decode_step)
        self._next_rid = 0

    # ------------------------------------------------------------- frontend
    def submit(self, prompt: np.ndarray, **kw) -> Request:
        req = Request(self._next_rid, np.asarray(prompt, np.int32), **kw)
        self._next_rid += 1
        self.queue.append(req)
        return req

    # ------------------------------------------------------------ admission
    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            self.slots[slot] = req
            T = len(req.prompt)
            logits, pf_cache = self.model.prefill_step(
                self.params, {"tokens": jnp.asarray(req.prompt[None, :])},
                max_len=self.max_len)
            self.cache = _splice_cache(self.cache, pf_cache, slot)
            self.cache_len = self.cache_len.at[slot].set(T)
            tok = self._sample(logits[0])
            req.out_tokens.append(int(tok))

    def _sample(self, logits: jax.Array) -> int:
        if self.temperature <= 0:
            return int(jnp.argmax(logits, -1))
        self.rng, sub = jax.random.split(self.rng)
        return int(jax.random.categorical(sub, logits / self.temperature))

    # ----------------------------------------------------------------- step
    def step(self) -> int:
        """One engine iteration: admit, decode one token for every active
        slot, retire finished requests.  Returns #active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        last = np.zeros((self.max_batch, 1), np.int32)
        for i in active:
            last[i, 0] = self.slots[i].out_tokens[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(last), self.cache_len)
        self.cache_len = self.cache_len + jnp.asarray(
            [1 if self.slots[i] is not None else 0
             for i in range(self.max_batch)], jnp.int32)
        for i in active:
            req = self.slots[i]
            tok = self._sample(logits[i])
            req.out_tokens.append(tok)
            limit = req.max_new_tokens
            if (req.eos_id is not None and tok == req.eos_id) or \
                    len(req.out_tokens) >= limit:
                req.done = True
                self.slots[i] = None
        return len(active)

    def run_until_drained(self, max_iters: int = 10_000) -> None:
        it = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and it < max_iters:
            self.step()
            it += 1


def _splice_cache(cache, pf_cache, slot: int):
    """Insert a prefilled single-request cache into batch position ``slot``.

    Grouped (scan-stacked) cache leaves carry [n_groups, B, ...]; tail and
    enc_out leaves carry [B, ...] — the batch axis index comes from the path.
    """
    def visit(path, buf, new):
        if not hasattr(buf, "ndim") or buf.ndim == 0:
            return buf
        head = str(getattr(path[0], "key", getattr(path[0], "idx", path[0])))
        baxis = 1 if head == "groups" else 0
        n = new
        for axis in range(buf.ndim):
            if axis == baxis:
                continue
            if n.shape[axis] < buf.shape[axis]:
                width = [(0, 0)] * n.ndim
                width[axis] = (0, buf.shape[axis] - n.shape[axis])
                n = jnp.pad(n, width)
            elif n.shape[axis] > buf.shape[axis]:
                n = jax.lax.slice_in_dim(n, 0, buf.shape[axis], axis=axis)
        idx = [slice(None)] * buf.ndim
        idx[baxis] = slice(slot, slot + 1)
        return buf.at[tuple(idx)].set(n.astype(buf.dtype))

    return jax.tree_util.tree_map_with_path(visit, cache, pf_cache)
