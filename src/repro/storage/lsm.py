"""LSM-tree ordered KV store — a faithful-enough leveldb stand-in.

Structure: an in-memory *memtable* (dict) backed by a write-ahead log for
atomic batches, flushed into immutable sorted *runs* (sstables).  Reads
consult memtable then runs newest-first; scans merge all levels.  Compaction
merges runs and applies a caller-supplied ``drop`` predicate — this is the
hook the paper adds to leveldb so the set-tombstone can discard superseded
element-keys without ever issuing deletes (§4.3.3).

Every operation is metered in :class:`IoStats` (bytes read / written /
transferred), because the paper's central claim is about **bytes read and
written over the life of the set** (§2.1: O(n) per op, O(n²) lifetime for
riak-objects vs O(causal metadata) for bigset).
"""
from __future__ import annotations

import bisect
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Tuple

TOMBSTONE = b"\xff\xfe__deleted__"  # storage-level delete marker


@dataclass
class IoStats:
    bytes_written: int = 0      # WAL + memtable writes (foreground)
    bytes_read: int = 0         # get/scan bytes returned + keys touched
    bytes_flushed: int = 0      # memtable -> run
    bytes_compacted: int = 0    # compaction rewrite volume
    num_writes: int = 0
    num_reads: int = 0
    num_seeks: int = 0

    def snapshot(self) -> "IoStats":
        return IoStats(**vars(self))

    def delta(self, since: "IoStats") -> "IoStats":
        return IoStats(**{k: getattr(self, k) - getattr(since, k) for k in vars(self)})

    def total_io(self) -> int:
        return self.bytes_written + self.bytes_read


class IoMeter:
    """Live window over a store's :class:`IoStats` (per-query accounting).

    The query executor opens a meter around each plan execution so results
    can report *bytes touched by this query* — the paper's O(result +
    causal metadata) claim made measurable (§2.1, §4.4).
    """

    def __init__(self, stats: IoStats):
        self._stats = stats
        self._before = stats.snapshot()

    def delta(self) -> IoStats:
        return self._stats.delta(self._before)


class _Run:
    """Immutable sorted run of (key, value) pairs."""

    __slots__ = ("keys", "values")

    def __init__(self, items: List[Tuple[bytes, bytes]]):
        self.keys = [k for k, _ in items]
        self.values = [v for _, v in items]

    def get(self, key: bytes) -> Optional[bytes]:
        i = bisect.bisect_left(self.keys, key)
        if i < len(self.keys) and self.keys[i] == key:
            return self.values[i]
        return None

    def scan(self, lo: bytes, hi: bytes) -> Iterator[Tuple[bytes, bytes]]:
        i = bisect.bisect_left(self.keys, lo)
        while i < len(self.keys) and self.keys[i] < hi:
            yield self.keys[i], self.values[i]
            i += 1

    def __len__(self) -> int:
        return len(self.keys)


class LsmStore:
    """Ordered KV store with memtable + sorted runs + pluggable compaction."""

    def __init__(self, memtable_limit: int = 4096, auto_compact_runs: int = 8):
        self.memtable: Dict[bytes, bytes] = {}
        self.runs: List[_Run] = []  # newest first
        self.stats = IoStats()
        self.memtable_limit = memtable_limit
        self.auto_compact_runs = auto_compact_runs
        # drop(key, value) -> bool: True to discard during compaction.
        # Set by the bigset layer (the paper's modified-leveldb hook).
        self.compaction_filter: Optional[Callable[[bytes, bytes], bool]] = None
        self.on_discard: Optional[Callable[[bytes, bytes], None]] = None
        self._compacting = False

    # ----------------------------------------------------------------- write
    def put_batch(self, items: List[Tuple[bytes, bytes]]) -> None:
        """Atomic write batch (WAL append then memtable apply)."""
        for k, v in items:
            self.stats.bytes_written += len(k) + len(v)
            self.memtable[k] = v
        self.stats.num_writes += 1
        if len(self.memtable) >= self.memtable_limit:
            self.flush()

    def put(self, key: bytes, value: bytes) -> None:
        self.put_batch([(key, value)])

    def delete(self, key: bytes) -> None:
        self.put_batch([(key, TOMBSTONE)])

    # ------------------------------------------------------------------ read
    def get(self, key: bytes) -> Optional[bytes]:
        self.stats.num_reads += 1
        v = self.memtable.get(key)
        if v is None:
            for run in self.runs:
                v = run.get(key)
                if v is not None:
                    break
        if v is None or v == TOMBSTONE:
            self.stats.bytes_read += len(key)
            return None
        self.stats.bytes_read += len(key) + len(v)
        return v

    def scan(self, lo: bytes, hi: bytes) -> Iterator[Tuple[bytes, bytes]]:
        """Merged iterator over [lo, hi); newest level wins per key."""
        self.stats.num_seeks += 1
        mem = sorted(
            (k, v) for k, v in self.memtable.items() if lo <= k < hi
        )
        levels: List[Iterator[Tuple[bytes, bytes]]] = [iter(mem)]
        levels += [run.scan(lo, hi) for run in self.runs]
        yield from self._merge(levels)

    def seek(
        self, lo: bytes, hi: Optional[bytes] = None,
        limit: Optional[int] = None,
    ) -> Iterator[Tuple[bytes, bytes]]:
        """Bounded scan: position at ``lo`` and stream at most ``limit`` live
        entries below ``hi``.

        This is the primitive the query executor drives — a range query pays
        for the entries it returns (the iterator is lazy and metering happens
        per yielded entry), never for the whole keyspace.
        """
        if hi is None:
            hi = b"\xff" * 24  # past any encoded key (tags are 0x01/0x02)
        it = self.scan(lo, hi)
        return itertools.islice(it, limit) if limit is not None else it

    def meter(self) -> IoMeter:
        """Open a per-query IO accounting window over this store's stats."""
        return IoMeter(self.stats)

    def _merge(
        self, levels: List[Iterator[Tuple[bytes, bytes]]]
    ) -> Iterator[Tuple[bytes, bytes]]:
        import heapq

        heap: List[Tuple[bytes, int, bytes]] = []
        iters = levels
        for idx, it in enumerate(iters):
            for k, v in it:
                heap.append((k, idx, v))
                break
        heapq.heapify(heap)
        last_key: Optional[bytes] = None
        while heap:
            k, idx, v = heapq.heappop(heap)
            nxt = next(iters[idx], None)
            if nxt is not None:
                heapq.heappush(heap, (nxt[0], idx, nxt[1]))
            if k == last_key:
                continue  # older level shadowed
            last_key = k
            if v == TOMBSTONE:
                continue
            self.stats.bytes_read += len(k) + len(v)
            yield k, v

    # ------------------------------------------------------------ level mgmt
    def flush(self) -> None:
        if not self.memtable:
            return
        items = sorted(self.memtable.items())
        self.stats.bytes_flushed += sum(len(k) + len(v) for k, v in items)
        self.runs.insert(0, _Run(items))
        self.memtable = {}
        if len(self.runs) >= self.auto_compact_runs and not self._compacting:
            self.compact()

    def compact(self) -> List[Tuple[bytes, bytes]]:
        """Merge all levels into one run, applying the compaction filter.

        Returns the list of (key, value) pairs *discarded by the filter*
        (storage tombstones are dropped silently).  The bigset layer uses the
        returned dots to shrink the set-tombstone (§4.3.3).
        """
        self._compacting = True
        try:
            return self._compact_inner()
        finally:
            self._compacting = False

    def _compact_inner(self) -> List[Tuple[bytes, bytes]]:
        self.flush()
        merged: List[Tuple[bytes, bytes]] = []
        discarded: List[Tuple[bytes, bytes]] = []
        seen_keys: set = set()
        flt = self.compaction_filter
        # newest-first iteration; first occurrence of a key wins
        for run in self.runs:
            for k, v in zip(run.keys, run.values):
                if k in seen_keys:
                    continue
                seen_keys.add(k)
                self.stats.bytes_compacted += len(k) + len(v)
                if v == TOMBSTONE:
                    continue
                if flt is not None and flt(k, v):
                    discarded.append((k, v))
                    if self.on_discard is not None:
                        self.on_discard(k, v)
                    continue
                merged.append((k, v))
        merged.sort()
        self.stats.bytes_compacted += sum(len(k) + len(v) for k, v in merged)
        self.runs = [_Run(merged)] if merged else []
        return discarded

    # ------------------------------------------------------------- inspection
    def __len__(self) -> int:
        n = 0
        seen: set = set()
        for k, v in self.memtable.items():
            seen.add(k)
            if v != TOMBSTONE:
                n += 1
        for run in self.runs:
            for k, v in zip(run.keys, run.values):
                if k in seen:
                    continue
                seen.add(k)
                if v != TOMBSTONE:
                    n += 1
        return n

    def approximate_bytes(self) -> int:
        total = sum(len(k) + len(v) for k, v in self.memtable.items())
        for run in self.runs:
            total += sum(len(k) + len(v) for k, v in zip(run.keys, run.values))
        return total
