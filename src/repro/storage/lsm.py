"""LSM-tree ordered KV store — a faithful-enough leveldb stand-in.

Structure: an in-memory *memtable* (dict), flushed into immutable sorted
*runs* (sstables).  Reads consult memtable then runs newest-first; scans
merge all levels.  Compaction merges runs and applies a caller-supplied
``drop`` predicate — this is the hook the paper adds to leveldb so the
set-tombstone can discard superseded element-keys without ever issuing
deletes (§4.3.3).

Durability is opt-in: construct with a :class:`~repro.storage.wal.DurableMedia`
and every batch is framed into an append-only WAL with **group commit** —
one fsync acknowledges up to ``group_depth`` batches (§4.3's log-before-
memtable discipline, with leveldb's batched sync amortization).  Flushes
and compactions publish segment files plus a manifest recording the WAL
*horizon*; :meth:`LsmStore.recover` rebuilds a crashed store by loading
the manifested segments and replaying only the WAL records above the
horizon.  Without media the store is volatile and every WAL path is a
no-op (zero extra accounting).

Every operation is metered in :class:`IoStats` (bytes read / written /
transferred), because the paper's central claim is about **bytes read and
written over the life of the set** (§2.1: O(n) per op, O(n²) lifetime for
riak-objects vs O(causal metadata) for bigset).

Reads go through :class:`LsmIterator`, a *positional* merged cursor: it
bisects every level to its start key, streams a heap merge, and can
:meth:`~LsmIterator.seek` to a new position in O(log n) per level — the
entries skipped by a seek are never touched, so they cost no ``bytes_read``.
That positional seek is what lets the query layer's gallop joins skip IO
instead of merely skipping Python iterations.  Each immutable run also
carries statistics (key count, key-range fences, cumulative byte offsets);
:meth:`LsmStore.range_stats` turns them into O(log n) cardinality/byte
estimates for any key range — the input to cost-based join planning
(:mod:`repro.query.planner`).
"""
from __future__ import annotations

import bisect
import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from .wal import (MANIFEST, DurableMedia, RecoveryResult, WalError,
                  decode_manifest, decode_segment, decode_wal,
                  encode_manifest, encode_segment, encode_wal_record)

TOMBSTONE = b"\xff\xfe__deleted__"  # storage-level delete marker


@dataclass
class IoStats:
    bytes_written: int = 0      # memtable apply volume (foreground writes)
    bytes_read: int = 0         # get/scan bytes returned + keys touched
    bytes_flushed: int = 0      # memtable -> run
    bytes_compacted: int = 0    # compaction rewrite volume
    bytes_wal: int = 0          # WAL record bytes appended (durable mode)
    bytes_recovered: int = 0    # WAL bytes replayed by recover()
    num_writes: int = 0
    num_reads: int = 0
    num_seeks: int = 0
    num_fsyncs: int = 0         # group commits: one fsync acks many batches
    num_recoveries: int = 0

    def snapshot(self) -> "IoStats":
        return IoStats(**vars(self))

    def delta(self, since: "IoStats") -> "IoStats":
        return IoStats(**{k: getattr(self, k) - getattr(since, k) for k in vars(self)})

    def total_io(self) -> int:
        return self.bytes_written + self.bytes_read


class IoMeter:
    """Live window over a store's :class:`IoStats` (per-query accounting).

    The query executor opens a meter around each plan execution so results
    can report *bytes touched by this query* — the paper's O(result +
    causal metadata) claim made measurable (§2.1, §4.4).
    """

    def __init__(self, stats: IoStats):
        self._stats = stats
        self._before = stats.snapshot()

    def delta(self) -> IoStats:
        return self._stats.delta(self._before)


@dataclass(frozen=True)
class RunStats:
    """Statistics of one immutable run: cardinality, fences, volume."""

    key_count: int
    min_key: bytes       # key-range fences: a range outside [min, max]
    max_key: bytes       # cannot touch this run
    total_bytes: int


@dataclass(frozen=True)
class RangeStats:
    """Approximate cost of a key range: entry count and byte volume.

    Counts are upper bounds — shadowed keys and storage tombstones are
    included (deduplicating them would cost the scan the estimate exists
    to avoid).  Good enough for *relative* cost decisions (join planning),
    not for exact cardinality.
    """

    keys: int
    bytes: int


class _Run:
    """Immutable sorted run of (key, value) pairs.

    ``cum_bytes[i]`` is the byte volume of entries ``[0, i)`` — immutability
    makes the prefix sums free to keep, and they turn any range's byte
    estimate into two bisects and a subtraction.
    """

    __slots__ = ("keys", "values", "cum_bytes")

    def __init__(self, items: List[Tuple[bytes, bytes]]):
        self.keys = [k for k, _ in items]
        self.values = [v for _, v in items]
        cum = [0]
        for k, v in items:
            cum.append(cum[-1] + len(k) + len(v))
        self.cum_bytes = cum

    def get(self, key: bytes) -> Optional[bytes]:
        i = bisect.bisect_left(self.keys, key)
        if i < len(self.keys) and self.keys[i] == key:
            return self.values[i]
        return None

    def span(self, lo: bytes, hi: Optional[bytes]) -> Tuple[int, int]:
        """Index range [i, j) of keys in [lo, hi); hi=None is unbounded."""
        i = bisect.bisect_left(self.keys, lo)
        j = len(self.keys) if hi is None else bisect.bisect_left(self.keys, hi)
        return i, max(i, j)

    def stats(self) -> RunStats:
        return RunStats(
            key_count=len(self.keys),
            min_key=self.keys[0] if self.keys else b"",
            max_key=self.keys[-1] if self.keys else b"",
            total_bytes=self.cum_bytes[-1],
        )

    def __len__(self) -> int:
        return len(self.keys)


class LsmIterator:
    """Positional merged cursor over a snapshot of the store.

    Construction bisects every level (the sorted memtable view plus each
    immutable run) to the first key >= ``lo`` and streams a heap merge in
    key order — newest level wins per key, storage tombstones are dropped.
    ``hi=None`` is genuinely unbounded: the cursor runs to the end of the
    keyspace, whatever the keys look like.

    :meth:`seek` repositions the cursor in O(log n) per level.  Entries
    skipped over by a seek are **never touched**: no ``bytes_read`` is
    metered for them (each ``seek`` counts one ``num_seeks``) — this is the
    storage half of the query layer's gallop join.

    The cursor snapshots its levels at construction: writes issued while it
    is open are not visible through it (same semantics as the previous
    per-scan memtable snapshot).
    """

    __slots__ = ("_store", "_hi", "_keys", "_vals", "_pos", "_heap", "_last")

    def __init__(self, store: "LsmStore", lo: bytes = b"",
                 hi: Optional[bytes] = None):
        self._store = store
        self._hi = hi
        mem_keys, mem_vals = store._memtable_view()
        self._keys: List[List[bytes]] = [mem_keys]
        self._vals: List[List[bytes]] = [mem_vals]
        for run in store.runs:  # newest first: lower index shadows higher
            self._keys.append(run.keys)
            self._vals.append(run.values)
        self._pos = [0] * len(self._keys)
        self._heap: List[Tuple[bytes, int, bytes]] = []
        self._last: Optional[bytes] = None
        self._position(lo)

    def _push(self, idx: int) -> None:
        i = self._pos[idx]
        ks = self._keys[idx]
        if i < len(ks):
            k = ks[i]
            if self._hi is None or k < self._hi:
                heapq.heappush(self._heap, (k, idx, self._vals[idx][i]))
                self._pos[idx] = i + 1

    def _position(self, lo: bytes) -> None:
        self._store.stats.num_seeks += 1
        self._heap = []
        for idx, ks in enumerate(self._keys):
            self._pos[idx] = bisect.bisect_left(ks, lo)
            self._push(idx)

    def seek(self, lo: bytes) -> None:
        """Reposition at the first live key >= ``lo`` (any direction)."""
        self._last = None
        self._position(lo)

    def __iter__(self) -> "LsmIterator":
        return self

    def __next__(self) -> Tuple[bytes, bytes]:
        while self._heap:
            k, idx, v = heapq.heappop(self._heap)
            self._push(idx)
            if k == self._last:
                continue  # older level shadowed
            self._last = k
            if v == TOMBSTONE:
                continue
            self._store.stats.bytes_read += len(k) + len(v)
            return k, v
        raise StopIteration


class LsmStore:
    """Ordered KV store with memtable + sorted runs + pluggable compaction.

    Pass ``media`` (a :class:`~repro.storage.wal.DurableMedia`) for a
    durable store: batches are WAL-framed before the memtable apply and
    acknowledged by group commit — the fsync fires every ``group_depth``
    batches, so ``commit_seq`` (the acknowledged horizon) trails ``seq``
    by at most ``group_depth - 1`` un-fsynced batches.  ``sync()`` forces
    the pending group commit.  Without media the store is volatile and
    none of the WAL fields move.
    """

    def __init__(self, memtable_limit: int = 4096, auto_compact_runs: int = 8,
                 media: Optional["DurableMedia"] = None, group_depth: int = 1):
        self.memtable: Dict[bytes, bytes] = {}
        self.runs: List[_Run] = []  # newest first
        self.stats = IoStats()
        self.memtable_limit = memtable_limit
        self.auto_compact_runs = auto_compact_runs
        self.media = media
        self.group_depth = max(1, group_depth)
        self._seq = 0              # seq of the latest batch appended
        self.commit_seq = 0        # highest durable (acknowledged) seq
        self._pending = 0          # batches appended since the last fsync
        self._manifest_horizon = 0  # seqs <= this live in durable segments
        self._next_seg = 0
        self._seg_names: List[str] = []  # newest first, parallel to runs
        # drop(key, value) -> bool: True to discard during compaction.
        # Set by the bigset layer (the paper's modified-leveldb hook).
        self.compaction_filter: Optional[Callable[[bytes, bytes], bool]] = None
        self.on_discard: Optional[Callable[[bytes, bytes], None]] = None
        self._compacting = False
        # lazily-built sorted view of the memtable, invalidated by writes:
        # cursor positioning is O(log memtable + page), not O(memtable sort)
        # per scan call
        self._mem_keys: Optional[List[bytes]] = None
        self._mem_vals: Optional[List[bytes]] = None

    # ----------------------------------------------------------------- write
    def put_batch(self, items: List[Tuple[bytes, bytes]]) -> int:
        """Atomic write batch: WAL append, memtable apply, group commit.

        In durable mode the batch is CRC-framed into the WAL buffer first
        (billed to ``bytes_wal``), then applied to the memtable; the fsync
        that *acknowledges* it is deferred until ``group_depth`` batches
        are pending (or a flush captures them in a durable segment), so
        fsyncs < batches whenever ``group_depth > 1``.  Returns the batch
        seq; it is durable once ``commit_seq`` reaches it.  Volatile
        stores skip every WAL step and acknowledge immediately.
        """
        self._seq += 1
        seq = self._seq
        if self.media is not None:
            record = encode_wal_record(seq, items)
            self.media.wal_append(record)
            self.stats.bytes_wal += len(record)
            self._pending += 1
        for k, v in items:
            self.stats.bytes_written += len(k) + len(v)
            self.memtable[k] = v
        self.stats.num_writes += 1
        self._mem_keys = self._mem_vals = None
        if len(self.memtable) >= self.memtable_limit:
            self.flush()
        if self.media is None:
            self.commit_seq = seq
        elif self._pending >= self.group_depth:
            self.sync()
        return seq

    def sync(self) -> None:
        """Force the pending group commit: one fsync acknowledges every
        appended batch (``commit_seq`` catches up to the latest seq).
        A crash point armed at a WAL byte offset fires here, tearing the
        durable log mid-record."""
        if self.media is None or self._pending == 0:
            return
        self.media.wal_sync()
        self.stats.num_fsyncs += 1
        self._pending = 0
        self.commit_seq = self._seq

    def put(self, key: bytes, value: bytes) -> None:
        self.put_batch([(key, value)])

    def delete(self, key: bytes) -> None:
        self.put_batch([(key, TOMBSTONE)])

    # ------------------------------------------------------------- recovery
    def recover(self) -> RecoveryResult:
        """Rebuild a crashed store from its durable media.

        Loads the manifested segments as runs (newest first), then replays
        WAL records **above** the manifest horizon into the memtable —
        records at or below it were already captured by a durable flush
        (and possibly rewritten by compaction), so replaying them would
        resurrect discarded element-keys; they are counted as skipped and
        their bytes are never re-billed.  A torn final record (mid-fsync
        crash) is discarded by CRC framing.  Restores exactly the
        acknowledged prefix: every batch with ``seq <= commit_seq`` at
        crash time, nothing beyond the durable WAL.

        Only valid on a freshly-constructed store holding the media.
        """
        if self.media is None:
            raise WalError("recover() requires durable media")
        if self.memtable or self.runs or self._seq:
            raise WalError("recover() on a store that already has state")
        segments, horizon, next_seg = decode_manifest(
            self.media.read_file(MANIFEST))
        for name in segments:  # manifest order is newest-first, like runs
            data = self.media.read_file(name)
            if data is None:
                raise WalError(f"manifest references missing segment {name}")
            self.runs.append(_Run(decode_segment(data)))
        self._seg_names = list(segments)
        self._manifest_horizon = horizon
        self._next_seg = next_seg
        records, torn_bytes = decode_wal(bytes(self.media.wal))
        replayed = skipped = nbytes = 0
        last_seq = horizon
        for rec in records:
            last_seq = max(last_seq, rec.seq)
            if rec.seq <= horizon:
                skipped += 1
                continue
            for k, v in rec.items:
                self.memtable[k] = v
            replayed += 1
            nbytes += rec.nbytes
        self._mem_keys = self._mem_vals = None
        self._seq = last_seq        # continue batch numbering monotonically
        self.commit_seq = last_seq  # everything restored is durable
        self._pending = 0
        self.stats.bytes_recovered += nbytes
        self.stats.num_recoveries += 1
        return RecoveryResult(
            segments=len(segments), batches_replayed=replayed,
            batches_skipped=skipped, bytes_replayed=nbytes,
            torn_bytes=torn_bytes, horizon=horizon, last_seq=last_seq)

    # ------------------------------------------------------------------ read
    def get(self, key: bytes) -> Optional[bytes]:
        self.stats.num_reads += 1
        v = self.memtable.get(key)
        if v is None:
            for run in self.runs:
                v = run.get(key)
                if v is not None:
                    break
        if v is None or v == TOMBSTONE:
            self.stats.bytes_read += len(key)
            return None
        self.stats.bytes_read += len(key) + len(v)
        return v

    def _memtable_view(self) -> Tuple[List[bytes], List[bytes]]:
        """Sorted (keys, values) view of the memtable, cached until a write.

        Keeping the view bisectable makes cursor positioning O(log n +
        page) instead of O(memtable) per scan — read-heavy cursor paging
        sorts once, not once per page.
        """
        if self._mem_keys is None:
            items = sorted(self.memtable.items())
            self._mem_keys = [k for k, _ in items]
            self._mem_vals = [v for _, v in items]
        return self._mem_keys, self._mem_vals

    def scan(self, lo: bytes = b"", hi: Optional[bytes] = None) -> LsmIterator:
        """Merged positional cursor over [lo, hi); newest level wins per
        key.  ``hi=None`` scans to the end of the keyspace.  Use the
        returned cursor's :meth:`LsmIterator.seek` to gallop without
        paying for skipped keys."""
        return LsmIterator(self, lo, hi)

    def seek(
        self, lo: bytes, hi: Optional[bytes] = None,
        limit: Optional[int] = None,
    ) -> Iterator[Tuple[bytes, bytes]]:
        """Bounded scan: position at ``lo`` and stream at most ``limit`` live
        entries below ``hi``.  ``hi=None`` is genuinely unbounded — the
        merged cursor has no upper fence, whatever the key bytes are.

        This is the primitive the query executor drives — a range query pays
        for the entries it returns (the iterator is lazy and metering happens
        per yielded entry), never for the whole keyspace.
        """
        it = LsmIterator(self, lo, hi)
        return itertools.islice(it, limit) if limit is not None else it

    def meter(self) -> IoMeter:
        """Open a per-query IO accounting window over this store's stats."""
        return IoMeter(self.stats)

    # ------------------------------------------------------------ statistics
    def run_stats(self) -> List[RunStats]:
        """Per-run statistics, newest first: count, fences, byte volume."""
        return [run.stats() for run in self.runs]

    def range_stats(self, lo: bytes, hi: Optional[bytes] = None) -> RangeStats:
        """Approximate keys/bytes in ``[lo, hi)`` across all levels.

        O(log n) per run (bisect against the fences + cumulative byte
        offsets) plus O(matching memtable entries); never touches values.
        The count is an upper bound (shadowed keys and storage tombstones
        included) — the planner's cost model only needs relative
        magnitudes.  Callers with a tuple-key prefix get ``[lo, hi)`` from
        :func:`repro.storage.keycodec.prefix_bounds`.
        """
        mem_keys, mem_vals = self._memtable_view()
        i = bisect.bisect_left(mem_keys, lo)
        j = len(mem_keys) if hi is None else bisect.bisect_left(mem_keys, hi)
        j = max(i, j)
        keys = j - i
        nbytes = sum(
            len(mem_keys[x]) + len(mem_vals[x]) for x in range(i, j))
        for run in self.runs:
            i, j = run.span(lo, hi)
            keys += j - i
            nbytes += run.cum_bytes[j] - run.cum_bytes[i]
        return RangeStats(keys=keys, bytes=nbytes)

    # ------------------------------------------------------------ level mgmt
    def flush(self) -> None:
        if not self.memtable:
            return
        items = sorted(self.memtable.items())
        self.stats.bytes_flushed += sum(len(k) + len(v) for k, v in items)
        self.runs.insert(0, _Run(items))
        self.memtable = {}
        self._mem_keys = self._mem_vals = None
        if self.media is not None:
            # Publish the run as a durable segment and advance the manifest
            # horizon to the last captured batch: those batches are now
            # durable without their WAL fsync, and the unsynced WAL tail
            # (all <= horizon) is redundant.  A crash between the two
            # publishes leaves the old manifest pointing at the old
            # segments + durable WAL — still exactly the acknowledged
            # prefix.
            name = f"seg-{self._next_seg:08d}"
            self._next_seg += 1
            self._manifest_horizon = self._seq
            self.media.write_file(name, encode_segment(items))
            self._seg_names.insert(0, name)
            self._publish_manifest()
            self.media.wal_drop_buffer()
            self._pending = 0
            self.commit_seq = self._seq
        if len(self.runs) >= self.auto_compact_runs and not self._compacting:
            self.compact()

    def _publish_manifest(self) -> None:
        self.media.write_file(
            MANIFEST,
            encode_manifest(self._seg_names, self._manifest_horizon,
                            self._next_seg))

    def compact(self) -> List[Tuple[bytes, bytes]]:
        """Merge all levels into one run, applying the compaction filter.

        Returns the list of (key, value) pairs *discarded by the filter*
        (storage tombstones are dropped silently).  The bigset layer uses the
        returned dots to shrink the set-tombstone (§4.3.3).
        """
        self._compacting = True
        try:
            return self._compact_inner()
        finally:
            self._compacting = False

    def _compact_inner(self) -> List[Tuple[bytes, bytes]]:
        self.flush()
        merged: List[Tuple[bytes, bytes]] = []
        discarded: List[Tuple[bytes, bytes]] = []
        seen_keys: set = set()
        flt = self.compaction_filter
        # newest-first iteration; first occurrence of a key wins
        for run in self.runs:
            for k, v in zip(run.keys, run.values):
                if k in seen_keys:
                    continue
                seen_keys.add(k)
                self.stats.bytes_compacted += len(k) + len(v)
                if v == TOMBSTONE:
                    continue
                if flt is not None and flt(k, v):
                    discarded.append((k, v))
                    if self.on_discard is not None:
                        self.on_discard(k, v)
                    continue
                merged.append((k, v))
        merged.sort()
        self.stats.bytes_compacted += sum(len(k) + len(v) for k, v in merged)
        self.runs = [_Run(merged)] if merged else []
        if self.media is not None:
            # One merged segment replaces every prior one, then the WAL is
            # atomically emptied: records <= horizon must never replay
            # after the filter discarded their keys (the set-tombstone
            # already shrank past those dots).  Crash ordering is safe at
            # every publish: before the manifest lands the old
            # segments+WAL are authoritative; after it, the merged
            # segment is, and stale WAL records fall at or below the new
            # horizon so recovery skips them.
            stale = self._seg_names
            self._seg_names = []
            self._manifest_horizon = self._seq
            if merged:
                name = f"seg-{self._next_seg:08d}"
                self._next_seg += 1
                self.media.write_file(name, encode_segment(merged))
                self._seg_names = [name]
            self._publish_manifest()
            self.media.wal_reset()
            self._pending = 0
            self.commit_seq = self._seq
            for name in stale:
                self.media.delete_file(name)
        return discarded

    # ------------------------------------------------------------- inspection
    def __len__(self) -> int:
        n = 0
        seen: set = set()
        for k, v in self.memtable.items():
            seen.add(k)
            if v != TOMBSTONE:
                n += 1
        for run in self.runs:
            for k, v in zip(run.keys, run.values):
                if k in seen:
                    continue
                seen.add(k)
                if v != TOMBSTONE:
                    n += 1
        return n

    def approximate_bytes(self) -> int:
        total = sum(len(k) + len(v) for k, v in self.memtable.items())
        for run in self.runs:
            total += sum(len(k) + len(v) for k, v in zip(run.keys, run.values))
        return total
