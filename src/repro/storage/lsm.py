"""LSM-tree ordered KV store — a faithful-enough leveldb stand-in.

Structure: an in-memory *memtable* (dict) backed by a write-ahead log for
atomic batches, flushed into immutable sorted *runs* (sstables).  Reads
consult memtable then runs newest-first; scans merge all levels.  Compaction
merges runs and applies a caller-supplied ``drop`` predicate — this is the
hook the paper adds to leveldb so the set-tombstone can discard superseded
element-keys without ever issuing deletes (§4.3.3).

Every operation is metered in :class:`IoStats` (bytes read / written /
transferred), because the paper's central claim is about **bytes read and
written over the life of the set** (§2.1: O(n) per op, O(n²) lifetime for
riak-objects vs O(causal metadata) for bigset).

Reads go through :class:`LsmIterator`, a *positional* merged cursor: it
bisects every level to its start key, streams a heap merge, and can
:meth:`~LsmIterator.seek` to a new position in O(log n) per level — the
entries skipped by a seek are never touched, so they cost no ``bytes_read``.
That positional seek is what lets the query layer's gallop joins skip IO
instead of merely skipping Python iterations.  Each immutable run also
carries statistics (key count, key-range fences, cumulative byte offsets);
:meth:`LsmStore.range_stats` turns them into O(log n) cardinality/byte
estimates for any key range — the input to cost-based join planning
(:mod:`repro.query.planner`).
"""
from __future__ import annotations

import bisect
import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Tuple

TOMBSTONE = b"\xff\xfe__deleted__"  # storage-level delete marker


@dataclass
class IoStats:
    bytes_written: int = 0      # WAL + memtable writes (foreground)
    bytes_read: int = 0         # get/scan bytes returned + keys touched
    bytes_flushed: int = 0      # memtable -> run
    bytes_compacted: int = 0    # compaction rewrite volume
    num_writes: int = 0
    num_reads: int = 0
    num_seeks: int = 0

    def snapshot(self) -> "IoStats":
        return IoStats(**vars(self))

    def delta(self, since: "IoStats") -> "IoStats":
        return IoStats(**{k: getattr(self, k) - getattr(since, k) for k in vars(self)})

    def total_io(self) -> int:
        return self.bytes_written + self.bytes_read


class IoMeter:
    """Live window over a store's :class:`IoStats` (per-query accounting).

    The query executor opens a meter around each plan execution so results
    can report *bytes touched by this query* — the paper's O(result +
    causal metadata) claim made measurable (§2.1, §4.4).
    """

    def __init__(self, stats: IoStats):
        self._stats = stats
        self._before = stats.snapshot()

    def delta(self) -> IoStats:
        return self._stats.delta(self._before)


@dataclass(frozen=True)
class RunStats:
    """Statistics of one immutable run: cardinality, fences, volume."""

    key_count: int
    min_key: bytes       # key-range fences: a range outside [min, max]
    max_key: bytes       # cannot touch this run
    total_bytes: int


@dataclass(frozen=True)
class RangeStats:
    """Approximate cost of a key range: entry count and byte volume.

    Counts are upper bounds — shadowed keys and storage tombstones are
    included (deduplicating them would cost the scan the estimate exists
    to avoid).  Good enough for *relative* cost decisions (join planning),
    not for exact cardinality.
    """

    keys: int
    bytes: int


class _Run:
    """Immutable sorted run of (key, value) pairs.

    ``cum_bytes[i]`` is the byte volume of entries ``[0, i)`` — immutability
    makes the prefix sums free to keep, and they turn any range's byte
    estimate into two bisects and a subtraction.
    """

    __slots__ = ("keys", "values", "cum_bytes")

    def __init__(self, items: List[Tuple[bytes, bytes]]):
        self.keys = [k for k, _ in items]
        self.values = [v for _, v in items]
        cum = [0]
        for k, v in items:
            cum.append(cum[-1] + len(k) + len(v))
        self.cum_bytes = cum

    def get(self, key: bytes) -> Optional[bytes]:
        i = bisect.bisect_left(self.keys, key)
        if i < len(self.keys) and self.keys[i] == key:
            return self.values[i]
        return None

    def span(self, lo: bytes, hi: Optional[bytes]) -> Tuple[int, int]:
        """Index range [i, j) of keys in [lo, hi); hi=None is unbounded."""
        i = bisect.bisect_left(self.keys, lo)
        j = len(self.keys) if hi is None else bisect.bisect_left(self.keys, hi)
        return i, max(i, j)

    def stats(self) -> RunStats:
        return RunStats(
            key_count=len(self.keys),
            min_key=self.keys[0] if self.keys else b"",
            max_key=self.keys[-1] if self.keys else b"",
            total_bytes=self.cum_bytes[-1],
        )

    def __len__(self) -> int:
        return len(self.keys)


class LsmIterator:
    """Positional merged cursor over a snapshot of the store.

    Construction bisects every level (the sorted memtable view plus each
    immutable run) to the first key >= ``lo`` and streams a heap merge in
    key order — newest level wins per key, storage tombstones are dropped.
    ``hi=None`` is genuinely unbounded: the cursor runs to the end of the
    keyspace, whatever the keys look like.

    :meth:`seek` repositions the cursor in O(log n) per level.  Entries
    skipped over by a seek are **never touched**: no ``bytes_read`` is
    metered for them (each ``seek`` counts one ``num_seeks``) — this is the
    storage half of the query layer's gallop join.

    The cursor snapshots its levels at construction: writes issued while it
    is open are not visible through it (same semantics as the previous
    per-scan memtable snapshot).
    """

    __slots__ = ("_store", "_hi", "_keys", "_vals", "_pos", "_heap", "_last")

    def __init__(self, store: "LsmStore", lo: bytes = b"",
                 hi: Optional[bytes] = None):
        self._store = store
        self._hi = hi
        mem_keys, mem_vals = store._memtable_view()
        self._keys: List[List[bytes]] = [mem_keys]
        self._vals: List[List[bytes]] = [mem_vals]
        for run in store.runs:  # newest first: lower index shadows higher
            self._keys.append(run.keys)
            self._vals.append(run.values)
        self._pos = [0] * len(self._keys)
        self._heap: List[Tuple[bytes, int, bytes]] = []
        self._last: Optional[bytes] = None
        self._position(lo)

    def _push(self, idx: int) -> None:
        i = self._pos[idx]
        ks = self._keys[idx]
        if i < len(ks):
            k = ks[i]
            if self._hi is None or k < self._hi:
                heapq.heappush(self._heap, (k, idx, self._vals[idx][i]))
                self._pos[idx] = i + 1

    def _position(self, lo: bytes) -> None:
        self._store.stats.num_seeks += 1
        self._heap = []
        for idx, ks in enumerate(self._keys):
            self._pos[idx] = bisect.bisect_left(ks, lo)
            self._push(idx)

    def seek(self, lo: bytes) -> None:
        """Reposition at the first live key >= ``lo`` (any direction)."""
        self._last = None
        self._position(lo)

    def __iter__(self) -> "LsmIterator":
        return self

    def __next__(self) -> Tuple[bytes, bytes]:
        while self._heap:
            k, idx, v = heapq.heappop(self._heap)
            self._push(idx)
            if k == self._last:
                continue  # older level shadowed
            self._last = k
            if v == TOMBSTONE:
                continue
            self._store.stats.bytes_read += len(k) + len(v)
            return k, v
        raise StopIteration


class LsmStore:
    """Ordered KV store with memtable + sorted runs + pluggable compaction."""

    def __init__(self, memtable_limit: int = 4096, auto_compact_runs: int = 8):
        self.memtable: Dict[bytes, bytes] = {}
        self.runs: List[_Run] = []  # newest first
        self.stats = IoStats()
        self.memtable_limit = memtable_limit
        self.auto_compact_runs = auto_compact_runs
        # drop(key, value) -> bool: True to discard during compaction.
        # Set by the bigset layer (the paper's modified-leveldb hook).
        self.compaction_filter: Optional[Callable[[bytes, bytes], bool]] = None
        self.on_discard: Optional[Callable[[bytes, bytes], None]] = None
        self._compacting = False
        # lazily-built sorted view of the memtable, invalidated by writes:
        # cursor positioning is O(log memtable + page), not O(memtable sort)
        # per scan call
        self._mem_keys: Optional[List[bytes]] = None
        self._mem_vals: Optional[List[bytes]] = None

    # ----------------------------------------------------------------- write
    def put_batch(self, items: List[Tuple[bytes, bytes]]) -> None:
        """Atomic write batch (WAL append then memtable apply)."""
        for k, v in items:
            self.stats.bytes_written += len(k) + len(v)
            self.memtable[k] = v
        self.stats.num_writes += 1
        self._mem_keys = self._mem_vals = None
        if len(self.memtable) >= self.memtable_limit:
            self.flush()

    def put(self, key: bytes, value: bytes) -> None:
        self.put_batch([(key, value)])

    def delete(self, key: bytes) -> None:
        self.put_batch([(key, TOMBSTONE)])

    # ------------------------------------------------------------------ read
    def get(self, key: bytes) -> Optional[bytes]:
        self.stats.num_reads += 1
        v = self.memtable.get(key)
        if v is None:
            for run in self.runs:
                v = run.get(key)
                if v is not None:
                    break
        if v is None or v == TOMBSTONE:
            self.stats.bytes_read += len(key)
            return None
        self.stats.bytes_read += len(key) + len(v)
        return v

    def _memtable_view(self) -> Tuple[List[bytes], List[bytes]]:
        """Sorted (keys, values) view of the memtable, cached until a write.

        Keeping the view bisectable makes cursor positioning O(log n +
        page) instead of O(memtable) per scan — read-heavy cursor paging
        sorts once, not once per page.
        """
        if self._mem_keys is None:
            items = sorted(self.memtable.items())
            self._mem_keys = [k for k, _ in items]
            self._mem_vals = [v for _, v in items]
        return self._mem_keys, self._mem_vals

    def scan(self, lo: bytes = b"", hi: Optional[bytes] = None) -> LsmIterator:
        """Merged positional cursor over [lo, hi); newest level wins per
        key.  ``hi=None`` scans to the end of the keyspace.  Use the
        returned cursor's :meth:`LsmIterator.seek` to gallop without
        paying for skipped keys."""
        return LsmIterator(self, lo, hi)

    def seek(
        self, lo: bytes, hi: Optional[bytes] = None,
        limit: Optional[int] = None,
    ) -> Iterator[Tuple[bytes, bytes]]:
        """Bounded scan: position at ``lo`` and stream at most ``limit`` live
        entries below ``hi``.  ``hi=None`` is genuinely unbounded — the
        merged cursor has no upper fence, whatever the key bytes are.

        This is the primitive the query executor drives — a range query pays
        for the entries it returns (the iterator is lazy and metering happens
        per yielded entry), never for the whole keyspace.
        """
        it = LsmIterator(self, lo, hi)
        return itertools.islice(it, limit) if limit is not None else it

    def meter(self) -> IoMeter:
        """Open a per-query IO accounting window over this store's stats."""
        return IoMeter(self.stats)

    # ------------------------------------------------------------ statistics
    def run_stats(self) -> List[RunStats]:
        """Per-run statistics, newest first: count, fences, byte volume."""
        return [run.stats() for run in self.runs]

    def range_stats(self, lo: bytes, hi: Optional[bytes] = None) -> RangeStats:
        """Approximate keys/bytes in ``[lo, hi)`` across all levels.

        O(log n) per run (bisect against the fences + cumulative byte
        offsets) plus O(matching memtable entries); never touches values.
        The count is an upper bound (shadowed keys and storage tombstones
        included) — the planner's cost model only needs relative
        magnitudes.  Callers with a tuple-key prefix get ``[lo, hi)`` from
        :func:`repro.storage.keycodec.prefix_bounds`.
        """
        mem_keys, mem_vals = self._memtable_view()
        i = bisect.bisect_left(mem_keys, lo)
        j = len(mem_keys) if hi is None else bisect.bisect_left(mem_keys, hi)
        j = max(i, j)
        keys = j - i
        nbytes = sum(
            len(mem_keys[x]) + len(mem_vals[x]) for x in range(i, j))
        for run in self.runs:
            i, j = run.span(lo, hi)
            keys += j - i
            nbytes += run.cum_bytes[j] - run.cum_bytes[i]
        return RangeStats(keys=keys, bytes=nbytes)

    # ------------------------------------------------------------ level mgmt
    def flush(self) -> None:
        if not self.memtable:
            return
        items = sorted(self.memtable.items())
        self.stats.bytes_flushed += sum(len(k) + len(v) for k, v in items)
        self.runs.insert(0, _Run(items))
        self.memtable = {}
        self._mem_keys = self._mem_vals = None
        if len(self.runs) >= self.auto_compact_runs and not self._compacting:
            self.compact()

    def compact(self) -> List[Tuple[bytes, bytes]]:
        """Merge all levels into one run, applying the compaction filter.

        Returns the list of (key, value) pairs *discarded by the filter*
        (storage tombstones are dropped silently).  The bigset layer uses the
        returned dots to shrink the set-tombstone (§4.3.3).
        """
        self._compacting = True
        try:
            return self._compact_inner()
        finally:
            self._compacting = False

    def _compact_inner(self) -> List[Tuple[bytes, bytes]]:
        self.flush()
        merged: List[Tuple[bytes, bytes]] = []
        discarded: List[Tuple[bytes, bytes]] = []
        seen_keys: set = set()
        flt = self.compaction_filter
        # newest-first iteration; first occurrence of a key wins
        for run in self.runs:
            for k, v in zip(run.keys, run.values):
                if k in seen_keys:
                    continue
                seen_keys.add(k)
                self.stats.bytes_compacted += len(k) + len(v)
                if v == TOMBSTONE:
                    continue
                if flt is not None and flt(k, v):
                    discarded.append((k, v))
                    if self.on_discard is not None:
                        self.on_discard(k, v)
                    continue
                merged.append((k, v))
        merged.sort()
        self.stats.bytes_compacted += sum(len(k) + len(v) for k, v in merged)
        self.runs = [_Run(merged)] if merged else []
        return discarded

    # ------------------------------------------------------------- inspection
    def __len__(self) -> int:
        n = 0
        seen: set = set()
        for k, v in self.memtable.items():
            seen.add(k)
            if v != TOMBSTONE:
                n += 1
        for run in self.runs:
            for k, v in zip(run.keys, run.values):
                if k in seen:
                    continue
                seen.add(k)
                if v != TOMBSTONE:
                    n += 1
        return n

    def approximate_bytes(self) -> int:
        total = sum(len(k) + len(v) for k, v in self.memtable.items())
        for run in self.runs:
            total += sum(len(k) + len(v) for k, v in zip(run.keys, run.values))
        return total
