"""Order-preserving tuple -> bytes key codec and the bigset key kinds.

leveldb (and our LSM stand-in) orders keys lexicographically by raw bytes.
Bigset requires element-keys to sort by ``(set, kind, element, actor,
counter)`` so that (a) a set's keyspace is one contiguous range, (b) the
clock/tombstone keys sort *before* the element keys of the same set, and
(c) element keys sort by element then dot — the property that enables the
§4.4 streaming ORSWOT join and range queries.

The *kind* byte partitions a set's keyspace into sub-ranges:

* ``KIND_CLOCK``     — ``(set, 0)``: the serialized set-clock
* ``KIND_TOMBSTONE`` — ``(set, 1)``: the serialized set-tombstone
* ``KIND_ELEMENT``   — ``(set, 2, element, actor, counter)``: one per insert
* ``KIND_INDEX``     — ``(set, 3, index_name, index_key, element, actor,
  counter)``: secondary-index postings, mirroring element-keys dot-for-dot
  (a posting is live iff its dot is live under the same set-tombstone)

Components supported: ``bytes``/``str`` (escaped, terminator-based) and
non-negative ``int`` (fixed 8-byte big-endian).  Escaping maps ``0x00`` to
``0x00 0x01`` and terminates with ``0x00 0x00``, preserving order.
"""
from __future__ import annotations

import struct
from typing import Tuple

class KeyCodecError(ValueError):
    """Malformed, truncated, or out-of-range key material.

    Typed (and a ``ValueError`` subclass, so pre-existing handlers keep
    working) rather than an assert or a leaked ``struct.error``: the
    ``python -O`` CI job runs with asserts stripped, and sync/serve paths
    decode peer-supplied keys — they must fail loudly on garbage.
    """


KIND_CLOCK = 0
KIND_TOMBSTONE = 1
KIND_ELEMENT = 2
KIND_INDEX = 3

_STR_TAG = b"\x01"
_INT_TAG = b"\x02"
_TERM = b"\x00\x00"
_ESC = b"\x00\x01"


def encode_key(parts: Tuple) -> bytes:
    out = bytearray()
    for p in parts:
        if isinstance(p, str):
            p = p.encode("utf-8")
        if isinstance(p, (bytes, bytearray)):
            out += _STR_TAG
            out += bytes(p).replace(b"\x00", _ESC)
            out += _TERM
        elif isinstance(p, int):
            if p < 0 or p >= 1 << 64:
                raise KeyCodecError(f"int key component out of range: {p}")
            out += _INT_TAG
            out += struct.pack(">Q", p)
        else:
            raise TypeError(f"unsupported key component type: {type(p)!r}")
    return bytes(out)


def decode_key(key: bytes) -> Tuple:
    parts = []
    i = 0
    n = len(key)
    while i < n:
        tag = key[i : i + 1]
        i += 1
        if tag == _STR_TAG:
            buf = bytearray()
            while True:
                j = key.find(b"\x00", i)
                if j < 0:
                    raise KeyCodecError(
                        f"unterminated string component at offset {i}")
                nxt = key[j : j + 2]
                if nxt == _TERM:
                    buf += key[i:j]
                    i = j + 2
                    break
                elif nxt == _ESC:
                    buf += key[i:j] + b"\x00"
                    i = j + 2
                else:
                    raise KeyCodecError(
                        f"malformed escape at offset {j} in string component")
            parts.append(bytes(buf))
        elif tag == _INT_TAG:
            if n - i < 8:
                raise KeyCodecError(
                    f"truncated int component at offset {i}: "
                    f"{n - i} of 8 bytes")
            parts.append(struct.unpack(">Q", key[i : i + 8])[0])
            i += 8
        else:
            raise KeyCodecError(f"bad tag byte {tag!r} at offset {i - 1}")
    return tuple(parts)


def successor_bytes(b: bytes) -> bytes:
    """The immediate successor of ``b`` in bytes order (``b + b"\\x00"``).

    Used to turn an inclusive component bound into the exclusive bound of
    the next value: in the order-preserving codec, ``encode_key((.., x))``
    through ``encode_key((.., successor_bytes(x)))`` spans exactly the keys
    whose component equals ``x`` plus all of their extensions.
    """
    return b + b"\x00"


def prefix_bounds(parts: Tuple) -> Tuple[bytes, bytes]:
    """Encoded ``[lo, hi)`` bounds covering every key extending ``parts``.

    ``hi`` is the encoded prefix followed by ``0xff``: component tags are
    ``0x01``/``0x02``, so no well-formed key extending the prefix can reach
    it, and any key with a different component diverges before it.
    """
    lo = encode_key(parts)
    return lo, lo + b"\xff"
