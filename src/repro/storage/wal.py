"""Durable media for the LSM stand-in: WAL, segment files, crash injection.

The paper's bigsets inherit durability from leveldb (§4.3: every batch hits
a log before the memtable).  This module supplies the equivalent for our
simulated store without touching the real filesystem: a
:class:`DurableMedia` models one vnode's disk — an append-only write-ahead
log with an explicit *unsynced buffer* (bytes written but not yet fsynced),
plus a namespace of atomically-published files (segments and a manifest).

Crash semantics are the interesting part, and they are deterministic by
construction (no wall clock, no hidden randomness — invariant BS001):

* ``crash()`` drops the unsynced WAL buffer and nothing else.  Everything
  previously fsynced or atomically published survives.
* A :class:`CrashPoint` arms a seeded kill point.  ``wal_bytes=N`` makes
  the *next fsync that would carry the durable WAL past byte N* die mid-way,
  leaving the durable log truncated at exactly N — which in general tears
  the final record (the CRC-framed decoder discards the torn tail).
  ``file_writes=K`` makes the K-th subsequent atomic file publish raise
  *before* publishing — modelling a crash mid-flush or mid-compaction.

Record framing: each WAL record is ``<len, crc32>`` header + body, body is
``<seq, n_items>`` + length-prefixed key/value pairs.  :func:`decode_wal`
stops at the first short or CRC-mismatched frame and reports the torn byte
count — a partial record is indistinguishable from garbage and must never
be replayed (invariant 11: acknowledged ⇒ durable, and nothing *beyond*
the durable prefix is resurrected).

Segments are whole flushed runs, CRC-framed the same way; the manifest
(msgpack) names the live segments newest-first and records the *horizon*:
the highest batch seq already folded into a durable segment.  Recovery
replays only WAL records **above** the horizon — records at or below it
were captured by a flush (and possibly rewritten by a compaction that
shrank the set-tombstone), so replaying them would resurrect element-keys
whose dots were already discarded.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import msgpack

MANIFEST = "MANIFEST"

_HDR = struct.Struct("<II")       # body_len, crc32(body)
_BODY_HDR = struct.Struct("<QI")  # seq, n_items
_ITEM_HDR = struct.Struct("<II")  # key_len, value_len


class WalError(RuntimeError):
    """Durable-media misuse or unrecoverable corruption (not a crash)."""


class CrashError(RuntimeError):
    """A scheduled :class:`CrashPoint` fired: the vnode process is dead.

    The in-memory store that raised this must be discarded; the
    :class:`DurableMedia` it was writing to survives and can be handed to
    a fresh store's ``recover()``.
    """


@dataclass(frozen=True)
class CrashPoint:
    """A deterministic kill point, armed via :meth:`DurableMedia.schedule_crash`.

    ``wal_bytes``: die during the fsync that would carry the durable WAL
    past this absolute byte offset, truncating it there (torn tail).
    ``file_writes``: die on the N-th subsequent atomic file publish
    (1-based), before the file lands — segment/manifest/WAL-reset writes
    all count, so N selects mid-flush vs mid-compaction deaths.
    """

    wal_bytes: Optional[int] = None
    file_writes: Optional[int] = None


@dataclass(frozen=True)
class RecoveryResult:
    """What ``LsmStore.recover()`` rebuilt, for assertions and spans."""

    segments: int            # durable runs loaded from the manifest
    batches_replayed: int    # WAL records above the horizon -> memtable
    batches_skipped: int     # WAL records <= horizon (already in segments)
    bytes_replayed: int      # WAL bytes applied (billed once, to bytes_recovered)
    torn_bytes: int          # trailing bytes discarded by CRC framing
    horizon: int             # manifest horizon (highest segment-covered seq)
    last_seq: int            # highest seq restored (continues numbering)


class DurableMedia:
    """One vnode's simulated disk: durable WAL bytes + published files.

    Writes are buffered (``wal_append``) until ``wal_sync`` — the fsync —
    moves them into the durable log.  File publishes (``write_file``,
    ``wal_reset``) are atomic: they either land whole or, under an armed
    :class:`CrashPoint`, not at all.  ``crash()`` models power loss: the
    unsynced buffer is gone, counters and durable state remain.
    """

    def __init__(self) -> None:
        self.files: Dict[str, bytes] = {}
        self.wal = bytearray()          # durable (fsynced) log bytes
        self._buffer = bytearray()      # written, not yet fsynced
        self.wal_fsyncs = 0             # group-commit fsyncs issued
        self.file_fsyncs = 0            # atomic file publishes
        self.crashes = 0
        self._crash: Optional[CrashPoint] = None
        self._file_writes_seen = 0

    # --------------------------------------------------------------- faults
    def schedule_crash(self, point: CrashPoint) -> None:
        """Arm a kill point; the matching write raises :class:`CrashError`."""
        self._crash = point
        self._file_writes_seen = 0

    def crash(self) -> None:
        """Power loss: drop the unsynced buffer, disarm any kill point."""
        self._buffer.clear()
        self._crash = None
        self.crashes += 1

    def _check_file_crash(self) -> None:
        cp = self._crash
        if cp is not None and cp.file_writes is not None:
            self._file_writes_seen += 1
            if self._file_writes_seen >= cp.file_writes:
                raise CrashError(
                    f"crashed on file publish #{self._file_writes_seen}")

    # ------------------------------------------------------------------ WAL
    def wal_append(self, data: bytes) -> None:
        """Buffer bytes at the log tail; durable only after ``wal_sync``."""
        self._buffer.extend(data)

    def wal_pending(self) -> int:
        """Bytes written but not yet fsynced (lost by a crash)."""
        return len(self._buffer)

    def wal_sync(self) -> None:
        """fsync: move the buffer into the durable log (one group commit).

        Under an armed ``wal_bytes`` kill point the fsync dies mid-write:
        the durable log is truncated at exactly that offset — usually in
        the middle of a record — and :class:`CrashError` is raised.
        """
        if not self._buffer:
            return
        cp = self._crash
        if cp is not None and cp.wal_bytes is not None \
                and len(self.wal) + len(self._buffer) >= cp.wal_bytes:
            keep = max(cp.wal_bytes - len(self.wal), 0)
            self.wal.extend(self._buffer[:keep])
            raise CrashError(
                f"crashed mid-fsync: durable WAL torn at byte {len(self.wal)}")
        self.wal.extend(self._buffer)
        self._buffer.clear()
        self.wal_fsyncs += 1

    def wal_drop_buffer(self) -> None:
        """Discard unsynced bytes made redundant by a durable flush."""
        self._buffer.clear()

    def wal_reset(self, data: bytes = b"") -> None:
        """Atomically replace the log (write-temp + rename, one publish)."""
        self._check_file_crash()
        self.wal = bytearray(data)
        self._buffer.clear()
        self.file_fsyncs += 1

    # ---------------------------------------------------------------- files
    def write_file(self, name: str, data: bytes) -> None:
        """Atomically publish a file; crash points fire *before* it lands."""
        self._check_file_crash()
        self.files[name] = bytes(data)
        self.file_fsyncs += 1

    def read_file(self, name: str) -> Optional[bytes]:
        return self.files.get(name)

    def delete_file(self, name: str) -> None:
        self.files.pop(name, None)


# -------------------------------------------------------------- WAL framing
def encode_wal_record(seq: int, items: List[Tuple[bytes, bytes]]) -> bytes:
    """Frame one write batch: ``<len, crc>`` + ``<seq, n>`` + k/v pairs."""
    parts = [_BODY_HDR.pack(seq, len(items))]
    for k, v in items:
        parts.append(_ITEM_HDR.pack(len(k), len(v)))
        parts.append(k)
        parts.append(v)
    body = b"".join(parts)
    return _HDR.pack(len(body), zlib.crc32(body)) + body


@dataclass(frozen=True)
class WalRecord:
    seq: int
    items: Tuple[Tuple[bytes, bytes], ...]
    nbytes: int  # framed size (header + body)


def decode_wal(data: bytes) -> Tuple[List[WalRecord], int]:
    """Decode records until the first torn/corrupt frame.

    Returns ``(records, torn_bytes)`` — the trailing bytes that failed
    length or CRC framing.  A torn tail is *expected* after a mid-fsync
    crash and is silently discarded by recovery; only bytes before it
    were ever acknowledged.
    """
    records: List[WalRecord] = []
    off = 0
    n = len(data)
    while off < n:
        if n - off < _HDR.size:
            break  # torn header
        body_len, crc = _HDR.unpack_from(data, off)
        body_start = off + _HDR.size
        if n - body_start < body_len:
            break  # torn body
        body = data[body_start:body_start + body_len]
        if zlib.crc32(body) != crc:
            break  # corrupt frame: stop replay here
        seq, n_items = _BODY_HDR.unpack_from(body, 0)
        pos = _BODY_HDR.size
        items: List[Tuple[bytes, bytes]] = []
        ok = True
        for _ in range(n_items):
            if len(body) - pos < _ITEM_HDR.size:
                ok = False
                break
            klen, vlen = _ITEM_HDR.unpack_from(body, pos)
            pos += _ITEM_HDR.size
            if len(body) - pos < klen + vlen:
                ok = False
                break
            items.append((body[pos:pos + klen], body[pos + klen:pos + klen + vlen]))
            pos += klen + vlen
        if not ok:
            break  # CRC passed but framing is inconsistent: treat as torn
        records.append(WalRecord(seq, tuple(items), _HDR.size + body_len))
        off = body_start + body_len
    return records, n - off


# ----------------------------------------------------------- segment framing
def encode_segment(items: List[Tuple[bytes, bytes]]) -> bytes:
    """Frame one immutable sorted run (same CRC framing as WAL records)."""
    parts = [struct.pack("<I", len(items))]
    for k, v in items:
        parts.append(_ITEM_HDR.pack(len(k), len(v)))
        parts.append(k)
        parts.append(v)
    body = b"".join(parts)
    return _HDR.pack(len(body), zlib.crc32(body)) + body


def decode_segment(data: bytes) -> List[Tuple[bytes, bytes]]:
    """Decode a published segment; corruption here is fatal, not torn.

    Segments are published atomically — unlike the WAL there is no legal
    partial state, so any framing failure raises :class:`WalError`.
    """
    if len(data) < _HDR.size:
        raise WalError("segment shorter than its header")
    body_len, crc = _HDR.unpack(data[:_HDR.size])
    body = data[_HDR.size:]
    if len(body) != body_len or zlib.crc32(body) != crc:
        raise WalError("segment failed CRC framing")
    (count,) = struct.unpack_from("<I", body, 0)
    pos = 4
    items: List[Tuple[bytes, bytes]] = []
    for _ in range(count):
        if len(body) - pos < _ITEM_HDR.size:
            raise WalError("segment item header truncated")
        klen, vlen = _ITEM_HDR.unpack_from(body, pos)
        pos += _ITEM_HDR.size
        if len(body) - pos < klen + vlen:
            raise WalError("segment item payload truncated")
        items.append((body[pos:pos + klen], body[pos + klen:pos + klen + vlen]))
        pos += klen + vlen
    return items


# ---------------------------------------------------------------- manifest
def encode_manifest(segments: List[str], horizon: int, next_seg: int) -> bytes:
    return msgpack.packb(
        {"segments": list(segments), "horizon": horizon, "next_seg": next_seg},
        use_bin_type=True)


def decode_manifest(data: Optional[bytes]) -> Tuple[List[str], int, int]:
    """Returns ``(segments newest-first, horizon, next_seg)``; empty-media
    defaults when no manifest was ever published."""
    if data is None:
        return [], 0, 0
    doc = msgpack.unpackb(data, raw=False)
    return list(doc["segments"]), int(doc["horizon"]), int(doc["next_seg"])
