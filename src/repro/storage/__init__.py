"""Durable ordered key/value substrate (leveldb stand-in).

The paper's contribution is storage co-design: bigset decomposes a CRDT set
across a *range of keys* in an ordered store and modifies compaction to
consult the set-tombstone.  This package provides that substrate with full
byte accounting (bytes read / written / compacted), which is the cost model
the paper's §2.1 analysis and Figures 1-3 are built on.
"""
from .keycodec import KeyCodecError, decode_key, encode_key
from .lsm import IoStats, LsmStore
from .wal import (CrashError, CrashPoint, DurableMedia, RecoveryResult,
                  WalError)

__all__ = [
    "encode_key", "decode_key", "KeyCodecError", "LsmStore", "IoStats",
    "DurableMedia", "CrashPoint", "CrashError", "RecoveryResult", "WalError",
]
