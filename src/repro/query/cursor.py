"""Opaque resumable cursors for paginated bigset queries.

A cursor names *where a scan stopped*: the last element-key boundary the
executor emitted.  Because element-keys are stored in lexicographic element
order, resumption is a single storage seek strictly past that element
(``element + b"\\x00"`` is the immediate successor in the order-preserving
codec) — no server-side state, no skip-counting, O(1) to resume regardless
of how many pages came before.  Clients treat tokens as opaque bytes.

Token layout: urlsafe-base64( msgpack([version, scope, last_element]) ||
crc32 ) — the scope binds a token to the query shape that minted it, and the
checksum rejects truncated or spliced tokens.
"""
from __future__ import annotations

import base64
import binascii
import struct
import zlib
from typing import Optional

import msgpack

CURSOR_VERSION = 1
LEASE_VERSION = 1


class CursorError(ValueError):
    """Malformed, corrupted, or mismatched cursor token."""


class LeaseError(CursorError):
    """Lease token problems: wrong session, corruption, or expiry."""


def encode_cursor(scope: bytes, element: bytes, inclusive: bool = False) -> bytes:
    """Mint an opaque resume token.

    ``inclusive=False`` (the common case) resumes strictly past ``element``
    — the last element a page emitted.  ``inclusive=True`` resumes *at*
    ``element`` — used when a page emitted nothing (e.g. ``limit=0``) and the
    next page must start from the current head.
    """
    payload = msgpack.packb([CURSOR_VERSION, scope, element, bool(inclusive)])
    crc = struct.pack(">I", zlib.crc32(payload))
    return base64.urlsafe_b64encode(payload + crc)


def decode_cursor(token: bytes, scope: bytes) -> "tuple[bytes, bool]":
    """Validate ``token`` against ``scope``; return (element, inclusive)."""
    try:
        raw = base64.urlsafe_b64decode(token)
    except (binascii.Error, ValueError) as e:
        raise CursorError(f"undecodable cursor: {e}") from None
    if len(raw) < 5:
        raise CursorError("cursor too short")
    payload, crc = raw[:-4], raw[-4:]
    if struct.pack(">I", zlib.crc32(payload)) != crc:
        raise CursorError("cursor checksum mismatch")
    try:
        version, tok_scope, element, inclusive = msgpack.unpackb(payload)
    except Exception as e:
        raise CursorError(f"malformed cursor payload: {e}") from None
    if version != CURSOR_VERSION:
        raise CursorError(f"unsupported cursor version {version}")
    if tok_scope != scope:
        raise CursorError("cursor was minted for a different query")
    return element, bool(inclusive)


def wrap_lease(session_id: bytes, cursor: bytes, nonce: int = 0) -> bytes:
    """Bind a raw cursor to one service session as an opaque lease token.

    The serve layer never hands raw cursors to clients: it wraps them so a
    token minted for one session cannot resume another session's scan (the
    lease *deadline* lives server-side in the service's lease table — the
    token only carries the binding).  ``nonce`` keeps tokens distinct even
    when cursors collide byte-for-byte: two identical scans in one session
    must hold two independent leases, or releasing one would strand the
    other.  Same armor as cursors: msgpack payload + crc32, urlsafe base64.
    """
    payload = msgpack.packb([LEASE_VERSION, session_id, cursor, nonce])
    crc = struct.pack(">I", zlib.crc32(payload))
    return base64.urlsafe_b64encode(payload + crc)


def unwrap_lease(token: bytes, session_id: bytes) -> bytes:
    """Validate a lease token against ``session_id``; return the raw cursor."""
    try:
        raw = base64.urlsafe_b64decode(token)
    except (binascii.Error, ValueError) as e:
        raise LeaseError(f"undecodable lease: {e}") from None
    if len(raw) < 5:
        raise LeaseError("lease too short")
    payload, crc = raw[:-4], raw[-4:]
    if struct.pack(">I", zlib.crc32(payload)) != crc:
        raise LeaseError("lease checksum mismatch")
    try:
        version, tok_session, cursor, _nonce = msgpack.unpackb(payload)
    except Exception as e:
        raise LeaseError(f"malformed lease payload: {e}") from None
    if version != LEASE_VERSION:
        raise LeaseError(f"unsupported lease version {version}")
    if tok_session != session_id:
        raise LeaseError("lease belongs to a different session")
    return cursor


def resume_point(
    cursor: Optional[bytes], scope: bytes
) -> "tuple[Optional[bytes], Optional[bytes]]":
    """Decode a cursor into ``(start, after)`` seek arguments.

    Returns ``(None, None)`` for no cursor (scan from the range start),
    ``(element, None)`` for an inclusive token, ``(None, element)`` for the
    usual resume-strictly-past token.
    """
    if cursor is None:
        return None, None
    element, inclusive = decode_cursor(cursor, scope)
    return (element, None) if inclusive else (None, element)
