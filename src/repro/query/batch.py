"""Vectorised dot-visibility filtering for element-key streams.

The hot loop of every bigset read is "has the set-tombstone seen this dot?"
— executed once per element-key.  The scalar path does a Python dict probe
per dot; this module batches a whole scan chunk into dense ``(actors,
counters)`` ``int32`` arrays and dispatches the ``kernels/dot_seen`` kernel
(Pallas on TPU, pure-jnp reference elsewhere) so visibility for thousands of
keys resolves in one device call.

The tombstone is converted once per query into the dense
:class:`~repro.core.vclock.DenseClock` *interval* form (per-actor
``(lo, hi)`` run arrays); every chunk then reuses it.  The build is
O(interval runs) — causal metadata — with **no window cap**: the old
bitmap form had to fall back to scalar probes beyond a fixed per-actor
spread, but a run covers any span at constant cost.  Dots by actors the
tombstone has never heard of are unseen by definition and route to the
sentinel counter ``0``, which no 1-based run can contain.  Batch shapes
are padded to a fixed bucket so jit traces a handful of shapes, not one
per chunk length.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from ..core.clock import Clock
from ..core.dots import Dot
from ..core.vclock import from_clock

# Chunks smaller than this aren't worth a device dispatch.
MIN_BATCH = 32
# Pad batches up to a multiple of this so jit sees few distinct shapes.
PAD_BUCKET = 512


class BatchVisibility:
    """Batched ``tombstone.seen(dot)`` over chunks of a scan stream."""

    def __init__(
        self,
        tombstone: Clock,
        *,
        use_pallas: bool = False,
        interpret: Optional[bool] = None,
        min_batch: int = MIN_BATCH,
        stats=None,
    ):
        self.tombstone = tombstone
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.min_batch = min_batch
        # per-query launch accounting (QueryStats.kernel_launches/_rows):
        # the cross-query micro-batcher's per-query baseline
        self.stats = stats
        self._dense = None
        self._actor_index: Dict[object, int] = {}
        # counters are 1-based, so 0 is unseen by every run — the routing
        # target for padding and for actors the tombstone never heard of
        self._sentinel = 0

        if tombstone.is_zero():
            self._mode = "empty"
            return
        self._mode = "dense"
        actors = sorted(tombstone.actors(), key=repr)
        self._actor_index = {a: i for i, a in enumerate(actors)}
        self._dense = from_clock(tombstone, self._actor_index, len(actors))

    # ------------------------------------------------------------------ api
    def seen_mask(self, dots: Sequence[Dot]) -> np.ndarray:
        """bool[N] — which of ``dots`` has the tombstone seen (i.e. are dead)?"""
        n = len(dots)
        if n == 0:
            return np.zeros((0,), bool)
        if self._mode == "empty":
            return np.zeros((n,), bool)
        if n < self.min_batch:
            ts = self.tombstone
            return np.fromiter((ts.seen(d) for d in dots), bool, count=n)
        idx = self._actor_index
        actors = np.empty((n,), np.int32)
        counters = np.empty((n,), np.int32)
        for i, d in enumerate(dots):
            j = idx.get(d.actor, -1)
            if j < 0:
                # unknown actor: route to slot 0 with the sentinel counter,
                # which the kernel reports unseen
                actors[i] = 0
                counters[i] = self._sentinel
            else:
                actors[i] = j
                counters[i] = d.counter
        pad = (-n) % PAD_BUCKET
        if pad:
            actors = np.pad(actors, (0, pad))
            counters = np.pad(
                counters, (0, pad), constant_values=self._sentinel)
        if self.stats is not None:
            self.stats.kernel_launches += 1
            self.stats.kernel_rows += n
        from ..kernels.dot_seen import dot_seen

        mask = dot_seen(
            self._dense, actors, counters,
            use_pallas=self.use_pallas, interpret=self.interpret,
        )
        return np.asarray(mask)[:n]

    def seen_scalar(self, dots: Sequence[Dot]) -> np.ndarray:
        """Scalar oracle (for tests / tiny batches)."""
        ts = self.tombstone
        return np.fromiter(
            (ts.seen(d) for d in dots), bool, count=len(dots))
