"""Logical query plans over bigsets (paper §4.4).

A plan is a small frozen dataclass naming *what* to compute; the streaming
executor (:mod:`repro.query.executor`) decides *how* — which LSM seeks to
issue, how to batch visibility filtering, when to stop.  Plans are
deliberately storage-agnostic so the cluster layer can scatter the same plan
to every replica and quorum-merge the partial results.

Supported shapes:

* :class:`Membership` — is ``element`` in the set (plus its causal context)?
  A single seek (§4.4: "querying for a lone element ... only requires a
  seek, not a full set fold").
* :class:`Range` — ordered members in ``[start, end)``, optionally limited
  and resumable via a cursor.
* :class:`Count` — cardinality of a range without materialising it.
* :class:`Scan` — full-set pagination: a Range with a page size, built for
  cursoring through million-element sets.
* :class:`Join` — cross-set streaming intersect/union/difference, a zipper
  over two lexicographic element streams (§4.4's streaming ORSWOT join
  generalised to two sets).
* :class:`IndexLookup` — elements whose registered secondary index
  (:mod:`repro.index`) produced exactly ``key``: a seek into the posting
  range, never an element fold.
* :class:`IndexRange` — elements whose index key falls in ``[start, end)``,
  streamed in ``(index_key, element)`` order with limit/cursor pagination.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

import msgpack

from ..index.postings import lookup_span

JOIN_KINDS = ("intersect", "union", "difference")
JOIN_STRATEGIES = ("zipper", "gallop")


class PlanError(ValueError):
    """Raised for malformed or unsupported plans."""


@dataclass(frozen=True)
class Membership:
    set_name: bytes
    element: bytes


@dataclass(frozen=True)
class Range:
    set_name: bytes
    start: Optional[bytes] = None   # inclusive; None = set start
    end: Optional[bytes] = None     # exclusive; None = set end
    limit: Optional[int] = None     # max elements returned
    cursor: Optional[bytes] = None  # opaque resume token (wins over start)


@dataclass(frozen=True)
class Count:
    set_name: bytes
    start: Optional[bytes] = None
    end: Optional[bytes] = None


@dataclass(frozen=True)
class Scan:
    set_name: bytes
    page_size: int = 1000
    cursor: Optional[bytes] = None


@dataclass(frozen=True)
class Join:
    """Cross-set streaming join.

    Result entry dots belong to the *left* set's clock domain when the
    element is present there, otherwise the right set's — they are a causal
    context for that set only, never a blend of both (each set has its own
    clock, so equal dots name unrelated inserts across sets).

    ``strategy`` pins the executor's algorithm (``"zipper"`` zippers both
    ordered streams end-to-end; ``"gallop"`` drives the smaller side and
    probes the larger with bounded storage seeks); ``None`` — the default —
    lets the cost-based planner (:mod:`repro.query.planner`) choose from
    LSM run statistics.  The strategy never changes the result, only its
    cost, so it is deliberately **not** part of the cursor scope: a scan
    may switch strategy between pages as statistics shift.
    """

    kind: str                       # intersect | union | difference
    left: bytes                     # left set name
    right: bytes                    # right set name
    limit: Optional[int] = None
    cursor: Optional[bytes] = None
    strategy: Optional[str] = None  # zipper | gallop | None = planner picks


@dataclass(frozen=True)
class IndexLookup:
    """Exact-match probe of one secondary index (``index key == key``)."""

    set_name: bytes
    index: bytes                    # index name (IndexSpec.name)
    key: bytes                      # exact index key to match
    limit: Optional[int] = None
    cursor: Optional[bytes] = None


@dataclass(frozen=True)
class IndexRange:
    """Index-ordered scan over ``[start, end)`` of one secondary index.

    Results stream in ``(index_key, element)`` order — an element appears
    once per matching index key (multi-valued extractors may match several
    times), each carrying its full surviving dot context.
    """

    set_name: bytes
    index: bytes
    start: Optional[bytes] = None   # inclusive; None = index start
    end: Optional[bytes] = None     # exclusive; None = index end
    limit: Optional[int] = None
    cursor: Optional[bytes] = None  # opaque resume token


Plan = Union[Membership, Range, Count, Scan, Join, IndexLookup, IndexRange]
IndexPlan = Union[IndexLookup, IndexRange]


def index_span(plan: IndexPlan) -> Tuple[Optional[bytes], Optional[bytes]]:
    """Normalise an index plan to its ``[start, end)`` index-key span.

    A lookup is the degenerate range matching exactly ``key`` — both shapes
    share one executor path, one cursor scope, and one quorum merge.
    """
    if isinstance(plan, IndexLookup):
        return lookup_span(plan.key)
    return plan.start, plan.end


def validate(plan: Plan) -> Plan:
    """Check a plan's invariants; returns the plan for chaining."""
    if isinstance(plan, Membership):
        if not plan.set_name or plan.element is None:
            raise PlanError("membership needs a set name and an element")
    elif isinstance(plan, Range):
        if not plan.set_name:
            raise PlanError("range needs a set name")
        if plan.limit is not None and plan.limit < 0:
            raise PlanError("range limit must be >= 0")
        if (plan.start is not None and plan.end is not None
                and plan.start >= plan.end):
            raise PlanError("empty range: start >= end")
    elif isinstance(plan, Count):
        if not plan.set_name:
            raise PlanError("count needs a set name")
        if (plan.start is not None and plan.end is not None
                and plan.start >= plan.end):
            raise PlanError("empty range: start >= end")
    elif isinstance(plan, Scan):
        if not plan.set_name:
            raise PlanError("scan needs a set name")
        if plan.page_size <= 0:
            raise PlanError("scan page_size must be > 0")
    elif isinstance(plan, Join):
        if plan.kind not in JOIN_KINDS:
            raise PlanError(f"unknown join kind {plan.kind!r}")
        if not plan.left or not plan.right:
            raise PlanError("join needs two set names")
        if plan.limit is not None and plan.limit < 0:
            raise PlanError("join limit must be >= 0")
        if plan.strategy is not None and plan.strategy not in JOIN_STRATEGIES:
            raise PlanError(
                f"unknown join strategy {plan.strategy!r} "
                f"(expected one of {JOIN_STRATEGIES} or None)")
    elif isinstance(plan, IndexLookup):
        if not plan.set_name or not plan.index:
            raise PlanError("index lookup needs a set name and an index name")
        if plan.key is None:
            raise PlanError("index lookup needs a key")
        if plan.limit is not None and plan.limit < 0:
            raise PlanError("index lookup limit must be >= 0")
    elif isinstance(plan, IndexRange):
        if not plan.set_name or not plan.index:
            raise PlanError("index range needs a set name and an index name")
        if plan.limit is not None and plan.limit < 0:
            raise PlanError("index range limit must be >= 0")
        if (plan.start is not None and plan.end is not None
                and plan.start >= plan.end):
            raise PlanError("empty index range: start >= end")
    else:
        raise PlanError(f"unknown plan type {type(plan).__name__}")
    return plan


# ------------------------------------------------------------- wire codec
# The serve layer (:mod:`repro.serve.bigset_service`) ships plans between
# client and service as a versioned msgpack envelope: ``[version, shape,
# fields]``.  Field maps (not positional tuples) so shapes can grow fields
# without breaking older tokensets; bytes stay bytes under msgpack, so set
# names and range bounds round-trip exactly.
PLAN_WIRE_VERSION = 1

_WIRE_SHAPES = {
    Membership: "membership",
    Range: "range",
    Count: "count",
    Scan: "scan",
    Join: "join",
    IndexLookup: "index_lookup",
    IndexRange: "index_range",
}
_SHAPE_TYPES = {tag: cls for cls, tag in _WIRE_SHAPES.items()}


def plan_to_wire(plan: Plan) -> bytes:
    """Encode a validated plan as its wire envelope (every shape)."""
    validate(plan)
    shape = _WIRE_SHAPES[type(plan)]
    fields = {
        f: getattr(plan, f) for f in type(plan).__dataclass_fields__
    }
    return msgpack.packb([PLAN_WIRE_VERSION, shape, fields])


def plan_from_wire(blob: bytes) -> Plan:
    """Decode and validate a wire envelope back into a plan.

    Raises :class:`PlanError` for anything malformed — undecodable bytes,
    unknown versions or shapes, missing or extra fields — so the serve
    layer can map every bad request to one error path.
    """
    try:
        envelope = msgpack.unpackb(blob)
    except Exception as e:
        raise PlanError(f"undecodable plan envelope: {e}") from None
    if not (isinstance(envelope, (list, tuple)) and len(envelope) == 3):
        raise PlanError(f"malformed plan envelope: {envelope!r}")
    version, shape, fields = envelope
    if version != PLAN_WIRE_VERSION:
        raise PlanError(f"unsupported plan wire version {version!r}")
    cls = _SHAPE_TYPES.get(shape)
    if cls is None:
        raise PlanError(f"unknown plan shape {shape!r}")
    if not isinstance(fields, dict):
        raise PlanError("plan fields must be a map")
    known = set(cls.__dataclass_fields__)
    unknown = set(fields) - known
    if unknown:
        raise PlanError(f"unknown {shape} fields {sorted(unknown)}")
    try:
        plan = cls(**fields)
    except TypeError as e:
        raise PlanError(f"bad {shape} fields: {e}") from None
    return validate(plan)


def cursor_scope(plan: Plan) -> bytes:
    """The scope a cursor is valid for — tokens must not cross query shapes.

    Components are length-delimited (msgpack), not joined with a separator:
    ``Range(b"a:b")`` and ``Range(b"a", start=b"b:")`` must never share a
    scope, or one query's cursor would resume the other.
    """
    if isinstance(plan, (Range, Count)):
        return msgpack.packb(
            ["range", plan.set_name, plan.start or b"", plan.end or b""])
    if isinstance(plan, Scan):
        return msgpack.packb(["scan", plan.set_name])
    if isinstance(plan, Join):
        # strategy is deliberately not part of the scope: both strategies
        # emit the same element sequence, so a cursor minted under one
        # must resume under the other (the planner may flip mid-scan)
        return msgpack.packb(["join", plan.kind, plan.left, plan.right])
    if isinstance(plan, (IndexLookup, IndexRange)):
        start, end = index_span(plan)
        return msgpack.packb(
            ["index", plan.set_name, plan.index, start or b"", end or b""])
    raise PlanError(f"plan {type(plan).__name__} does not paginate")
