"""Logical query plans over bigsets (paper §4.4).

A plan is a small frozen dataclass naming *what* to compute; the streaming
executor (:mod:`repro.query.executor`) decides *how* — which LSM seeks to
issue, how to batch visibility filtering, when to stop.  Plans are
deliberately storage-agnostic so the cluster layer can scatter the same plan
to every replica and quorum-merge the partial results.

Supported shapes:

* :class:`Membership` — is ``element`` in the set (plus its causal context)?
  A single seek (§4.4: "querying for a lone element ... only requires a
  seek, not a full set fold").
* :class:`Range` — ordered members in ``[start, end)``, optionally limited
  and resumable via a cursor.
* :class:`Count` — cardinality of a range without materialising it.
* :class:`Scan` — full-set pagination: a Range with a page size, built for
  cursoring through million-element sets.
* :class:`Join` — cross-set streaming intersect/union/difference, a zipper
  over two lexicographic element streams (§4.4's streaming ORSWOT join
  generalised to two sets).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

import msgpack

JOIN_KINDS = ("intersect", "union", "difference")


class PlanError(ValueError):
    """Raised for malformed or unsupported plans."""


@dataclass(frozen=True)
class Membership:
    set_name: bytes
    element: bytes


@dataclass(frozen=True)
class Range:
    set_name: bytes
    start: Optional[bytes] = None   # inclusive; None = set start
    end: Optional[bytes] = None     # exclusive; None = set end
    limit: Optional[int] = None     # max elements returned
    cursor: Optional[bytes] = None  # opaque resume token (wins over start)


@dataclass(frozen=True)
class Count:
    set_name: bytes
    start: Optional[bytes] = None
    end: Optional[bytes] = None


@dataclass(frozen=True)
class Scan:
    set_name: bytes
    page_size: int = 1000
    cursor: Optional[bytes] = None


@dataclass(frozen=True)
class Join:
    """Cross-set streaming join.

    Result entry dots belong to the *left* set's clock domain when the
    element is present there, otherwise the right set's — they are a causal
    context for that set only, never a blend of both (each set has its own
    clock, so equal dots name unrelated inserts across sets).
    """

    kind: str                       # intersect | union | difference
    left: bytes                     # left set name
    right: bytes                    # right set name
    limit: Optional[int] = None
    cursor: Optional[bytes] = None


Plan = Union[Membership, Range, Count, Scan, Join]


def validate(plan: Plan) -> Plan:
    """Check a plan's invariants; returns the plan for chaining."""
    if isinstance(plan, Membership):
        if not plan.set_name or plan.element is None:
            raise PlanError("membership needs a set name and an element")
    elif isinstance(plan, Range):
        if not plan.set_name:
            raise PlanError("range needs a set name")
        if plan.limit is not None and plan.limit < 0:
            raise PlanError("range limit must be >= 0")
        if (plan.start is not None and plan.end is not None
                and plan.start >= plan.end):
            raise PlanError("empty range: start >= end")
    elif isinstance(plan, Count):
        if not plan.set_name:
            raise PlanError("count needs a set name")
        if (plan.start is not None and plan.end is not None
                and plan.start >= plan.end):
            raise PlanError("empty range: start >= end")
    elif isinstance(plan, Scan):
        if not plan.set_name:
            raise PlanError("scan needs a set name")
        if plan.page_size <= 0:
            raise PlanError("scan page_size must be > 0")
    elif isinstance(plan, Join):
        if plan.kind not in JOIN_KINDS:
            raise PlanError(f"unknown join kind {plan.kind!r}")
        if not plan.left or not plan.right:
            raise PlanError("join needs two set names")
        if plan.limit is not None and plan.limit < 0:
            raise PlanError("join limit must be >= 0")
    else:
        raise PlanError(f"unknown plan type {type(plan).__name__}")
    return plan


def cursor_scope(plan: Plan) -> bytes:
    """The scope a cursor is valid for — tokens must not cross query shapes.

    Components are length-delimited (msgpack), not joined with a separator:
    ``Range(b"a:b")`` and ``Range(b"a", start=b"b:")`` must never share a
    scope, or one query's cursor would resume the other.
    """
    if isinstance(plan, (Range, Count)):
        return msgpack.packb(
            ["range", plan.set_name, plan.start or b"", plan.end or b""])
    if isinstance(plan, Scan):
        return msgpack.packb(["scan", plan.set_name])
    if isinstance(plan, Join):
        return msgpack.packb(["join", plan.kind, plan.left, plan.right])
    raise PlanError(f"plan {type(plan).__name__} does not paginate")
