"""Cost-based join planning over LSM run statistics.

The paper's bet is that decomposition makes work proportional to causal
metadata, not cardinality (§2.1), and that the full-read trade-off "is
mitigated by enabling queries on sets" (§4.4).  A join that always zippers
both element streams end-to-end betrays that bet: intersecting a
100-element set against a 1M-element set pays O(n) of the large side.  This
module is the chooser that keeps join IO proportional to the *smaller*
side when the data is skewed:

* **zipper** — the §4.4 streaming join: both ordered element streams are
  merged end-to-end.  Cost ~ ``left.keys + right.keys``.  Optimal when the
  sides are comparable (every key must be visited anyway), and the only
  correct shape for ``union`` (every entry of both sides is emitted —
  there is nothing to skip).
* **gallop** — drive the smaller side's stream; probe the larger side with
  bounded positional seeks (:meth:`repro.storage.lsm.LsmIterator.seek`
  skips the gap without touching it).  Cost ~ ``drive.keys * (1 +
  SEEK_COST_KEYS)`` — independent of the large side's cardinality.

Statistics come from :meth:`repro.storage.lsm.LsmStore.range_stats`: per-run
key counts, range fences, and cumulative byte offsets make any range's
cardinality/volume estimate a couple of bisects, never a scan.  The chosen
strategy is surfaced in :attr:`repro.query.executor.QueryStats.strategy`
and rides the serve layer's per-page stats to clients.

Both strategies return byte-identical entries (asserted in
``tests/test_planner.py``); the planner only moves cost, never results —
which is also why a cursor minted under one strategy resumes under the
other.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional

from ..core.bigset import element_range
from ..storage.lsm import LsmStore
from .plan import PlanError

ZIPPER = "zipper"
GALLOP = "gallop"

# One positional probe (a bisect per level, heap rebuild, one element's
# keys decoded + visibility-filtered) costs about this many sequentially
# streamed keys.  Gallop wins once the large side exceeds
# SEEK_COST_KEYS x the small side — measured crossover in
# benchmarks/bench_joins.py.
SEEK_COST_KEYS = 12.0


@dataclass(frozen=True)
class SideStats:
    """Approximate size of one join side's element range."""

    keys: int    # element-key count (upper bound: shadowed keys included)
    bytes: int   # byte volume of the range


@dataclass(frozen=True)
class JoinChoice:
    """The planner's verdict: which algorithm, driving which side, and why."""

    strategy: str          # "zipper" | "gallop"
    drive: str             # side the executor streams: "left" | "right"
    left: SideStats
    right: SideStats
    est_zipper: float      # estimated keys touched by the zipper
    est_gallop: float      # estimated keys touched by the gallop (inf: n/a)
    reason: str


def side_stats(store: LsmStore, set_name: bytes) -> SideStats:
    """Size of one set's element range, from run statistics (no scan)."""
    lo, hi = element_range(set_name)
    rs = store.range_stats(lo, hi)
    return SideStats(keys=rs.keys, bytes=rs.bytes)


def quorum_side_stats(stores: Iterable[LsmStore], set_name: bytes) -> SideStats:
    """Aggregate side size across the replicas a coverage query touches.

    Sums preserve the left:right skew ratio (each replica holds the full
    set), which is all the cost model compares.
    """
    keys = nbytes = 0
    for store in stores:
        s = side_stats(store, set_name)
        keys += s.keys
        nbytes += s.bytes
    return SideStats(keys=keys, bytes=nbytes)


def gallop_drive(kind: str, left: SideStats, right: SideStats) -> Optional[str]:
    """Which side a gallop join would drive, or None if gallop can't apply.

    Intersect is symmetric: drive whichever side is smaller.  Difference
    must emit the left side's survivors, so it can only ever drive left
    (galloping helps exactly when the right side is the big one).  Union
    emits every entry of both sides — nothing can be skipped.
    """
    if kind == "intersect":
        return "left" if left.keys <= right.keys else "right"
    if kind == "difference":
        return "left"
    return None


def choose_join(
    kind: str,
    left: SideStats,
    right: SideStats,
    forced: Optional[str] = None,
) -> JoinChoice:
    """Pick zipper vs gallop for one join from its sides' run statistics.

    ``forced`` (the plan's ``strategy`` field) overrides the cost model —
    except for union, which structurally cannot gallop and always zippers.
    """
    drive = gallop_drive(kind, left, right)
    est_zipper = float(left.keys + right.keys)
    if drive is None:
        est_gallop = float("inf")
    else:
        d = left if drive == "left" else right
        est_gallop = d.keys * (1.0 + SEEK_COST_KEYS)

    if forced is not None:
        if forced not in (ZIPPER, GALLOP):
            raise PlanError(f"unknown join strategy {forced!r}")
        if forced == GALLOP and drive is None:
            strategy = ZIPPER
            reason = "forced gallop, but union must stream both sides"
        else:
            strategy = forced
            reason = f"forced {forced}"
    elif est_gallop < est_zipper:
        strategy = GALLOP
        reason = (f"gallop ~{est_gallop:.0f} keys beats "
                  f"zipper ~{est_zipper:.0f}")
    else:
        strategy = ZIPPER
        reason = (f"zipper ~{est_zipper:.0f} keys beats "
                  f"gallop ~{est_gallop:.0f}")

    if strategy == ZIPPER:
        drive = "left"  # the zipper streams both; left is just convention
    return JoinChoice(
        strategy=strategy, drive=drive or "left", left=left, right=right,
        est_zipper=est_zipper, est_gallop=est_gallop, reason=reason)
