"""Bigset query engine (paper §4.4).

The paper's decomposition trade-off — writes become O(causal metadata) but a
full read must stream every element-key — is "mitigated by enabling queries
on sets": because element-keys live in one lexicographically ordered range,
membership is a seek, range scans touch only their result, and cross-set
joins are ordered-stream zippers.  This package is that query layer:

* :mod:`repro.query.plan`     — logical plans (membership / range / count /
  paginated scan / cross-set streaming joins / secondary-index lookups and
  ranges over :mod:`repro.index` postings);
* :mod:`repro.query.cursor`   — opaque resumable pagination tokens;
* :mod:`repro.query.batch`    — vectorised dot-visibility filtering that
  dispatches the ``kernels/dot_seen`` Pallas kernel over dense
  ``(actors, counters)`` batches instead of per-dot Python checks;
* :mod:`repro.query.executor` — the streaming executor: bounded-memory folds
  over LSM seeks, with per-query :class:`~repro.storage.lsm.IoStats`;
* :mod:`repro.query.planner`  — cost-based join planning: zipper vs
  seek-gallop, chosen from LSM run statistics
  (:meth:`repro.storage.lsm.LsmStore.range_stats`), surfaced in
  :attr:`~repro.query.executor.QueryStats.strategy`.

Cluster-level scatter/gather with quorum merge and read-repair lives in
:meth:`repro.cluster.clusters.BigsetCluster.query`.
"""
from .cursor import (CursorError, LeaseError, decode_cursor, encode_cursor,
                     unwrap_lease, wrap_lease)
from .executor import (QueryExecutor, QueryResult, QueryStats, gallop_join,
                       zipper_join)
from .plan import (Count, IndexLookup, IndexRange, Join, Membership, Plan,
                   PlanError, Range, Scan, plan_from_wire, plan_to_wire,
                   validate)
from .planner import (GALLOP, ZIPPER, JoinChoice, SideStats, choose_join,
                      quorum_side_stats, side_stats)

__all__ = [
    "Count", "CursorError", "GALLOP", "IndexLookup", "IndexRange", "Join",
    "JoinChoice", "LeaseError", "Membership", "Plan", "PlanError",
    "QueryExecutor", "QueryResult", "QueryStats", "Range", "Scan",
    "SideStats", "ZIPPER", "choose_join", "decode_cursor", "encode_cursor",
    "gallop_join", "plan_from_wire", "plan_to_wire", "quorum_side_stats",
    "side_stats", "unwrap_lease", "validate", "wrap_lease", "zipper_join",
]
