"""Streaming bigset query executor (paper §4.4).

Executes logical plans against one :class:`~repro.core.bigset.BigsetVnode`
with three invariants:

* **Seek, don't fold**: every plan positions the LSM iterator at the first
  relevant element-key (cursor resumption seeks strictly past the last
  emitted element) and stops at the range end or limit — a range query costs
  O(result + causal metadata) bytes, never O(n).  Verified against
  per-query :class:`~repro.storage.lsm.IoStats` in ``tests/test_query.py``.
* **Bounded memory**: the element-key stream is consumed in fixed-size
  chunks; at most one chunk plus the entry currently being grouped is ever
  held.  Million-element sets page through a fixed-size window.
* **Batched visibility**: each chunk's dots are tested against the
  set-tombstone in one :class:`~repro.query.batch.BatchVisibility` dispatch
  (the Pallas ``dot_seen`` kernel) instead of per-dot Python probes.

Joins come in two strategies, chosen per query by the cost-based planner
(:mod:`repro.query.planner`) from LSM run statistics — or pinned via the
plan's ``strategy`` field:

* :func:`zipper_join` merges two ordered element streams end-to-end; when
  one side falls behind it drains its already-read chunk, then repositions
  the LSM cursor with one **positional seek** (skipped keys cost no IO).
* :func:`gallop_join` streams only the smaller (drive) side and probes the
  larger with bounded storage seeks — cost proportional to the small
  side's cardinality, independent of the large side's.

Both emit byte-identical entries; the chosen strategy is reported in
:attr:`QueryStats.strategy` and flows through the serve layer's per-page
stats.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterator, List, Optional, Tuple

import msgpack

from ..core.bigset import BigsetVnode
from ..core.clock import Clock
from ..core.dots import Dot, DotList
from ..storage.keycodec import successor_bytes
from .batch import BatchVisibility
from .cursor import decode_cursor, encode_cursor, resume_point
from .plan import (Count, IndexLookup, IndexRange, Join, Membership, Plan,
                   PlanError, Range, Scan)
from .plan import cursor_scope, index_span, validate
from .planner import GALLOP, choose_join, side_stats

DEFAULT_BATCH_SIZE = 1024
# chunk size right after a positional seek: the next read should pay for a
# probe-sized bite, not a full prefetch the gallop may immediately skip
SEEK_CHUNK = 8


@dataclass
class QueryStats:
    """Per-query cost accounting (fed by the store's IoStats meter)."""

    bytes_read: int = 0
    num_seeks: int = 0
    keys_scanned: int = 0
    elements_emitted: int = 0
    batches: int = 0
    keys_probed: int = 0   # point probes issued (membership / index lookup /
                           # gallop probes), counted on hits AND misses
    kernel_launches: int = 0  # batched dot_seen dispatches this query paid
    kernel_rows: int = 0      # dots those dispatches covered (pre-padding)
    strategy: str = ""     # join strategy the planner executed ("" otherwise)
    coverage: str = ""     # ring coverage the cluster planned for this query
                           # ("epoch=E;partitions=P;vnodes=V;r=R")


@dataclass
class QueryResult:
    entries: List[Tuple[bytes, DotList]] = field(default_factory=list)
    present: Optional[bool] = None    # Membership only
    count: Optional[int] = None       # Count only
    cursor: Optional[bytes] = None    # more pages exist iff not None
    clock: Optional[Clock] = None     # set-clock snapshot (quorum merge)
    stats: QueryStats = field(default_factory=QueryStats)
    # IndexLookup/IndexRange only: (index_key, element, dots) in index order
    index_entries: Optional[List[Tuple[bytes, bytes, DotList]]] = None

    @property
    def members(self) -> List[bytes]:
        return [e for e, _ in self.entries]


class _EntryStream:
    """Visible (element, dots) stream over a bounded element range.

    Groups the raw element-key stream by element and filters each chunk's
    dots through one batched visibility dispatch.  The raw stream is a
    positional :class:`~repro.core.bigset.ElementCursor`: ``seek_to``
    (galloping joins, cursor resumption) repositions it with one O(log n)
    storage seek — the skipped keys are never read, so they cost neither
    ``bytes_read`` nor ``keys_scanned`` — without rebuilding the tombstone
    filter.
    """

    def __init__(
        self,
        vnode: BigsetVnode,
        set_name: bytes,
        vis: BatchVisibility,
        stats: QueryStats,
        start: Optional[bytes] = None,
        end: Optional[bytes] = None,
        after: Optional[bytes] = None,
        batch_size: int = DEFAULT_BATCH_SIZE,
    ):
        self._vnode = vnode
        self._set = set_name
        self._vis = vis
        self._stats = stats
        self._batch = batch_size
        # Grow chunks geometrically: a limit-25 page must not pre-pay for a
        # full batch of keys (O(result), not O(batch)); deep scans still
        # amortise into full-width visibility dispatches.
        self._chunk = min(32, batch_size)
        # last element the raw cursor has read into a chunk: the boundary
        # between draining already-paid read-ahead and a storage seek
        self._last_raw_el: Optional[bytes] = None
        self._raw = vnode.element_cursor(
            set_name, start=start, end=end, after=after)
        self._gen = self._generate()
        self.head: Optional[Tuple[bytes, DotList]] = next(self._gen, None)

    def advance(self) -> Optional[Tuple[bytes, DotList]]:
        """Pop and return the current head; load the next entry."""
        h = self.head
        self.head = next(self._gen, None)
        return h

    def seek_to(self, element: bytes) -> None:
        """Position the head at the first visible entry >= ``element``.

        When the target is still inside the chunk the raw cursor already
        read (and metered), draining to it is free IO.  Past that
        read-ahead, one positional storage seek jumps the gap — the
        skipped keys are never read, so they cost no ``bytes_read`` and no
        ``keys_scanned``, and nothing already paid for is re-read.  The
        chunk size resets small after a seek so the next read pays for a
        probe-sized bite, not a full prefetch.
        """
        while self.head is not None and self.head[0] < element:
            if self._last_raw_el is None or self._last_raw_el >= element:
                self.advance()
                continue
            self._raw.seek(element)
            self._chunk = SEEK_CHUNK
            self._last_raw_el = None
            self._gen = self._generate()
            self.head = next(self._gen, None)
            return

    def _generate(self) -> Iterator[Tuple[bytes, DotList]]:
        raw = self._raw
        cur_el: Optional[bytes] = None
        cur_dots: List[Dot] = []
        while True:
            chunk: List[Tuple[bytes, Dot]] = []
            for el, dot, _v in raw:
                chunk.append((el, dot))
                self._last_raw_el = el
                if len(chunk) >= self._chunk:
                    break
            if not chunk:
                break
            self._chunk = min(self._chunk * 4, self._batch)
            dead = self._vis.seen_mask([d for _, d in chunk])
            self._stats.keys_scanned += len(chunk)
            self._stats.batches += 1
            for (el, dot), is_dead in zip(chunk, dead):
                if el != cur_el:
                    if cur_el is not None and cur_dots:
                        yield cur_el, tuple(cur_dots)
                    cur_el, cur_dots = el, []
                if not is_dead:
                    cur_dots.append(dot)
        if cur_el is not None and cur_dots:
            yield cur_el, tuple(cur_dots)


class _IndexStream:
    """Visible ``((index_key, element), dots)`` stream over a posting range.

    Groups the raw posting stream by ``(index_key, element)`` and filters
    each chunk's dots through one batched visibility dispatch — the same
    Pallas ``dot_seen`` path element scans use, because a posting is live
    iff its dot is live.  Each surviving group then fetches its element's
    full surviving dot context from the element keyspace (a bounded seek),
    so index results carry the same causal context a Range would return —
    total cost O(matches + causal metadata).
    """

    def __init__(
        self,
        vnode: BigsetVnode,
        set_name: bytes,
        index_name: bytes,
        vis: BatchVisibility,
        stats: QueryStats,
        start: Optional[bytes] = None,
        end: Optional[bytes] = None,
        at: Optional[Tuple[bytes, bytes]] = None,
        after: Optional[Tuple[bytes, bytes]] = None,
        batch_size: int = DEFAULT_BATCH_SIZE,
    ):
        self._vnode = vnode
        self._set = set_name
        self._index = index_name
        self._vis = vis
        self._stats = stats
        self._end = end
        self._batch = batch_size
        self._gen = self._generate(start=start, at=at, after=after)
        self.head: Optional[Tuple[Tuple[bytes, bytes], DotList]] = next(
            self._gen, None)

    def advance(self) -> Optional[Tuple[Tuple[bytes, bytes], DotList]]:
        h = self.head
        self.head = next(self._gen, None)
        return h

    def _generate(
        self,
        start: Optional[bytes],
        at: Optional[Tuple[bytes, bytes]],
        after: Optional[Tuple[bytes, bytes]],
    ) -> Iterator[Tuple[Tuple[bytes, bytes], DotList]]:
        raw = self._vnode.fold_postings(
            self._set, self._index, start=start, end=self._end,
            at=at, after=after)
        cur: Optional[Tuple[bytes, bytes]] = None
        cur_live = False
        chunk_size = min(32, self._batch)
        while True:
            chunk: List[Tuple[bytes, bytes, Dot]] = []
            for ik, el, dot in raw:
                chunk.append((ik, el, dot))
                if len(chunk) >= chunk_size:
                    break
            if not chunk:
                break
            chunk_size = min(chunk_size * 4, self._batch)
            dead = self._vis.seen_mask([d for _, _, d in chunk])
            self._stats.keys_scanned += len(chunk)
            self._stats.batches += 1
            for (ik, el, dot), is_dead in zip(chunk, dead):
                if (ik, el) != cur:
                    if cur is not None and cur_live:
                        entry = self._entry(cur)
                        if entry is not None:
                            yield entry
                    cur, cur_live = (ik, el), False
                if not is_dead:
                    cur_live = True
        if cur is not None and cur_live:
            entry = self._entry(cur)
            if entry is not None:
                yield entry

    def _entry(
        self, pos: Tuple[bytes, bytes]
    ) -> Optional[Tuple[Tuple[bytes, bytes], DotList]]:
        """Fetch the element's full surviving dots (the ISSUE's "then fetch
        matching elements" step): one bounded seek into the element range."""
        _ik, element = pos
        dots = [
            d for _e, d, _v in self._vnode.fold_raw(
                self._set, start=element, end=successor_bytes(element))
        ]
        mask = self._vis.seen_mask(dots)
        live = tuple(sorted(d for d, is_dead in zip(dots, mask) if not is_dead))
        return (pos, live) if live else None


class QueryExecutor:
    """Executes :mod:`repro.query.plan` plans against one vnode."""

    def __init__(
        self,
        vnode: BigsetVnode,
        *,
        batch_size: int = DEFAULT_BATCH_SIZE,
        use_pallas: bool = False,
        interpret: Optional[bool] = None,
    ):
        self.vnode = vnode
        self.batch_size = batch_size
        self.use_pallas = use_pallas
        self.interpret = interpret

    # ----------------------------------------------------------------- public
    def execute(self, plan: Plan) -> QueryResult:
        validate(plan)
        meter = self.vnode.store.meter()
        if isinstance(plan, Membership):
            res = self._membership(plan)
        elif isinstance(plan, Range):
            res = self._range(plan.set_name, plan.start, plan.end,
                              plan.limit, plan.cursor, cursor_scope(plan))
        elif isinstance(plan, Scan):
            res = self._range(plan.set_name, None, None,
                              plan.page_size, plan.cursor, cursor_scope(plan))
        elif isinstance(plan, Count):
            res = self._count(plan)
        elif isinstance(plan, Join):
            res = self._join(plan)
        elif isinstance(plan, (IndexLookup, IndexRange)):
            res = self._index(plan)
        else:  # pragma: no cover - validate() already rejects
            raise PlanError(f"unknown plan {type(plan).__name__}")
        io = meter.delta()
        res.stats.bytes_read = io.bytes_read
        res.stats.num_seeks = io.num_seeks
        account_emitted(res)
        return res

    def entry_stream(
        self,
        set_name: bytes,
        start: Optional[bytes] = None,
        end: Optional[bytes] = None,
        after: Optional[bytes] = None,
        stats: Optional[QueryStats] = None,
    ) -> _EntryStream:
        """Visible entry stream hook (also driven by the cluster layer)."""
        stats = stats if stats is not None else QueryStats()
        vis = BatchVisibility(
            self.vnode.read_tombstone(set_name),
            use_pallas=self.use_pallas, interpret=self.interpret,
            stats=stats)
        return _EntryStream(
            self.vnode, set_name, vis, stats,
            start=start, end=end, after=after, batch_size=self.batch_size)

    def index_stream(
        self,
        set_name: bytes,
        index_name: bytes,
        start: Optional[bytes] = None,
        end: Optional[bytes] = None,
        at: Optional[Tuple[bytes, bytes]] = None,
        after: Optional[Tuple[bytes, bytes]] = None,
        stats: Optional[QueryStats] = None,
    ) -> _IndexStream:
        """Visible posting-group stream (also driven by the cluster layer)."""
        stats = stats if stats is not None else QueryStats()
        vis = BatchVisibility(
            self.vnode.read_tombstone(set_name),
            use_pallas=self.use_pallas, interpret=self.interpret,
            stats=stats)
        return _IndexStream(
            self.vnode, set_name, index_name, vis, stats,
            start=start, end=end, at=at, after=after,
            batch_size=self.batch_size)

    # ---------------------------------------------------------------- shapes
    def _membership(self, plan: Membership) -> QueryResult:
        res = QueryResult(clock=self.vnode.read_clock(plan.set_name))
        res.stats.keys_probed += 1  # misses must account the probed key too
        stream = self.entry_stream(
            plan.set_name, start=plan.element,
            end=plan.element + b"\x00", stats=res.stats)
        entry = stream.advance()
        if entry is not None:
            res.entries = [(entry[0], tuple(sorted(entry[1])))]
            res.present = True
        else:
            res.present = False
        return res

    def _range(
        self,
        set_name: bytes,
        start: Optional[bytes],
        end: Optional[bytes],
        limit: Optional[int],
        cursor: Optional[bytes],
        scope: bytes,
    ) -> QueryResult:
        resume_start, after = resume_point(cursor, scope)
        if resume_start is not None:
            start = resume_start
        res = QueryResult(clock=self.vnode.read_clock(set_name))
        stream = self.entry_stream(
            set_name, start=start, end=end, after=after, stats=res.stats)
        collect_page(stream_entries(stream), limit, scope, res)
        return res

    def _count(self, plan: Count) -> QueryResult:
        res = QueryResult(clock=self.vnode.read_clock(plan.set_name))
        stream = self.entry_stream(
            plan.set_name, start=plan.start, end=plan.end, stats=res.stats)
        n = 0
        while stream.advance() is not None:
            n += 1
        res.count = n
        return res

    def _index(self, plan) -> QueryResult:
        scope = cursor_scope(plan)
        start, end = index_span(plan)
        at, after = index_resume_point(plan.cursor, scope)
        res = QueryResult(
            clock=self.vnode.read_clock(plan.set_name), index_entries=[])
        if isinstance(plan, IndexLookup):
            res.stats.keys_probed += 1
        stream = self.index_stream(
            plan.set_name, plan.index, start=start, end=end,
            at=at, after=after, stats=res.stats)
        collect_index_page(stream, plan.limit, scope, res)
        return res

    def _join(self, plan: Join) -> QueryResult:
        scope = cursor_scope(plan)
        start, after = resume_point(plan.cursor, scope)
        res = QueryResult(
            clock=self.vnode.read_clock(plan.left).join(
                self.vnode.read_clock(plan.right)))
        choice = choose_join(
            plan.kind,
            side_stats(self.vnode.store, plan.left),
            side_stats(self.vnode.store, plan.right),
            forced=plan.strategy)
        res.stats.strategy = choice.strategy
        if choice.strategy == GALLOP:
            drive_name, probe_name = (
                (plan.left, plan.right) if choice.drive == "left"
                else (plan.right, plan.left))
            drive = self.entry_stream(
                drive_name, start=start, after=after, stats=res.stats)
            probe = self.element_probe(probe_name, res.stats)
            entries = gallop_join(plan.kind, drive, probe, choice.drive)
        else:
            left = self.entry_stream(
                plan.left, start=start, after=after, stats=res.stats)
            right = self.entry_stream(
                plan.right, start=start, after=after, stats=res.stats)
            entries = zipper_join(plan.kind, left, right)
        collect_page(entries, plan.limit, scope, res)
        return res

    def element_probe(
        self, set_name: bytes, stats: QueryStats
    ) -> Callable[[bytes], Optional[DotList]]:
        """Bounded point probe: one element's surviving dots, or None.

        The gallop join's larger-side primitive — a storage seek spanning
        exactly the element's keys (like Membership), visibility-filtered
        through the same batched path as streams.  Counted in
        ``keys_probed`` on hits AND misses; only the element's own keys
        land in ``keys_scanned``, never the gap galloped over.
        """
        vis = BatchVisibility(
            self.vnode.read_tombstone(set_name),
            use_pallas=self.use_pallas, interpret=self.interpret,
            stats=stats)
        vnode = self.vnode

        def probe(element: bytes) -> Optional[DotList]:
            stats.keys_probed += 1
            dots = [
                dot for _el, dot, _v in vnode.fold_raw(
                    set_name, start=element, end=element + b"\x00")
            ]
            stats.keys_scanned += len(dots)
            if not dots:
                return None
            dead = vis.seen_mask(dots)
            live = tuple(d for d, is_dead in zip(dots, dead) if not is_dead)
            return live or None

        return probe


def stream_entries(stream) -> Iterator[Tuple[bytes, DotList]]:
    """Drain a head/advance entry stream as an iterator."""
    while stream.head is not None:
        yield stream.advance()


def account_emitted(res: QueryResult) -> None:
    """Fill ``stats.elements_emitted`` for every plan shape.

    ``Count`` streams the whole range without materialising entries, so its
    emitted work is the count itself — leaving it at ``len(entries) == 0``
    under-reports the query's output.
    """
    res.stats.elements_emitted = (
        res.count if res.count is not None else len(res.entries))


def encode_index_position(index_key: bytes, element: bytes) -> bytes:
    """Pack an index cursor position — length-delimited, like plan scopes,
    so ``(b"a:b", b"c")`` and ``(b"a", b"b:c")`` never alias."""
    return msgpack.packb([index_key, element])


def index_resume_point(
    cursor: Optional[bytes], scope: bytes
) -> "Tuple[Optional[Tuple[bytes, bytes]], Optional[Tuple[bytes, bytes]]]":
    """Decode an index cursor into ``(at, after)`` posting-group positions."""
    if cursor is None:
        return None, None
    pos, inclusive = decode_cursor(cursor, scope)
    index_key, element = msgpack.unpackb(pos)
    return ((index_key, element), None) if inclusive else (
        None, (index_key, element))


def collect_index_page(
    stream,
    limit: Optional[int],
    scope: bytes,
    res: QueryResult,
) -> None:
    """Pagination over ``((index_key, element), dots)`` streams.

    Same rule as :func:`collect_page`, but the resume position is the
    ``(index_key, element)`` group boundary — an element can recur under
    several index keys, so the element alone cannot name where a page
    stopped.  Fills both ``res.index_entries`` and the flat ``res.entries``.
    """
    if res.index_entries is None:
        res.index_entries = []
    while stream.head is not None:
        (index_key, element), dots = stream.head
        if limit is not None and len(res.index_entries) >= limit:
            if res.index_entries:
                last_ik, last_el, _ = res.index_entries[-1]
                res.cursor = encode_cursor(
                    scope, encode_index_position(last_ik, last_el))
            else:
                res.cursor = encode_cursor(
                    scope, encode_index_position(index_key, element),
                    inclusive=True)
            return
        stream.advance()
        res.index_entries.append((index_key, element, dots))
        res.entries.append((element, dots))


def collect_page(
    entries: Iterator[Tuple[bytes, DotList]],
    limit: Optional[int],
    scope: bytes,
    res: QueryResult,
) -> None:
    """The one pagination rule, shared by vnode and quorum paths.

    Fills ``res.entries`` up to ``limit`` and mints the resume cursor:
    exclusive past the last emitted element, or inclusive at the next
    pending element when the page emitted nothing (``limit=0``).
    """
    for el, dots in entries:
        if limit is not None and len(res.entries) >= limit:
            if res.entries:
                res.cursor = encode_cursor(scope, res.entries[-1][0])
            else:
                res.cursor = encode_cursor(scope, el, inclusive=True)
            return
        res.entries.append((el, dots))


def gallop_join(
    kind: str, drive, probe, drive_side: str = "left"
) -> Iterator[Tuple[bytes, DotList]]:
    """Seek-gallop join: stream the small (drive) side, probe the large.

    ``drive`` is a head/advance entry stream (vnode or quorum);
    ``probe(element)`` resolves the larger side's surviving dots for
    exactly that element via a bounded storage seek, or None.  Total cost
    is O(drive + probes) — the large side's cardinality never appears.

    Emitted dots follow the same single-domain rule as
    :func:`zipper_join`: intersect yields the LEFT set's dots (the drive
    entry's when driving left, the probe's when driving right);
    difference emits left survivors, so it must always drive left.  Union
    structurally cannot gallop (every entry of both sides is emitted) —
    the planner maps it to the zipper before execution reaches here.
    """
    if kind == "intersect":
        while drive.head is not None:
            el, ddots = drive.advance()
            pdots = probe(el)
            if pdots is not None:
                yield el, tuple(ddots if drive_side == "left" else pdots)
    elif kind == "difference":
        if drive_side != "left":
            raise PlanError("gallop difference must drive the left side")
        while drive.head is not None:
            el, ddots = drive.advance()
            if probe(el) is None:
                yield el, tuple(ddots)
    else:
        raise PlanError(f"gallop join cannot execute kind {kind!r}")


def zipper_join(
    kind: str, left, right
) -> Iterator[Tuple[bytes, DotList]]:
    """Ordered zipper over two visible entry streams (§4.4 streaming join).

    Entry dots always come from a *single* set's clock domain — the left
    set when the element is present there, otherwise the right set.  Dots
    from the two sets must never be mixed in one tuple: the same
    ``(actor, counter)`` names unrelated inserts in each set, so a blended
    tuple would be unusable (and dangerous) as a remove context.
    """
    if kind == "intersect":
        while left.head is not None and right.head is not None:
            lh, rh = left.head[0], right.head[0]
            if lh < rh:
                left.seek_to(rh)
            elif rh < lh:
                right.seek_to(lh)
            else:
                el, ld = left.advance()
                right.advance()
                yield el, tuple(ld)
    elif kind == "union":
        while left.head is not None or right.head is not None:
            if right.head is None or (
                    left.head is not None and left.head[0] < right.head[0]):
                yield left.advance()
            elif left.head is None or right.head[0] < left.head[0]:
                yield right.advance()
            else:
                el, ld = left.advance()
                right.advance()
                yield el, tuple(ld)
    elif kind == "difference":
        while left.head is not None:
            if right.head is None or left.head[0] < right.head[0]:
                yield left.advance()
            elif right.head[0] < left.head[0]:
                right.seek_to(left.head[0])
            else:
                left.advance()
                right.advance()
    else:  # pragma: no cover - validate() already rejects
        raise PlanError(f"unknown join kind {kind!r}")
