"""Deterministic synthetic data pipeline.

Sharded, seekable token stream: batch i is a pure function of (seed, step,
host), so restarts and elastic re-sharding reproduce the exact stream — a
prerequisite for the bit-equal restore test and for straggler backfill.
A light zipf-mixture LM task (order-2 markov over a small alphabet) gives a
learnable signal so examples/train_100m.py shows a real loss curve.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_order: int = 2
    n_states: int = 64


class SyntheticLM:
    """Order-k markov chain over a vocab-projected state space."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        n = cfg.n_states
        # sparse-ish transition matrix with zipf stationary mass
        probs = rng.dirichlet(np.full(n, 0.3), size=n)
        self.trans = probs
        self.proj = rng.integers(0, cfg.vocab_size, size=n)

    def batch(self, step: int, *, host: int = 0, n_hosts: int = 1
              ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_host = cfg.global_batch // n_hosts
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4099 + host)
        n = cfg.n_states
        B, T = per_host, cfg.seq_len + 1
        states = np.empty((B, T), np.int64)
        states[:, 0] = rng.integers(0, n, B)
        u = rng.random((B, T))
        cum = np.cumsum(self.trans, axis=1)
        for t in range(1, T):
            row = cum[states[:, t - 1]]
            states[:, t] = (u[:, t : t + 1] < row).argmax(axis=1)
        tokens = self.proj[states].astype(np.int32)
        return {"tokens": tokens}

    def stream(self, start_step: int = 0, **kw) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, **kw)
            step += 1
