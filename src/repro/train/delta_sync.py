"""Dot-tracked gradient delta synchronisation (async / straggler-tolerant DP).

The paper's delta-replication idea applied to gradient exchange: each
host's per-step gradient contribution is a *dot* ``(host, step)``.  An
aggregator (or every peer, symmetrically) folds contributions into a sum
keyed by its logical clock:

* duplicate delivery is a no-op (dot already seen — Algorithm 2's test);
* a straggler past the deadline is simply a *missing dot*: the step closes
  with a quorum of contributions and rescales by the count (partial
  all-reduce), and the late delta is discarded on arrival because its step
  has been sealed (its dot is added to the tombstone clock);
* the clocks make the protocol idempotent and order-free, so the transport
  may drop/duplicate/reorder — anti-entropy (re-request by missing dot) is
  exact, not heuristic.

This is the control-plane logic; on a real fleet the payload movement is a
reduce-scatter and this plane only tracks *which* contributions are in.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import numpy as np

from ..core.clock import Clock
from ..core.dots import Dot


@dataclass
class GradDelta:
    host: str
    step: int
    n_samples: int
    grads: Any  # pytree

    @property
    def dot(self) -> Dot:
        return Dot(self.host, self.step + 1)  # dots are 1-based events


class DeltaAggregator:
    """Per-step gradient folding with causal dedup + straggler sealing."""

    def __init__(self, hosts: List[str], quorum: Optional[int] = None):
        self.hosts = list(hosts)
        self.quorum = quorum or len(hosts)
        self.seen = Clock.zero()      # contributions folded
        self.sealed = Clock.zero()    # steps closed per host (tombstone role)
        self.acc: Dict[int, Tuple[Any, int, int]] = {}  # step -> (sum, n, cnt)

    def offer(self, d: GradDelta) -> bool:
        """Fold a contribution.  False => duplicate or late (discarded)."""
        if self.seen.seen(d.dot) or self.sealed.seen(d.dot):
            return False
        self.seen = self.seen.add(d.dot)
        if d.step in self.acc:
            s, n, c = self.acc[d.step]
            s = jax.tree_util.tree_map(lambda a, b: a + b, s, d.grads)
            self.acc[d.step] = (s, n + d.n_samples, c + 1)
        else:
            self.acc[d.step] = (d.grads, d.n_samples, 1)
        return True

    def ready(self, step: int) -> bool:
        return step in self.acc and self.acc[step][2] >= self.quorum

    def missing(self, step: int) -> List[str]:
        d = step + 1
        return [h for h in self.hosts if not (
            self.seen.seen(Dot(h, d)) or self.sealed.seen(Dot(h, d)))]

    def seal(self, step: int) -> Tuple[Any, int]:
        """Close the step (deadline or quorum): returns (mean grads, count).

        Hosts that have not contributed are tombstoned for this step, so a
        late delta can never double-apply (same mechanism as §4.3.2's
        "if the adds were unseen they never get added").
        """
        if step not in self.acc:
            raise KeyError(f"no contributions for step {step}")
        for h in self.missing(step):
            self.sealed = self.sealed.add(Dot(h, step + 1))
        s, n, c = self.acc.pop(step)
        mean = jax.tree_util.tree_map(lambda a: a / max(n, 1), s)
        return mean, c
