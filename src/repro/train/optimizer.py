"""Sharded AdamW with configurable optimizer-state memory policies.

Policies (``ModelConfig.optimizer_moments``) — the HBM table in DESIGN.md §5:

* ``fp32``     — m, v in fp32 (12 B/param of state): default for ≤30B archs.
* ``bf16``     — m, v in bf16 (4 B/param): mid-size fallback.
* ``factored`` — m in bf16, v rank-1 factored à la Adafactor (row+col fp32,
  ~0 B/param): required for the 123B/314B/398B cells to fit 16 GB/chip on
  the single-pod mesh.

Optimizer state inherits each parameter's sharding (ZeRO-style: the state
lives wherever the param shard lives; with 2D-sharded params the state is
fully distributed).  Updates compute in fp32 regardless of storage dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moments: str = "fp32"          # fp32 | bf16 | factored
    grad_clip: float = 1.0


def _factored(leaf: jax.Array) -> bool:
    return leaf.ndim >= 2 and leaf.shape[-1] >= 8 and leaf.shape[-2] >= 8


def init_opt_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    mdt = jnp.float32 if cfg.moments == "fp32" else jnp.bfloat16

    def init_leaf(p):
        st = {"m": jnp.zeros(p.shape, mdt)}
        if cfg.moments == "factored" and _factored(p):
            st["v_row"] = jnp.zeros(p.shape[:-1], jnp.float32)
            st["v_col"] = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        else:
            vdt = jnp.float32 if cfg.moments != "bf16" else jnp.bfloat16
            st["v"] = jnp.zeros(p.shape, vdt)
        return st

    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree_util.tree_map(init_leaf, params),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(
    grads, opt_state, params, cfg: AdamWConfig,
) -> Tuple[Any, Dict[str, Any]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, st):
        g = g.astype(jnp.float32) * clip
        m = st["m"].astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        new_st = {"m": m.astype(st["m"].dtype)}
        if "v" in st:
            v = st["v"].astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
            new_st["v"] = v.astype(st["v"].dtype)
            v_hat = v / b2c
        else:
            g2 = g * g
            v_row = st["v_row"] * cfg.b2 + g2.mean(-1) * (1 - cfg.b2)
            v_col = st["v_col"] * cfg.b2 + g2.mean(-2) * (1 - cfg.b2)
            new_st["v_row"] = v_row
            new_st["v_col"] = v_col
            denom = jnp.maximum(v_row.mean(-1, keepdims=True), 1e-30)[..., None]
            v_hat = (v_row[..., None] * v_col[..., None, :] / denom) / b2c
        m_hat = m / b1c
        pf = p.astype(jnp.float32)
        new_p = pf - cfg.lr * (m_hat / (jnp.sqrt(v_hat) + cfg.eps)
                               + cfg.weight_decay * pf)
        return new_p.astype(p.dtype), new_st

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(opt_state["mu"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    return new_params, {"step": step, "mu": new_mu}


def opt_state_pspecs(opt_state, param_pspecs):
    """Optimizer state shardings mirror parameter shardings."""
    from jax.sharding import PartitionSpec as P

    def leaf_spec(st, ps):
        out = {"m": ps}
        if "v" in st:
            out["v"] = ps
        else:
            sub = list(ps) if ps else []
            sub = sub + [None] * (st["m"].ndim - len(sub))
            out["v_row"] = P(*sub[:-1]) if len(sub) > 1 else P()
            out["v_col"] = P(*(sub[:-2] + sub[-1:])) if len(sub) > 1 else P()
        return out

    mu = jax.tree_util.tree_map(
        leaf_spec, opt_state["mu"], param_pspecs,
        is_leaf=lambda x: isinstance(x, dict) and "m" in x)
    return {"step": P(), "mu": mu}
