from .optimizer import AdamWConfig, adamw_update, init_opt_state
from .data import DataConfig, SyntheticLM
from .delta_sync import DeltaAggregator, GradDelta
