"""Serving launcher: batched requests through the continuous-batching engine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch pixtral-12b \\
      --preset smoke --requests 6 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from ..configs import ARCHS, get_config, smoke_config
from ..models import build_model
from ..serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.preset == "smoke" else get_config(args.arch)
    if cfg.is_encoder_decoder:
        raise SystemExit("serve CLI drives decoder-only archs; whisper needs "
                         "encoder frames (see tests/test_archs.py)")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      max_len=args.max_len, temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 16))),
                       max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on {jax.default_backend()})")
    for r in reqs[:3]:
        print(f"  req{r.rid}: {list(r.out_tokens)}")


if __name__ == "__main__":
    main()
