"""Production mesh construction (single-pod 16×16, multi-pod 2×16×16).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
only ``dryrun.py`` forces the 512-device host platform).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware model for the roofline (per chip)
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw_per_link": 50e9,       # B/s per link (~)
    "ici_links": 4,                # 2D torus: 4 links/chip (v5e)
    "hbm_bytes": 16 * 1024**3,
}
