import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count at first init.
# This forcing is dry-run-only — tests and benches see the real device(s).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions the step function),
  * the program fits (``compiled.memory_analysis()`` per-device bytes),
  * and it yields the roofline terms (``cost_analysis()`` FLOPs/bytes +
    collective bytes parsed from the compiled HLO).

Artifacts land in ``benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json``
(resumable; EXPERIMENTS.md §Dry-run/§Roofline are generated from them).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both] [--force]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, get_config, input_specs
from ..configs.shapes import SHAPES, cell_applicable
from ..models import build_model
from ..models.sharding import (make_rules, sharding_rules, tree_pspecs)
from ..train.optimizer import opt_state_pspecs
from .mesh import HW, make_production_mesh

ART_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

_COLL_RE = re.compile(
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
_LINE_RE = re.compile(
    r"=\s*(?:\()?\s*(?P<dtype>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]"
    r".*?(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2, "f8e4m3": 1,
    "f8e5m2": 1,
}


def parse_collectives(hlo: str):
    """Per-device ICI traffic estimate from compiled (post-SPMD) HLO text."""
    out = []
    for line in hlo.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        dims = [int(x) for x in m.group("dims").split(",") if x] or [1]
        nbytes = _DTYPE_BYTES.get(m.group("dtype"), 4)
        size = nbytes
        for d in dims:
            size *= d
        g = _GROUPS_RE.search(line)
        if g:
            n = int(g.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            n = len(gb.group(1).split(",")) if gb else 1
        op = m.group("op")
        # ring-algorithm per-device transferred bytes
        if op == "all-reduce":
            moved = 2 * size * (n - 1) / max(n, 1)
        elif op == "all-gather":
            moved = size * (n - 1) / max(n, 1)          # size = gathered result
        elif op == "reduce-scatter":
            moved = size * (n - 1)                       # size = scattered result
        elif op == "all-to-all":
            moved = size * (n - 1) / max(n, 1)
        else:  # collective-permute
            moved = size
        out.append({"op": op, "result_bytes": size, "group": n,
                    "moved_bytes": moved})
    return out


def cell_rules(mesh, shape_name: str):
    """Logical→physical bindings per shape cell (DESIGN.md §5)."""
    if shape_name == "long_500k":
        return make_rules(mesh, batch=None, kv_seq=("data",),
                          kv_heads="model")
    if shape_name.startswith("decode"):
        return make_rules(mesh, kv_seq="model")
    return make_rules(mesh)


def ep_rules(shape_name: str):
    """Expert-parallel variant: experts over the model axis (the §Perf
    hillclimb for MoE cells whose expert count divides the axis)."""
    def build(mesh):
        base = cell_rules(mesh, shape_name)
        over = dict(base.rules)
        over["experts"] = "model"
        over["moe_cap"] = None
        return make_rules(mesh, **over)
    return build


CACHE_RULES = {
    "k": ("batch", "kv_heads", "kv_seq", None),
    "v": ("batch", "kv_heads", "kv_seq", None),
    "k_scale": ("batch", "kv_heads", "kv_seq", None),
    "v_scale": ("batch", "kv_heads", "kv_seq", None),
    "conv": ("batch", None, "ff"),
    "h": ("batch", "ff", None),
    "enc_out": ("batch", None, None),
}


def cache_pspecs(cache, rules):
    def visit(path, leaf):
        name = str(getattr(path[-1], "key", getattr(path[-1], "idx", path[-1])))
        logical = CACHE_RULES.get(name)
        if logical is None:
            return P()
        spec = [None] * (leaf.ndim - len(logical)) + [rules.axis(l) for l in logical]
        used = set()
        for i, (dim, a) in enumerate(zip(leaf.shape[-len(spec):], spec)):
            if a is not None and dim % rules.mesh_axis_size(a) != 0:
                a = None
            flat = a if isinstance(a, tuple) else (a,) if a else ()
            if any(f in used for f in flat):
                a = None  # a mesh axis shards at most one dim
            used.update(flat)
            spec[i] = a
        return P(*spec)

    return jax.tree_util.tree_map_with_path(visit, cache)


def batch_pspecs(batch, rules):
    def visit(leaf):
        spec = ["batch"] + [None] * (leaf.ndim - 1)
        return rules.spec(*spec)
    specs = jax.tree_util.tree_map(visit, batch)
    # guard divisibility (e.g. global_batch 1)
    def fix(leaf, spec):
        out = []
        for dim, a in zip(leaf.shape, spec):
            if a is not None and dim % rules.mesh_axis_size(a) != 0:
                a = None
            out.append(a)
        return P(*out)
    return jax.tree_util.tree_map(fix, batch, specs)


def named(mesh, tree):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), tree)


def logits_pspec(cfg, shape, rules):
    """(batch, vocab) spec with divisibility fallbacks."""
    b_ax = rules.axis("batch")
    if b_ax is not None and shape.global_batch % rules.mesh_axis_size(b_ax) != 0:
        b_ax = None
    v_ax = rules.axis("vocab")
    if v_ax is not None and cfg.vocab_size % rules.mesh_axis_size(v_ax) != 0:
        v_ax = None
    return P(b_ax, v_ax)


def compile_cell(cfg, shape, mesh, rules):
    """Lower + compile one step function for one cell; returns compiled."""
    model = build_model(cfg)
    batch = input_specs(cfg, shape)
    with sharding_rules(rules):
        if shape.kind == "train":
            state_shapes = jax.eval_shape(
                lambda: model.init_train_state(jax.random.key(0)))
            p_specs = tree_pspecs(state_shapes.params, rules)
            o_specs = opt_state_pspecs(state_shapes.opt, p_specs)
            state_specs = type(state_shapes)(p_specs, o_specs, P())
            b_specs = batch_pspecs(batch, rules)
            fn = jax.jit(
                model.train_step,
                in_shardings=(named(mesh, state_specs), named(mesh, b_specs)),
                out_shardings=(named(mesh, state_specs),
                               named(mesh, {"loss": P(), "step": P()})),
                donate_argnums=(0,),
            )
            lowered = fn.lower(state_shapes, batch)
        elif shape.kind == "prefill":
            params_shapes = jax.eval_shape(
                lambda: model.init(jax.random.key(0)))
            p_specs = tree_pspecs(params_shapes, rules)
            b_specs = batch_pspecs(batch, rules)
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_specs = cache_pspecs(cache_shapes, rules)
            logits_spec = logits_pspec(cfg, shape, rules)

            def prefill(params, b):
                return model.prefill_step(params, b, max_len=shape.seq_len)

            fn = jax.jit(
                prefill,
                in_shardings=(named(mesh, p_specs), named(mesh, b_specs)),
                out_shardings=(NamedSharding(mesh, logits_spec),
                               named(mesh, c_specs)),
            )
            lowered = fn.lower(params_shapes, batch)
        else:  # decode
            params_shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
            p_specs = tree_pspecs(params_shapes, rules)
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_specs = cache_pspecs(cache_shapes, rules)
            tok_spec = batch_pspecs(
                {"tokens": batch["tokens"], "cache_len": batch["cache_len"]},
                rules)
            logits_spec = logits_pspec(cfg, shape, rules)

            def decode(params, cache, tokens, cache_len):
                return model.decode_step(params, cache, tokens, cache_len)

            fn = jax.jit(
                decode,
                in_shardings=(named(mesh, p_specs), named(mesh, c_specs),
                              named(mesh, tok_spec["tokens"]),
                              named(mesh, tok_spec["cache_len"])),
                out_shardings=(NamedSharding(mesh, logits_spec),
                               named(mesh, c_specs)),
                donate_argnums=(1,),
            )
            lowered = fn.lower(params_shapes, cache_shapes, batch["tokens"],
                               batch["cache_len"])

        return lowered.compile()


def measure(compiled):
    """flops / bytes / collective traffic of a compiled module."""
    ca = compiled.cost_analysis() or {}
    colls = parse_collectives(compiled.as_text())
    moved = sum(c["moved_bytes"] for c in colls)
    by_op = {}
    for c in colls:
        by_op.setdefault(c["op"], [0, 0.0])
        by_op[c["op"]][0] += 1
        by_op[c["op"]][1] += c["moved_bytes"]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_moved": moved,
        "coll_by_op": by_op,
        "n_coll": len(colls),
    }


def corrected_costs(cfg, shape, mesh, rules, base):
    """XLA cost_analysis counts while-loop (scan) bodies ONCE.  Correct by
    differencing two small *unrolled* depth variants:

        X_group = X(2·g + tail layers) − X(g + tail layers)
        X_total = X(g + tail) + (n_groups − 1) · X_group

    Exact for the layer stack (each group is identical); inner time-scans
    (mamba selective scan) remain counted once — their flops are O(T·D·N)
    elementwise, <1% of the projection matmuls (noted in EXPERIMENTS.md).
    """
    g = cfg.group_len
    n_groups = cfg.n_layers // g if cfg.scan_layers else 0
    if n_groups <= 1:
        return dict(base), False  # unrolled already: exact
    tail = cfg.n_layers - n_groups * g
    small1 = cfg.replace(n_layers=g + tail, scan_layers=False)
    small2 = cfg.replace(n_layers=2 * g + tail, scan_layers=False)
    m1 = measure(compile_cell(small1, shape, mesh, rules))
    m2 = measure(compile_cell(small2, shape, mesh, rules))
    out = {}
    for key in ("flops", "bytes", "coll_moved"):
        per_group = max(m2[key] - m1[key], 0.0)
        out[key] = m1[key] + (n_groups - 1) * per_group
    # collective op census: extrapolate counts the same way
    by_op = {}
    ops = set(m1["coll_by_op"]) | set(m2["coll_by_op"])
    for op in ops:
        c1, b1 = m1["coll_by_op"].get(op, [0, 0.0])
        c2, b2 = m2["coll_by_op"].get(op, [0, 0.0])
        by_op[op] = [c1 + (n_groups - 1) * max(c2 - c1, 0),
                     b1 + (n_groups - 1) * max(b2 - b1, 0.0)]
    out["coll_by_op"] = by_op
    out["n_coll"] = m1["n_coll"] + (n_groups - 1) * max(
        m2["n_coll"] - m1["n_coll"], 0)
    return out, True


def run_cell(arch: str, shape_name: str, mesh_kind: str, force: bool = False,
             rules_override=None, tag: str = "", cfg_override=None) -> dict:
    ART_DIR.mkdir(parents=True, exist_ok=True)
    out_path = ART_DIR / f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "skipped": why}
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = rules_override(mesh) if rules_override else cell_rules(mesh, shape_name)
    compiled = compile_cell(cfg, shape, mesh, rules)
    t_compile = time.time() - t0
    t_lower = 0.0

    mem = compiled.memory_analysis()
    raw = measure(compiled)
    cost, was_corrected = corrected_costs(cfg, shape, mesh, rules, raw)
    moved = cost["coll_moved"]
    by_op = cost["coll_by_op"]
    colls = list(range(cost["n_coll"]))  # count only

    n_chips = 512 if mesh_kind == "multi" else 256
    flops = cost["flops"]
    bytes_accessed = cost["bytes"]
    t_compute = flops / HW["peak_flops_bf16"]
    # HBM-traffic model from the compiled buffer assignment: arguments are
    # read once, outputs written once, every temp buffer written + read once.
    # (XLA:CPU's "bytes accessed" counts unfused per-op operand bytes — kept
    # as a diagnostic in cost.bytes_accessed_per_device, but it overstates
    # fused-TPU HBM traffic by 1-2 orders.)
    hbm_traffic = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                   + 2 * mem.temp_size_in_bytes)
    t_memory = hbm_traffic / HW["hbm_bw"]
    t_memory_hlo = bytes_accessed / HW["hbm_bw"]
    t_coll = moved / (HW["ici_links"] * HW["ici_bw_per_link"])

    # MODEL_FLOPS (whole step, all chips)
    n_p = cfg.n_params()
    n_a = cfg.n_active_params()
    if shape.kind == "train":
        model_flops = 6 * n_a * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2 * n_a * shape.global_batch * shape.seq_len
    else:
        model_flops = 2 * n_a * shape.global_batch
    model_flops_per_chip = model_flops / n_chips

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "n_chips": n_chips,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
            "hbm_bytes": HW["hbm_bytes"],
        },
        "cost": {
            "flops_per_device": flops,
            "bytes_accessed_per_device": bytes_accessed,
            "scan_corrected": was_corrected,
            "raw_flops_per_device": raw["flops"],
            "raw_bytes_per_device": raw["bytes"],
        },
        "collectives": {
            "moved_bytes_per_device": moved,
            "by_op": {k: {"count": v[0], "moved_bytes": v[1]}
                      for k, v in by_op.items()},
            "n_collectives": len(colls),
        },
        "roofline": {
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_memory_hlo_s": t_memory_hlo,
            "hbm_traffic_bytes": hbm_traffic,
            "t_collective_s": t_coll,
            "dominant": max(
                [("compute", t_compute), ("memory", t_memory),
                 ("collective", t_coll)], key=lambda kv: kv[1])[0],
            "model_flops_total": model_flops,
            "model_flops_per_chip": model_flops_per_chip,
            "useful_flops_ratio": (model_flops_per_chip / flops) if flops else 0.0,
            "roofline_fraction": (
                model_flops_per_chip / HW["peak_flops_bf16"]
                / max(t_compute, t_memory, t_coll)
            ) if max(t_compute, t_memory, t_coll) > 0 else 0.0,
        },
        "params": {"total": n_p, "active": n_a},
    }
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                key = f"{arch} × {shape} × {mesh_kind}"
                try:
                    rec = run_cell(arch, shape, mesh_kind, force=args.force)
                    if "skipped" in rec:
                        print(f"[skip] {key}: {rec['skipped']}", flush=True)
                    else:
                        r = rec["roofline"]
                        print(
                            f"[ ok ] {key}: compile={rec['t_compile_s']}s "
                            f"dom={r['dominant']} "
                            f"frac={r['roofline_fraction']:.3f} "
                            f"mem={rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB",
                            flush=True)
                except Exception as e:
                    failures.append((key, repr(e)))
                    print(f"[FAIL] {key}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for k, e in failures:
            print(" ", k, e)
        raise SystemExit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
