"""Bigset query-service launcher: the serve layer driven end to end.

Builds a :class:`BigsetCluster`, fronts it with :class:`BigsetService`, and
drives the full client lifecycle over the wire protocol: batch inserts,
a cursor-paginated scan with per-page IoStats, a deliberately small byte
budget so backpressure engages mid-scan (the client backs off and resumes
the same cursor), and a membership → remove causal-context round trip.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_bigset \\
      --elements 5000 --page-size 500 --replicas 3

Every stdout line is stable enough for CI to grep; the final line is
``serve_bigset demo ok``.
"""
from __future__ import annotations

import argparse
import time

from ..cluster.clusters import BigsetCluster
from ..obs.export import write_chrome_trace
from ..obs.trace import Tracer
from ..query.plan import Count, Scan
from ..serve.bigset_service import (Backpressure, BigsetClient, BigsetService,
                                    ServiceConfig)

SET = b"demo"


def _expect(cond: bool, what: str) -> None:
    """Demo self-check that survives ``python -O`` (the CI smoke runs this
    launcher assert-stripped, so a bare assert would check nothing)."""
    if not cond:
        raise RuntimeError(f"serve_bigset demo failed: {what}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--elements", type=int, default=5000)
    ap.add_argument("--page-size", type=int, default=500)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--budget-window", type=float, default=1.0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable tracing and write a Chrome trace-event "
                         "file (load in chrome://tracing / Perfetto)")
    args = ap.parse_args(argv)

    tracer = Tracer() if args.trace_out else None
    cluster = BigsetCluster(args.replicas, tracer=tracer)
    service = BigsetService(cluster)  # default config: generous budget
    client = BigsetClient(service)

    # ---- write path: batch inserts through the wire protocol -------------
    t0 = time.perf_counter()
    for base in range(0, args.elements, 1000):
        ops = [["add", b"%08d" % i]
               for i in range(base, min(base + 1000, args.elements))]
        client.batch(SET, ops)
    dt = time.perf_counter() - t0
    print(f"inserted {args.elements} elements in {dt:.2f}s "
          f"({args.elements / dt:.0f} el/s over the wire)")

    # ---- paginated scan: O(page) bytes per request -----------------------
    seen = 0
    n_pages = 0
    t0 = time.perf_counter()
    for page in client.pages(Scan(SET, page_size=args.page_size)):
        seen += len(page.entries)
        n_pages += 1
        if n_pages <= 3 or page.cursor is None:
            print(f"  page {n_pages}: {len(page.entries)} elements, "
                  f"{page.stats['bytes_read']}B read, "
                  f"{page.stats['num_seeks']} seeks")
    dt = time.perf_counter() - t0
    _expect(seen == args.elements,
            f"scan saw {seen} of {args.elements} elements")
    print(f"scanned {seen} elements in {n_pages} pages / {dt:.2f}s")

    # ---- saturation: an over-budget client is rejected, then resumes -----
    # byte_budget=1 makes every page overspend its window: page N+1 is
    # rejected until the window rolls, deterministically — the demo shows
    # the rejection AND that the cursor survives it.
    retries = [0]

    def backoff(seconds: float) -> None:
        retries[0] += 1
        print(f"backpressure engaged: retrying in {seconds:.3f}s "
              f"(cursor preserved)")
        time.sleep(seconds)

    tight = BigsetClient(BigsetService(cluster, ServiceConfig(
        byte_budget=1, budget_window=args.budget_window, lease_ttl=60.0)))
    slow = []
    for page in tight.pages(Scan(SET, page_size=args.page_size),
                            sleep=backoff):
        slow.extend(page.members)
        if len(slow) >= 3 * args.page_size or page.cursor is None:
            break  # three pages prove the reject→resume cycle
    _expect(slow == [b"%08d" % i for i in range(len(slow))], "pages drifted")
    _expect(retries[0] > 0, "saturation demo never engaged backpressure")
    print(f"saturated scan: {len(slow)} elements under a 1-byte/"
          f"{args.budget_window:g}s budget, {retries[0]} retries, "
          f"no element re-emitted or skipped")

    # ---- causal-context round trip ---------------------------------------
    def ride_out(fn, *fn_args, **fn_kw):
        """Point queries share the budget with the scan: back off the same way."""
        while True:
            try:
                return fn(*fn_args, **fn_kw)
            except Backpressure as bp:
                backoff(bp.retry_after)

    present, ctx = ride_out(client.membership, SET, b"%08d" % 0)
    _expect(present and bool(ctx), "inserted element not found by membership")
    client.remove(SET, b"%08d" % 0, ctx=ctx)
    present, _ = ride_out(client.membership, SET, b"%08d" % 0)
    _expect(not present, "element still visible after ctx remove")
    count = ride_out(client.query, Count(SET)).count
    _expect(count == args.elements - 1,
            f"count {count} != {args.elements - 1} after one remove")
    print(f"membership ctx round-trip remove ok; count now {count}")

    client.close()
    if tracer is not None:
        write_chrome_trace(tracer.spans, args.trace_out)
        print(f"wrote {len(tracer.spans)} spans -> {args.trace_out}")
    print("serve_bigset demo ok")


if __name__ == "__main__":
    main()
