"""§Perf hillclimb driver: compile tagged variants of chosen cells and
compare their roofline terms against the baseline artifact.

Variants (napkin math in EXPERIMENTS.md §Perf):

* ``ep``        — expert-parallel MoE (experts over the model axis) instead
                  of baseline TP-MoE: removes the per-device [B,E·C,D]
                  dispatch all-gather; valid when E % 16 == 0.
* ``mb<k>``     — k gradient-accumulation microbatches (activation peak ÷ k,
                  slight compute overhead from per-microbatch re-reads).
* ``noremat``   — disable activation checkpointing (−~30% recompute FLOPs,
                  + saved-activation memory): for compute-bound cells with
                  HBM headroom.
* ``kvint8``    — int8 KV cache with per-(token,head) scales: halves the
                  decode memory term (beyond-paper; production-standard).
* ``nosp`` / ``mb<k>nosp`` — disable sequence parallelism (the SP all-
                  gathers around every chunked attention dominate the
                  collective term); microbatches recover the memory SP won.
* ``seqdata``   — bind the activation seq axis to ('data','model') for
                  long-context prefill (2-D sequence parallelism).
* ``kvboth``    — shard decode KV cache seq over both axes.

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb \\
      --arch granite-moe-1b-a400m --shape train_4k --variant ep
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

from ..configs import get_config
from .dryrun import ART_DIR, cell_rules, ep_rules, run_cell
from ..models.sharding import make_rules


def variant_spec(name: str, arch: str, shape: str):
    cfg = get_config(arch)
    if name == "ep":
        return None, ep_rules(shape)
    if name in ("nosp", "mb4nosp", "mb2nosp", "mb8nosp"):
        pass  # handled below (before the generic mb<k> parse)
    elif name.startswith("mb"):
        k = int(name[2:])
        return cfg.replace(n_microbatches=k), None
    if name == "noremat":
        return cfg.replace(remat=False), None
    if name == "kvint8":
        return cfg.replace(kv_cache_dtype="int8"), None
    if name in ("nosp", "mb4nosp", "mb2nosp"):
        def rules(mesh):
            base = cell_rules(mesh, shape)
            over = dict(base.rules)
            over["seq"] = None    # no sequence parallelism: kills per-chunk
            return make_rules(mesh, **over)  # activation re-gathers
        cfg2 = None
        if name.startswith("mb"):
            cfg2 = cfg.replace(n_microbatches=int(name[2]))
        return cfg2, rules
    if name == "seqdata":
        def rules(mesh):
            base = cell_rules(mesh, shape)
            over = dict(base.rules)
            over["seq"] = ("data", "model")
            over["batch"] = None
            return make_rules(mesh, **over)
        return None, rules
    if name == "kvboth":
        def rules(mesh):
            base = cell_rules(mesh, shape)
            over = dict(base.rules)
            over["kv_seq"] = ("data", "model")
            over["batch"] = None
            return make_rules(mesh, **over)
        return None, rules
    raise SystemExit(f"unknown variant {name}")


def compare(base: dict, var: dict, label: str) -> None:
    b, v = base["roofline"], var["roofline"]
    bm = base["memory"]["peak_estimate_bytes"] / 2**30
    vm = var["memory"]["peak_estimate_bytes"] / 2**30
    print(f"\n=== {label} ===")
    print(f"{'term':<12}{'baseline':>14}{'variant':>14}{'delta':>10}")
    for key, name in (("t_compute_s", "compute"), ("t_memory_s", "memory"),
                      ("t_collective_s", "collective")):
        d = (v[key] - b[key]) / max(b[key], 1e-12) * 100
        print(f"{name:<12}{b[key]:>13.4f}s{v[key]:>13.4f}s{d:>+9.1f}%")
    print(f"{'mem GiB':<12}{bm:>14.2f}{vm:>14.2f}"
          f"{(vm - bm) / max(bm, 1e-9) * 100:>+9.1f}%")
    print(f"{'dominant':<12}{b['dominant']:>14}{v['dominant']:>14}")
    print(f"{'frac':<12}{b['roofline_fraction']:>14.3f}"
          f"{v['roofline_fraction']:>14.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", required=True)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    base_path = ART_DIR / f"{args.arch}__{args.shape}__{args.mesh}.json"
    if not base_path.exists():
        run_cell(args.arch, args.shape, args.mesh)
    base = json.loads(base_path.read_text())

    cfg_over, rules_over = variant_spec(args.variant, args.arch, args.shape)
    var = run_cell(args.arch, args.shape, args.mesh, force=args.force,
                   rules_override=rules_over, cfg_override=cfg_over,
                   tag=f"__{args.variant}")
    compare(base, var, f"{args.arch} × {args.shape} × {args.mesh} "
                       f"[{args.variant}]")


if __name__ == "__main__":
    main()
