"""Training launcher.

``--preset smoke`` runs the reduced same-family config end-to-end on local
devices (CPU-friendly); ``--preset full`` builds the assigned full-size
config (requires the production mesh — on this box use ``dryrun.py`` to
prove the full configs compile).  The loop itself is the fault-tolerant
driver: BigStore checkpoints, membership-derived assignments, straggler
sealing.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \\
      --steps 20 --preset smoke
"""
from __future__ import annotations

import argparse

import numpy as np

from ..configs import ARCHS, get_config, smoke_config
from ..runtime.ft import FTConfig, FTTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--hosts", type=int, default=2)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="simulate a host crash+restore at this step")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.preset == "smoke" else get_config(args.arch)
    ft = FTConfig(n_hosts=args.hosts, global_batch=args.global_batch,
                  seq_len=args.seq_len, ckpt_every=args.ckpt_every)
    tr = FTTrainer(cfg, ft)
    print(f"arch={cfg.name} preset={args.preset} "
          f"layers={cfg.n_layers} d_model={cfg.d_model} "
          f"hosts={ft.n_hosts} batch={ft.global_batch}x{ft.seq_len}")

    remaining = args.steps
    if args.crash_at and args.crash_at < args.steps:
        losses = tr.train_steps(args.crash_at)
        print(f"steps 1..{args.crash_at}: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
        tr.checkpoint()
        tr.crash_host(min(1, ft.n_hosts - 1))
        step = tr.restore()
        print(f"[fault] crashed host, restored at step {step}, "
              f"dp={tr.elastic.current_assignment().dp_size}")
        remaining = args.steps - args.crash_at
    losses = tr.train_steps(remaining)
    print(f"final loss {losses[-1]:.4f} "
          f"(ckpt store {tr.store.total_bytes() / 1e6:.1f} MB)")


if __name__ == "__main__":
    main()
