"""Observability: tracing, metrics, exporters (the stack's joining view).

* :mod:`repro.obs.trace` — spans with injectable clocks and explicit
  cross-network parenting; disabled mode is a strict no-op.
* :mod:`repro.obs.metrics` — counters/gauges/fixed-bucket histograms plus
  adapters lifting the existing per-layer stat structs into uniformly
  named metrics.
* :mod:`repro.obs.export` — JSONL span dumps and Chrome trace-event
  files (flamegraphs), with span-tree integrity helpers.
"""
from .trace import NULL_TRACER, NullTracer, Span, TraceContext, Tracer
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      lift_ae_stats, lift_dispatch_stats, lift_io_stats,
                      lift_network, lift_query_stats, lift_struct)
from .export import (span_trees, spans_to_chrome, spans_to_jsonl, tree_names,
                     write_chrome_trace, write_jsonl)

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Span", "TraceContext",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "lift_struct", "lift_io_stats", "lift_query_stats", "lift_ae_stats",
    "lift_network", "lift_dispatch_stats",
    "spans_to_jsonl", "write_jsonl", "spans_to_chrome",
    "write_chrome_trace", "span_trees", "tree_names",
]
