"""Span exporters: JSONL dumps and Chrome trace-event files.

Two formats, one source (:attr:`repro.obs.trace.Tracer.spans`):

* **JSONL** — one span per line, machine-greppable, append-friendly; the
  format CI artifacts and offline analysis consume.
* **Chrome trace-event** — ``{"traceEvents": [...]}`` of complete
  (``"ph": "X"``) events, loadable in ``chrome://tracing`` / Perfetto for
  flamegraph viewing.  Each event carries ``span_id`` / ``parent_id`` /
  ``trace_id`` in ``args`` so the span *tree* round-trips through the
  format, not just the timings — the CI smoke job re-parses an exported
  file and checks every parent resolves.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional

from .trace import Span


def span_to_dict(span: Span) -> dict:
    return {
        "name": span.name,
        "trace_id": span.trace_id,
        "span_id": span.span_id,
        "parent_id": span.parent_id,
        "start": span.start,
        "end": span.end,
        "attrs": {k: _jsonable(v) for k, v in span.attrs.items()},
    }


def _jsonable(v):
    """Span attrs may hold bytes (set names, elements): make them JSON-safe."""
    if isinstance(v, bytes):
        return v.decode("utf-8", "backslashreplace")
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


# --------------------------------------------------------------------- jsonl
def spans_to_jsonl(spans: Iterable[Span]) -> str:
    return "".join(json.dumps(span_to_dict(s), sort_keys=True) + "\n"
                   for s in spans)


def write_jsonl(spans: Iterable[Span], path: str) -> None:
    with open(path, "w") as f:
        f.write(spans_to_jsonl(spans))


# -------------------------------------------------------------- chrome trace
def spans_to_chrome(spans: Iterable[Span]) -> dict:
    """Complete ("X") trace events; ts/dur in microseconds per the spec.

    ``pid`` is constant (one process), ``tid`` is the trace id — so each
    request's tree renders as its own track in the viewer.
    """
    events: List[dict] = []
    for s in spans:
        events.append({
            "name": s.name,
            "cat": s.name.split(".", 1)[0],
            "ph": "X",
            "ts": s.start * 1e6,
            "dur": s.duration * 1e6,
            "pid": 1,
            "tid": s.trace_id,
            "args": {
                "trace_id": s.trace_id,
                "span_id": s.span_id,
                "parent_id": s.parent_id,
                **{k: _jsonable(v) for k, v in s.attrs.items()},
            },
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(spans: Iterable[Span], path: str) -> None:
    with open(path, "w") as f:
        json.dump(spans_to_chrome(spans), f)


# ----------------------------------------------------------------- tree view
def span_trees(spans: Iterable[Span]) -> Dict[int, dict]:
    """Group spans into ``{trace_id: {"roots": [...], "children": {...},
    "orphans": [...]}}``.

    A span whose ``parent_id`` is missing from its trace is an **orphan**
    — under lossy delivery that means a *dropped* parent, which the
    explicit-context design makes impossible (children are parented on
    the sender's still-local span, never on an in-flight one), so tests
    assert ``orphans == []``.
    """
    by_trace: Dict[int, List[Span]] = {}
    for s in spans:
        by_trace.setdefault(s.trace_id, []).append(s)
    out: Dict[int, dict] = {}
    for trace_id, group in by_trace.items():
        ids = {s.span_id for s in group}
        children: Dict[int, List[Span]] = {}
        roots, orphans = [], []
        for s in group:
            if s.parent_id is None:
                roots.append(s)
            elif s.parent_id in ids:
                children.setdefault(s.parent_id, []).append(s)
            else:
                orphans.append(s)
        out[trace_id] = {"roots": roots, "children": children,
                         "orphans": orphans}
    return out


def tree_names(spans: Iterable[Span], trace_id: Optional[int] = None
               ) -> Dict[str, int]:
    """``{span name: count}`` for one trace (default: the only trace) —
    the coverage check tests and CI run against an exported tree."""
    trees = span_trees(spans)
    if trace_id is None:
        if len(trees) != 1:
            raise ValueError(f"expected one trace, found {sorted(trees)}")
        trace_id = next(iter(trees))
    names: Dict[str, int] = {}
    tree = trees[trace_id]
    stack = list(tree["roots"])
    while stack:
        s = stack.pop()
        names[s.name] = names.get(s.name, 0) + 1
        stack.extend(tree["children"].get(s.span_id, ()))
    return names
