"""Unified metrics registry for the bigset stack.

The repo accumulated five siloed, pull-based stat structs (storage
:class:`~repro.storage.lsm.IoStats`, per-query :class:`~repro.query.
executor.QueryStats`, :class:`~repro.cluster.antientropy.AntiEntropyStats`,
:class:`~repro.cluster.sim.Network` counters, serve admission counters).
Each is still the *source of truth* for its layer — they are cheap,
allocation-free, and the benchmarks read them directly — but no single
view ever joined them.  This module is that view: a registry of uniformly
named counters, gauges, and fixed-bucket histograms, plus **adapters**
that lift each existing struct into it without the structs knowing.

Naming convention: dotted lowercase ``layer.field`` —
``storage.bytes_read``, ``serve.pages_served``, ``antientropy.
digest_bytes``, ``net.bytes_sent``, ``kernels.dot_seen.launches``.
Lifted snapshots are **gauges set to the struct's current value** (the
structs are already monotonic ledgers; re-lifting is idempotent), while
event-driven instrumentation (serve request counts, latency histograms)
uses counters/histograms owned by the registry itself.
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Optional, Sequence, Tuple, Union

Number = Union[int, float]

# Default histogram buckets: latencies in seconds, 1us .. ~4s, x4 steps.
# Fixed at registration so two runs bucket identically (determinism).
LATENCY_BUCKETS: Tuple[float, ...] = tuple(
    1e-6 * 4 ** i for i in range(12))


class Counter:
    """Monotonic event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: Number = 1) -> None:
        if n < 0:
            raise ValueError(f"counter decremented by {n}")
        self.value += n

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Point-in-time value (lifted struct fields land here)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value: Number = 0

    def set(self, v: Number) -> None:
        self.value = v

    def inc(self, n: Number = 1) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram: ``buckets`` are inclusive upper bounds,
    plus an implicit overflow bucket.  Bucketing is a bisect, so observe
    is O(log buckets) and two identical runs fill identical counts."""

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Sequence[float] = LATENCY_BUCKETS):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"histogram buckets must be sorted: {buckets!r}")
        self.buckets: Tuple[float, ...] = tuple(buckets)
        self.counts: List[int] = [0] * (len(self.buckets) + 1)
        self.sum: float = 0.0
        self.count: int = 0

    def observe(self, v: Number) -> None:
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    def snapshot(self) -> dict:
        return {"type": "histogram", "buckets": list(self.buckets),
                "counts": list(self.counts), "sum": self.sum,
                "count": self.count}


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Get-or-create registry keyed by metric name.

    A name is bound to one metric kind forever — asking for the same name
    as a different kind raises, so a typo cannot silently fork a series.
    ``snapshot()`` is a plain ``{name: {...}}`` dict in sorted-name order:
    msgpack/JSON-ready, which is exactly what the serve layer's ``stats``
    op and ``benchmarks/run.py --metrics-out`` ship.
    """

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, kind: type, **kwargs) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = kind(**kwargs)
        elif type(m) is not kind:
            raise TypeError(
                f"metric {name!r} is a {type(m).__name__}, "
                f"not a {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
        h = self._get(name, Histogram, buckets=buckets)
        if h.buckets != tuple(buckets):
            raise ValueError(
                f"histogram {name!r} already registered with buckets "
                f"{h.buckets}")
        return h

    def snapshot(self) -> Dict[str, dict]:
        return {name: self._metrics[name].snapshot()
                for name in sorted(self._metrics)}


# ------------------------------------------------------------------ adapters
# Lift the stack's existing stat structs into uniformly named gauges.  Each
# adapter reads ``vars(struct)`` so a field added to a struct shows up in
# the registry without touching this module — the structs stay the single
# source of field names.

def lift_struct(reg: MetricsRegistry, prefix: str, struct: object) -> None:
    """Lift every numeric field of a stats dataclass into ``prefix.field``."""
    for field_name, value in vars(struct).items():
        if isinstance(value, (int, float)):
            reg.gauge(f"{prefix}.{field_name}").set(value)


def lift_io_stats(reg: MetricsRegistry, io, prefix: str = "storage") -> None:
    """:class:`~repro.storage.lsm.IoStats` → ``storage.*`` gauges."""
    lift_struct(reg, prefix, io)


def lift_durable_media(reg: MetricsRegistry, media,
                       prefix: str = "storage.media") -> None:
    """:class:`~repro.storage.wal.DurableMedia` counters → gauges.

    ``wal_fsyncs`` against the store's batch count is the group-commit
    amortization evidence (fsyncs < batches at depth > 1); ``crashes``
    and the durable log size round out the fault ledger.  The replay-side
    counters (``bytes_recovered``, ``num_recoveries``) already ride
    :func:`lift_io_stats` — IoStats lifting is vars()-driven.
    """
    reg.gauge(f"{prefix}.wal_fsyncs").set(media.wal_fsyncs)
    reg.gauge(f"{prefix}.file_fsyncs").set(media.file_fsyncs)
    reg.gauge(f"{prefix}.wal_durable_bytes").set(len(media.wal))
    reg.gauge(f"{prefix}.wal_pending_bytes").set(media.wal_pending())
    reg.gauge(f"{prefix}.crashes").set(media.crashes)


def lift_query_stats(reg: MetricsRegistry, stats,
                     prefix: str = "query") -> None:
    """One query's :class:`~repro.query.executor.QueryStats` accumulated
    into ``query.*`` counters (queries are events, not snapshots); the
    join strategy becomes a per-strategy counter."""
    for field_name, value in vars(stats).items():
        if isinstance(value, (int, float)):
            reg.counter(f"{prefix}.{field_name}").inc(value)
    if getattr(stats, "strategy", ""):
        reg.counter(f"{prefix}.strategy.{stats.strategy}").inc()


def lift_ae_stats(reg: MetricsRegistry, stats,
                  prefix: str = "antientropy") -> None:
    """:class:`~repro.cluster.antientropy.AntiEntropyStats` →
    ``antientropy.*`` gauges."""
    lift_struct(reg, prefix, stats)


def lift_network(reg: MetricsRegistry, net, prefix: str = "net") -> None:
    """:class:`~repro.cluster.sim.Network` counters → ``net.*`` gauges.

    ``net.bytes_sent`` is the wire-bytes/op evidence the delta-interval
    replication work (ROADMAP) measures itself against — which is why
    :meth:`Network.send` now refuses un-billed non-empty payloads.
    """
    reg.gauge(f"{prefix}.bytes_sent").set(net.bytes_sent)
    reg.gauge(f"{prefix}.msgs_sent").set(net.msgs_sent)
    reg.gauge(f"{prefix}.msgs_dropped").set(net.msgs_dropped)
    reg.gauge(f"{prefix}.pending").set(net.pending())


def lift_dispatch_stats(reg: MetricsRegistry, stats: Optional[object] = None,
                        prefix: str = "kernels.dot_seen") -> None:
    """Pallas ``dot_seen`` launch ledger → ``kernels.dot_seen.*`` gauges.

    Defaults to the process-wide :data:`repro.kernels.dot_seen.ops.
    DISPATCHES` counter — the baseline the ROADMAP cross-query
    micro-batcher must beat (fewer launches over wider batches).
    """
    if stats is None:
        from ..kernels.dot_seen.ops import DISPATCHES
        stats = DISPATCHES
    lift_struct(reg, prefix, stats)
