"""End-to-end tracing for the bigset stack.

One request — serve envelope in, page out — crosses six layers: the
service, the cluster coordinator, per-replica executors, LSM storage, the
Pallas visibility kernel, and the simulated network (replication, read
repair, anti-entropy).  Each layer's stat structs (IoStats, QueryStats,
AntiEntropyStats, ...) meter its own silo; this module is the joining
view: a **span** per unit of work, explicitly parented into one tree per
request, so the paper's cost claims become per-request evidence instead
of pull-based aggregates.

Design constraints, in order:

* **Disabled ⇒ zero behavior change.**  The default tracer is
  :data:`NULL_TRACER`: every instrumentation point degrades to a cheap
  no-op, and — critically — network payloads are *never* wrapped, so the
  bytes a disabled cluster ships are byte-identical to the pre-tracing
  code (asserted in ``tests/test_obs.py``).
* **Deterministic under injected clocks.**  The tracer takes a
  ``clock() -> float`` exactly like the serve layer's lease clock: tests
  drive a fake clock and assert exact span durations.  Span ids are a
  plain counter, not random — two identical runs produce identical trees.
* **Causality over call stacks.**  Synchronous work parents implicitly
  via a current-span stack; work that crosses the (droppable, duplicable,
  reorderable) network carries an explicit :class:`TraceContext` inside
  the message payload, so a replica's delivery span parents under the
  coordinator span *whenever it runs* — a dropped message is simply a
  missing leaf, a duplicated one is two leaves, never a broken tree.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, List, Optional


@dataclass(frozen=True)
class TraceContext:
    """The portable identity of a span: enough to parent remote work.

    This is what rides inside network payloads (see
    :class:`~repro.cluster.clusters.TracedPayload`) — two ints, so the
    wire-byte cost of tracing is negligible and accountable.
    """

    trace_id: int
    span_id: int


class Span:
    """One unit of traced work.  Mutable until :meth:`Tracer.finish`."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "start", "end",
                 "attrs")

    def __init__(self, name: str, trace_id: int, span_id: int,
                 parent_id: Optional[int], start: float,
                 attrs: Dict[str, Any]):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.start = start
        self.end: Optional[float] = None
        self.attrs = attrs

    def context(self) -> TraceContext:
        return TraceContext(self.trace_id, self.span_id)

    def set(self, **attrs: Any) -> "Span":
        """Attach (or overwrite) attributes; returns self for chaining."""
        self.attrs.update(attrs)
        return self

    @property
    def duration(self) -> float:
        return (self.end - self.start) if self.end is not None else 0.0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, trace={self.trace_id}, "
                f"id={self.span_id}, parent={self.parent_id}, "
                f"dur={self.duration:.6f}, attrs={self.attrs})")


class Tracer:
    """Span factory + in-memory sink.

    ``clock`` is injectable monotonic seconds (the ``bigset_service``
    lease-clock idiom); ids are sequential so tests are exact.  Finished
    spans accumulate in :attr:`spans` until :meth:`clear` / :meth:`drain`
    — exporters (:mod:`repro.obs.export`) read them from there.
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.monotonic):  # bigset-lint: disable=BS001 -- default for the *injectable* clock; deterministic runs inject a fake (tests/test_obs.py)
        self._clock = clock
        self._next_id = 0
        self._stack: List[Span] = []
        self.spans: List[Span] = []

    # ------------------------------------------------------------ span api
    def current(self) -> Optional[TraceContext]:
        """Context of the innermost open span, or None outside any span."""
        return self._stack[-1].context() if self._stack else None

    def start(self, name: str, parent: Optional[TraceContext] = None,
              **attrs: Any) -> Span:
        """Open a span.  ``parent`` defaults to the current span; a span
        opened with neither starts a new trace (it is a root)."""
        if parent is None:
            parent = self.current()
        self._next_id += 1
        if parent is None:
            trace_id, parent_id = self._next_id, None
        else:
            trace_id, parent_id = parent.trace_id, parent.span_id
        return Span(name, trace_id, self._next_id, parent_id,
                    self._clock(), attrs)

    def finish(self, span: Span) -> Span:
        span.end = self._clock()
        self.spans.append(span)
        return span

    @contextmanager
    def span(self, name: str, parent: Optional[TraceContext] = None,
             **attrs: Any) -> Iterator[Span]:
        """Scoped span: children opened inside parent under it implicitly."""
        sp = self.start(name, parent=parent, **attrs)
        self._stack.append(sp)
        try:
            yield sp
        except BaseException as e:
            sp.set(error=type(e).__name__)
            raise
        finally:
            self._stack.pop()
            self.finish(sp)

    # ---------------------------------------------------------------- sink
    def clear(self) -> None:
        self.spans = []

    def drain(self) -> List[Span]:
        """Pop-and-return all finished spans (exporters' consume step)."""
        out, self.spans = self.spans, []
        return out


class _NullSpan(Span):
    """Shared inert span: every mutation is a no-op."""

    def __init__(self):
        super().__init__("null", 0, 0, None, 0.0, {})

    def set(self, **attrs: Any) -> "Span":
        return self

    def context(self) -> TraceContext:  # pragma: no cover - never parented
        return TraceContext(0, 0)


class NullTracer(Tracer):
    """Tracing off: no spans, no ids, no clock reads, no payload wrapping.

    Instrumentation points must ALSO consult :attr:`enabled` before doing
    anything that would alter observable behavior (wrapping a network
    payload, building attribute dicts from expensive reprs) — the null
    tracer makes the *span calls* free, ``enabled`` keeps the *side
    effects* out.
    """

    enabled = False
    _SPAN = _NullSpan()

    def __init__(self):
        super().__init__(clock=lambda: 0.0)

    def current(self) -> Optional[TraceContext]:
        return None

    def start(self, name: str, parent: Optional[TraceContext] = None,
              **attrs: Any) -> Span:
        return self._SPAN

    def finish(self, span: Span) -> Span:
        return span

    @contextmanager
    def span(self, name: str, parent: Optional[TraceContext] = None,
             **attrs: Any) -> Iterator[Span]:
        yield self._SPAN


NULL_TRACER = NullTracer()
