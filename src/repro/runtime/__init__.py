from .elastic import Assignment, ElasticController, derive_assignment
from .ft import FTConfig, FTTrainer
