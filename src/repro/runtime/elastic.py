"""Elastic scaling: membership-CRDT-driven data-parallel reconfiguration.

A simulated fleet of DP hosts whose roster is the converged ORSWOT
membership view.  On joins/leaves the batch partition is recomputed from
the *sorted alive set* (pure function of the view — every host derives the
same assignment with no coordinator), the seekable data pipeline re-shards,
and training resumes from the BigStore checkpoint.  This is the control
loop a 1000-node fleet runs on every membership epoch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..cluster.membership import GossipCluster


@dataclass
class Assignment:
    epoch: int
    hosts: Tuple[str, ...]          # sorted alive hosts
    batch_slices: Dict[str, Tuple[int, int]]  # host -> [lo, hi) of global batch

    @property
    def dp_size(self) -> int:
        return len(self.hosts)


def derive_assignment(members: frozenset, global_batch: int, epoch: int
                      ) -> Assignment:
    """Deterministic assignment from a membership view (no coordination)."""
    hosts = tuple(sorted(members))
    n = len(hosts)
    if n == 0:
        return Assignment(epoch, (), {})
    per = global_batch // n
    extra = global_batch - per * n
    slices = {}
    lo = 0
    for i, h in enumerate(hosts):
        hi = lo + per + (1 if i < extra else 0)
        slices[h] = (lo, hi)
        lo = hi
    return Assignment(epoch, hosts, slices)


class ElasticController:
    """Wraps a gossip cluster and emits assignments on membership change."""

    def __init__(self, n_nodes: int, global_batch: int):
        self.cluster = GossipCluster(n_nodes)
        self.cluster.settle()
        self.global_batch = global_batch
        self.epoch = 0
        self._last_members: Optional[frozenset] = None

    def current_assignment(self) -> Assignment:
        views = self.cluster.views()
        members = views[0]
        if not self.cluster.converged():
            # conservative: intersect views until gossip converges
            for v in views[1:]:
                members &= v
        if members != self._last_members:
            self.epoch += 1
            self._last_members = members
        return derive_assignment(members, self.global_batch, self.epoch)

    # -------------------------------------------------------------- events
    def scale_up(self, node_id: str) -> Assignment:
        self.cluster.node_joins(node_id)
        self.cluster.settle()
        return self.current_assignment()

    def scale_down(self, node_id: str) -> Assignment:
        self.cluster.node_leaves(node_id)
        self.cluster.settle()
        return self.current_assignment()

    def fail(self, node_id: str, detected_by: str) -> Assignment:
        """Crash: no goodbye message; a peer ejects via observed-remove."""
        self.cluster.eject(detected_by, node_id)
        self.cluster.settle()
        return self.current_assignment()
