"""Fault-tolerant training driver (single-process simulation of a DP fleet).

Composes every plane the framework provides:

* **model step** — a real jit'd train step over host-local batches, with
  host gradients folded through the dot-tracked :class:`DeltaAggregator`
  (dedup, quorum, straggler sealing);
* **durability** — BigStore decomposed delta checkpoints every
  ``ckpt_every`` steps (each host saves its own shard slice);
* **elasticity** — membership-CRDT assignment; hosts can crash/join
  between steps, batches re-partition, state restores from a quorum;
* **determinism** — the seekable data pipeline makes post-restore
  training bit-comparable to an uninterrupted run (tested).

This is a *simulation harness* (hosts are objects, not processes), but the
decision logic is exactly what each real host would run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.bigstore import BigStore
from ..checkpoint.manager import (flatten_state, state_shard_names,
                                  unflatten_state)
from ..configs.base import ModelConfig
from ..models import build_model
from ..models.model import TrainState
from ..train.data import DataConfig, SyntheticLM
from ..train.delta_sync import DeltaAggregator, GradDelta
from ..train.optimizer import adamw_update
from .elastic import ElasticController, derive_assignment


@dataclass
class FTConfig:
    n_hosts: int = 4
    global_batch: int = 8
    seq_len: int = 32
    ckpt_every: int = 5
    replication: int = 3
    quorum_frac: float = 0.75  # straggler sealing quorum
    seed: int = 0


class FTTrainer:
    def __init__(self, cfg: ModelConfig, ft: FTConfig):
        self.cfg = cfg
        self.ft = ft
        self.model = build_model(cfg)
        self.data = SyntheticLM(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=ft.seq_len,
            global_batch=ft.global_batch, seed=ft.seed))
        self.state: TrainState = self.model.init_train_state(
            jax.random.key(ft.seed))
        self.store = BigStore(ft.n_hosts, replication=ft.replication)
        self.elastic = ElasticController(ft.n_hosts, ft.global_batch)
        self.step = 0
        self.grad_fn = jax.jit(self.model.grad_step)
        self.loss_history: List[float] = []

    # ------------------------------------------------------------- stepping
    def _host_batch(self, host: str, assignment, step: int):
        lo, hi = assignment.batch_slices[host]
        full = self.data.batch(step)
        return {k: v[lo:hi] for k, v in full.items()}, hi - lo

    def train_steps(self, n: int, *, slow_hosts: Dict[str, int] | None = None
                    ) -> List[float]:
        """Run n steps; ``slow_hosts`` maps host -> steps of lateness
        (their contribution misses the deadline and is sealed out)."""
        slow_hosts = slow_hosts or {}
        losses = []
        for _ in range(n):
            assignment = self.elastic.current_assignment()
            hosts = list(assignment.hosts)
            agg = DeltaAggregator(
                hosts, quorum=max(1, int(len(hosts) * self.ft.quorum_frac)))
            losses_this = []
            for host in hosts:
                if slow_hosts.get(host, 0) > 0:
                    slow_hosts[host] -= 1
                    continue  # misses the deadline this step
                batch, n_samples = self._host_batch(host, assignment, self.step)
                loss, grads = self.grad_fn(
                    self.state.params,
                    {k: jnp.asarray(v) for k, v in batch.items()})
                agg.offer(GradDelta(host, self.step, n_samples, grads))
                losses_this.append(float(loss))
            mean_grads, n_contrib = agg.seal(self.step)
            new_params, new_opt = adamw_update(
                mean_grads, self.state.opt, self.state.params,
                self.model.opt_cfg)
            self.state = TrainState(new_params, new_opt, self.state.step + 1)
            self.step += 1
            loss = float(np.mean(losses_this)) if losses_this else float("nan")
            losses.append(loss)
            self.loss_history.append(loss)
            if self.step % self.ft.ckpt_every == 0:
                self.checkpoint()
        return losses

    # ----------------------------------------------------------- durability
    def checkpoint(self) -> Dict[str, int]:
        shards = flatten_state(self.state)
        return self.store.save(b"run0", shards, self.step)

    def crash_host(self, idx: int, detected_by: str = "node0") -> None:
        self.store.kill(idx)
        self.elastic.fail(f"node{idx}", detected_by)

    def join_host(self, idx: int) -> None:
        self.store.revive(idx)
        self.elastic.scale_up(f"node{idx}")

    def restore(self) -> int:
        expect = state_shard_names(self.state)
        shards = self.store.restore(b"run0", expect=expect)
        step = max(s for s, _ in shards.values())
        self.state = unflatten_state(self.state, shards)
        self.step = step
        return step
