"""Pytree ⇄ shard-dict bridging for BigStore checkpoints.

Shard naming uses the pytree key-path (ordered, so the restore fold streams
shards in path order — the §4.4 lexicographic property is what lets a
restore begin materialising the state before the fold completes).
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def flatten_state(state) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        out[_path_str(path)] = np.asarray(leaf)
    return out


def state_shard_names(state) -> List[str]:
    return sorted(flatten_tree_paths(state))


def flatten_tree_paths(state) -> List[str]:
    return [
        _path_str(p) for p, _ in jax.tree_util.tree_flatten_with_path(state)[0]
    ]


def unflatten_state(template, shards: Dict[str, Tuple[int, np.ndarray]]):
    """Rebuild a pytree from restored shards using ``template`` structure."""
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for path, leaf in leaves_with_path:
        name = _path_str(path)
        if name not in shards:
            raise KeyError(f"missing shard {name}")
        _step, arr = shards[name]
        arr = np.asarray(arr)
        new_leaves.append(jnp.asarray(arr.reshape(np.shape(leaf))).astype(
            leaf.dtype if hasattr(leaf, "dtype") else arr.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
