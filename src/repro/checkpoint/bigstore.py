"""BigStore — decomposed delta checkpointing over bigset CRDTs.

This is the paper's technique applied to the framework's durability plane
(DESIGN.md §2 mapping table).  A monolithic checkpoint is Riak's
riak-object: every save serializes the whole train-state blob — O(n) per
save, O(n²) over a run.  BigStore decomposes the train state the way
bigset decomposes a Set:

* **element**  = one state shard, named ``<param-path>/<slice>``;
* **insert**   = saving a shard: a fresh dot + the shard bytes as the
  element value, written with the *previous* save's dots as the op context
  — the paper's add-supersedes-add rule (§footnote 1) automatically
  tombstones the stale shard so storage compaction (§4.3.3) reclaims it;
* **delta replication** = each host durably writes only *its own* slice of
  the state plus causal metadata, then ships the element-keys to R-1 peer
  stores (Algorithm 2 apply: dot-seen check + append — no read-modify-write
  of a checkpoint blob anywhere);
* **restore**  = a quorum streaming fold (§4.4): any R surviving stores
  merge with the streaming ORSWOT join; per-shard concurrent versions
  resolve by highest step.  A checkpoint is usable iff the merged set
  covers every expected shard — torn/partial saves are safe by
  construction (the old shard version survives until superseded).

Delta saves skip shards whose content hash is unchanged (MoE cold experts,
frozen embeddings): the old element simply stays live — this is where the
O(Δ) vs O(n) gap shows up in benchmarks/bench_checkpoint.py.
"""
from __future__ import annotations

import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import msgpack
import numpy as np

from ..core.bigset import BigsetVnode, InsertDelta
from ..core.clock import Clock
from ..core.dots import Dot
from ..core.streaming import streaming_join


def _pack_shard(step: int, arr: np.ndarray) -> bytes:
    return msgpack.packb({
        "step": step,
        "dtype": str(arr.dtype),
        "shape": list(arr.shape),
        "data": arr.tobytes(),
    })


def _unpack_shard(raw: bytes) -> Tuple[int, np.ndarray]:
    o = msgpack.unpackb(raw, strict_map_key=False)
    dt = o["dtype"]
    if dt == "bfloat16":
        import jax.numpy as jnp
        arr = np.frombuffer(o["data"], np.uint16).view(jnp.bfloat16)
    else:
        arr = np.frombuffer(o["data"], np.dtype(dt))
    return o["step"], arr.reshape(o["shape"])


class BigStoreHost:
    """One host's durable checkpoint replica (a bigset vnode + helpers)."""

    def __init__(self, host_id: str):
        self.host_id = host_id
        self.vnode = BigsetVnode(host_id)
        self._last_hash: Dict[Tuple[bytes, bytes], int] = {}
        self.alive = True

    # ------------------------------------------------------------------ save
    def save_shard(self, run: bytes, name: bytes, step: int,
                   arr: np.ndarray, *, delta_only: bool = True
                   ) -> Optional[InsertDelta]:
        """Insert one shard; returns the replication delta (None if skipped
        because the content is unchanged — the delta-checkpoint fast path)."""
        h = zlib.crc32(arr.tobytes())
        key = (run, name)
        if delta_only and self._last_hash.get(key) == h:
            return None
        self._last_hash[key] = h
        _, ctx = self.vnode.is_member(run, name)   # supersede previous save
        delta = self.vnode.coordinate_insert(
            run, name, ctx, value=_pack_shard(step, arr))
        return delta

    def apply(self, delta: InsertDelta) -> bool:
        return self.vnode.replica_insert(delta)

    def compact(self):
        return self.vnode.compact()

    # ----------------------------------------------------------------- reads
    def stream(self, run: bytes):
        rs_clock = self.vnode.read_clock(run)
        entries = []
        values: Dict[Tuple[bytes, Dot], bytes] = {}
        cur: Optional[bytes] = None
        dots: List[Dot] = []
        for el, dot, val in self.vnode.fold_values(run):
            values[(el, dot)] = val
            if el != cur:
                if cur is not None:
                    entries.append((cur, tuple(dots)))
                cur, dots = el, [dot]
            else:
                dots.append(dot)
        if cur is not None:
            entries.append((cur, tuple(dots)))
        return rs_clock, entries, values


class BigStore:
    """Replicated checkpoint store across N hosts (replication factor R)."""

    def __init__(self, n_hosts: int, replication: int = 3):
        self.hosts = [BigStoreHost(f"ckpt-host{i}") for i in range(n_hosts)]
        self.r = min(replication, n_hosts)

    def replicas_for(self, shard_name: bytes, owner: int) -> List[int]:
        """Preference list: owner + next R-1 alive hosts (ring order)."""
        n = len(self.hosts)
        out = []
        i = owner
        while len(out) < self.r and len(out) < n:
            if self.hosts[i % n].alive:
                out.append(i % n)
            i += 1
            if i - owner > 2 * n:
                break
        return out

    def owner_of(self, shard_name: bytes) -> int:
        return zlib.crc32(shard_name) % len(self.hosts)

    # ------------------------------------------------------------------ save
    def save(self, run: bytes, shards: Dict[str, np.ndarray], step: int,
             *, delta_only: bool = True) -> Dict[str, int]:
        """Save a shard-dict.  Each shard is coordinated by its owner host
        and delta-replicated to R-1 peers.  Returns {written|skipped: n}."""
        stats = {"written": 0, "skipped": 0, "bytes": 0}
        for name, arr in shards.items():
            bname = name.encode()
            prefs = self.replicas_for(bname, self.owner_of(bname))
            if not prefs:
                raise RuntimeError("no alive replicas")
            coord = self.hosts[prefs[0]]
            delta = coord.save_shard(run, bname, step, np.asarray(arr),
                                     delta_only=delta_only)
            if delta is None:
                stats["skipped"] += 1
                continue
            stats["written"] += 1
            stats["bytes"] += delta.size_bytes()
            for i in prefs[1:]:
                self.hosts[i].apply(delta)
        return stats

    # --------------------------------------------------------------- restore
    def restore(self, run: bytes, *, expect: Optional[Iterable[str]] = None
                ) -> Dict[str, Tuple[int, np.ndarray]]:
        """Quorum streaming restore from all alive hosts."""
        alive = [h for h in self.hosts if h.alive]
        if not alive:
            raise RuntimeError("no alive checkpoint hosts")
        streams = []
        value_maps = []
        for h in alive:
            clock, entries, values = h.stream(run)
            streams.append((clock, entries))
            value_maps.append(values)

        out: Dict[str, Tuple[int, np.ndarray]] = {}
        for element, dots in streaming_join(streams):
            best: Optional[Tuple[int, np.ndarray]] = None
            for dot in dots:
                raw = None
                for vm in value_maps:
                    raw = vm.get((element, dot))
                    if raw is not None:
                        break
                if raw is None:
                    continue
                step, arr = _unpack_shard(raw)
                if best is None or step > best[0] or (
                        step == best[0] and dot > getattr(best, "dot", dots[0])):
                    best = (step, arr)
            if best is not None:
                out[element.decode()] = best
        if expect is not None:
            missing = set(expect) - set(out)
            if missing:
                raise RuntimeError(
                    f"checkpoint incomplete: {len(missing)} shards missing "
                    f"(e.g. {sorted(missing)[:3]})")
        return out

    # ------------------------------------------------------------------- ops
    def kill(self, idx: int) -> None:
        self.hosts[idx].alive = False

    def revive(self, idx: int) -> None:
        """Node replacement: fresh store learns via anti-entropy."""
        from ..cluster.antientropy import sync
        self.hosts[idx] = BigStoreHost(f"ckpt-host{idx}")
        donors = [h for i, h in enumerate(self.hosts) if h.alive and i != idx]
        if donors:
            runs = self._known_runs(donors[0])
            for run in runs:
                sync(self.hosts[idx].vnode, donors[0].vnode, run)

    def _known_runs(self, host: BigStoreHost) -> List[bytes]:
        runs = set()
        for k, _ in host.vnode.store.scan(b"", b"\xff" * 12):
            from ..storage.keycodec import decode_key
            parts = decode_key(k)
            runs.add(parts[0])
        return sorted(runs)

    def compact_all(self) -> None:
        for h in self.hosts:
            if h.alive:
                h.compact()

    def total_bytes(self) -> int:
        return sum(h.vnode.store.approximate_bytes()
                   for h in self.hosts if h.alive)

    def io_stats(self):
        from ..storage.lsm import IoStats
        agg = IoStats()
        for h in self.hosts:
            for k in vars(agg):
                setattr(agg, k, getattr(agg, k) + getattr(h.vnode.store.stats, k))
        return agg
