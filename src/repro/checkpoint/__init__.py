from .bigstore import BigStore, BigStoreHost
from .manager import flatten_state, state_shard_names, unflatten_state
