from .ops import flash_attention
from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention", "flash_attention_pallas", "attention_ref"]
