"""Pure-jnp oracle for blocked attention (causal / sliding-window, GQA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _constrain(x, *axes):
    # lazy import: repro.models imports the kernels package, so a top-level
    # import here would be circular
    from ...models.sharding import constrain
    return constrain(x, *axes)


def attention_ref(
    q: jax.Array,          # [B, Hq, T, D]
    k: jax.Array,          # [B, Hkv, S, D]
    v: jax.Array,          # [B, Hkv, S, D]
    *,
    causal: bool = True,
    window: int | None = None,   # sliding window size (None = full)
    scale: float | None = None,
    q_chunk: int = 1024,
) -> jax.Array:            # [B, Hq, T, D]
    """Chunked-over-queries attention (statically unrolled).

    The f32 [B,H,T,S] logits tensor of a naive softmax-attention dominated
    HBM at the 4k/32k cells; chunking queries bounds the live score block at
    [B, H, q_chunk, S_visible] (the jnp analogue of the Pallas kernel's
    blocking).  A *python* loop — not lax.map — so dry-run cost_analysis
    counts every chunk's FLOPs.  Extras vs naive:

    * bf16 inputs keep bf16 score/prob tensors (f32 only for the row
      reductions), halving the workspace;
    * sliding-window layers statically slice the reachable KV range per
      chunk — at 32k context a 1k-window layer touches 1/16th of the keys
      (the jnp analogue of the kernel's block skipping);
    * causal chunks drop keys beyond the chunk's last query.
    """
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    if Hq % Hkv != 0:
        raise ValueError(f"GQA requires Hq % Hkv == 0, got {Hq} % {Hkv}")
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    acc_dt = jnp.float32 if q.dtype == jnp.float32 else jnp.bfloat16
    kq = jnp.repeat(k, group, axis=1).astype(acc_dt)
    vq = jnp.repeat(v, group, axis=1).astype(acc_dt)

    def one_chunk(qc: jax.Array, q0: int) -> jax.Array:
        Tc = qc.shape[2]
        off = S - T  # queries occupy the LAST T positions of the context
        # static reachable KV range for this chunk
        k_lo, k_hi = 0, S
        if causal:
            k_hi = min(S, q0 + off + Tc)
        if window is not None:
            k_lo = max(0, q0 + off - window + 1)
        ks = kq[:, :, k_lo:k_hi, :]
        vs = vq[:, :, k_lo:k_hi, :]
        logits = jnp.einsum("bhtd,bhsd->bhts", qc.astype(acc_dt), ks)
        logits = logits * jnp.asarray(scale, acc_dt)  # stays acc_dt-sized
        # shard the score block: heads when they divide the mesh axis,
        # otherwise the query-chunk dim ("attn_q" falls back — minitron's 24
        # heads / whisper's 6 heads don't divide 16)
        logits = _constrain(logits, "batch", "heads", "attn_q", None)
        qpos = q0 + jnp.arange(Tc) + off
        kpos = k_lo + jnp.arange(k_hi - k_lo)
        mask = jnp.ones((Tc, k_hi - k_lo), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        m = jnp.max(logits, axis=-1, keepdims=True)
        p = jnp.exp((logits - m).astype(acc_dt))
        p = jnp.where(mask[None, None], p, 0)
        denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
        probs = (p / jnp.maximum(denom, 1e-30).astype(acc_dt))
        probs = _constrain(probs, "batch", "heads", "attn_q", None)
        return jnp.einsum("bhts,bhsd->bhtd", probs, vs,
                          preferred_element_type=jnp.float32)

    if T <= q_chunk:
        return one_chunk(q, 0).astype(q.dtype)
    outs = []
    for q0 in range(0, T, q_chunk):
        outs.append(one_chunk(q[:, :, q0:q0 + q_chunk], q0))
    return jnp.concatenate(outs, axis=2).astype(q.dtype)
