"""Jit'd public attention entry point with pallas/ref dispatch."""
from __future__ import annotations

import jax

from .kernel import flash_attention_pallas
from .ref import attention_ref


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Blocked attention.  [B,Hq,T,D] x [B,Hkv,S,D] -> [B,Hq,T,D].

    ``use_pallas=False`` (default on CPU / in dry-run lowering) runs the
    pure-jnp reference, which XLA fuses adequately and which keeps the
    dry-run HLO compilable on any backend; on real TPU pass
    ``use_pallas=True`` for the VMEM-blocked kernel.
    """
    if use_pallas:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=interpret)
    return attention_ref(q, k, v, causal=causal, window=window, scale=scale)
