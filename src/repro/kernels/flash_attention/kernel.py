"""Pallas TPU flash attention (prefill): blocked online-softmax.

Design for the MXU/VMEM hierarchy:

* grid = (B·Hq, T/BQ, S/BKV); the KV axis is the innermost (sequential)
  dimension so the f32 accumulator lives in VMEM scratch across KV steps.
* Q tile [BQ, D] and KV tiles [BKV, D] are VMEM-resident; BQ = BKV = 128
  aligns both MXU operands (D = 64..256 for the assigned archs).
* online softmax carries (m, l) row statistics in SMEM-sized scratch,
  rescaling the accumulator per step — memory is O(BQ·D) independent of S.
* causal + sliding-window masks are iota comparisons; fully-masked KV
  blocks are skipped via ``pl.when`` (no MXU work issued).
* GQA: the kernel receives K/V already indexed per-q-head (the wrapper maps
  q-head → kv-head in the BlockSpec index_map, so no repeat materialises).

VMEM per step (BQ=BKV=128, D=256, f32 accum):
  q/k/v tiles 3·128·256·4 ≈ 384 KiB, acc 128 KiB, logits 64 KiB → < 1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_kv: int, t_total: int, s_total: int):
    iq = pl.program_id(1)
    ikv = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions (queries sit at the tail of the context)
    q_start = iq * block_q + (s_total - t_total)
    kv_start = ikv * block_kv

    # block-level reachability: any (qpos >= kpos) and within window
    q_hi = q_start + block_q - 1
    k_lo = kv_start
    k_hi = kv_start + block_kv - 1
    reachable = True
    if causal:
        reachable = k_lo <= q_hi
    if window is not None:
        reachable = jnp.logical_and(reachable, k_hi > q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [BQ, D]
        k = k_ref[0, 0].astype(jnp.float32)  # [BKV, D]
        v = v_ref[0, 0].astype(jnp.float32)  # [BKV, D]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [BQ, BKV]

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        mask = jnp.ones_like(logits, dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]                                  # [BQ]
        m_cur = jnp.max(logits, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard: rows with everything masked keep NEG_INF
        p = jnp.exp(logits - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        alpha = jnp.where(m_prev == NEG_INF, 0.0, alpha)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ikv == n_kv - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_kv",
                     "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # [B, Hq, T, D]
    k: jax.Array,  # [B, Hkv, S, D]
    v: jax.Array,  # [B, Hkv, S, D]
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    if Hq % Hkv != 0:
        raise ValueError(f"GQA requires Hq % Hkv == 0, got {Hq} % {Hkv}")
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    block_q = min(block_q, T)
    block_kv = min(block_kv, S)
    if T % block_q != 0 or S % block_kv != 0:
        raise ValueError(
            f"sequence lengths must tile the blocks: T={T} % block_q="
            f"{block_q}, S={S} % block_kv={block_kv}")

    grid = (B * Hq, T // block_q, S // block_kv)

    def q_index(h, iq, ikv):
        return (h // Hq, h % Hq, iq, 0)

    def kv_index(h, iq, ikv):
        b, hq = h // Hq, h % Hq
        return (b, hq // group, ikv, 0)  # GQA: share the kv head

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, t_total=T, s_total=S)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), q_index),
            pl.BlockSpec((1, 1, block_kv, D), kv_index),
            pl.BlockSpec((1, 1, block_kv, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), q_index),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),    # acc
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
        ],
        interpret=interpret,
    )(q, k, v)
