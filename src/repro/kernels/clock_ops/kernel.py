"""Pallas TPU kernels for interval clock-lattice ops.

Pure VPU work: the boundary-sweep run merge (union / difference /
intersection) and run-length popcount over ``int32[A, R]`` run arrays.
A counter is *live* under the op's predicate over (in-A, in-B); output runs
start at live points whose predecessor is dead and end at live points whose
successor is dead.  All candidate boundaries are input run edges, so each
actor row is a fixed-shape O(P²) broadcast compare with P = Ra + Rb —
branch-free and layout-friendly.

Tiled over actor blocks so arbitrarily large actor universes stream through
VMEM; the run axis stays whole per block (clocks are causal-metadata-sized).
For the framework's clocks (A ≤ 512 hosts, R ≤ 1024 runs) a few tiles
suffice: per block (BA=8, P=2048) the [BA, P, P] live masks are ~32 MiB of
bool compares streamed by the VPU, with [BA, P] outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INT32_MAX = 2**31 - 1


def _contains(s, e, x):
    """bool[BA, P] — is x[i, p] inside any (s, e)[i, :] run?"""
    return jnp.any(
        (s[:, None, :] <= x[:, :, None]) & (x[:, :, None] <= e[:, None, :]),
        axis=-1,
    )


def _merge_kernel(a_s_ref, a_e_ref, b_s_ref, b_e_ref, o_s_ref, o_e_ref,
                  *, mode: str):
    a_s, a_e = a_s_ref[...], a_e_ref[...]               # int32[BA, Ra]
    b_s, b_e = b_s_ref[...], b_e_ref[...]               # int32[BA, Rb]
    a_valid = a_s <= a_e
    b_valid = b_s <= b_e

    if mode == "or":
        def live(x):
            return _contains(a_s, a_e, x) | _contains(b_s, b_e, x)
        cand_s = jnp.concatenate([a_s, b_s], axis=1)
        cand_e = jnp.concatenate([a_e, b_e], axis=1)
    elif mode == "andnot":
        def live(x):
            return _contains(a_s, a_e, x) & ~_contains(b_s, b_e, x)
        cand_s = jnp.concatenate([a_s, b_e + 1], axis=1)
        cand_e = jnp.concatenate([a_e, b_s - 1], axis=1)
    else:  # "and"
        def live(x):
            return _contains(a_s, a_e, x) & _contains(b_s, b_e, x)
        cand_s = jnp.concatenate([a_s, b_s], axis=1)
        cand_e = jnp.concatenate([a_e, b_e], axis=1)
    valid = jnp.concatenate([a_valid, b_valid], axis=1)

    is_start = valid & live(cand_s) & ~live(cand_s - 1)
    # drop duplicate start values (identical runs in both inputs): keep the
    # first occurrence per row, via a lower-triangular "earlier" mask
    p = cand_s.shape[1]
    row = jax.lax.broadcasted_iota(jnp.int32, (p, p), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (p, p), 1)
    earlier = col < row                                  # [P, P] q < p
    same = cand_s[:, :, None] == cand_s[:, None, :]      # [BA, P, P]
    dup = jnp.any(same & earlier[None, :, :] & is_start[:, None, :], axis=-1)
    is_start = is_start & ~dup

    is_end = valid & live(cand_e) & ~live(cand_e + 1)
    # each output run ends at the smallest end boundary >= its start
    reach = is_end[:, None, :] & (cand_e[:, None, :] >= cand_s[:, :, None])
    ends_for = jnp.min(
        jnp.where(reach, cand_e[:, None, :], _INT32_MAX), axis=-1)

    o_s_ref[...] = jnp.where(is_start, cand_s, 1).astype(jnp.int32)
    o_e_ref[...] = jnp.where(is_start, ends_for, 0).astype(jnp.int32)


def _popcount_kernel(s_ref, e_ref, o_ref):
    o_ref[...] = jnp.maximum(e_ref[...] - s_ref[...] + 1, 0).sum(axis=-1)


def _tiles(n: int, b: int) -> int:
    return (n + b - 1) // b


@functools.partial(jax.jit,
                   static_argnames=("mode", "block_a", "interpret"))
def _merge_op(mode, a_s, a_e, b_s, b_e, *, block_a: int = 8,
              interpret: bool = True):
    A, ra = a_s.shape
    rb = b_s.shape[1]
    ba = min(block_a, A)
    grid = (_tiles(A, ba),)
    p = ra + rb
    return pl.pallas_call(
        functools.partial(_merge_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ba, ra), lambda i: (i, 0)),
            pl.BlockSpec((ba, ra), lambda i: (i, 0)),
            pl.BlockSpec((ba, rb), lambda i: (i, 0)),
            pl.BlockSpec((ba, rb), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ba, p), lambda i: (i, 0)),
            pl.BlockSpec((ba, p), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((A, p), jnp.int32),
            jax.ShapeDtypeStruct((A, p), jnp.int32),
        ],
        interpret=interpret,
    )(a_s, a_e, b_s, b_e)


def join_pallas(a_s, a_e, b_s, b_e, **kw):
    return _merge_op("or", a_s, a_e, b_s, b_e, **kw)


def subtract_pallas(a_s, a_e, b_s, b_e, **kw):
    return _merge_op("andnot", a_s, a_e, b_s, b_e, **kw)


def intersect_pallas(a_s, a_e, b_s, b_e, **kw):
    return _merge_op("and", a_s, a_e, b_s, b_e, **kw)


@functools.partial(jax.jit, static_argnames=("block_a", "interpret"))
def popcount_pallas(starts: jax.Array, ends: jax.Array, *, block_a: int = 8,
                    interpret: bool = True) -> jax.Array:
    A, r = starts.shape
    ba = min(block_a, A)
    grid = (_tiles(A, ba),)
    return pl.pallas_call(
        _popcount_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ba, r), lambda i: (i, 0)),
            pl.BlockSpec((ba, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ba,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((A,), jnp.int32),
        interpret=interpret,
    )(starts, ends)
