"""Pallas TPU kernels for clock-lattice bitwise ops.

Pure VPU work: OR / AND-NOT / popcount over ``uint32[A, W]`` bitmap tiles.
Tiled (block_a × block_w) so arbitrarily large actor universes / windows
stream through VMEM; for the framework's clocks (A ≤ 512 hosts, W ≤ 2048
words ≈ 64k events) a single tile suffices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _join_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] | b_ref[...]


def _subtract_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] & ~b_ref[...]


def _popcount_kernel(a_ref, o_ref):
    x = a_ref[...]
    x = x - ((x >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    x = (x + (x >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    x = (x * jnp.uint32(0x01010101)) >> jnp.uint32(24)
    o_ref[...] += x.astype(jnp.int32).sum(axis=-1)


def _tiles(n: int, b: int) -> int:
    return (n + b - 1) // b


@functools.partial(jax.jit,
                   static_argnames=("kernel", "block_a", "block_w", "interpret"))
def _binary_op(kernel, a: jax.Array, b: jax.Array, *, block_a: int = 8,
               block_w: int = 512, interpret: bool = True) -> jax.Array:
    A, W = a.shape
    ba, bw = min(block_a, A), min(block_w, W)
    grid = (_tiles(A, ba), _tiles(W, bw))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ba, bw), lambda i, j: (i, j)),
            pl.BlockSpec((ba, bw), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((ba, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((A, W), jnp.uint32),
        interpret=interpret,
    )(a, b)


def join_pallas(a, b, **kw):
    return _binary_op(_join_kernel, a, b, **kw)


def subtract_pallas(a, b, **kw):
    return _binary_op(_subtract_kernel, a, b, **kw)


@functools.partial(jax.jit, static_argnames=("block_a", "block_w", "interpret"))
def popcount_pallas(a: jax.Array, *, block_a: int = 8, block_w: int = 512,
                    interpret: bool = True) -> jax.Array:
    A, W = a.shape
    ba, bw = min(block_a, A), min(block_w, W)

    def kernel(a_ref, o_ref):
        @pl.when(pl.program_id(1) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)
        _popcount_kernel(a_ref, o_ref)

    grid = (_tiles(A, ba), _tiles(W, bw))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ba, bw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((ba,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((A,), jnp.int32),
        interpret=interpret,
    )(a)
