"""Jit'd wrappers for interval clock-lattice ops with pallas/ref dispatch.

Join / subtract / intersect are boundary-sweep run merges over the dense
``(lo, hi)`` run arrays of :class:`repro.core.vclock.DenseClock`; both
dispatch paths return the merged-but-unsorted run arrays, and the wrapper
canonicalises row order (sorted by start, empty ``(1, 0)`` slots last) so
ref and Pallas agree bit-for-bit.  Subtract is origin-free: there is no
alignment precondition beyond a shared actor universe.
"""
from __future__ import annotations

import jax

from ...core.vclock import DenseClock, sort_runs
from . import kernel as K
from . import ref as R


def _dispatch(pallas_fn, ref_fn, use_pallas: bool, interpret: bool | None):
    if not use_pallas:
        return ref_fn
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    def run(*args):
        return pallas_fn(*args, interpret=interpret)

    return run


def _merged(pallas_fn, ref_fn, a: DenseClock, b: DenseClock,
            use_pallas: bool, interpret: bool | None) -> DenseClock:
    if a.starts.shape[0] != b.starts.shape[0]:
        raise ValueError("dense clocks must share the actor universe")
    s, e = _dispatch(pallas_fn, ref_fn, use_pallas, interpret)(
        a.starts, a.ends, b.starts, b.ends)
    return DenseClock(*sort_runs(s, e))


def join(a: DenseClock, b: DenseClock, *, use_pallas: bool = False,
         interpret: bool | None = None) -> DenseClock:
    return _merged(K.join_pallas, R.join_ref, a, b, use_pallas, interpret)


def subtract(a: DenseClock, b: DenseClock, *, use_pallas: bool = False,
             interpret: bool | None = None) -> DenseClock:
    return _merged(K.subtract_pallas, R.subtract_ref, a, b,
                   use_pallas, interpret)


def intersect(a: DenseClock, b: DenseClock, *, use_pallas: bool = False,
              interpret: bool | None = None) -> DenseClock:
    return _merged(K.intersect_pallas, R.intersect_ref, a, b,
                   use_pallas, interpret)


def popcount(a: DenseClock, *, use_pallas: bool = False,
             interpret: bool | None = None) -> jax.Array:
    return _dispatch(K.popcount_pallas, R.popcount_ref, use_pallas, interpret)(
        a.starts, a.ends)
