"""Jit'd wrappers for clock-lattice ops with pallas/ref dispatch."""
from __future__ import annotations

import jax

from ...core.vclock import DenseClock
from . import kernel as K
from . import ref as R


def _dispatch(pallas_fn, ref_fn, use_pallas: bool, interpret: bool | None):
    if not use_pallas:
        return ref_fn
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    def run(*args):
        return pallas_fn(*args, interpret=interpret)

    return run


def join(a: DenseClock, b: DenseClock, *, use_pallas: bool = False,
         interpret: bool | None = None) -> DenseClock:
    import jax.numpy as jnp

    bits = _dispatch(K.join_pallas, R.join_ref, use_pallas, interpret)(a.bits, b.bits)
    return DenseClock(jnp.maximum(a.origin, b.origin), bits)


def subtract(a: DenseClock, b: DenseClock, *, use_pallas: bool = False,
             interpret: bool | None = None) -> DenseClock:
    bits = _dispatch(K.subtract_pallas, R.subtract_ref, use_pallas, interpret)(
        a.bits, b.bits)
    return DenseClock(a.origin, bits)


def popcount(a: DenseClock, *, use_pallas: bool = False,
             interpret: bool | None = None) -> jax.Array:
    return _dispatch(K.popcount_pallas, R.popcount_ref, use_pallas, interpret)(a.bits)
