from .ops import intersect, join, popcount, subtract

__all__ = ["join", "subtract", "intersect", "popcount"]
