from .ops import join, popcount, subtract

__all__ = ["join", "subtract", "popcount"]
