"""Pure-jnp oracles for the bitwise clock-lattice kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def join_ref(a_bits: jax.Array, b_bits: jax.Array) -> jax.Array:
    """Window union: set-clock ⊔ delta-clock (uint32[A, W])."""
    return a_bits | b_bits


def subtract_ref(a_bits: jax.Array, b_bits: jax.Array) -> jax.Array:
    """Tombstone shrink (§4.3.3): a AND NOT b."""
    return a_bits & ~b_bits


def popcount_ref(bits: jax.Array) -> jax.Array:
    """Events per actor in the window — clock-density stats (int32[A])."""
    x = bits
    x = x - ((x >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    x = (x + (x >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    x = (x * jnp.uint32(0x01010101)) >> jnp.uint32(24)
    return x.astype(jnp.int32).sum(axis=-1)
