"""Pure-jnp oracles for the interval clock-lattice kernels.

Each op is the boundary-sweep run merge of
:func:`repro.core.vclock._interval_merge` over ``(lo, hi)`` run arrays —
union (join), difference (tombstone shrink, §4.3.3) and intersection
(tombstone ∩ raw trim) — plus run-length popcount.  Outputs are the
*unsorted* merged run arrays; the ops wrapper canonicalises row order for
both the ref and Pallas paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.vclock import _interval_merge


def join_ref(a_s: jax.Array, a_e: jax.Array,
             b_s: jax.Array, b_e: jax.Array):
    """Run union: set-clock ⊔ delta-clock (int32[A, Ra+Rb] pair)."""
    return _interval_merge(a_s, a_e, b_s, b_e, "or")


def subtract_ref(a_s: jax.Array, a_e: jax.Array,
                 b_s: jax.Array, b_e: jax.Array):
    """Tombstone shrink (§4.3.3): a minus b, origin-free run difference."""
    return _interval_merge(a_s, a_e, b_s, b_e, "andnot")


def intersect_ref(a_s: jax.Array, a_e: jax.Array,
                  b_s: jax.Array, b_e: jax.Array):
    """Run intersection: events seen by both clocks."""
    return _interval_merge(a_s, a_e, b_s, b_e, "and")


def popcount_ref(starts: jax.Array, ends: jax.Array) -> jax.Array:
    """Events per actor — Σ (hi - lo + 1) over valid runs (int32[A])."""
    return jnp.maximum(ends - starts + 1, 0).sum(axis=-1)
