from .ops import dot_seen
from .kernel import dot_seen_pallas
from .ref import dot_seen_ref

__all__ = ["dot_seen", "dot_seen_pallas", "dot_seen_ref"]
