"""Pure-jnp oracle for the dot-seen kernel.

Semantics are exactly :func:`repro.core.vclock.dots_seen`: for each dot
``(actor, counter)``, test whether the dense interval clock (per-actor
``(lo, hi)`` run arrays) has observed it.  This is the per-element-key
filter of the bigset read fold and the dedup test of delta apply (paper
Algorithms 1 & 2).
"""
from __future__ import annotations

import jax

from ...core.vclock import DenseClock, dots_seen as _dots_seen


def dot_seen_ref(
    starts: jax.Array,    # int32[A, R]
    ends: jax.Array,      # int32[A, R]
    actors: jax.Array,    # int32[N]
    counters: jax.Array,  # int32[N]
) -> jax.Array:           # bool[N]
    return _dots_seen(DenseClock(starts, ends), actors, counters)
