"""Pure-jnp oracle for the dot-seen kernel.

Semantics are exactly :func:`repro.core.vclock.dots_seen`: for each dot
``(actor, counter)``, test whether the dense clock (origin VV + window
bitmap) has observed it.  This is the per-element-key filter of the bigset
read fold and the dedup test of delta apply (paper Algorithms 1 & 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.vclock import DenseClock, dots_seen as _dots_seen


def dot_seen_ref(
    origin: jax.Array,    # int32[A]
    bits: jax.Array,      # uint32[A, W]
    actors: jax.Array,    # int32[N]
    counters: jax.Array,  # int32[N]
) -> jax.Array:           # bool[N]
    return _dots_seen(DenseClock(origin, bits), actors, counters)
