"""Jit'd public wrapper for the dot-seen kernel.

Dispatch: Pallas (interpret on CPU, compiled on TPU) or the pure-jnp
reference.  The bigset read fold and delta-batch dedup call this with the
tombstone / set-clock in dense *interval* form: per-actor ``(lo, hi)`` run
arrays (``DenseClock.starts`` / ``.ends``), O(interval runs) with no
window cap.

Every call is tallied in the process-wide :data:`DISPATCHES` ledger
(launch count + rows dispatched, padding included).  That ledger is the
measured baseline for the ROADMAP cross-query micro-batcher: today 1000
concurrent small queries pay 1000 launches over tiny arrays, and the only
honest way to claim a coalescer wins is to watch ``launches`` fall while
``rows`` holds.  ``benchmarks/bench_serve.py`` reports it as amortized
launches/query; the metrics registry lifts it via
:func:`repro.obs.metrics.lift_dispatch_stats`.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ...core.vclock import DenseClock
from .kernel import dot_seen_pallas
from .ref import dot_seen_ref


@dataclass
class DispatchStats:
    """Kernel-launch ledger: device calls and rows (dots) they covered."""

    launches: int = 0       # dot_seen invocations (one device dispatch each)
    rows: int = 0           # total rows dispatched, padding included
    pallas_launches: int = 0  # subset of launches routed to the Pallas kernel

    def snapshot(self) -> "DispatchStats":
        return DispatchStats(**vars(self))

    def delta(self, since: "DispatchStats") -> "DispatchStats":
        return DispatchStats(
            **{k: getattr(self, k) - getattr(since, k) for k in vars(self)})


DISPATCHES = DispatchStats()


def dot_seen(
    clock: DenseClock,
    actors: jax.Array,
    counters: jax.Array,
    *,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """bool[N] — which dots has ``clock`` seen?"""
    actors = jnp.asarray(actors, jnp.int32)
    counters = jnp.asarray(counters, jnp.int32)
    DISPATCHES.launches += 1
    DISPATCHES.rows += int(actors.shape[0])
    if use_pallas:
        DISPATCHES.pallas_launches += 1
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return dot_seen_pallas(
            clock.starts, clock.ends, actors, counters, interpret=interpret
        )
    return dot_seen_ref(clock.starts, clock.ends, actors, counters)
