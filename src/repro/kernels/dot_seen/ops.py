"""Jit'd public wrapper for the dot-seen kernel.

Dispatch: Pallas (interpret on CPU, compiled on TPU) or the pure-jnp
reference.  The bigset read fold and delta-batch dedup call this with the
tombstone / set-clock in dense form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.vclock import DenseClock
from .kernel import dot_seen_pallas
from .ref import dot_seen_ref


def dot_seen(
    clock: DenseClock,
    actors: jax.Array,
    counters: jax.Array,
    *,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """bool[N] — which dots has ``clock`` seen?"""
    actors = jnp.asarray(actors, jnp.int32)
    counters = jnp.asarray(counters, jnp.int32)
    if use_pallas:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return dot_seen_pallas(
            clock.origin, clock.bits, actors, counters, interpret=interpret
        )
    return dot_seen_ref(clock.origin, clock.bits, actors, counters)
