"""Pallas TPU kernel: batched dot-seen test against a dense interval clock.

TPU adaptation (see DESIGN.md §2): TPUs have no efficient scatter/gather
unit, so the per-dot row lookups ``starts[actor, :]`` / ``ends[actor, :]``
are expressed as **one-hot contractions on the MXU**:

* ``starts[actor, :]`` → onehot(actors, A) @ starts          [BN, R]
* ``ends[actor, :]``   → onehot(actors, A) @ ends            [BN, R]

Run bounds and counters are exact in f32 (< 2²⁴), so the contraction is
bit-exact; the membership test ``any(lo ≤ c ≤ hi)`` is then a VPU
broadcast-compare over the R run columns.  The whole clock (starts + ends)
is VMEM-resident — it is causal-metadata-sized, O(interval runs), which is
the paper's entire point — while the dot stream is tiled over the grid.

VMEM budget per block (A=128, R=256, BN=1024):
  runs 2·128·256·4B = 256 KiB, onehotA 1024·128·4 = 512 KiB,
  rows 2·1024·256·4 = 2 MiB  →  ~2.8 MiB  <  16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 1024


def _kernel(starts_ref, ends_ref, actors_ref, counters_ref, out_ref,
            *, n_actors: int):
    actors = actors_ref[...]                            # int32[BN]
    counters = counters_ref[...]                        # int32[BN]
    bn = actors.shape[0]

    # --- gather the actor's run row via one-hot matmul (f32-exact: < 2^24)
    onehot_a = (actors[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (bn, n_actors), 1)).astype(jnp.float32)      # [BN, A]
    rows_s = jnp.dot(onehot_a, starts_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)        # [BN, R]
    rows_e = jnp.dot(onehot_a, ends_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)        # [BN, R]

    # --- interval membership: empty slots are (1, 0), which never match
    c = counters[:, None].astype(jnp.float32)                   # [BN, 1]
    hit = (rows_s <= c) & (c <= rows_e)                         # [BN, R]
    seen = jnp.any(hit, axis=1)
    out_ref[...] = seen.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def dot_seen_pallas(
    starts: jax.Array,    # int32[A, R]
    ends: jax.Array,      # int32[A, R]
    actors: jax.Array,    # int32[N]
    counters: jax.Array,  # int32[N]
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
) -> jax.Array:
    n = actors.shape[0]
    n_actors, n_runs = starts.shape

    pad = (-n) % block_n
    if pad:
        actors = jnp.pad(actors, (0, pad))
        counters = jnp.pad(counters, (0, pad))
    n_pad = actors.shape[0]

    grid = (n_pad // block_n,)
    out = pl.pallas_call(
        functools.partial(_kernel, n_actors=n_actors),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_actors, n_runs), lambda i: (0, 0)),   # starts
            pl.BlockSpec((n_actors, n_runs), lambda i: (0, 0)),   # ends
            pl.BlockSpec((block_n,), lambda i: (i,)),             # actors
            pl.BlockSpec((block_n,), lambda i: (i,)),             # counters
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(starts, ends, actors, counters)
    return out[:n].astype(bool)
