"""Pallas TPU kernel: batched dot-seen test against a dense clock.

TPU adaptation (see DESIGN.md §2): TPUs have no efficient scatter/gather
unit, so the per-dot lookups ``origin[actor]`` and ``bits[actor, word]``
are expressed as **one-hot contractions on the MXU**:

* ``origin[actor]``      → onehot(actors, A) @ origin            [BN]
* ``bits[actor, :]``     → onehot(actors, A) @ bits               [BN, W]
* ``row[word]``          → Σ_w onehot(word, W) ⊙ row              [BN]

uint32 words are split into two exact-in-f32 uint16 halves before the
contraction and reassembled in integer registers, keeping the test
bit-exact.  The whole clock (origin + bitmap) is VMEM-resident — it is
causal-metadata-sized, which is the paper's entire point — while the dot
stream is tiled over the grid.

VMEM budget per block (A=128, W=256, BN=1024):
  bits halves 2·128·256·4B = 256 KiB, onehotA 1024·128·4 = 512 KiB,
  rows 2·1024·256·4 = 2 MiB, onehotW 1 MiB  →  ~4 MiB  <  16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 1024


def _kernel(origin_ref, bits_lo_ref, bits_hi_ref, actors_ref, counters_ref,
            out_ref, *, n_actors: int, n_words: int):
    actors = actors_ref[...]                            # int32[BN]
    counters = counters_ref[...]                        # int32[BN]
    bn = actors.shape[0]

    # --- gather origin[actor] via one-hot matmul (f32-exact: A, counters small)
    onehot_a = (actors[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (bn, n_actors), 1)).astype(jnp.float32)      # [BN, A]
    origin_f = origin_ref[...].astype(jnp.float32)              # [A]
    org = jnp.dot(onehot_a, origin_f[:, None],
                  preferred_element_type=jnp.float32)[:, 0]     # [BN]
    org = org.astype(jnp.int32)

    rel = counters - org - 1                                    # [BN]
    word = jnp.clip(rel // 32, 0, n_words - 1)
    bit = (rel % 32).astype(jnp.uint32)
    in_window = (rel >= 0) & (rel < n_words * 32)

    # --- gather bits[actor, word] via two one-hot contractions, 16b halves
    rows_lo = jnp.dot(onehot_a, bits_lo_ref[...],
                      preferred_element_type=jnp.float32)       # [BN, W]
    rows_hi = jnp.dot(onehot_a, bits_hi_ref[...],
                      preferred_element_type=jnp.float32)       # [BN, W]
    onehot_w = (word[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (bn, n_words), 1)).astype(jnp.float32)       # [BN, W]
    lo = jnp.sum(rows_lo * onehot_w, axis=1)                    # [BN] f32
    hi = jnp.sum(rows_hi * onehot_w, axis=1)
    wval = lo.astype(jnp.uint32) | (hi.astype(jnp.uint32) << jnp.uint32(16))

    hit = ((wval >> bit) & jnp.uint32(1)) == jnp.uint32(1)
    seen = (counters <= org) | (in_window & hit)
    out_ref[...] = seen.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def dot_seen_pallas(
    origin: jax.Array,    # int32[A]
    bits: jax.Array,      # uint32[A, W]
    actors: jax.Array,    # int32[N]
    counters: jax.Array,  # int32[N]
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
) -> jax.Array:
    n = actors.shape[0]
    n_actors, n_words = bits.shape
    bits_lo = (bits & jnp.uint32(0xFFFF)).astype(jnp.float32)
    bits_hi = (bits >> jnp.uint32(16)).astype(jnp.float32)

    pad = (-n) % block_n
    if pad:
        actors = jnp.pad(actors, (0, pad))
        counters = jnp.pad(counters, (0, pad))
    n_pad = actors.shape[0]

    grid = (n_pad // block_n,)
    out = pl.pallas_call(
        functools.partial(_kernel, n_actors=n_actors, n_words=n_words),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_actors,), lambda i: (0,)),            # origin
            pl.BlockSpec((n_actors, n_words), lambda i: (0, 0)),  # bits lo
            pl.BlockSpec((n_actors, n_words), lambda i: (0, 0)),  # bits hi
            pl.BlockSpec((block_n,), lambda i: (i,)),             # actors
            pl.BlockSpec((block_n,), lambda i: (i,)),             # counters
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(origin, bits_lo, bits_hi, actors, counters)
    return out[:n].astype(bool)
