"""Pallas TPU kernels for the framework's compute hot spots.

Paper-technique kernels (the bigset causal-metadata plane):
* ``dot_seen``    - batched dot-membership filter (read fold / delta dedup)
* ``clock_ops``   - clock-lattice join / subtract / intersect / popcount
  over dense (actor, lo, hi) interval-run arrays

Model-plane kernels (the assigned-architecture hot spots):
* ``flash_attention``  - blocked prefill attention (causal/SWA, GQA)
* ``decode_attention`` - flash-decode over long KV caches
* ``mamba_scan``       - chunked selective scan (SSM archs)

Each subpackage is ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper with pallas/ref dispatch) and ``ref.py`` (pure-jnp oracle).
All kernels validate against their oracle in ``interpret=True`` across
shape/dtype sweeps in tests/test_kernels.py.
"""
from .dot_seen import dot_seen
from .flash_attention import flash_attention
from .decode_attention import decode_attention
from .mamba_scan import mamba_scan, mamba_step
from . import clock_ops

__all__ = ["dot_seen", "flash_attention", "decode_attention", "mamba_scan",
           "mamba_step", "clock_ops"]
