"""Jit'd selective-scan entry point with pallas/ref dispatch."""
from __future__ import annotations

from typing import Tuple

import jax

from .kernel import mamba_scan_pallas
from .ref import mamba_scan_ref, mamba_step_ref


def mamba_scan(
    x: jax.Array, delta: jax.Array, A: jax.Array, Bm: jax.Array,
    Cm: jax.Array, D: jax.Array, *, use_pallas: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Selective scan y [B,T,D].  (Final state via the ref when needed.)"""
    if use_pallas:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return mamba_scan_pallas(x, delta, A, Bm, Cm, D, interpret=interpret)
    y, _ = mamba_scan_ref(x, delta, A, Bm, Cm, D)
    return y


def mamba_step(x, delta, A, Bm, Cm, D, h) -> Tuple[jax.Array, jax.Array]:
    """Single decode step (state-carrying); pure-jnp, O(1) in sequence."""
    return mamba_step_ref(x, delta, A, Bm, Cm, D, h)
