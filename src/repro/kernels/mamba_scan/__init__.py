from .ops import mamba_scan, mamba_step
from .kernel import mamba_scan_pallas
from .ref import mamba_scan_ref, mamba_step_ref

__all__ = ["mamba_scan", "mamba_step", "mamba_scan_pallas", "mamba_scan_ref",
           "mamba_step_ref"]
