"""Pure-jnp oracle for the Mamba-1 selective scan.

Recurrence (per batch, channel d, state n):
    h_t = exp(Δ_t · A[d,n]) · h_{t-1} + Δ_t · B_t[n] · x_t[d]
    y_t = Σ_n C_t[n] · h_t[d,n] + D[d] · x_t[d]

Reference uses ``jax.lax.scan`` over time (exact, O(T) sequential) and
returns the final state so decode can continue the recurrence.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def mamba_scan_ref(
    x: jax.Array,        # [B, T, D]    (post-conv activations)
    delta: jax.Array,    # [B, T, D]    (softplus-ed step sizes)
    A: jax.Array,        # [D, N]       (negative; log-spaced init)
    Bm: jax.Array,       # [B, T, N]
    Cm: jax.Array,       # [B, T, N]
    D: jax.Array,        # [D]
    h0: jax.Array | None = None,  # [B, D, N]
) -> Tuple[jax.Array, jax.Array]:  # y [B,T,D], h_T [B,D,N]
    Bsz, T, Dm = x.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, Dm, N), jnp.float32)

    xf = x.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def scan_one(h0_b, x_b, d_b, B_b, C_b):
        def body(h, inp):
            x_t, d_t, b_t, c_t = inp
            a = jnp.exp(d_t[:, None] * Af)              # [D, N]
            h = a * h + (d_t * x_t)[:, None] * b_t[None, :]
            y = (h * c_t[None, :]).sum(-1)              # [D]
            return h, y
        hT, ys = jax.lax.scan(body, h0_b, (x_b, d_b, B_b, C_b))
        return hT, ys

    hT, ys = jax.vmap(scan_one)(h0.astype(jnp.float32), xf, df, Bf, Cf)
    y = ys + xf * D.astype(jnp.float32)[None, None, :]
    return y.astype(x.dtype), hT


def mamba_step_ref(
    x: jax.Array,      # [B, D]  one token
    delta: jax.Array,  # [B, D]
    A: jax.Array,      # [D, N]
    Bm: jax.Array,     # [B, N]
    Cm: jax.Array,     # [B, N]
    D: jax.Array,      # [D]
    h: jax.Array,      # [B, D, N]
) -> Tuple[jax.Array, jax.Array]:
    a = jnp.exp(delta[..., None] * A[None])             # [B, D, N]
    h = a * h.astype(jnp.float32) + (delta * x)[..., None] * Bm[:, None, :]
    y = (h * Cm[:, None, :]).sum(-1) + x * D[None]
    return y.astype(x.dtype), h
