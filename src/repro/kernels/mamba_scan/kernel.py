"""Pallas TPU kernel for the Mamba-1 selective scan (chunked).

Decomposition for the TPU memory hierarchy:

* grid = (B, D/BD, T/L) with the **time-chunk axis sequential** ("arbitrary"
  semantics) so the recurrent state h [BD, N] persists in VMEM scratch
  across chunks — HBM traffic is exactly one pass over x/Δ/B/C plus one
  [BD, N] state, never T·N intermediates.
* within a chunk the recurrence runs as an L-step ``fori_loop`` over VMEM
  tiles; each step is [BD, N] elementwise VPU work.  (The matmul-dual SSD
  form is a recorded hillclimb candidate — see EXPERIMENTS.md §Perf.)
* channels are blocked at BD=512 (f32 state 512·16·4 = 32 KiB VMEM).

Shapes: x/Δ [B, T, D], A [D, N], B/C [B, T, N], y [B, T, D].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(x_ref, d_ref, a_ref, b_ref, c_ref, dd_ref, y_ref, h_ref, *,
                  chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)        # [BD, N]
    Dd = dd_ref[...].astype(jnp.float32)      # [BD]

    def body(t, h):
        x_t = x_ref[0, t, :].astype(jnp.float32)       # [BD]
        d_t = d_ref[0, t, :].astype(jnp.float32)       # [BD]
        b_t = b_ref[0, t, :].astype(jnp.float32)       # [N]
        c_t = c_ref[0, t, :].astype(jnp.float32)       # [N]
        a_t = jnp.exp(d_t[:, None] * A)                # [BD, N]
        h = a_t * h + (d_t * x_t)[:, None] * b_t[None, :]
        y_t = (h * c_t[None, :]).sum(axis=1) + x_t * Dd
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_ref[...])
    h_ref[...] = h


@functools.partial(
    jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def mamba_scan_pallas(
    x: jax.Array,      # [B, T, D]
    delta: jax.Array,  # [B, T, D]
    A: jax.Array,      # [D, N]
    Bm: jax.Array,     # [B, T, N]
    Cm: jax.Array,     # [B, T, N]
    D: jax.Array,      # [D]
    *,
    block_d: int = 512,
    chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    Bsz, T, Dm = x.shape
    N = A.shape[1]
    block_d = min(block_d, Dm)
    chunk = min(chunk, T)
    if Dm % block_d != 0 or T % chunk != 0:
        raise ValueError(
            f"model dims must tile the blocks: Dm={Dm} % block_d={block_d}, "
            f"T={T} % chunk={chunk}")

    grid = (Bsz, Dm // block_d, T // chunk)

    y = pl.pallas_call(
        functools.partial(_mamba_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, i, c: (b, c, i)),  # x
            pl.BlockSpec((1, chunk, block_d), lambda b, i, c: (b, c, i)),  # Δ
            pl.BlockSpec((block_d, N), lambda b, i, c: (i, 0)),            # A
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),        # B
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),        # C
            pl.BlockSpec((block_d,), lambda b, i, c: (i,)),                # D
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, i, c: (b, c, i)),
        out_shape=jax.ShapeDtypeStruct((Bsz, T, Dm), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(x, delta, A, Bm, Cm, D)
    return y
