"""Jit'd decode-attention entry point with pallas/ref dispatch."""
from __future__ import annotations

import jax

from .kernel import decode_attention_pallas
from .ref import decode_attention_ref


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    window: int | None = None,
    scale: float | None = None,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    if use_pallas:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return decode_attention_pallas(
            q, k_cache, v_cache, cache_len, window=window, scale=scale,
            interpret=interpret)
    return decode_attention_ref(
        q, k_cache, v_cache, cache_len, window=window, scale=scale)
