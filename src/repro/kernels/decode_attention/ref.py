"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(
    q: jax.Array,          # [B, Hq, D]      (one new token per sequence)
    k_cache: jax.Array,    # [B, Hkv, S, D]
    v_cache: jax.Array,    # [B, Hkv, S, D]
    cache_len: jax.Array,  # int32[B]        (valid prefix length per seq)
    *,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:            # [B, Hq, D]
    B, Hq, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    kq = jnp.repeat(k_cache, group, axis=1)
    vq = jnp.repeat(v_cache, group, axis=1)
    logits = jnp.einsum(
        "bhd,bhsd->bhs", q.astype(jnp.float32), kq.astype(jnp.float32)
    ) * scale
    pos = jnp.arange(S)[None, :]                       # [1, S]
    valid = pos < cache_len[:, None]                   # [B, S]
    if window is not None:
        valid &= pos >= (cache_len[:, None] - window)
    logits = jnp.where(valid[:, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)
