"""Pallas TPU flash-decode: one query token vs a long KV cache.

decode_32k / long_500k lower this step.  The MXU wants ≥8-row operands, so
the q-head *group* of a GQA kv head forms the row block (padded to the
sublane minimum): for each (batch, kv-head) the kernel streams KV tiles
[BKV, D] from HBM through VMEM, carrying online-softmax stats — the
arithmetic-intensity profile is exactly "read the cache once", which is the
HBM-bandwidth roofline decode lives on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, scale: float, window: int | None, block_kv: int,
                   group_pad: int):
    ikv = pl.program_id(1)
    n_kv = pl.num_programs(1)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cache_len = len_ref[0]
    kv_start = ikv * block_kv
    lo_bound = 0 if window is None else cache_len - window

    @pl.when(jnp.logical_and(kv_start < cache_len,
                             kv_start + block_kv > lo_bound))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # [G, D]
        k = k_ref[0, 0].astype(jnp.float32)      # [BKV, D]
        v = v_ref[0, 0].astype(jnp.float32)      # [BKV, D]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # [G, BKV]
        pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        valid = pos < cache_len
        if window is not None:
            valid &= pos >= cache_len - window
        logits = jnp.where(valid, logits, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        p = jnp.where(valid, jnp.exp(logits - m_new[:, None]), 0.0)
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ikv == n_kv - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "scale", "block_kv", "interpret"),
)
def decode_attention_pallas(
    q: jax.Array,          # [B, Hq, D]
    k_cache: jax.Array,    # [B, Hkv, S, D]
    v_cache: jax.Array,    # [B, Hkv, S, D]
    cache_len: jax.Array,  # int32[B]
    *,
    window: int | None = None,
    scale: float | None = None,
    block_kv: int = 256,
    interpret: bool = True,
) -> jax.Array:
    B, Hq, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    if Hq % Hkv != 0:
        raise ValueError(f"GQA requires Hq % Hkv == 0, got {Hq} % {Hkv}")
    group = Hq // Hkv
    group_pad = max(8, group)  # sublane minimum
    if scale is None:
        scale = D ** -0.5
    block_kv = min(block_kv, S)
    if S % block_kv != 0:
        raise ValueError(f"cache length {S} not divisible by block_kv {block_kv}")

    # [B, Hkv, G, D] with the group padded to the sublane minimum
    qg = q.reshape(B, Hkv, group, D)
    if group_pad != group:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, group_pad - group), (0, 0)))

    grid = (B * Hkv, S // block_kv)

    def q_index(h, ikv):
        return (h // Hkv, h % Hkv, 0, 0)

    def kv_index(h, ikv):
        return (h // Hkv, h % Hkv, ikv, 0)

    def len_index(h, ikv):
        return (h // Hkv,)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, block_kv=block_kv,
        group_pad=group_pad)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), len_index),
            pl.BlockSpec((1, 1, group_pad, D), q_index),
            pl.BlockSpec((1, 1, block_kv, D), kv_index),
            pl.BlockSpec((1, 1, block_kv, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, group_pad, D), q_index),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group_pad, D), jnp.float32),
            pltpu.VMEM((group_pad,), jnp.float32),
            pltpu.VMEM((group_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len.astype(jnp.int32), qg, k_cache, v_cache)
    return out[:, :, :group, :].reshape(B, Hq, D)
