"""Three replicated-set clusters: Riak full-state, delta-replication, bigset.

These are the paper's three contenders (Figure 1).  All share the same
topology (N replicas per set, coordinator-forwarding, downstream
replication) and the same storage substrate, so the only variable is the
representation + replication strategy — exactly the comparison the paper
makes.

* :class:`RiakSetCluster` — §2: the ORSWOT serialized as one blob in a
  riak-object; every write reads + rewrites the blob; replication ships the
  full state; downstream merge on version-vector conflict.
* :class:`DeltaCluster` — §3: delta mutators ship small deltas, but the
  downstream replica still read-merge-writes the full blob.
* :class:`BigsetCluster` — §4: decomposed keys, clock-only writes,
  element-key deltas, dot-seen downstream apply.
"""
from __future__ import annotations

import msgpack
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from ..core.bigset import BigsetVnode, InsertDelta, RemoveDelta
from ..core.clock import Clock
from ..core.delta_orswot import delta_add, delta_remove, join_delta
from ..core.dots import Dot
from ..core.orswot import Orswot
from ..core.streaming import merge_entry, quorum_is_member, quorum_read
from ..index.spec import IndexSpec
from ..obs.trace import NULL_TRACER, TraceContext, Tracer
from ..query import cursor as query_cursor
from ..query import plan as query_plan
from ..query.executor import (QueryExecutor, QueryResult, QueryStats,
                              account_emitted, collect_index_page,
                              collect_page, gallop_join, index_resume_point,
                              stream_entries, zipper_join)
from ..query.planner import GALLOP, choose_join, quorum_side_stats
from ..storage.lsm import LsmStore
from ..storage.wal import DurableMedia, RecoveryResult
from .antientropy import (AntiEntropyScheduler, AntiEntropyStats,
                          SyncRequest, apply_digest_reply,
                          build_digest_reply, survivors_digest)
from .sim import Message, Network


class VnodeDown(RuntimeError):
    """An operation was routed to a crashed vnode (crash()ed, not restarted)."""


# ------------------------------------------------------------ serve sessions
class ClusterSession:
    """Hook surface the serve layer attaches to cluster entry points.

    A session observes — never alters — what its requests cost: the service
    (:mod:`repro.serve.bigset_service`) feeds its byte-budget admission
    control from ``observe_query`` (per-page :class:`~repro.query.executor.
    QueryStats`, themselves fed from storage IoStats) and its write
    accounting from ``observe_mutation`` (delta sizes).  The default
    implementation is a no-op so library callers pay nothing.
    """

    def observe_query(self, plan, result: "QueryResult") -> None:
        pass

    def observe_mutation(self, delta) -> None:
        pass


# ------------------------------------------------------------ traced payloads
@dataclass(frozen=True)
class TracedPayload:
    """A network payload carrying its sender's :class:`TraceContext`.

    Only minted when tracing is **enabled** — disabled clusters ship the
    raw payload object, byte-identical to untraced operation (asserted in
    ``tests/test_obs.py``).  The context names a span that was finished
    *before* the message entered the network, so however delivery goes
    (dropped, duplicated, reordered), a delivered message's ``net.deliver``
    span always parents under a span that exists: drops lose leaves,
    never tree integrity.
    """

    ctx: TraceContext
    payload: Any


# --------------------------------------------------------------- orswot codec
def orswot_to_bytes(s: Orswot) -> bytes:
    """Run-length orswot codec: the clock ships as interval runs."""
    obj = s.clock.to_obj()
    obj["e"] = sorted(
        (e, sorted((d.actor, d.counter) for d in ds))
        for e, ds in s.entries.items()
    )
    return msgpack.packb(obj)


def orswot_from_bytes(b: Optional[bytes]) -> Orswot:
    """Decode an orswot blob — run-length or legacy per-dot clock form."""
    if b is None:
        return Orswot.new()
    o = msgpack.unpackb(b, strict_map_key=False)
    clock = Clock.from_obj(o)
    entries = {
        e: frozenset(Dot(a, c) for a, c in ds) for e, ds in o["e"]
    }
    return Orswot(clock, entries)


class _ClusterBase:
    """Shared topology: ``n_replicas`` vnodes all replicating every set."""

    def __init__(self, n_replicas: int = 3, net: Optional[Network] = None,
                 sync: bool = True):
        self.n = n_replicas
        self.net = net or Network()
        self.sync = sync  # deliver replication traffic immediately
        self.actors = [f"vnode{i}" for i in range(n_replicas)]

    def _replicate(self, src: str, payload, size: int) -> None:
        for a in self.actors:
            if a != src:
                self.net.send(src, a, payload, size)
        if self.sync:
            self.net.deliver_all(self._handle)

    def settle(self) -> None:
        self.net.deliver_all(self._handle)

    def _handle(self, msg: Message) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    def io_stats(self):
        raise NotImplementedError


class RiakSetCluster(_ClusterBase):
    """Full-state ORSWOT-in-a-blob (Riak Sets, §2)."""

    def __init__(self, n_replicas: int = 3, net: Optional[Network] = None,
                 sync: bool = True):
        super().__init__(n_replicas, net, sync)
        self.stores: Dict[str, LsmStore] = {a: LsmStore() for a in self.actors}

    def _key(self, set_name: bytes) -> bytes:
        return b"riak_set/" + set_name

    def _load(self, actor: str, set_name: bytes) -> Orswot:
        return orswot_from_bytes(self.stores[actor].get(self._key(set_name)))

    def _save(self, actor: str, set_name: bytes, s: Orswot) -> bytes:
        blob = orswot_to_bytes(s)
        self.stores[actor].put(self._key(set_name), blob)
        return blob

    def add(self, set_name: bytes, element: bytes, coordinator: int = 0) -> None:
        actor = self.actors[coordinator]
        s = self._load(actor, set_name)           # read whole set — O(n)
        s = s.add(actor, element)
        blob = self._save(actor, set_name, s)     # write whole set — O(n)
        self._replicate(actor, ("state", set_name, blob), len(blob))

    def remove(self, set_name: bytes, element: bytes, coordinator: int = 0) -> None:
        actor = self.actors[coordinator]
        s = self._load(actor, set_name)
        ctx = s.context_of(element)
        s = s.remove(element, ctx)
        blob = self._save(actor, set_name, s)
        self._replicate(actor, ("state", set_name, blob), len(blob))

    def _handle(self, msg: Message) -> None:
        _, set_name, blob = msg.payload
        local = self._load(msg.dst, set_name)      # read whole set
        incoming = orswot_from_bytes(blob)
        if incoming.clock.descends(local.clock):
            merged = incoming                      # supersedes: store directly
        else:
            merged = local.merge(incoming)         # conflict: full merge
        self._save(msg.dst, set_name, merged)      # write whole set

    def read(self, set_name: bytes, r: int = 1) -> Orswot:
        acc = self._load(self.actors[0], set_name)
        for a in self.actors[1:r]:
            acc = acc.merge(self._load(a, set_name))
        return acc

    def value(self, set_name: bytes, r: int = 1):
        return self.read(set_name, r).value()

    def io_stats(self):
        from ..storage.lsm import IoStats
        agg = IoStats()
        for st in self.stores.values():
            for k in vars(agg):
                setattr(agg, k, getattr(agg, k) + getattr(st.stats, k))
        return agg


class DeltaCluster(RiakSetCluster):
    """Delta-replication ORSWOT (§3): small wire deltas, full-state disk IO."""

    def add(self, set_name: bytes, element: bytes, coordinator: int = 0) -> None:
        actor = self.actors[coordinator]
        s = self._load(actor, set_name)            # still reads whole set
        s, delta = delta_add(s, actor, element)
        self._save(actor, set_name, s)             # still writes whole set
        dblob = orswot_to_bytes(delta)
        self._replicate(actor, ("delta", set_name, dblob), len(dblob))

    def remove(self, set_name: bytes, element: bytes, coordinator: int = 0) -> None:
        actor = self.actors[coordinator]
        s = self._load(actor, set_name)
        ctx = s.context_of(element)
        s, delta = delta_remove(s, element, ctx)
        self._save(actor, set_name, s)
        dblob = orswot_to_bytes(delta)
        self._replicate(actor, ("delta", set_name, dblob), len(dblob))

    def _handle(self, msg: Message) -> None:
        _, set_name, dblob = msg.payload
        local = self._load(msg.dst, set_name)      # read whole set
        delta = orswot_from_bytes(dblob)
        merged = join_delta(local, delta)          # merge ALWAYS (§3)
        self._save(msg.dst, set_name, merged)      # write whole set


class BigsetCluster(_ClusterBase):
    """Decomposed bigset cluster (§4).

    ``durable=True`` gives every vnode a :class:`DurableMedia`-backed
    store (WAL + group commit at ``group_depth``); :meth:`crash` /
    :meth:`restart` then model the ROADMAP's "node restarts under
    traffic" fault: a crash drops the vnode's in-memory state and its
    unsynced WAL tail, a restart replays the durable prefix and scheduled
    anti-entropy (:meth:`tick`) heals the rest from peers.
    """

    def __init__(self, n_replicas: int = 3, net: Optional[Network] = None,
                 sync: bool = True,
                 scheduler: Optional[AntiEntropyScheduler] = None,
                 tracer: Optional[Tracer] = None,
                 durable: bool = False, group_depth: int = 8,
                 media: Optional[Dict[str, DurableMedia]] = None):
        super().__init__(n_replicas, net, sync)
        self.durable = durable or media is not None
        self.group_depth = group_depth
        if self.durable:
            self.media: Optional[Dict[str, DurableMedia]] = (
                media or {a: DurableMedia() for a in self.actors})
            self.vnodes: Dict[str, BigsetVnode] = {
                a: BigsetVnode(a, store=LsmStore(
                    media=self.media[a], group_depth=group_depth))
                for a in self.actors
            }
        else:
            self.media = None
            self.vnodes = {a: BigsetVnode(a) for a in self.actors}
        self.crashed: Set[str] = set()
        # index specs by (set, index name): a restarted vnode re-registers
        # them so downstream extractors keep running identically everywhere
        self._index_specs: Dict[bytes, Dict[bytes, IndexSpec]] = {}
        # read repair feeds this; tick() drains it (see antientropy module)
        self.scheduler = scheduler or AntiEntropyScheduler(self.actors)
        # observability: NULL_TRACER by default — disabled tracing wraps no
        # payloads and records no spans (zero behavior change, invariant 10)
        self.tracer = tracer or NULL_TRACER

    # ------------------------------------------------------- crash / restart
    def _actor(self, vnode) -> str:
        return self.actors[vnode] if isinstance(vnode, int) else vnode

    def _coordinator(self, coordinator: int) -> str:
        actor = self.actors[coordinator]
        if actor in self.crashed:
            raise VnodeDown(f"{actor} is crashed")
        return actor

    def crash(self, vnode) -> None:
        """Kill a vnode: memtable, digests, and the unsynced WAL tail are
        gone; the durable media survives for :meth:`restart`.  In-flight
        and future traffic to the vnode is dropped by the network."""
        if not self.durable:
            raise RuntimeError("crash() requires a durable cluster")
        actor = self._actor(vnode)
        if actor in self.crashed:
            return
        self.crashed.add(actor)
        self.vnodes.pop(actor, None)
        self.media[actor].crash()
        self.net.blackhole(actor)

    def restart(self, vnode) -> RecoveryResult:
        """Bring a crashed vnode back from its durable media.

        A fresh store replays manifested segments + the WAL's acknowledged
        prefix (``storage.recover`` span); the new vnode adopts it — its
        per-set digests rebuild from one background fold on first touch —
        and re-registers every known index spec without backfill (postings
        were durable alongside their element-keys).  The unacknowledged
        tail is *not* back: scheduled anti-entropy heals it from peers,
        dot-bounded.  Returns the replay's :class:`RecoveryResult`.
        """
        actor = self._actor(vnode)
        if actor not in self.crashed:
            raise RuntimeError(f"{actor} is not crashed")
        store = LsmStore(media=self.media[actor],
                         group_depth=self.group_depth)
        with self.tracer.span("storage.recover", actor=actor) as sp:
            rec = store.recover()
            sp.set(segments=rec.segments,
                   batches_replayed=rec.batches_replayed,
                   batches_skipped=rec.batches_skipped,
                   bytes_replayed=rec.bytes_replayed,
                   torn_bytes=rec.torn_bytes)
        vn = BigsetVnode(actor, store=store)
        for set_name, specs in self._index_specs.items():
            for spec in specs.values():
                vn.register_index(set_name, spec, backfill=False)
        self.vnodes[actor] = vn
        self.net.heal(actor)
        self.crashed.discard(actor)
        return rec

    def sync_all(self) -> None:
        """Force the pending group commit on every live vnode — the write
        path's explicit acknowledgement barrier."""
        for vn in self.vnodes.values():
            vn.store.sync()

    def _traced(self, ctx_span, payload):
        """Wrap a payload with the span's context iff tracing is enabled."""
        if not self.tracer.enabled:
            return payload
        return TracedPayload(ctx_span.context(), payload)

    def add(self, set_name: bytes, element: bytes, coordinator: int = 0,
            ctx: Iterable[Dot] = (), value: bytes = b"",
            session: Optional[ClusterSession] = None) -> InsertDelta:
        """Coordinate an insert; returns the minted delta.

        The delta's ``dot`` is the insert's causal identity — the serve
        layer round-trips it to clients as the context for a later remove
        or replacing add.
        """
        actor = self._coordinator(coordinator)
        self.scheduler.note_set(set_name)
        with self.tracer.span("cluster.insert", set_name=set_name,
                              actor=actor) as sp:
            delta = self.vnodes[actor].coordinate_insert(
                set_name, element, ctx, value=value)
            self._replicate(actor, self._traced(sp, delta),
                            delta.size_bytes())
        if session is not None:
            session.observe_mutation(delta)
        return delta

    def register_index(self, set_name: bytes, spec: IndexSpec,
                       backfill: bool = True) -> int:
        """Register a secondary index on every replica (extractors must run
        identically downstream).  Returns total backfill postings written.
        The spec is remembered so a restarted vnode re-registers it."""
        self._index_specs.setdefault(set_name, {})[spec.name] = spec
        return sum(
            vn.register_index(set_name, spec, backfill=backfill)
            for vn in self.vnodes.values())

    def remove(self, set_name: bytes, element: bytes, coordinator: int = 0,
               ctx: Optional[Iterable[Dot]] = None,
               session: Optional[ClusterSession] = None
               ) -> Optional[RemoveDelta]:
        """Observed-remove: ctx defaults to a local membership probe (§4.3.2
        — "the client **must** provide a context for a remove").  Returns
        the shipped delta, or None when there was nothing to remove."""
        actor = self._coordinator(coordinator)
        vn = self.vnodes[actor]
        self.scheduler.note_set(set_name)
        if ctx is None:
            _, ctx = vn.is_member(set_name, element)
        ctx = tuple(ctx)
        if not ctx:
            return None
        with self.tracer.span("cluster.remove", set_name=set_name,
                              actor=actor) as sp:
            delta = vn.coordinate_remove(set_name, ctx)
            self._replicate(actor, self._traced(sp, delta),
                            delta.size_bytes())
        if session is not None:
            session.observe_mutation(delta)
        return delta

    def mutate(self, set_name: bytes, ops: Sequence[Tuple], coordinator: int = 0,
               session: Optional[ClusterSession] = None) -> List:
        """Batch mutation entry point (the serve layer's write path).

        ``ops`` is a sequence of ``("add", element[, value[, ctx]])`` and
        ``("remove", element[, ctx])`` tuples, applied in order through one
        coordinator so a remove can observe an earlier add in the same
        batch.  Returns the per-op deltas (None for no-op removes).
        """
        out: List = []
        for op in ops:
            kind, element = op[0], op[1]
            if kind == "add":
                value = op[2] if len(op) > 2 else b""
                ctx = op[3] if len(op) > 3 else ()
                out.append(self.add(set_name, element, coordinator, ctx=ctx,
                                    value=value, session=session))
            elif kind == "remove":
                ctx = op[2] if len(op) > 2 else None
                out.append(self.remove(set_name, element, coordinator,
                                       ctx=ctx, session=session))
            else:
                raise ValueError(f"unknown mutation op {kind!r}")
        return out

    def _handle(self, msg: Message) -> None:
        payload = msg.payload
        if isinstance(payload, TracedPayload):
            # the delivery span parents on the *sender's* span via the
            # carried context — correct under drop/dup/reorder, where the
            # call stack at delivery time says nothing about causality
            with self.tracer.span("net.deliver", parent=payload.ctx,
                                  src=msg.src, dst=msg.dst,
                                  size_bytes=msg.size_bytes):
                self._deliver(msg.dst, payload.payload)
        else:
            self._deliver(msg.dst, payload)

    def _deliver(self, dst: str, payload) -> None:
        vn = self.vnodes[dst]
        if isinstance(payload, InsertDelta):
            vn.replica_insert(payload)
        elif isinstance(payload, RemoveDelta):
            vn.replica_remove(payload)
        else:  # anti-entropy and membership traffic uses callables
            payload(vn)

    def read(self, set_name: bytes, r: int = 1) -> Orswot:
        streams = []
        for a in self.actors[:r]:
            rs = self.vnodes[a].read(set_name)
            streams.append((rs.clock, rs.entries()))
        return quorum_read(streams)

    def value(self, set_name: bytes, r: int = 1):
        return self.read(set_name, r).value()

    # -------------------------------------------------------------- queries
    def query(self, plan, r: Optional[int] = None, repair: bool = True,
              session: Optional[ClusterSession] = None) -> QueryResult:
        """Coverage-query path: scatter a plan to ``r`` replicas, stream the
        partial results through a quorum merge, and read-repair stragglers.

        Each replica contributes a lazy visible-entry stream (a storage seek
        + bounded scan, §4.4); the merge is the streaming ORSWOT join of
        :mod:`repro.core.streaming` with per-replica dot attribution so any
        replica missing a surviving dot gets the element-key delta replayed
        to it (read repair) — anti-entropy rides on the query workload.
        ``r`` defaults to a majority quorum.  A ``session``
        (:class:`ClusterSession`) observes the result post-accounting — the
        serve layer's backpressure budget hangs off this hook.
        """
        query_plan.validate(plan)
        if r is None:
            r = self.n // 2 + 1
        # coverage planning routes around crashed replicas: a non-quorum
        # crash leaves reads fully available (restart-under-traffic)
        live = [a for a in self.actors if a not in self.crashed]
        if len(live) < r:
            raise VnodeDown(
                f"need {r} replicas, {len(live)} live ({sorted(self.crashed)}"
                " crashed)")
        actors = live[:r]
        tr = self.tracer
        with tr.span("cluster.query", plan=type(plan).__name__,
                     set_name=getattr(plan, "set_name", b""), r=r) as qspan:
            meters = [self.vnodes[a].store.meter() for a in actors]
            # coverage sub-spans opened per quorum replica BEFORE execution
            # (their storage children get the replica's IoStats delta after)
            rspans = ([tr.start("replica.coverage", parent=qspan.context(),
                                actor=a) for a in actors]
                      if tr.enabled else None)
            if isinstance(plan, query_plan.Membership):
                res = self._q_membership(plan, actors, repair)
            elif isinstance(plan, query_plan.Range):
                res = self._q_range(
                    plan.set_name, plan.start, plan.end, plan.limit,
                    plan.cursor, query_plan.cursor_scope(plan), actors,
                    repair)
            elif isinstance(plan, query_plan.Scan):
                res = self._q_range(
                    plan.set_name, None, None, plan.page_size,
                    plan.cursor, query_plan.cursor_scope(plan), actors,
                    repair)
            elif isinstance(plan, query_plan.Count):
                res = self._q_count(plan, actors, repair)
            elif isinstance(plan, query_plan.Join):
                res = self._q_join(plan, actors, repair)
            elif isinstance(plan,
                            (query_plan.IndexLookup, query_plan.IndexRange)):
                res = self._q_index(plan, actors, repair)
            else:  # pragma: no cover - validate() rejects
                raise query_plan.PlanError(type(plan).__name__)
            for i, m in enumerate(meters):
                io = m.delta()
                res.stats.bytes_read += io.bytes_read
                res.stats.num_seeks += io.num_seeks
                if rspans is not None:
                    rspan = rspans[i]
                    tr.finish(tr.start(
                        "storage.scan", parent=rspan.context(),
                        bytes_read=io.bytes_read, num_seeks=io.num_seeks))
                    tr.finish(rspan.set(bytes_read=io.bytes_read,
                                        num_seeks=io.num_seeks))
            account_emitted(res)
            if tr.enabled:
                # one summary span for the query's batched-visibility work:
                # the per-query half of the kernel-launch baseline
                tr.finish(tr.start(
                    "kernel.dot_seen", parent=qspan.context(),
                    launches=res.stats.kernel_launches,
                    rows=res.stats.kernel_rows))
                qspan.set(elements=res.stats.elements_emitted,
                          bytes_read=res.stats.bytes_read)
        if session is not None:
            session.observe_query(plan, res)
        return res

    def _executors(self, actors) -> List[QueryExecutor]:
        return [QueryExecutor(self.vnodes[a]) for a in actors]

    def _repair(self, set_name: bytes, element: bytes, dots, per_stream,
                clocks, actors) -> None:
        """Replay surviving element-keys to quorum replicas missing them.

        The replayed delta carries the stored value, fetched from a replica
        that holds the key (element-keys are immutable payload under CRDT
        liveness, so any holder's copy is authoritative).
        """
        from ..core.bigset import element_key

        tr = self.tracer
        rspan = None  # opened lazily: only an actual replay deserves a span
        sent = False
        replayed = 0
        for dot in dots:
            targets = [
                a for i, a in enumerate(actors)
                if dot not in (per_stream[i] or frozenset())
                and not clocks[i].seen(dot)
            ]
            if not targets:
                # everyone already has it: the common case is free
                self.scheduler.record_repair_miss(set_name)
                continue
            donors = [
                a for i, a in enumerate(actors)
                if per_stream[i] is not None and dot in per_stream[i]
            ]
            value: Optional[bytes] = None
            src = None
            for donor in donors:
                v = self.vnodes[donor].store.get(
                    element_key(set_name, element, dot))
                if v is not None:
                    value, src = v, donor
                    break
            if value is None:
                # no replica can supply the payload (the stream head
                # outlived its key, or the donor raced a compaction):
                # shipping a fabricated b"" would poison downstream index
                # postings, so skip the dot and let scheduled anti-entropy
                # replay it with its real value
                self.scheduler.record_no_donor(set_name)
                continue
            if rspan is None and tr.enabled:
                rspan = tr.start("query.read_repair", set_name=set_name,
                                 element=element)
            for a in targets:
                delta = InsertDelta(set_name, element, dot, value=value)
                payload = (TracedPayload(rspan.context(), delta)
                           if rspan is not None else delta)
                self.net.send(src, a, payload, delta.size_bytes())
                self.scheduler.record_repair_hit(set_name, a, src)
                sent = True
                replayed += 1
        if rspan is not None:
            tr.finish(rspan.set(replayed=replayed))
        if sent and self.sync:
            self.net.deliver_all(self._handle)

    def _q_membership(self, plan, actors, repair) -> QueryResult:
        probes = [ex.execute(plan) for ex in self._executors(actors)]
        clocks = [p.clock for p in probes]
        res_stats = QueryStats(
            keys_scanned=sum(p.stats.keys_scanned for p in probes),
            batches=sum(p.stats.batches for p in probes),
            keys_probed=sum(p.stats.keys_probed for p in probes))
        per_stream = [
            frozenset(p.entries[0][1]) if p.present else None for p in probes
        ]
        present, dots = quorum_is_member(list(zip(clocks, per_stream)))
        res = QueryResult(clock=Clock.zero(), stats=res_stats)
        for c in clocks:
            res.clock = res.clock.join(c)
        res.present = present
        if present:
            res.entries = [(plan.element, dots)]
            if repair:
                self._repair(plan.set_name, plan.element, dots, per_stream,
                             clocks, actors)
        return res

    def _quorum_stream(self, set_name, actors, start, end, after, repair,
                       stats: Optional[QueryStats] = None) -> "_QuorumStream":
        streams = [
            ex.entry_stream(set_name, start=start, end=end, after=after,
                            stats=stats)
            for ex in self._executors(actors)
        ]
        clocks = [self.vnodes[a].read_clock(set_name) for a in actors]
        repair_fn = (
            (lambda el, dots, per: self._repair(
                set_name, el, dots, per, clocks, actors))
            if repair else None)
        return _QuorumStream(streams, clocks, repair_fn)

    def _q_range(self, set_name, start, end, limit, cursor, scope, actors,
                 repair) -> QueryResult:
        resume_start, after = query_cursor.resume_point(cursor, scope)
        if resume_start is not None:
            start = resume_start
        res = QueryResult()
        merged = self._quorum_stream(set_name, actors, start, end, after,
                                     repair, stats=res.stats)
        res.clock = merged.clock
        collect_page(stream_entries(merged), limit, scope, res)
        return res

    def _q_count(self, plan, actors, repair) -> QueryResult:
        res = QueryResult()
        merged = self._quorum_stream(
            plan.set_name, actors, plan.start, plan.end, None, repair,
            stats=res.stats)
        res.clock = merged.clock
        n = 0
        while merged.advance() is not None:
            n += 1
        res.count = n
        return res

    def _q_index(self, plan, actors, repair) -> QueryResult:
        """Quorum-merged index query.

        Each replica contributes its visible posting-group stream; the merge
        is the same streaming ORSWOT rule as element ranges, keyed by
        ``(index_key, element)``.  A replica missing a surviving element
        gets the element-key delta replayed (read repair) — downstream
        ``replica_insert`` re-derives the postings from the delta, so index
        repair is the ordinary write path, not a second protocol.
        """
        scope = query_plan.cursor_scope(plan)
        start, end = query_plan.index_span(plan)
        at, after = index_resume_point(plan.cursor, scope)
        res = QueryResult(index_entries=[])
        if isinstance(plan, query_plan.IndexLookup):
            # one probe per replica, matching the quorum membership path
            res.stats.keys_probed += len(actors)
        streams = [
            ex.index_stream(plan.set_name, plan.index, start=start, end=end,
                            at=at, after=after, stats=res.stats)
            for ex in self._executors(actors)
        ]
        clocks = [self.vnodes[a].read_clock(plan.set_name) for a in actors]
        repair_fn = (
            (lambda pos, dots, per: self._repair(
                plan.set_name, pos[1], dots, per, clocks, actors))
            if repair else None)

        def absent_fn(i, pos):
            ds = self.vnodes[actors[i]].is_member(plan.set_name, pos[1])[1]
            return frozenset(ds) if ds else None

        merged = _QuorumStream(streams, clocks, repair_fn, absent_fn)
        res.clock = merged.clock
        collect_index_page(merged, plan.limit, scope, res)
        return res

    def _q_join(self, plan, actors, repair) -> QueryResult:
        """Quorum-merged cross-set join, strategy chosen by the planner.

        Statistics aggregate each side's element range across the quorum's
        stores (the skew ratio is what the cost model compares).  A gallop
        drives the smaller side's quorum stream and probes the larger side
        replica-by-replica through the same ORSWOT merge rule — probed
        elements still get read repair, so galloping trades only the
        *incidental* repair of skipped non-matches, never correctness.
        """
        scope = query_plan.cursor_scope(plan)
        start, after = query_cursor.resume_point(plan.cursor, scope)
        res = QueryResult()
        stores = [self.vnodes[a].store for a in actors]
        choice = choose_join(
            plan.kind,
            quorum_side_stats(stores, plan.left),
            quorum_side_stats(stores, plan.right),
            forced=plan.strategy)
        res.stats.strategy = choice.strategy
        if choice.strategy == GALLOP:
            drive_name, probe_name = (
                (plan.left, plan.right) if choice.drive == "left"
                else (plan.right, plan.left))
            drive = self._quorum_stream(drive_name, actors, start, None,
                                        after, repair, stats=res.stats)
            probe, probe_clock = self._quorum_probe(
                probe_name, actors, repair, res.stats)
            res.clock = drive.clock.join(probe_clock)
            entries = gallop_join(plan.kind, drive, probe, choice.drive)
        else:
            left = self._quorum_stream(plan.left, actors, start, None, after,
                                       repair, stats=res.stats)
            right = self._quorum_stream(plan.right, actors, start, None,
                                        after, repair, stats=res.stats)
            res.clock = left.clock.join(right.clock)
            entries = zipper_join(plan.kind, left, right)
        collect_page(entries, plan.limit, scope, res)
        return res

    def _quorum_probe(self, set_name, actors, repair, stats: QueryStats):
        """Quorum point probe for gallop joins: (probe_fn, joined clock).

        Probes every quorum replica for one element (a bounded seek each),
        merges the surviving dots with the same optimized-OR-set rule the
        streaming merge uses, and read-repairs replicas missing a
        surviving dot — the membership path's semantics, packaged as the
        gallop join's larger-side primitive.
        """
        clocks = [self.vnodes[a].read_clock(set_name) for a in actors]
        probes = [
            ex.element_probe(set_name, stats) for ex in self._executors(actors)
        ]
        clock = Clock.zero()
        for c in clocks:
            clock = clock.join(c)

        def probe(element):
            per_stream = [
                frozenset(ds) if ds else None
                for ds in (p(element) for p in probes)
            ]
            dots = merge_entry(per_stream, clocks)
            if not dots:
                return None
            if repair:
                self._repair(set_name, element, dots, per_stream, clocks,
                             actors)
            return tuple(sorted(dots))

        return probe, clock

    # -------------------------------------------------------- anti-entropy
    def tick(self, budget: Optional[int] = None) -> int:
        """Run one scheduler beat: pump scheduled sync rounds through the
        network.

        Each round is a bidirectional pull for one (set, replica pair) —
        hottest repair-fed pairs first, then the round-robin baseline.
        Every message (request, reply) rides ``self.net``, so drop/dup/
        reorder semantics apply to anti-entropy exactly as to replication;
        a lost reply simply leaves the pair divergent for a later tick.
        Returns the number of rounds started.
        """
        rounds = self.scheduler.next_rounds(budget)
        tr = self.tracer
        started = 0
        for set_name, a, b in rounds:
            if a in self.crashed or b in self.crashed:
                # a dead member can neither pull nor answer; the scheduler
                # keeps the pair queued for a post-restart tick
                self.scheduler.stats.rounds_crashed += 1
                continue
            with tr.span("ae.round", set_name=set_name, pair=[a, b]):
                self._ae_pull(a, b, set_name)
                self._ae_pull(b, a, set_name)
            self.scheduler.stats.rounds += 1
            started += 1
        if self.sync:
            self.settle()
        return started

    def _ae_pull(self, dst: str, src: str, set_name: bytes) -> None:
        """``dst`` pulls ``set_name`` from ``src``: request and reply are
        separate network messages (each can drop, duplicate, reorder).

        The request snapshots ``dst``'s digest at send time; the reply is
        built against ``src``'s state at *delivery* time — the same
        at-least-once world replication lives in, which is why
        ``apply_digest_reply`` is idempotent.
        """
        stats = self.scheduler.stats
        tr = self.tracer
        pull_span = (tr.start("ae.pull", set_name=set_name, dst=dst, src=src)
                     if tr.enabled else None)
        vn = self.vnodes[dst]
        req = SyncRequest(set_name, vn.read_clock(set_name),
                          survivors_digest(vn, set_name))
        stats.pulls += 1
        stats.digest_bytes += req.size_bytes()

        def handle_request(src_vn: BigsetVnode) -> None:
            reply = build_digest_reply(
                src_vn, req.set_name, req.clock, req.survivors)
            stats.keys_scanned += reply.keys_scanned
            stats.digest_bytes += reply.digest_bytes()
            stats.payload_bytes += reply.payload_bytes()
            if reply.skipped:
                stats.rounds_skipped += 1
            else:
                stats.rounds_synced += 1
                stats.keys_shipped += len(reply.missing)

            def handle_reply(dst_vn: BigsetVnode) -> None:
                apply_digest_reply(dst_vn, reply)

            reply_payload = (
                TracedPayload(pull_span.context(), handle_reply)
                if pull_span is not None else handle_reply)
            self.net.send(src, dst, reply_payload, reply.size_bytes())

        req_payload = (TracedPayload(pull_span.context(), handle_request)
                       if pull_span is not None else handle_request)
        self.net.send(dst, src, req_payload, req.size_bytes())
        if pull_span is not None:
            # the pull itself is async: the span closes at send time and
            # the request/reply deliveries attach to it by carried context
            tr.finish(pull_span)

    def ae_stats(self) -> AntiEntropyStats:
        """Scheduled anti-entropy cost ledger (sits next to io_stats())."""
        return self.scheduler.stats

    def compact_all(self) -> None:
        for vn in self.vnodes.values():
            vn.compact()

    def io_stats(self):
        from ..storage.lsm import IoStats
        agg = IoStats()
        for vn in self.vnodes.values():
            for k in vars(agg):
                setattr(agg, k, getattr(agg, k) + getattr(vn.store.stats, k))
        return agg


class _QuorumStream:
    """Streaming quorum merge of per-replica visible entry streams.

    Presents the same head/advance/seek_to surface as the executor's
    per-vnode entry stream, so joins compose over quorum-merged sides.
    Memory is bounded: one head entry per replica.  Surviving dots follow
    the optimized-OR-set rule of :func:`repro.core.streaming.merge_entry`;
    per-element per-replica attribution is handed to ``repair_fn`` so the
    cluster can replay missing element-keys (read repair).
    """

    def __init__(self, streams, clocks, repair_fn=None, absent_fn=None):
        self._streams = streams
        self.clocks = clocks
        self._repair = repair_fn
        self._absent = absent_fn
        self.clock = Clock.zero()
        for c in clocks:
            self.clock = self.clock.join(c)
        self.head: Optional[Tuple[bytes, Tuple[Dot, ...]]] = None
        self._pump()

    def advance(self) -> Optional[Tuple[bytes, Tuple[Dot, ...]]]:
        h = self.head
        self._pump()
        return h

    def seek_to(self, element: bytes) -> None:
        if self.head is not None and self.head[0] >= element:
            return
        for s in self._streams:
            s.seek_to(element)
        self._pump()

    def _pump(self) -> None:
        """Advance to the next element that survives the quorum merge."""
        while True:
            heads = [s.head for s in self._streams]
            live = [h[0] for h in heads if h is not None]
            if not live:
                self.head = None
                return
            el = min(live)
            per_stream: List[Optional[frozenset]] = [None] * len(heads)
            for i, s in enumerate(self._streams):
                if s.head is not None and s.head[0] == el:
                    per_stream[i] = frozenset(s.advance()[1])
                elif self._absent is not None:
                    # index streams are ordered by (index_key, element): a
                    # replica absent from THIS posting group may still hold
                    # the element under another index key, so its surviving
                    # dots must join the merge or concurrent dots it has
                    # seen would be wrongly killed (element streams never
                    # need this — absence there means no surviving dots)
                    per_stream[i] = self._absent(i, el)
            dots = merge_entry(per_stream, self.clocks)
            if dots and self._repair is not None:
                self._repair(el, dots, per_stream)
            if dots:
                self.head = (el, tuple(sorted(dots)))
                return
