"""Three replicated-set clusters: Riak full-state, delta-replication, bigset.

These are the paper's three contenders (Figure 1).  All share the same
topology (N replicas per set, coordinator-forwarding, downstream
replication) and the same storage substrate, so the only variable is the
representation + replication strategy — exactly the comparison the paper
makes.

* :class:`RiakSetCluster` — §2: the ORSWOT serialized as one blob in a
  riak-object; every write reads + rewrites the blob; replication ships the
  full state; downstream merge on version-vector conflict.
* :class:`DeltaCluster` — §3: delta mutators ship small deltas, but the
  downstream replica still read-merge-writes the full blob.
* :class:`BigsetCluster` — §4: decomposed keys, clock-only writes,
  element-key deltas, dot-seen downstream apply.
"""
from __future__ import annotations

import msgpack
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from ..core.bigset import BigsetVnode, InsertDelta, RemoveDelta
from ..core.clock import Clock
from ..core.delta_orswot import delta_add, delta_remove, join_delta
from ..core.dots import Dot
from ..core.orswot import Orswot
from ..core.streaming import quorum_read
from ..storage.lsm import LsmStore
from .sim import Message, Network


# --------------------------------------------------------------- orswot codec
def orswot_to_bytes(s: Orswot) -> bytes:
    return msgpack.packb(
        {
            "b": sorted(s.clock.base.items()),
            "c": sorted((a, sorted(x)) for a, x in s.clock.cloud.items()),
            "e": sorted(
                (e, sorted((d.actor, d.counter) for d in ds))
                for e, ds in s.entries.items()
            ),
        }
    )


def orswot_from_bytes(b: Optional[bytes]) -> Orswot:
    if b is None:
        return Orswot.new()
    o = msgpack.unpackb(b, strict_map_key=False)
    clock = Clock({a: n for a, n in o["b"]}, {a: frozenset(s) for a, s in o["c"]},
                  _normalise=False)
    entries = {
        e: frozenset(Dot(a, c) for a, c in ds) for e, ds in o["e"]
    }
    return Orswot(clock, entries)


class _ClusterBase:
    """Shared topology: ``n_replicas`` vnodes all replicating every set."""

    def __init__(self, n_replicas: int = 3, net: Optional[Network] = None,
                 sync: bool = True):
        self.n = n_replicas
        self.net = net or Network()
        self.sync = sync  # deliver replication traffic immediately
        self.actors = [f"vnode{i}" for i in range(n_replicas)]

    def _replicate(self, src: str, payload, size: int) -> None:
        for a in self.actors:
            if a != src:
                self.net.send(src, a, payload, size)
        if self.sync:
            self.net.deliver_all(self._handle)

    def settle(self) -> None:
        self.net.deliver_all(self._handle)

    def _handle(self, msg: Message) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    def io_stats(self):
        raise NotImplementedError


class RiakSetCluster(_ClusterBase):
    """Full-state ORSWOT-in-a-blob (Riak Sets, §2)."""

    def __init__(self, n_replicas: int = 3, net: Optional[Network] = None,
                 sync: bool = True):
        super().__init__(n_replicas, net, sync)
        self.stores: Dict[str, LsmStore] = {a: LsmStore() for a in self.actors}

    def _key(self, set_name: bytes) -> bytes:
        return b"riak_set/" + set_name

    def _load(self, actor: str, set_name: bytes) -> Orswot:
        return orswot_from_bytes(self.stores[actor].get(self._key(set_name)))

    def _save(self, actor: str, set_name: bytes, s: Orswot) -> bytes:
        blob = orswot_to_bytes(s)
        self.stores[actor].put(self._key(set_name), blob)
        return blob

    def add(self, set_name: bytes, element: bytes, coordinator: int = 0) -> None:
        actor = self.actors[coordinator]
        s = self._load(actor, set_name)           # read whole set — O(n)
        s = s.add(actor, element)
        blob = self._save(actor, set_name, s)     # write whole set — O(n)
        self._replicate(actor, ("state", set_name, blob), len(blob))

    def remove(self, set_name: bytes, element: bytes, coordinator: int = 0) -> None:
        actor = self.actors[coordinator]
        s = self._load(actor, set_name)
        ctx = s.context_of(element)
        s = s.remove(element, ctx)
        blob = self._save(actor, set_name, s)
        self._replicate(actor, ("state", set_name, blob), len(blob))

    def _handle(self, msg: Message) -> None:
        _, set_name, blob = msg.payload
        local = self._load(msg.dst, set_name)      # read whole set
        incoming = orswot_from_bytes(blob)
        if incoming.clock.descends(local.clock):
            merged = incoming                      # supersedes: store directly
        else:
            merged = local.merge(incoming)         # conflict: full merge
        self._save(msg.dst, set_name, merged)      # write whole set

    def read(self, set_name: bytes, r: int = 1) -> Orswot:
        acc = self._load(self.actors[0], set_name)
        for a in self.actors[1:r]:
            acc = acc.merge(self._load(a, set_name))
        return acc

    def value(self, set_name: bytes, r: int = 1):
        return self.read(set_name, r).value()

    def io_stats(self):
        from ..storage.lsm import IoStats
        agg = IoStats()
        for st in self.stores.values():
            for k in vars(agg):
                setattr(agg, k, getattr(agg, k) + getattr(st.stats, k))
        return agg


class DeltaCluster(RiakSetCluster):
    """Delta-replication ORSWOT (§3): small wire deltas, full-state disk IO."""

    def add(self, set_name: bytes, element: bytes, coordinator: int = 0) -> None:
        actor = self.actors[coordinator]
        s = self._load(actor, set_name)            # still reads whole set
        s, delta = delta_add(s, actor, element)
        self._save(actor, set_name, s)             # still writes whole set
        dblob = orswot_to_bytes(delta)
        self._replicate(actor, ("delta", set_name, dblob), len(dblob))

    def remove(self, set_name: bytes, element: bytes, coordinator: int = 0) -> None:
        actor = self.actors[coordinator]
        s = self._load(actor, set_name)
        ctx = s.context_of(element)
        s, delta = delta_remove(s, element, ctx)
        self._save(actor, set_name, s)
        dblob = orswot_to_bytes(delta)
        self._replicate(actor, ("delta", set_name, dblob), len(dblob))

    def _handle(self, msg: Message) -> None:
        _, set_name, dblob = msg.payload
        local = self._load(msg.dst, set_name)      # read whole set
        delta = orswot_from_bytes(dblob)
        merged = join_delta(local, delta)          # merge ALWAYS (§3)
        self._save(msg.dst, set_name, merged)      # write whole set


class BigsetCluster(_ClusterBase):
    """Decomposed bigset cluster (§4)."""

    def __init__(self, n_replicas: int = 3, net: Optional[Network] = None,
                 sync: bool = True):
        super().__init__(n_replicas, net, sync)
        self.vnodes: Dict[str, BigsetVnode] = {
            a: BigsetVnode(a) for a in self.actors
        }

    def add(self, set_name: bytes, element: bytes, coordinator: int = 0,
            ctx: Iterable[Dot] = ()) -> None:
        actor = self.actors[coordinator]
        delta = self.vnodes[actor].coordinate_insert(set_name, element, ctx)
        self._replicate(actor, delta, delta.size_bytes())

    def remove(self, set_name: bytes, element: bytes, coordinator: int = 0,
               ctx: Optional[Iterable[Dot]] = None) -> None:
        """Observed-remove: ctx defaults to a local membership probe (§4.3.2
        — "the client **must** provide a context for a remove")."""
        actor = self.actors[coordinator]
        vn = self.vnodes[actor]
        if ctx is None:
            _, ctx = vn.is_member(set_name, element)
        ctx = tuple(ctx)
        if not ctx:
            return
        delta = vn.coordinate_remove(set_name, ctx)
        self._replicate(actor, delta, delta.size_bytes())

    def _handle(self, msg: Message) -> None:
        vn = self.vnodes[msg.dst]
        if isinstance(msg.payload, InsertDelta):
            vn.replica_insert(msg.payload)
        elif isinstance(msg.payload, RemoveDelta):
            vn.replica_remove(msg.payload)
        else:  # anti-entropy and membership traffic uses callables
            msg.payload(vn)

    def read(self, set_name: bytes, r: int = 1) -> Orswot:
        streams = []
        for a in self.actors[:r]:
            rs = self.vnodes[a].read(set_name)
            streams.append((rs.clock, rs.entries()))
        return quorum_read(streams)

    def value(self, set_name: bytes, r: int = 1):
        return self.read(set_name, r).value()

    def compact_all(self) -> None:
        for vn in self.vnodes.values():
            vn.compact()

    def io_stats(self):
        from ..storage.lsm import IoStats
        agg = IoStats()
        for vn in self.vnodes.values():
            for k in vars(agg):
                setattr(agg, k, getattr(agg, k) + getattr(vn.store.stats, k))
        return agg
