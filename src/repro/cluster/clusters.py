"""Three replicated-set clusters: Riak full-state, delta-replication, bigset.

These are the paper's three contenders (Figure 1).  All share the same
topology (N replicas per set, coordinator-forwarding, downstream
replication) and the same storage substrate, so the only variable is the
representation + replication strategy — exactly the comparison the paper
makes.

* :class:`RiakSetCluster` — §2: the ORSWOT serialized as one blob in a
  riak-object; every write reads + rewrites the blob; replication ships the
  full state; downstream merge on version-vector conflict.
* :class:`DeltaCluster` — §3: delta mutators ship small deltas, but the
  downstream replica still read-merge-writes the full blob.
* :class:`BigsetCluster` — §4: decomposed keys, clock-only writes,
  element-key deltas, dot-seen downstream apply.
"""
from __future__ import annotations

import msgpack
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from ..core.bigset import BigsetVnode, InsertDelta, RemoveDelta
from ..core.clock import Clock
from ..core.delta_orswot import delta_add, delta_remove, join_delta
from ..core.dots import Dot
from ..core.orswot import Orswot
from ..core.streaming import merge_entry, quorum_is_member, quorum_read
from ..index.spec import IndexSpec
from ..obs.trace import NULL_TRACER, TraceContext, Tracer
from ..query import cursor as query_cursor
from ..query import plan as query_plan
from ..query.executor import (QueryExecutor, QueryResult, QueryStats,
                              account_emitted, collect_index_page,
                              collect_page, gallop_join, index_resume_point,
                              stream_entries, zipper_join)
from ..query.planner import (GALLOP, SideStats, choose_join, side_stats,
                             quorum_side_stats)
from ..storage.lsm import LsmStore
from ..storage.wal import DurableMedia, RecoveryResult
from .antientropy import (AntiEntropyScheduler, AntiEntropyStats,
                          HandoffTask, RetireTask, SyncRequest,
                          apply_digest_reply, build_digest_reply,
                          handoff_complete, survivors_digest)
from .placement import (CoveragePlan, PreferenceList, Ring, RingDelta,
                        VnodeDown, plan_coverage)
from .sim import Message, Network

__all__ = [
    "BigsetCluster", "ClusterSession", "DeltaCluster", "RiakSetCluster",
    "Ring", "VnodeDown",
]


# ------------------------------------------------------------ serve sessions
class ClusterSession:
    """Hook surface the serve layer attaches to cluster entry points.

    A session observes — never alters — what its requests cost: the service
    (:mod:`repro.serve.bigset_service`) feeds its byte-budget admission
    control from ``observe_query`` (per-page :class:`~repro.query.executor.
    QueryStats`, themselves fed from storage IoStats) and its write
    accounting from ``observe_mutation`` (delta sizes).  The default
    implementation is a no-op so library callers pay nothing.
    """

    def observe_query(self, plan, result: "QueryResult") -> None:
        pass

    def observe_mutation(self, delta) -> None:
        pass


# ------------------------------------------------------------ traced payloads
@dataclass(frozen=True)
class TracedPayload:
    """A network payload carrying its sender's :class:`TraceContext`.

    Only minted when tracing is **enabled** — disabled clusters ship the
    raw payload object, byte-identical to untraced operation (asserted in
    ``tests/test_obs.py``).  The context names a span that was finished
    *before* the message entered the network, so however delivery goes
    (dropped, duplicated, reordered), a delivered message's ``net.deliver``
    span always parents under a span that exists: drops lose leaves,
    never tree integrity.
    """

    ctx: TraceContext
    payload: Any


# --------------------------------------------------------------- orswot codec
def orswot_to_bytes(s: Orswot) -> bytes:
    """Run-length orswot codec: the clock ships as interval runs."""
    obj = s.clock.to_obj()
    obj["e"] = sorted(
        (e, sorted((d.actor, d.counter) for d in ds))
        for e, ds in s.entries.items()
    )
    return msgpack.packb(obj)


def orswot_from_bytes(b: Optional[bytes]) -> Orswot:
    """Decode an orswot blob — run-length or legacy per-dot clock form."""
    if b is None:
        return Orswot.new()
    o = msgpack.unpackb(b, strict_map_key=False)
    clock = Clock.from_obj(o)
    entries = {
        e: frozenset(Dot(a, c) for a, c in ds) for e, ds in o["e"]
    }
    return Orswot(clock, entries)


class _ClusterBase:
    """Shared topology: ``n_replicas`` vnodes all replicating every set."""

    def __init__(self, n_replicas: int = 3, net: Optional[Network] = None,
                 sync: bool = True):
        self.n = n_replicas
        self.net = net or Network()
        self.sync = sync  # deliver replication traffic immediately
        self.actors = [f"vnode{i}" for i in range(n_replicas)]

    def _replicate(self, src: str, payload, size: int) -> None:
        for a in self.actors:
            if a != src:
                self.net.send(src, a, payload, size)
        if self.sync:
            self.net.deliver_all(self._handle)

    def settle(self) -> None:
        self.net.deliver_all(self._handle)

    def _handle(self, msg: Message) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    def io_stats(self):
        raise NotImplementedError


class RiakSetCluster(_ClusterBase):
    """Full-state ORSWOT-in-a-blob (Riak Sets, §2)."""

    def __init__(self, n_replicas: int = 3, net: Optional[Network] = None,
                 sync: bool = True):
        super().__init__(n_replicas, net, sync)
        self.stores: Dict[str, LsmStore] = {a: LsmStore() for a in self.actors}

    def _key(self, set_name: bytes) -> bytes:
        return b"riak_set/" + set_name

    def _load(self, actor: str, set_name: bytes) -> Orswot:
        return orswot_from_bytes(self.stores[actor].get(self._key(set_name)))

    def _save(self, actor: str, set_name: bytes, s: Orswot) -> bytes:
        blob = orswot_to_bytes(s)
        self.stores[actor].put(self._key(set_name), blob)
        return blob

    def add(self, set_name: bytes, element: bytes, coordinator: int = 0) -> None:
        actor = self.actors[coordinator]
        s = self._load(actor, set_name)           # read whole set — O(n)
        s = s.add(actor, element)
        blob = self._save(actor, set_name, s)     # write whole set — O(n)
        self._replicate(actor, ("state", set_name, blob), len(blob))

    def remove(self, set_name: bytes, element: bytes, coordinator: int = 0) -> None:
        actor = self.actors[coordinator]
        s = self._load(actor, set_name)
        ctx = s.context_of(element)
        s = s.remove(element, ctx)
        blob = self._save(actor, set_name, s)
        self._replicate(actor, ("state", set_name, blob), len(blob))

    def _handle(self, msg: Message) -> None:
        _, set_name, blob = msg.payload
        local = self._load(msg.dst, set_name)      # read whole set
        incoming = orswot_from_bytes(blob)
        if incoming.clock.descends(local.clock):
            merged = incoming                      # supersedes: store directly
        else:
            merged = local.merge(incoming)         # conflict: full merge
        self._save(msg.dst, set_name, merged)      # write whole set

    def read(self, set_name: bytes, r: int = 1) -> Orswot:
        acc = Orswot.new()
        for a in self.actors[:max(r, 1)]:
            acc = acc.merge(self._load(a, set_name))
        return acc

    def value(self, set_name: bytes, r: int = 1):
        return self.read(set_name, r).value()

    def io_stats(self):
        from ..storage.lsm import IoStats
        agg = IoStats()
        for st in self.stores.values():
            for k in vars(agg):
                setattr(agg, k, getattr(agg, k) + getattr(st.stats, k))
        return agg


class DeltaCluster(RiakSetCluster):
    """Delta-replication ORSWOT (§3): small wire deltas, full-state disk IO."""

    def add(self, set_name: bytes, element: bytes, coordinator: int = 0) -> None:
        actor = self.actors[coordinator]
        s = self._load(actor, set_name)            # still reads whole set
        s, delta = delta_add(s, actor, element)
        self._save(actor, set_name, s)             # still writes whole set
        dblob = orswot_to_bytes(delta)
        self._replicate(actor, ("delta", set_name, dblob), len(dblob))

    def remove(self, set_name: bytes, element: bytes, coordinator: int = 0) -> None:
        actor = self.actors[coordinator]
        s = self._load(actor, set_name)
        ctx = s.context_of(element)
        s, delta = delta_remove(s, element, ctx)
        self._save(actor, set_name, s)
        dblob = orswot_to_bytes(delta)
        self._replicate(actor, ("delta", set_name, dblob), len(dblob))

    def _handle(self, msg: Message) -> None:
        _, set_name, dblob = msg.payload
        local = self._load(msg.dst, set_name)      # read whole set
        delta = orswot_from_bytes(dblob)
        merged = join_delta(local, delta)          # merge ALWAYS (§3)
        self._save(msg.dst, set_name, merged)      # write whole set


class BigsetCluster(_ClusterBase):
    """Decomposed bigset cluster (§4).

    ``durable=True`` gives every vnode a :class:`DurableMedia`-backed
    store (WAL + group commit at ``group_depth``); :meth:`crash` /
    :meth:`restart` then model the ROADMAP's "node restarts under
    traffic" fault: a crash drops the vnode's in-memory state and its
    unsynced WAL tail, a restart replays the durable prefix and scheduled
    anti-entropy (:meth:`tick`) heals the rest from peers.
    """

    def __init__(self, n_replicas: int = 3, net: Optional[Network] = None,
                 sync: bool = True,
                 scheduler: Optional[AntiEntropyScheduler] = None,
                 tracer: Optional[Tracer] = None,
                 durable: bool = False, group_depth: int = 8,
                 media: Optional[Dict[str, DurableMedia]] = None,
                 ring: Optional[Ring] = None):
        super().__init__(n_replicas, net, sync)
        if ring is not None:
            # the ring names the cluster: its actors become the vnodes
            self.actors = list(ring.actors)
            self.n = len(self.actors)
        # degenerate default: one partition owned by everyone, storage
        # passthrough — byte-identical to the pre-partitioning cluster
        self.ring = ring if ring is not None else Ring.full(self.actors)
        self._rings: Dict[int, Ring] = {self.ring.epoch: self.ring}
        self._retired_epochs: Set[int] = set()
        # logical sets the write path has touched (handoff planning input)
        self._known_sets: Set[bytes] = set()
        # sloppy placement bookkeeping: (pset, fallback, owner) -> hint
        self._hints: Dict[Tuple[bytes, str, str],
                          Tuple[bytes, bytes, int, str, str]] = {}
        self._handoffs: List[HandoffTask] = []
        self._retires: List[RetireTask] = []
        # (old_epoch, handoff tasks, retire tasks): the old ring stays
        # serveable for pinned cursors until its transition fully retires
        self._transitions: List[Tuple[int, List[HandoffTask],
                                      List[RetireTask]]] = []
        self.durable = durable or media is not None
        self.group_depth = group_depth
        if self.durable:
            self.media: Optional[Dict[str, DurableMedia]] = (
                media or {a: DurableMedia() for a in self.actors})
            self.vnodes: Dict[str, BigsetVnode] = {
                a: BigsetVnode(a, store=LsmStore(
                    media=self.media[a], group_depth=group_depth))
                for a in self.actors
            }
        else:
            self.media = None
            self.vnodes = {a: BigsetVnode(a) for a in self.actors}
        self.crashed: Set[str] = set()
        # index specs by (set, index name): a restarted vnode re-registers
        # them so downstream extractors keep running identically everywhere
        self._index_specs: Dict[bytes, Dict[bytes, IndexSpec]] = {}
        # read repair feeds this; tick() drains it (see antientropy module)
        self.scheduler = scheduler or AntiEntropyScheduler(self.actors)
        # observability: NULL_TRACER by default — disabled tracing wraps no
        # payloads and records no spans (zero behavior change, invariant 10)
        self.tracer = tracer or NULL_TRACER

    # ---------------------------------------------------------- ring access
    def ring_for(self, epoch: Optional[int]) -> Ring:
        """The ring at ``epoch``, or the current ring when ``epoch`` is
        None, unknown, or already retired (handoff moved its data away).

        Cursor leases pin the epoch their plan ran under; falling forward
        to the current ring is safe because cursors are element
        boundaries — placement-agnostic — so a resumed page re-plans
        coverage under the live ring and continues from the same element.
        """
        if epoch is None or epoch in self._retired_epochs:
            return self.ring
        return self._rings.get(epoch, self.ring)

    def ring_state(self) -> Dict[str, object]:
        """Ring observability snapshot (the serve layer's ``stats`` op)."""
        return {
            "epoch": self.ring.epoch,
            "factor": self.ring.factor,
            "n_partitions": self.ring.n_partitions,
            "actors": list(self.ring.actors),
            "full_replication": self.ring.full_replication,
            "serveable_epochs": sorted(
                e for e in self._rings if e not in self._retired_epochs),
            "handoffs_pending": sum(1 for t in self._handoffs if not t.done),
            "retires_pending": sum(1 for t in self._retires if not t.done),
            "hints_pending": len(self._hints),
        }

    def _note_set(self, set_name: bytes, pref: PreferenceList,
                  pset: bytes) -> None:
        self._known_sets.add(set_name)
        if self.ring.full_replication:
            self.scheduler.note_set(pset)
        else:
            self.scheduler.note_set(pset, owners=pref.owners)

    def _route_write(self, entry: str, set_name: bytes,
                     pref: PreferenceList) -> Tuple[str, List[str]]:
        """Owner-routed write placement for one partition.

        Returns ``(coordinator, replication targets)``.  The coordinator
        is the client's entry vnode when it owns the partition, else the
        first live owner (clients route by the shared ring, so this hop
        is placement math, not a billed message).  Targets are every
        owner — crashed ones included, their messages drop in the
        blackholed network exactly as before partitioning — plus one
        *sloppy* fallback per crashed owner, with a hint recorded so the
        fallback's copy is handed to the owner when it returns.
        """
        live = [a for a in pref.owners if a not in self.crashed]
        down = [a for a in pref.owners if a in self.crashed]
        targets = list(pref.owners)
        fallbacks = iter(
            a for a in pref.fallbacks
            if a not in self.crashed and a not in targets)
        sloppy: List[str] = []
        hinted: List[Tuple[str, str]] = []
        for owner in down:
            fb = next(fallbacks, None)
            if fb is None:
                break
            targets.append(fb)
            sloppy.append(fb)
            hinted.append((fb, owner))
        if (not self.ring.full_replication
                and len(live) + len(sloppy) < self.ring.write_quorum()):
            # invariant 13: acknowledged ⇒ durable on a write-quorum of
            # the preference list.  Too few live owners and no fallbacks
            # left to park hints on — refuse loudly rather than ack a
            # write that a single further failure could erase.
            raise VnodeDown(
                f"write quorum unreachable for partition {pref.pid} of "
                f"{set_name!r}: {len(live)} live of {pref.owners}, "
                f"{len(sloppy)} fallbacks", vnode=down[0], set_name=set_name)
        for fb, owner in hinted:
            self._record_hint(set_name, pref, fb, owner)
        if entry in live:
            coordinator = entry
        elif live:
            coordinator = live[0]
        elif sloppy:
            coordinator = sloppy[0]
        else:
            raise VnodeDown(
                f"no live owner or fallback for partition {pref.pid} of "
                f"{set_name!r} ({pref.owners} crashed)",
                vnode=pref.owners[0], set_name=set_name)
        return coordinator, targets

    def _record_hint(self, set_name: bytes, pref: PreferenceList,
                     fallback: str, owner: str) -> None:
        pset = self.ring.storage_set(set_name, pref.pid)
        key = (pset, fallback, owner)
        if key not in self._hints:
            self._hints[key] = (set_name, pset, pref.pid, fallback, owner)
            self.scheduler.stats.hints_recorded += 1

    def _replicate_to(self, src: str, targets: Iterable[str], payload,
                      size: int) -> None:
        for a in targets:
            if a != src:
                self.net.send(src, a, payload, size)
        if self.sync:
            self.net.deliver_all(self._handle)

    # ------------------------------------------------------- crash / restart
    def _actor(self, vnode) -> str:
        return self.actors[vnode] if isinstance(vnode, int) else vnode

    def _coordinator(self, coordinator: int,
                     set_name: Optional[bytes] = None) -> str:
        actor = self._actor(coordinator)
        if actor in self.crashed:
            raise VnodeDown(f"{actor} is crashed", vnode=actor,
                            set_name=set_name)
        return actor

    def crash(self, vnode) -> None:
        """Kill a vnode: memtable, digests, and the unsynced WAL tail are
        gone; the durable media survives for :meth:`restart`.  In-flight
        and future traffic to the vnode is dropped by the network."""
        if not self.durable:
            raise RuntimeError("crash() requires a durable cluster")
        actor = self._actor(vnode)
        if actor in self.crashed:
            return
        self.crashed.add(actor)
        self.vnodes.pop(actor, None)
        self.media[actor].crash()
        self.net.blackhole(actor)

    def restart(self, vnode) -> RecoveryResult:
        """Bring a crashed vnode back from its durable media.

        A fresh store replays manifested segments + the WAL's acknowledged
        prefix (``storage.recover`` span); the new vnode adopts it — its
        per-set digests rebuild from one background fold on first touch —
        and re-registers every known index spec without backfill (postings
        were durable alongside their element-keys).  The unacknowledged
        tail is *not* back: scheduled anti-entropy heals it from peers,
        dot-bounded.  Returns the replay's :class:`RecoveryResult`.
        """
        actor = self._actor(vnode)
        if actor not in self.crashed:
            raise RuntimeError(f"{actor} is not crashed")
        store = LsmStore(media=self.media[actor],
                         group_depth=self.group_depth)
        with self.tracer.span("storage.recover", actor=actor) as sp:
            rec = store.recover()
            sp.set(segments=rec.segments,
                   batches_replayed=rec.batches_replayed,
                   batches_skipped=rec.batches_skipped,
                   bytes_replayed=rec.bytes_replayed,
                   torn_bytes=rec.torn_bytes)
        vn = BigsetVnode(actor, store=store)
        for set_name, specs in self._index_specs.items():
            for pset in self.ring.storage_sets(set_name):
                for spec in specs.values():
                    vn.register_index(pset, spec, backfill=False)
        self.vnodes[actor] = vn
        self.net.heal(actor)
        self.crashed.discard(actor)
        return rec

    def sync_all(self) -> None:
        """Force the pending group commit on every live vnode — the write
        path's explicit acknowledgement barrier."""
        for vn in self.vnodes.values():
            vn.store.sync()

    def _traced(self, ctx_span, payload):
        """Wrap a payload with the span's context iff tracing is enabled."""
        if not self.tracer.enabled:
            return payload
        return TracedPayload(ctx_span.context(), payload)

    def add(self, set_name: bytes, element: bytes, coordinator: int = 0,
            ctx: Iterable[Dot] = (), value: bytes = b"",
            session: Optional[ClusterSession] = None) -> InsertDelta:
        """Coordinate an insert; returns the minted delta.

        The delta's ``dot`` is the insert's causal identity — the serve
        layer round-trips it to clients as the context for a later remove
        or replacing add.

        Routing: the element's partition names its preference list; the
        write coordinates at an owner (the requested vnode when it owns
        the partition) and replicates to the other owners — plus sloppy
        fallbacks, hint recorded, for any crashed owner.
        """
        entry = self._coordinator(coordinator, set_name)
        pref = self.ring.preference_list(set_name, element)
        pset = self.ring.storage_set(set_name, pref.pid)
        self._note_set(set_name, pref, pset)
        actor, targets = self._route_write(entry, set_name, pref)
        with self.tracer.span("cluster.insert", set_name=set_name,
                              actor=actor) as sp:
            delta = self.vnodes[actor].coordinate_insert(
                pset, element, ctx, value=value)
            self._replicate_to(actor, targets, self._traced(sp, delta),
                               delta.size_bytes())
        if session is not None:
            session.observe_mutation(delta)
        return delta

    def register_index(self, set_name: bytes, spec: IndexSpec,
                       backfill: bool = True) -> int:
        """Register a secondary index on every replica (extractors must run
        identically downstream — including on vnodes that only ever see a
        partition via sloppy placement or a later ring change, so the spec
        lands on every vnode for every partition of the set).  Returns
        total backfill postings written.  The spec is remembered so a
        restarted or newly joined vnode re-registers it."""
        self._index_specs.setdefault(set_name, {})[spec.name] = spec
        return sum(
            vn.register_index(pset, spec, backfill=backfill)
            for pset in self.ring.storage_sets(set_name)
            for vn in self.vnodes.values())

    def remove(self, set_name: bytes, element: bytes, coordinator: int = 0,
               ctx: Optional[Iterable[Dot]] = None,
               session: Optional[ClusterSession] = None
               ) -> Optional[RemoveDelta]:
        """Observed-remove: ctx defaults to a local membership probe (§4.3.2
        — "the client **must** provide a context for a remove").  Returns
        the shipped delta, or None when there was nothing to remove.

        Routed like :meth:`add`: the probe and the clock-only write both
        happen at an owner of the element's partition, so the context dots
        and the tombstone live in the same partition clock domain.
        """
        entry = self._coordinator(coordinator, set_name)
        pref = self.ring.preference_list(set_name, element)
        pset = self.ring.storage_set(set_name, pref.pid)
        self._note_set(set_name, pref, pset)
        actor, targets = self._route_write(entry, set_name, pref)
        vn = self.vnodes[actor]
        if ctx is None:
            _, ctx = vn.is_member(pset, element)
        ctx = tuple(ctx)
        if not ctx:
            return None
        with self.tracer.span("cluster.remove", set_name=set_name,
                              actor=actor) as sp:
            delta = vn.coordinate_remove(pset, ctx)
            self._replicate_to(actor, targets, self._traced(sp, delta),
                               delta.size_bytes())
        if session is not None:
            session.observe_mutation(delta)
        return delta

    def mutate(self, set_name: bytes, ops: Sequence[Tuple], coordinator: int = 0,
               session: Optional[ClusterSession] = None) -> List:
        """Batch mutation entry point (the serve layer's write path).

        ``ops`` is a sequence of ``("add", element[, value[, ctx]])`` and
        ``("remove", element[, ctx])`` tuples, applied in order through one
        coordinator so a remove can observe an earlier add in the same
        batch.  Returns the per-op deltas (None for no-op removes).
        """
        out: List = []
        for op in ops:
            kind, element = op[0], op[1]
            if kind == "add":
                value = op[2] if len(op) > 2 else b""
                ctx = op[3] if len(op) > 3 else ()
                out.append(self.add(set_name, element, coordinator, ctx=ctx,
                                    value=value, session=session))
            elif kind == "remove":
                ctx = op[2] if len(op) > 2 else None
                out.append(self.remove(set_name, element, coordinator,
                                       ctx=ctx, session=session))
            else:
                raise ValueError(f"unknown mutation op {kind!r}")
        return out

    def _handle(self, msg: Message) -> None:
        payload = msg.payload
        if isinstance(payload, TracedPayload):
            # the delivery span parents on the *sender's* span via the
            # carried context — correct under drop/dup/reorder, where the
            # call stack at delivery time says nothing about causality
            with self.tracer.span("net.deliver", parent=payload.ctx,
                                  src=msg.src, dst=msg.dst,
                                  size_bytes=msg.size_bytes):
                self._deliver(msg.dst, payload.payload)
        else:
            self._deliver(msg.dst, payload)

    def _deliver(self, dst: str, payload) -> None:
        vn = self.vnodes[dst]
        if isinstance(payload, InsertDelta):
            vn.replica_insert(payload)
        elif isinstance(payload, RemoveDelta):
            vn.replica_remove(payload)
        else:  # anti-entropy and membership traffic uses callables
            payload(vn)

    def read(self, set_name: bytes, r: int = 1) -> Orswot:
        if self.ring.full_replication:
            streams = []
            for a in self.actors[:r]:
                rs = self.vnodes[a].read(set_name)
                streams.append((rs.clock, rs.entries()))
            return quorum_read(streams)
        live = [a for a in self.actors if a not in self.crashed]
        cover = plan_coverage(self.ring, set_name, live, r)
        clock = Clock.zero()
        entries: Dict[bytes, frozenset] = {}
        for _pid, pset, actors in cover.assignments:
            streams = []
            for a in actors:
                rs = self.vnodes[a].read(pset)
                streams.append((rs.clock, rs.entries()))
            part = quorum_read(streams)
            # partitions have disjoint elements and independent clock
            # domains; the joined clock is a membership-only view, never a
            # causal context (each entry's dots stay partition-scoped)
            clock = clock.join(part.clock)
            entries.update(part.entries)
        return Orswot(clock, entries)

    def value(self, set_name: bytes, r: int = 1):
        return self.read(set_name, r).value()

    # -------------------------------------------------------------- queries
    def _covers(self, plan, ring: Ring, r: int) -> List[CoveragePlan]:
        """Coverage plans the query needs: one per logical set touched.

        Membership covers only the element's own partition; range-shaped
        plans cover every partition of the set; joins cover both sides.
        """
        live = [a for a in self.actors if a not in self.crashed]
        if isinstance(plan, query_plan.Membership):
            pid = ring.partition(plan.set_name, plan.element)
            return [plan_coverage(ring, plan.set_name, live, r, pids=[pid])]
        if isinstance(plan, query_plan.Join):
            return [plan_coverage(ring, plan.left, live, r),
                    plan_coverage(ring, plan.right, live, r)]
        return [plan_coverage(ring, plan.set_name, live, r)]

    @staticmethod
    def _cover_vnodes(covers: Sequence[CoveragePlan]) -> List[str]:
        """Union of covered vnodes, first-appearance order (meter order)."""
        seen: List[str] = []
        for cover in covers:
            for _pid, _pset, actors in cover.assignments:
                for a in actors:
                    if a not in seen:
                        seen.append(a)
        return seen

    def query(self, plan, r: Optional[int] = None, repair: bool = True,
              session: Optional[ClusterSession] = None,
              ring_epoch: Optional[int] = None) -> QueryResult:
        """Coverage-query path: plan a minimal covering set over the ring's
        partition owners, stream each partition through an ``r``-replica
        quorum merge, and read-repair stragglers.

        Each covered replica contributes a lazy visible-entry stream (a
        storage seek + bounded scan, §4.4) for each partition it owns; the
        per-partition merge is the streaming ORSWOT join of
        :mod:`repro.core.streaming` with per-replica dot attribution so any
        replica missing a surviving dot gets the element-key delta replayed
        to it (read repair) — anti-entropy rides on the query workload.
        Partition streams fan in by element order, so results are
        byte-identical to an unpartitioned cluster.  ``r`` defaults to a
        majority of the replication factor.  ``ring_epoch`` pins the ring a
        cursor's plan ran under (cursor leases); a retired epoch falls
        forward to the current ring — cursors are element boundaries, so
        they resume under any ring.  A ``session``
        (:class:`ClusterSession`) observes the result post-accounting — the
        serve layer's backpressure budget hangs off this hook.
        """
        query_plan.validate(plan)
        ring = self.ring_for(ring_epoch)
        if r is None:
            r = ring.write_quorum()
        # coverage planning routes around crashed replicas: a non-quorum
        # crash leaves reads fully available (restart-under-traffic)
        covers = self._covers(plan, ring, r)
        vnode_order = self._cover_vnodes(covers)
        tr = self.tracer
        with tr.span("cluster.query", plan=type(plan).__name__,
                     set_name=getattr(plan, "set_name", b""), r=r) as qspan:
            meters = [self.vnodes[a].store.meter() for a in vnode_order]
            # coverage sub-spans opened per covered replica BEFORE execution
            # (their storage children get the replica's IoStats delta after)
            rspans = ([tr.start("replica.coverage", parent=qspan.context(),
                                actor=a) for a in vnode_order]
                      if tr.enabled else None)
            if isinstance(plan, query_plan.Membership):
                res = self._q_membership(plan, covers[0], repair)
            elif isinstance(plan, query_plan.Range):
                res = self._q_range(
                    plan.start, plan.end, plan.limit,
                    plan.cursor, query_plan.cursor_scope(plan), covers[0],
                    repair)
            elif isinstance(plan, query_plan.Scan):
                res = self._q_range(
                    None, None, plan.page_size,
                    plan.cursor, query_plan.cursor_scope(plan), covers[0],
                    repair)
            elif isinstance(plan, query_plan.Count):
                res = self._q_count(plan, covers[0], repair)
            elif isinstance(plan, query_plan.Join):
                res = self._q_join(plan, ring, covers, repair)
            elif isinstance(plan,
                            (query_plan.IndexLookup, query_plan.IndexRange)):
                res = self._q_index(plan, covers[0], repair)
            else:  # pragma: no cover - validate() rejects
                raise query_plan.PlanError(type(plan).__name__)
            res.stats.coverage = (
                f"epoch={ring.epoch};"
                f"partitions={sum(len(c.assignments) for c in covers)};"
                f"vnodes={len(vnode_order)};r={r}")
            for i, m in enumerate(meters):
                io = m.delta()
                res.stats.bytes_read += io.bytes_read
                res.stats.num_seeks += io.num_seeks
                if rspans is not None:
                    rspan = rspans[i]
                    tr.finish(tr.start(
                        "storage.scan", parent=rspan.context(),
                        bytes_read=io.bytes_read, num_seeks=io.num_seeks))
                    tr.finish(rspan.set(bytes_read=io.bytes_read,
                                        num_seeks=io.num_seeks))
            account_emitted(res)
            if tr.enabled:
                # one summary span for the query's batched-visibility work:
                # the per-query half of the kernel-launch baseline
                tr.finish(tr.start(
                    "kernel.dot_seen", parent=qspan.context(),
                    launches=res.stats.kernel_launches,
                    rows=res.stats.kernel_rows))
                qspan.set(elements=res.stats.elements_emitted,
                          bytes_read=res.stats.bytes_read)
        if session is not None:
            session.observe_query(plan, res)
        return res

    def _executors(self, actors) -> List[QueryExecutor]:
        return [QueryExecutor(self.vnodes[a]) for a in actors]

    def _repair(self, set_name: bytes, element: bytes, dots, per_stream,
                clocks, actors) -> None:
        """Replay surviving element-keys to quorum replicas missing them.

        The replayed delta carries the stored value, fetched from a replica
        that holds the key (element-keys are immutable payload under CRDT
        liveness, so any holder's copy is authoritative).
        """
        from ..core.bigset import element_key

        tr = self.tracer
        rspan = None  # opened lazily: only an actual replay deserves a span
        sent = False
        replayed = 0
        for dot in dots:
            targets = [
                a for i, a in enumerate(actors)
                if dot not in (per_stream[i] or frozenset())
                and not clocks[i].seen(dot)
            ]
            if not targets:
                # everyone already has it: the common case is free
                self.scheduler.record_repair_miss(set_name)
                continue
            donors = [
                a for i, a in enumerate(actors)
                if per_stream[i] is not None and dot in per_stream[i]
            ]
            value: Optional[bytes] = None
            src = None
            for donor in donors:
                v = self.vnodes[donor].store.get(
                    element_key(set_name, element, dot))
                if v is not None:
                    value, src = v, donor
                    break
            if value is None:
                # no replica can supply the payload (the stream head
                # outlived its key, or the donor raced a compaction):
                # shipping a fabricated b"" would poison downstream index
                # postings, so skip the dot and let scheduled anti-entropy
                # replay it with its real value
                self.scheduler.record_no_donor(set_name)
                continue
            if rspan is None and tr.enabled:
                rspan = tr.start("query.read_repair", set_name=set_name,
                                 element=element)
            for a in targets:
                delta = InsertDelta(set_name, element, dot, value=value)
                payload = (TracedPayload(rspan.context(), delta)
                           if rspan is not None else delta)
                self.net.send(src, a, payload, delta.size_bytes())
                self.scheduler.record_repair_hit(set_name, a, src)
                sent = True
                replayed += 1
        if rspan is not None:
            tr.finish(rspan.set(replayed=replayed))
        if sent and self.sync:
            self.net.deliver_all(self._handle)

    def _q_membership(self, plan, cover: CoveragePlan, repair) -> QueryResult:
        # membership touches exactly one partition: the element's own
        _pid, pset, actors = cover.assignments[0]
        probe_plan = (plan if pset == plan.set_name else
                      query_plan.Membership(pset, plan.element))
        probes = [ex.execute(probe_plan) for ex in self._executors(actors)]
        clocks = [p.clock for p in probes]
        res_stats = QueryStats(
            keys_scanned=sum(p.stats.keys_scanned for p in probes),
            batches=sum(p.stats.batches for p in probes),
            keys_probed=sum(p.stats.keys_probed for p in probes))
        per_stream = [
            frozenset(p.entries[0][1]) if p.present else None for p in probes
        ]
        present, dots = quorum_is_member(list(zip(clocks, per_stream)))
        res = QueryResult(clock=Clock.zero(), stats=res_stats)
        for c in clocks:
            res.clock = res.clock.join(c)
        res.present = present
        if present:
            res.entries = [(plan.element, dots)]
            if repair:
                self._repair(pset, plan.element, dots, per_stream,
                             clocks, actors)
        return res

    def _fan_stream(self, cover: CoveragePlan, start, end, after, repair,
                    stats: QueryStats):
        """One element-ordered stream over every covered partition.

        A single partition (the full-replication ring) returns the
        partition's quorum stream directly — the exact pre-partitioning
        object graph.  Multiple partitions fan in by head element;
        partitions split elements disjointly, so the k-way merge needs no
        cross-stream dedup and each element's quorum merge still happens
        entirely inside its own partition clock domain.
        """
        streams = [
            self._quorum_stream(pset, actors, start, end, after, repair,
                                stats=stats)
            for _pid, pset, actors in cover.assignments
        ]
        if len(streams) == 1:
            return streams[0]
        return _FanInStream(streams)

    def _quorum_stream(self, set_name, actors, start, end, after, repair,
                       stats: Optional[QueryStats] = None) -> "_QuorumStream":
        streams = [
            ex.entry_stream(set_name, start=start, end=end, after=after,
                            stats=stats)
            for ex in self._executors(actors)
        ]
        clocks = [self.vnodes[a].read_clock(set_name) for a in actors]
        repair_fn = (
            (lambda el, dots, per: self._repair(
                set_name, el, dots, per, clocks, actors))
            if repair else None)
        return _QuorumStream(streams, clocks, repair_fn)

    def _q_range(self, start, end, limit, cursor, scope, cover, repair
                 ) -> QueryResult:
        resume_start, after = query_cursor.resume_point(cursor, scope)
        if resume_start is not None:
            start = resume_start
        res = QueryResult()
        merged = self._fan_stream(cover, start, end, after, repair,
                                  stats=res.stats)
        res.clock = merged.clock
        collect_page(stream_entries(merged), limit, scope, res)
        return res

    def _q_count(self, plan, cover, repair) -> QueryResult:
        res = QueryResult()
        merged = self._fan_stream(cover, plan.start, plan.end, None, repair,
                                  stats=res.stats)
        res.clock = merged.clock
        n = 0
        while merged.advance() is not None:
            n += 1
        res.count = n
        return res

    def _index_quorum_stream(self, plan, pset, actors, at, after, repair,
                             res: QueryResult) -> "_QuorumStream":
        start, end = query_plan.index_span(plan)
        streams = [
            ex.index_stream(pset, plan.index, start=start, end=end,
                            at=at, after=after, stats=res.stats)
            for ex in self._executors(actors)
        ]
        clocks = [self.vnodes[a].read_clock(pset) for a in actors]
        repair_fn = (
            (lambda pos, dots, per: self._repair(
                pset, pos[1], dots, per, clocks, actors))
            if repair else None)

        def absent_fn(i, pos):
            ds = self.vnodes[actors[i]].is_member(pset, pos[1])[1]
            return frozenset(ds) if ds else None

        return _QuorumStream(streams, clocks, repair_fn, absent_fn)

    def _q_index(self, plan, cover, repair) -> QueryResult:
        """Quorum-merged index query.

        Each covered replica contributes its partition's visible
        posting-group stream; the per-partition merge is the same
        streaming ORSWOT rule as element ranges, keyed by
        ``(index_key, element)``, and partitions fan in by that same key
        (postings scatter across partitions with their elements, so every
        partition must be covered — the index key says nothing about the
        element hash).  A replica missing a surviving element gets the
        element-key delta replayed (read repair) — downstream
        ``replica_insert`` re-derives the postings from the delta, so index
        repair is the ordinary write path, not a second protocol.
        """
        scope = query_plan.cursor_scope(plan)
        at, after = index_resume_point(plan.cursor, scope)
        res = QueryResult(index_entries=[])
        if isinstance(plan, query_plan.IndexLookup):
            # one probe per covered replica stream, matching the quorum
            # membership path
            res.stats.keys_probed += sum(
                len(actors) for _pid, _pset, actors in cover.assignments)
        streams = [
            self._index_quorum_stream(plan, pset, actors, at, after, repair,
                                      res)
            for _pid, pset, actors in cover.assignments
        ]
        merged = streams[0] if len(streams) == 1 else _FanInStream(streams)
        res.clock = merged.clock
        collect_index_page(merged, plan.limit, scope, res)
        return res

    def _cover_side_stats(self, cover: CoveragePlan) -> SideStats:
        """One join side's size across its covered partition replicas.

        Sums preserve the left:right skew ratio the cost model compares,
        exactly as :func:`~repro.query.planner.quorum_side_stats` did for
        full replication (of which this is the one-partition special
        case)."""
        keys = nbytes = 0
        for _pid, pset, actors in cover.assignments:
            for a in actors:
                s = side_stats(self.vnodes[a].store, pset)
                keys += s.keys
                nbytes += s.bytes
        return SideStats(keys=keys, bytes=nbytes)

    def _fan_probe(self, set_name: bytes, ring: Ring, cover: CoveragePlan,
                   repair, stats: QueryStats):
        """Partition-routed point probe for gallop joins.

        Builds one quorum probe per covered partition; ``probe(element)``
        routes to the element's partition, so each probe is the same
        bounded-seek quorum merge it was under full replication.  Returns
        ``(probe, joined clock)``.
        """
        by_pid = {}
        clock = Clock.zero()
        for pid, pset, actors in cover.assignments:
            fn, pclock = self._quorum_probe(pset, actors, repair, stats)
            by_pid[pid] = fn
            clock = clock.join(pclock)

        def probe(element):
            return by_pid[ring.partition(set_name, element)](element)

        return probe, clock

    def _q_join(self, plan, ring: Ring, covers, repair) -> QueryResult:
        """Quorum-merged cross-set join, strategy chosen by the planner.

        Statistics aggregate each side's element range across its covered
        partition replicas (the skew ratio is what the cost model
        compares).  A gallop drives the smaller side's fan-in stream and
        probes the larger side partition-by-partition through the same
        ORSWOT merge rule — probed elements still get read repair, so
        galloping trades only the *incidental* repair of skipped
        non-matches, never correctness.
        """
        cover_l, cover_r = covers
        scope = query_plan.cursor_scope(plan)
        start, after = query_cursor.resume_point(plan.cursor, scope)
        res = QueryResult()
        choice = choose_join(
            plan.kind,
            self._cover_side_stats(cover_l),
            self._cover_side_stats(cover_r),
            forced=plan.strategy)
        res.stats.strategy = choice.strategy
        if choice.strategy == GALLOP:
            drive_name, drive_cover, probe_name, probe_cover = (
                (plan.left, cover_l, plan.right, cover_r)
                if choice.drive == "left"
                else (plan.right, cover_r, plan.left, cover_l))
            drive = self._fan_stream(drive_cover, start, None, after, repair,
                                     stats=res.stats)
            if len(probe_cover.assignments) == 1:
                _pid, pset, actors = probe_cover.assignments[0]
                probe, probe_clock = self._quorum_probe(
                    pset, actors, repair, res.stats)
            else:
                probe, probe_clock = self._fan_probe(
                    probe_name, ring, probe_cover, repair, res.stats)
            res.clock = drive.clock.join(probe_clock)
            entries = gallop_join(plan.kind, drive, probe, choice.drive)
        else:
            left = self._fan_stream(cover_l, start, None, after, repair,
                                    stats=res.stats)
            right = self._fan_stream(cover_r, start, None, after, repair,
                                     stats=res.stats)
            res.clock = left.clock.join(right.clock)
            entries = zipper_join(plan.kind, left, right)
        collect_page(entries, plan.limit, scope, res)
        return res

    def _quorum_probe(self, set_name, actors, repair, stats: QueryStats):
        """Quorum point probe for gallop joins: (probe_fn, joined clock).

        Probes every quorum replica for one element (a bounded seek each),
        merges the surviving dots with the same optimized-OR-set rule the
        streaming merge uses, and read-repairs replicas missing a
        surviving dot — the membership path's semantics, packaged as the
        gallop join's larger-side primitive.
        """
        clocks = [self.vnodes[a].read_clock(set_name) for a in actors]
        probes = [
            ex.element_probe(set_name, stats) for ex in self._executors(actors)
        ]
        clock = Clock.zero()
        for c in clocks:
            clock = clock.join(c)

        def probe(element):
            per_stream = [
                frozenset(ds) if ds else None
                for ds in (p(element) for p in probes)
            ]
            dots = merge_entry(per_stream, clocks)
            if not dots:
                return None
            if repair:
                self._repair(set_name, element, dots, per_stream, clocks,
                             actors)
            return tuple(sorted(dots))

        return probe, clock

    # ------------------------------------------------------------- handoff
    def add_vnode(self, name: Optional[str] = None) -> RingDelta:
        """Join a vnode: mint the next ring epoch and schedule digest
        handoff.

        The returned :class:`RingDelta` names exactly the partitions whose
        ownership moved; each gets a :class:`HandoffTask` per gaining
        owner (digest-ladder pulls pumped by :meth:`tick`) and a
        :class:`RetireTask` per leaving owner (its copy deleted only after
        every gaining owner's clock dominates — invariant 13).  Unmoved
        partitions are untouched: no tasks, no folds, no wire bytes.  The
        old epoch stays serveable for pinned cursors until its transition
        fully retires.
        """
        name = name or f"vnode{len(self.actors)}"
        if name in self.actors:
            raise ValueError(f"{name} already in the ring")
        if self.durable:
            self.media[name] = DurableMedia()
            vn = BigsetVnode(name, store=LsmStore(
                media=self.media[name], group_depth=self.group_depth))
        else:
            vn = BigsetVnode(name)
        self.vnodes[name] = vn
        self.actors.append(name)
        self.n = len(self.actors)
        self.scheduler.actors.append(name)
        old = self.ring
        new = old.with_actors(self.actors)
        self.ring = new
        self._rings[new.epoch] = new
        delta = old.delta_to(new)
        # the newcomer runs every known extractor before any data arrives,
        # so handed-off element deltas derive postings identically
        for set_name, specs in self._index_specs.items():
            for pset in new.storage_sets(set_name):
                for spec in specs.values():
                    vn.register_index(pset, spec, backfill=False)
        handoffs: List[HandoffTask] = []
        retires: List[RetireTask] = []
        for move in delta.moves:
            donors = move.survivors() or move.old_owners
            for set_name in sorted(self._known_sets):
                pset = new.storage_set(set_name, move.pid)
                for dst in move.joined:
                    handoffs.append(HandoffTask(
                        set_name, pset, move.pid, dst=dst, src=donors[0]))
                for leaver in move.left:
                    retires.append(RetireTask(
                        set_name, pset, move.pid, leaver=leaver,
                        waits_on=move.joined or move.new_owners))
                if not new.full_replication:
                    # re-scope the sync baseline to the new preference list
                    self.scheduler.note_set(pset, owners=move.new_owners)
        self._handoffs.extend(handoffs)
        self._retires.extend(retires)
        self._transitions.append((old.epoch, handoffs, retires))
        return delta

    def _promote_hints(self) -> None:
        """Hinted handoff: when a crashed owner returns, its sloppy
        fallback becomes a handoff donor and its copy a retire candidate."""
        for key in list(self._hints):
            pset, fallback, owner = key
            if owner in self.crashed or fallback in self.crashed:
                continue
            set_name, _pset, pid, _fb, _ow = self._hints.pop(key)
            self._handoffs.append(HandoffTask(
                set_name, pset, pid, dst=owner, src=fallback))
            self.scheduler.stats.hints_resolved += 1
            self._add_fallback_retire(set_name, pset, pid, fallback, owner)

    def _add_fallback_retire(self, set_name: bytes, pset: bytes, pid: int,
                             fallback: str, owner: str) -> None:
        if fallback in self.ring.owners(pid):
            return  # became a real owner meanwhile: its copy is not surplus
        for rt in self._retires:
            if rt.pset == pset and rt.leaver == fallback and not rt.done:
                if owner not in rt.waits_on:
                    rt.waits_on = rt.waits_on + (owner,)
                return
        self._retires.append(RetireTask(
            set_name, pset, pid, leaver=fallback, waits_on=(owner,)))

    def _tick_handoff(self) -> int:
        """Pump ring-change handoff: promote resolved hints, drive pending
        digest pulls, retire dominated copies, close finished transitions.

        Each pending task costs one digest pull per tick until the
        destination's clock descends the source's — dropped messages delay
        completion but can never fake it (:func:`handoff_complete`).
        """
        self._promote_hints()
        tr = self.tracer
        started = 0
        pumped: List[HandoffTask] = []
        for t in self._handoffs:
            if t.done:
                continue
            if t.src in self.crashed or t.dst in self.crashed:
                continue
            if handoff_complete(self.vnodes[t.src], self.vnodes[t.dst],
                                t.pset):
                t.done = True
                continue
            with tr.span("handoff.round", set_name=t.set_name, pset=t.pset,
                         pid=t.pid, src=t.src, dst=t.dst):
                self._ae_pull(t.dst, t.src, t.pset)
            self.scheduler.stats.handoff_rounds += 1
            pumped.append(t)
            started += 1
        if self.sync:
            self.settle()
            for t in pumped:
                if handoff_complete(self.vnodes[t.src], self.vnodes[t.dst],
                                    t.pset):
                    t.done = True
        self._tick_retire()
        return started

    def _tick_retire(self) -> None:
        for rt in self._retires:
            if rt.done or rt.leaver in self.crashed:
                continue
            if any(w in self.crashed for w in rt.waits_on):
                continue
            leaver_vn = self.vnodes[rt.leaver]
            if not all(
                    handoff_complete(leaver_vn, self.vnodes[w], rt.pset)
                    for w in rt.waits_on):
                continue
            if self.durable:
                # acknowledged⇒durable across the move: the gaining owners'
                # copies hit the WAL before the leaver's copy disappears
                for w in rt.waits_on:
                    self.vnodes[w].store.sync()
            leaver_vn.drop_set(rt.pset)
            # drop_set only writes storage tombstones; compact so the moved
            # partition's bytes physically leave the retiring replica
            leaver_vn.compact()
            if self.durable:
                leaver_vn.store.sync()
            self.scheduler.stats.handoff_retired += 1
            rt.done = True
        # an old epoch retires once its transition's tasks all completed;
        # pinned cursors then fall forward to the current ring
        still_open = []
        for old_epoch, hts, rts in self._transitions:
            if all(t.done for t in hts) and all(t.done for t in rts):
                self._retired_epochs.add(old_epoch)
            else:
                still_open.append((old_epoch, hts, rts))
        self._transitions = still_open

    # -------------------------------------------------------- anti-entropy
    def tick(self, budget: Optional[int] = None) -> int:
        """Run one scheduler beat: pump scheduled sync rounds through the
        network, then the ring-handoff engine.

        Each round is a bidirectional pull for one (set, replica pair) —
        hottest repair-fed pairs first, then the round-robin baseline.
        Every message (request, reply) rides ``self.net``, so drop/dup/
        reorder semantics apply to anti-entropy exactly as to replication;
        a lost reply simply leaves the pair divergent for a later tick.
        Returns the number of rounds started (scheduled + handoff).
        """
        rounds = self.scheduler.next_rounds(budget)
        tr = self.tracer
        started = 0
        for set_name, a, b in rounds:
            if a in self.crashed or b in self.crashed:
                # a dead member can neither pull nor answer; the scheduler
                # keeps the pair queued for a post-restart tick
                self.scheduler.stats.rounds_crashed += 1
                continue
            with tr.span("ae.round", set_name=set_name, pair=[a, b]):
                self._ae_pull(a, b, set_name)
                self._ae_pull(b, a, set_name)
            self.scheduler.stats.rounds += 1
            started += 1
        if self.sync:
            self.settle()
        started += self._tick_handoff()
        return started

    def _ae_pull(self, dst: str, src: str, set_name: bytes) -> None:
        """``dst`` pulls ``set_name`` from ``src``: request and reply are
        separate network messages (each can drop, duplicate, reorder).

        The request snapshots ``dst``'s digest at send time; the reply is
        built against ``src``'s state at *delivery* time — the same
        at-least-once world replication lives in, which is why
        ``apply_digest_reply`` is idempotent.
        """
        stats = self.scheduler.stats
        tr = self.tracer
        pull_span = (tr.start("ae.pull", set_name=set_name, dst=dst, src=src)
                     if tr.enabled else None)
        vn = self.vnodes[dst]
        req = SyncRequest(set_name, vn.read_clock(set_name),
                          survivors_digest(vn, set_name))
        stats.pulls += 1
        stats.digest_bytes += req.size_bytes()

        def handle_request(src_vn: BigsetVnode) -> None:
            reply = build_digest_reply(
                src_vn, req.set_name, req.clock, req.survivors)
            stats.keys_scanned += reply.keys_scanned
            stats.digest_bytes += reply.digest_bytes()
            stats.payload_bytes += reply.payload_bytes()
            if reply.skipped:
                stats.rounds_skipped += 1
            else:
                stats.rounds_synced += 1
                stats.keys_shipped += len(reply.missing)

            def handle_reply(dst_vn: BigsetVnode) -> None:
                apply_digest_reply(dst_vn, reply)

            reply_payload = (
                TracedPayload(pull_span.context(), handle_reply)
                if pull_span is not None else handle_reply)
            self.net.send(src, dst, reply_payload, reply.size_bytes())

        req_payload = (TracedPayload(pull_span.context(), handle_request)
                       if pull_span is not None else handle_request)
        self.net.send(dst, src, req_payload, req.size_bytes())
        if pull_span is not None:
            # the pull itself is async: the span closes at send time and
            # the request/reply deliveries attach to it by carried context
            tr.finish(pull_span)

    def ae_stats(self) -> AntiEntropyStats:
        """Scheduled anti-entropy cost ledger (sits next to io_stats())."""
        return self.scheduler.stats

    def compact_all(self) -> None:
        for vn in self.vnodes.values():
            vn.compact()

    def io_stats(self):
        from ..storage.lsm import IoStats
        agg = IoStats()
        for vn in self.vnodes.values():
            for k in vars(agg):
                setattr(agg, k, getattr(agg, k) + getattr(vn.store.stats, k))
        return agg


class _QuorumStream:
    """Streaming quorum merge of per-replica visible entry streams.

    Presents the same head/advance/seek_to surface as the executor's
    per-vnode entry stream, so joins compose over quorum-merged sides.
    Memory is bounded: one head entry per replica.  Surviving dots follow
    the optimized-OR-set rule of :func:`repro.core.streaming.merge_entry`;
    per-element per-replica attribution is handed to ``repair_fn`` so the
    cluster can replay missing element-keys (read repair).
    """

    def __init__(self, streams, clocks, repair_fn=None, absent_fn=None):
        self._streams = streams
        self.clocks = clocks
        self._repair = repair_fn
        self._absent = absent_fn
        self.clock = Clock.zero()
        for c in clocks:
            self.clock = self.clock.join(c)
        self.head: Optional[Tuple[bytes, Tuple[Dot, ...]]] = None
        self._pump()

    def advance(self) -> Optional[Tuple[bytes, Tuple[Dot, ...]]]:
        h = self.head
        self._pump()
        return h

    def seek_to(self, element: bytes) -> None:
        if self.head is not None and self.head[0] >= element:
            return
        for s in self._streams:
            s.seek_to(element)
        self._pump()

    def _pump(self) -> None:
        """Advance to the next element that survives the quorum merge."""
        while True:
            heads = [s.head for s in self._streams]
            live = [h[0] for h in heads if h is not None]
            if not live:
                self.head = None
                return
            el = min(live)
            per_stream: List[Optional[frozenset]] = [None] * len(heads)
            for i, s in enumerate(self._streams):
                if s.head is not None and s.head[0] == el:
                    per_stream[i] = frozenset(s.advance()[1])
                elif self._absent is not None:
                    # index streams are ordered by (index_key, element): a
                    # replica absent from THIS posting group may still hold
                    # the element under another index key, so its surviving
                    # dots must join the merge or concurrent dots it has
                    # seen would be wrongly killed (element streams never
                    # need this — absence there means no surviving dots)
                    per_stream[i] = self._absent(i, el)
            dots = merge_entry(per_stream, self.clocks)
            if dots and self._repair is not None:
                self._repair(el, dots, per_stream)
            if dots:
                self.head = (el, tuple(sorted(dots)))
                return


class _FanInStream:
    """Key-ordered fan-in over per-partition quorum streams.

    Partitions split elements disjointly, so this is a pure k-way
    min-by-head interleave: no cross-stream dedup, and no cross-partition
    dot merging — each head was already quorum-merged (and read-repaired)
    inside its own partition's clock domain by its :class:`_QuorumStream`.
    Works for element streams (keys are elements) and index streams (keys
    are ``(index_key, element)`` pairs) alike.  The joined ``clock`` is a
    membership-only view, never a causal context (see
    :meth:`BigsetCluster.read`).
    """

    def __init__(self, streams):
        self._streams = streams
        self.clock = Clock.zero()
        for s in streams:
            self.clock = self.clock.join(s.clock)
        self.head = None
        self._pump()

    def advance(self):
        h = self.head
        self._pump()
        return h

    def seek_to(self, element) -> None:
        if self.head is not None and self.head[0] >= element:
            return
        for s in self._streams:
            s.seek_to(element)
        self._pump()

    def _pump(self) -> None:
        best = None
        for s in self._streams:
            if s.head is not None and (best is None
                                       or s.head[0] < best.head[0]):
                best = s
        self.head = None if best is None else best.advance()
