"""Replicated-cluster layer: vnodes, delta replication, quorums, anti-entropy.

Mirrors the paper's deployment model (§4): N vnodes each store a replica of
each datum, service many clients, act concurrently.  A deterministic,
seedable network simulation delivers messages with optional drop /
duplicate / reorder so convergence properties can be tested exhaustively.
"""
from .sim import DeliveryBudget, Network
from .antientropy import AntiEntropyScheduler, AntiEntropyStats
from .clusters import BigsetCluster, DeltaCluster, RiakSetCluster
from .placement import (CoveragePlan, PreferenceList, Ring, RingDelta,
                        VnodeDown, plan_coverage)

__all__ = [
    "AntiEntropyScheduler",
    "AntiEntropyStats",
    "BigsetCluster",
    "CoveragePlan",
    "DeliveryBudget",
    "DeltaCluster",
    "Network",
    "PreferenceList",
    "RiakSetCluster",
    "Ring",
    "RingDelta",
    "VnodeDown",
    "plan_coverage",
]
