"""Deterministic message-passing simulation for replica clusters.

Delivery semantics are configurable per test: messages can be dropped,
duplicated, and delivered in arbitrary (seeded-random) order.  CRDT
convergence must hold under *all* of these — the property tests drive this
directly.  Byte accounting (``bytes_sent``) feeds the paper's network-cost
comparisons (§3: deltas save wire bytes; §4: bigset saves wire *and* disk).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple


class DeliveryBudget(RuntimeError):
    """``deliver_all`` ran out of ``max_steps`` with messages still queued."""


@dataclass
class Message:
    src: str
    dst: str
    payload: Any
    size_bytes: int


class Network:
    def __init__(
        self,
        seed: int = 0,
        drop_prob: float = 0.0,
        dup_prob: float = 0.0,
        reorder: bool = False,
    ):
        self.rng = random.Random(seed)
        self.drop_prob = drop_prob
        self.dup_prob = dup_prob
        self.reorder = reorder
        self.queue: List[Message] = []
        self.bytes_sent = 0
        self.msgs_sent = 0
        self.msgs_dropped = 0
        # Crashed actors: traffic to them is dropped at send time AND at
        # delivery time — messages queued before the crash must not arrive
        # at a vnode that no longer exists.
        self.blackholes: Set[str] = set()

    def blackhole(self, actor: str) -> None:
        """Start dropping all traffic addressed to ``actor`` (crashed)."""
        self.blackholes.add(actor)

    def heal(self, actor: str) -> None:
        """Stop blackholing ``actor`` (restarted)."""
        self.blackholes.discard(actor)

    def send(self, src: str, dst: str, payload: Any, size_bytes: int) -> None:
        """Enqueue a message; ``size_bytes`` is its billed wire volume.

        The parameter is **required**, and a non-empty payload billed at
        zero raises: ``bytes_sent`` feeds every wire-cost comparison (and
        now the ``net.*`` metrics), so an unbilled call site would make
        those read 0 silently — the bug class this guard exists for.
        Empty-payload control messages (``None``, ``b""``, ``0``) may
        legitimately bill zero.
        """
        if size_bytes <= 0 and payload:
            raise ValueError(
                f"non-empty payload {type(payload).__name__} billed "
                f"{size_bytes} wire bytes ({src}->{dst})")
        self.msgs_sent += 1
        self.bytes_sent += size_bytes  # billed even if dropped: it was sent
        if dst in self.blackholes:
            self.msgs_dropped += 1
            return
        if self.drop_prob and self.rng.random() < self.drop_prob:
            self.msgs_dropped += 1
            return
        self.queue.append(Message(src, dst, payload, size_bytes))
        if self.dup_prob and self.rng.random() < self.dup_prob:
            self.queue.append(Message(src, dst, payload, size_bytes))

    def pending(self) -> int:
        return len(self.queue)

    def deliver_one(self, handler: Callable[[Message], None]) -> bool:
        if not self.queue:
            return False
        idx = self.rng.randrange(len(self.queue)) if self.reorder else 0
        msg = self.queue.pop(idx)
        if msg.dst in self.blackholes:
            self.msgs_dropped += 1  # queued before the crash, never arrives
            return True
        handler(msg)
        return True

    def deliver_all(self, handler: Callable[[Message], None], max_steps: int = 1_000_000) -> int:
        """Deliver until the queue drains.  Raises :class:`DeliveryBudget`
        if ``max_steps`` deliveries were not enough — callers treat
        ``deliver_all`` as "everything arrived" (``settle()``, replication
        fan-out), so silently returning with traffic still queued would
        turn a budget overrun into invisible message loss."""
        n = 0
        while self.queue and n < max_steps:
            self.deliver_one(handler)
            n += 1
        if self.queue:
            raise DeliveryBudget(
                f"deliver_all: {len(self.queue)} messages still queued "
                f"after {max_steps} deliveries")
        return n
