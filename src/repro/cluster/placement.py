"""Partitioned placement: the consistent-hash ring over bigset partitions.

The source paper's deployment context is Riak's ring: a bigset is
decomposed *on disk* precisely so vnodes can own slices of the element
keyspace instead of whole opaque sets.  This module is that ring for our
cluster — it turns ``(set_name, element)`` into a partition id and a
**preference list** of owner vnodes, so that

* writes route to the partition's N owners instead of fanning to every
  vnode (cluster capacity scales with vnode count);
* coverage queries plan a *minimal covering set* over partial owners
  (per-partition quorum merge instead of per-set);
* a ring change (:meth:`Ring.with_actors`) is described by a
  :class:`RingDelta` naming exactly the moved partitions, so handoff is
  digest-ladder anti-entropy over the moved partitions only — O(moved
  data + causal metadata), never O(cluster state).

Placement is **rendezvous (highest-random-weight) hashing**: each vnode's
score for a partition is a seeded keyed hash, and the owners are the
``factor`` top scorers.  Adding a vnode therefore moves only the
partitions where the newcomer out-scores an incumbent — the minimal-move
property a mod-N ring lacks — while every replica computes identical
placement from ``(actors, seed)`` with no shared state.

The **degenerate full-replication ring** (:meth:`Ring.full`) has one
partition owned by every vnode and stores under the set's own name, so a
cluster built without an explicit ring behaves — and bills wire bytes —
byte-identically to the pre-partitioning code.

Partition storage naming: partition ``pid`` of set ``s`` is stored as the
*independent bigset* ``s + b"\\x00#" + pid`` (the NUL keeps generated
names out of the application namespace).  Each partition has its own
set-clock, tombstone, and digest: dots minted for different partitions
are never conflated, which is what makes the per-partition quorum merge
exactly the ORSWOT merge it was before — element→partition is
deterministic, so every causal decision about an element happens inside
one partition's clock domain.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from hashlib import blake2b
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

#: default partition count — plenty of placement granularity for tens of
#: vnodes while keeping per-set metadata (clocks, digests) bounded
DEFAULT_PARTITIONS = 64

_PSET_SEP = b"\x00#"


class VnodeDown(RuntimeError):
    """An operation needed a crashed vnode (crash()ed, not restarted).

    Carries *which* vnode was down and for which set, so routing layers
    can record hinted-handoff bookkeeping and tests can assert the owner.
    """

    def __init__(self, message: str, vnode: Optional[str] = None,
                 set_name: Optional[bytes] = None):
        super().__init__(message)
        self.vnode = vnode
        self.set_name = set_name


# ------------------------------------------------------------- pset codec
def partition_set(set_name: bytes, pid: int) -> bytes:
    """Storage name of partition ``pid`` of ``set_name``."""
    return set_name + _PSET_SEP + pid.to_bytes(2, "big")


def split_partition_set(pset: bytes) -> Tuple[bytes, Optional[int]]:
    """Inverse of :func:`partition_set`; ``(pset, None)`` if unpartitioned."""
    i = pset.rfind(_PSET_SEP)
    if i < 0 or len(pset) - i != len(_PSET_SEP) + 2:
        return pset, None
    return pset[:i], int.from_bytes(pset[i + len(_PSET_SEP):], "big")


# ---------------------------------------------------------------- the ring
@dataclass(frozen=True)
class PreferenceList:
    """Placement verdict for one partition: owners first, then fallbacks.

    ``owners`` are the ``factor`` top rendezvous scorers — the replicas a
    write must reach and a coverage query draws its quorum from.
    ``fallbacks`` are the remaining vnodes in score order: sloppy
    placement targets when an owner is down (hinted handoff).
    """

    pid: int
    owners: Tuple[str, ...]
    fallbacks: Tuple[str, ...]


@dataclass(frozen=True)
class Ring:
    """A versioned, seeded consistent-hash ring over bigset partitions.

    Immutable: a membership change mints a *new* ring with a bumped
    ``epoch`` (:meth:`with_actors`), and :meth:`delta_to` names exactly
    the partitions whose ownership moved.  All placement is a pure
    function of ``(actors, factor, n_partitions, seed)``, so every vnode
    and every client computes identical routing with no coordination.
    """

    actors: Tuple[str, ...]
    factor: int
    n_partitions: int = DEFAULT_PARTITIONS
    seed: int = 0
    epoch: int = 0
    #: degenerate mode: one partition, every vnode an owner, storage
    #: passthrough — byte-identical to the pre-partitioning cluster
    full_replication: bool = False
    _ranking: Tuple[Tuple[str, ...], ...] = field(
        default=(), repr=False, compare=False)

    def __post_init__(self):
        if not self.actors:
            raise ValueError("ring needs at least one actor")
        if not (1 <= self.factor <= len(self.actors)):
            raise ValueError(
                f"factor {self.factor} not in [1, {len(self.actors)}]")
        if self.full_replication:
            ranking = (tuple(self.actors),) * self.n_partitions
        else:
            ranking = tuple(
                tuple(sorted(self.actors,
                             key=lambda a: self._score(pid, a),
                             reverse=True))
                for pid in range(self.n_partitions))
        object.__setattr__(self, "_ranking", ranking)

    # -------------------------------------------------------- constructors
    @classmethod
    def full(cls, actors: Sequence[str], epoch: int = 0) -> "Ring":
        """The degenerate full-replication ring (the default cluster)."""
        actors = tuple(actors)
        return cls(actors=actors, factor=len(actors), n_partitions=1,
                   epoch=epoch, full_replication=True)

    @classmethod
    def build(cls, actors: Sequence[str], factor: int = 3,
              n_partitions: int = DEFAULT_PARTITIONS, seed: int = 0,
              epoch: int = 0) -> "Ring":
        return cls(actors=tuple(actors), factor=factor,
                   n_partitions=n_partitions, seed=seed, epoch=epoch)

    @classmethod
    def from_members(cls, view, factor: int = 3,
                     n_partitions: int = DEFAULT_PARTITIONS, seed: int = 0,
                     epoch: int = 0) -> "Ring":
        """Build a ring from a membership view's alive-set.

        ``view`` is a :class:`~repro.cluster.membership.MembershipView`
        (or anything with ``members()``); members sort lexicographically
        so every node that shares the converged view builds the same ring.
        """
        members = sorted(view.members() if hasattr(view, "members")
                         else view)
        return cls.build(members, factor=min(factor, len(members)),
                         n_partitions=n_partitions, seed=seed, epoch=epoch)

    # ----------------------------------------------------------- placement
    def _score(self, pid: int, actor: str) -> int:
        h = blake2b(digest_size=8,
                    key=self.seed.to_bytes(8, "big", signed=False))
        h.update(pid.to_bytes(4, "big"))
        h.update(actor.encode())
        return int.from_bytes(h.digest(), "big")

    def partition(self, set_name: bytes, element: bytes) -> int:
        """The partition id of one ``(set, element)`` — seeded, stable."""
        if self.full_replication:
            return 0
        h = blake2b(digest_size=8,
                    key=self.seed.to_bytes(8, "big", signed=False))
        h.update(set_name)
        h.update(b"\x00")
        h.update(element)
        return int.from_bytes(h.digest(), "big") % self.n_partitions

    def owners(self, pid: int) -> Tuple[str, ...]:
        return self._ranking[pid][: self.factor]

    def fallbacks(self, pid: int) -> Tuple[str, ...]:
        return self._ranking[pid][self.factor:]

    def preference_list(self, set_name: bytes,
                        element: bytes) -> PreferenceList:
        pid = self.partition(set_name, element)
        return PreferenceList(pid, self.owners(pid), self.fallbacks(pid))

    def partitions(self) -> range:
        return range(self.n_partitions)

    def write_quorum(self) -> int:
        """Majority of the replication factor — the ack threshold."""
        return self.factor // 2 + 1

    # ------------------------------------------------------------- storage
    def storage_set(self, set_name: bytes, pid: int) -> bytes:
        """The bigset name partition ``pid`` of ``set_name`` stores under."""
        if self.full_replication:
            return set_name
        return partition_set(set_name, pid)

    def storage_sets(self, set_name: bytes) -> List[bytes]:
        return [self.storage_set(set_name, pid) for pid in self.partitions()]

    # --------------------------------------------------------- ring change
    def with_actors(self, actors: Sequence[str],
                    epoch: Optional[int] = None) -> "Ring":
        """A new ring over ``actors`` at ``epoch`` (default: bump by one)."""
        actors = tuple(actors)
        epoch = self.epoch + 1 if epoch is None else epoch
        if self.full_replication:
            return Ring.full(actors, epoch=epoch)
        return Ring(actors=actors, factor=min(self.factor, len(actors)),
                    n_partitions=self.n_partitions, seed=self.seed,
                    epoch=epoch)

    def delta_to(self, new: "Ring") -> "RingDelta":
        """The ownership moves between this ring and ``new``.

        Only partitions whose owner set changed appear — the heart of the
        O(moved partitions) rebalance bound.
        """
        if new.n_partitions != self.n_partitions and not (
                self.full_replication and new.full_replication):
            raise ValueError("rings must share a partition space")
        moves = []
        for pid in self.partitions():
            old = self.owners(pid)
            now = new.owners(pid)
            if set(old) != set(now):
                moves.append(PartitionMove(
                    pid=pid, old_owners=old, new_owners=now,
                    joined=tuple(a for a in now if a not in old),
                    left=tuple(a for a in old if a not in now)))
        return RingDelta(old_epoch=self.epoch, new_epoch=new.epoch,
                         moves=tuple(moves))


@dataclass(frozen=True)
class PartitionMove:
    """One partition's ownership change inside a :class:`RingDelta`."""

    pid: int
    old_owners: Tuple[str, ...]
    new_owners: Tuple[str, ...]
    joined: Tuple[str, ...]   # owners gained: must pull the partition
    left: Tuple[str, ...]     # owners lost: retire once joiners dominate

    def survivors(self) -> Tuple[str, ...]:
        """Old owners that remain owners — the preferred handoff donors."""
        return tuple(a for a in self.old_owners if a in self.new_owners)


@dataclass(frozen=True)
class RingDelta:
    """Ownership moves between two ring epochs (what handoff must ship)."""

    old_epoch: int
    new_epoch: int
    moves: Tuple[PartitionMove, ...]

    def moved_pids(self) -> Tuple[int, ...]:
        return tuple(m.pid for m in self.moves)


# ------------------------------------------------------------ coverage plan
@dataclass(frozen=True)
class CoveragePlan:
    """A minimal covering set over partial owners for one query.

    ``assignments`` maps every partition the query touches to the ``r``
    live owners whose streams join its quorum merge; ``vnodes`` is the
    (minimised) union — the query's storage footprint.  Surfaced to
    clients via :attr:`repro.query.executor.QueryStats.coverage`.
    """

    epoch: int
    r: int
    assignments: Tuple[Tuple[int, bytes, Tuple[str, ...]], ...]
    vnodes: FrozenSet[str]

    def describe(self) -> str:
        return (f"epoch={self.epoch};partitions={len(self.assignments)};"
                f"vnodes={len(self.vnodes)};r={self.r}")


def plan_coverage(ring: Ring, set_name: bytes, live: Iterable[str], r: int,
                  pids: Optional[Iterable[int]] = None) -> CoveragePlan:
    """Greedy minimal covering set: ``r`` live owners per partition.

    Owners already selected for another partition are preferred, so the
    plan's vnode footprint stays near the theoretical minimum and each
    touched vnode answers for many partitions in one pass.  Raises
    :class:`VnodeDown` naming a crashed owner when any partition cannot
    field ``r`` live owners — a coverage query never silently degrades
    below its quorum.
    """
    live_set = frozenset(live)
    chosen: Dict[int, Tuple[str, ...]] = {}
    used: set = set()
    for pid in (ring.partitions() if pids is None else pids):
        owners = ring.owners(pid)
        alive = [a for a in owners if a in live_set]
        if len(alive) < r:
            down = next((a for a in owners if a not in live_set), None)
            if down is None:
                raise ValueError(
                    f"r={r} exceeds replication factor {len(owners)}")
            raise VnodeDown(
                f"partition {pid} of {set_name!r} needs {r} owners, "
                f"{len(alive)} live (owner {down} down)",
                vnode=down, set_name=set_name)
        picked = [a for a in alive if a in used][:r]
        for a in alive:
            if len(picked) >= r:
                break
            if a not in picked:
                picked.append(a)
        used.update(picked)
        chosen[pid] = tuple(picked)
    assignments = tuple(
        (pid, ring.storage_set(set_name, pid), chosen[pid])
        for pid in sorted(chosen))
    return CoveragePlan(epoch=ring.epoch, r=r, assignments=assignments,
                        vnodes=frozenset(used))
