"""Elastic cluster membership as a delta-replicated ORSWOT.

The control plane of a 1000+-node training fleet has exactly the Riak-set
problem: every node needs a convergent view of *who is in the cluster*
under joins, leaves, crashes and partitions, without a coordinator on the
critical path.  We use the paper's machinery directly:

* the member set is an ORSWOT of node ids (observed-remove: ejecting a
  straggler only removes the *observed* incarnation — a concurrently
  re-joining node wins, add-wins semantics being precisely what you want
  for "the node restarted");
* joins/leaves generate **deltas** gossiped peer-to-peer (bounded by causal
  metadata, not fleet size);
* each node tracks its *incarnation* via the dots of its own entry, so a
  node that was ejected and rejoined is distinguishable from a stale view.

``MembershipView.data_parallel_groups`` derives the elastic mesh
assignment (data-axis size = |alive|), and
:meth:`repro.cluster.placement.Ring.from_members` builds the placement
ring from the same converged alive-set.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

from ..core.delta_orswot import delta_add, delta_remove
from ..core.orswot import Orswot
from .sim import Network


class MembershipView:
    """One node's convergent view of cluster membership."""

    def __init__(self, node_id: str):
        self.node_id = node_id
        self.state = Orswot.new()

    # ------------------------------------------------------------- mutators
    def join(self, node: Optional[str] = None) -> Orswot:
        node = node or self.node_id
        self.state, delta = delta_add(self.state, self.node_id, node)
        return delta

    def leave(self, node: Optional[str] = None) -> Orswot:
        """Observed-remove of a node (self-leave or straggler ejection)."""
        node = node or self.node_id
        ctx = self.state.context_of(node)
        self.state, delta = delta_remove(self.state, node, ctx)
        return delta

    # ---------------------------------------------------------------- merge
    def apply(self, delta: Orswot) -> None:
        self.state = self.state.merge(delta)

    def merge_view(self, other: "MembershipView") -> None:
        self.state = self.state.merge(other.state)

    # ---------------------------------------------------------------- reads
    def members(self) -> FrozenSet[str]:
        return frozenset(str(m) for m in self.state.value())

    def is_member(self, node: str) -> bool:
        return node in self.state.value()

    def incarnation(self, node: str) -> Tuple:
        return self.state.context_of(node)

    def data_parallel_groups(self, group_size: int = 1
                             ) -> Tuple[Tuple[str, ...], ...]:
        """Deterministic data-parallel mesh assignment over the alive-set.

        Sorted members chunk into groups of ``group_size`` (the final
        partial chunk is kept, so every alive node has a slot).  A pure
        function of :meth:`members`: any two converged views compute
        identical groups, and a join/leave perturbs only groups at and
        after the changed node's sorted position — the stability the
        elastic runtime (and :meth:`repro.cluster.placement.Ring.
        from_members`, which consumes the same alive-set) relies on.
        """
        if group_size < 1:
            raise ValueError("group_size must be >= 1")
        ms = sorted(self.members())
        return tuple(tuple(ms[i:i + group_size])
                     for i in range(0, len(ms), group_size))


class GossipCluster:
    """N nodes gossiping membership deltas over the simulated network."""

    def __init__(self, n_nodes: int, net: Optional[Network] = None):
        self.net = net or Network()
        self.nodes: Dict[str, MembershipView] = {}
        for i in range(n_nodes):
            nid = f"node{i}"
            self.nodes[nid] = MembershipView(nid)
        # bootstrap: every node joins and gossips
        for nid, view in self.nodes.items():
            self.broadcast(nid, view.join())

    def broadcast(self, src: str, delta: Orswot) -> None:
        for dst in self.nodes:
            if dst != src:
                self.net.send(src, dst, delta, delta.size_bytes())

    def settle(self) -> None:
        self.net.deliver_all(
            lambda m: self.nodes[m.dst].apply(m.payload))

    def anti_entropy_round(self) -> None:
        """Full-state pairwise repair (for partitions that dropped deltas)."""
        ids = sorted(self.nodes)
        for a, b in zip(ids, ids[1:] + ids[:1]):
            self.nodes[a].merge_view(self.nodes[b])
            self.nodes[b].merge_view(self.nodes[a])

    # --------------------------------------------------------------- events
    def node_joins(self, node_id: str) -> None:
        view = MembershipView(node_id)
        # bootstrap: a joining node seeds its view from an existing peer
        # (anti-entropy on join), then announces itself
        seeds = [v for v in self.nodes.values()]
        if seeds:
            view.merge_view(seeds[0])
        self.nodes[node_id] = view
        self.broadcast(node_id, view.join())

    def node_leaves(self, node_id: str) -> None:
        view = self.nodes[node_id]
        self.broadcast(node_id, view.leave())

    def eject(self, by: str, victim: str) -> None:
        """Straggler ejection by a peer (observed-remove)."""
        self.broadcast(by, self.nodes[by].leave(victim))

    def views(self) -> List[FrozenSet[str]]:
        return [v.members() for v in self.nodes.values()]

    def converged(self) -> bool:
        vs = self.views()
        return all(v == vs[0] for v in vs)
