"""Anti-entropy, handoff, and repair-hit-fed sync scheduling for bigsets.

The paper (§6) defers its anti-entropy design to future work ("key processes
we have developed including anti-entropy and hand-off").  We implement a
correct protocol here, built from the paper's own primitives, and make it
**digest-first and divergence-bounded** in the spirit of join-decomposition
digest sync (Enes et al., "Efficient Synchronization of State-based CRDTs")
applied to the decomposed ORSWOT (Bieniusa et al.).

The digest ladder — a pull of set S by replica A from replica B:

1. A sends ``SyncRequest(SC_A, D_A)``: its set-clock plus its **survivors
   digest** (a clock over the dots of its visible element-keys, maintained
   incrementally by the vnode — see :class:`repro.core.bigset.SetDigest` —
   so reading it never folds).
2. B compares.  ``SC_A == SC_B and D_A == D_B`` means converged: B answers
   with a digest-only skip.  Cost of the whole round: O(causal metadata)
   bytes, **zero element-key folds**.
3. Otherwise B computes ``need = D_B.diff_dots(SC_A)`` — the dots of its
   surviving keys A has never seen — by pure clock subtraction, then folds
   **only** the fenced element subranges whose digest buckets contain a
   needed dot (``vnode.digest_ranges``).  The reply carries those
   (element, dot, value) keys plus ``(SC_B, D_B)``; scan cost tracks the
   diverged subranges, not set cardinality.
4. A applies: each missing key via Algorithm 2 (dot-seen check + append);
   **removal inference** by clock math — every dot in
   ``D_A.diff_dots(D_B)`` that ``SC_B`` has seen was removed at B (B may
   have long since *compacted* the removal away; no tombstone exchange is
   needed, which is what makes subtraction-after-compaction safe) — then
   ``SC_A := SC_A ⊔ SC_B`` and a tombstone trim (also digest-backed,
   O(tombstone), no scan).

Run in both directions (:func:`sync`), the protocol makes both replicas'
read values equal under drop/dup/reorder (tests/test_antientropy.py).
:func:`full_sync` keeps the original full-fold exchange as a baseline, and
:func:`handoff` is that machinery with the ``missing`` filter removed.

**Scheduling.**  Nothing converges unless something *runs* sync.  The
:class:`AntiEntropyScheduler` closes ROADMAP's loop: the query path's read
repair (``BigsetCluster._repair``) reports per-(set, pair) repair hits —
direct evidence two replicas diverge — and the scheduler prioritises those
pairs, decaying scores so quiescent sets cool off, while a round-robin
baseline guarantees replicas *outside* every read quorum still converge.
``BigsetCluster.tick()`` pumps scheduled rounds through the simulated
:class:`~repro.cluster.sim.Network`, so the same drop/dup/reorder property
tests that cover replication cover scheduled anti-entropy.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..core.bigset import BigsetVnode, InsertDelta
from ..core.clock import Clock
from ..core.dots import Dot


# ------------------------------------------------------------ wire messages
@dataclass
class SyncRequest:
    """Pull opener: the requester's causal state, digest-sized."""

    set_name: bytes
    clock: Clock
    survivors: Clock

    def size_bytes(self) -> int:
        return (len(self.set_name) + self.clock.size_bytes()
                + self.survivors.size_bytes())


@dataclass
class DigestReply:
    """Answer to a :class:`SyncRequest`.

    ``skipped`` means the responder proved convergence from digests alone.
    Otherwise ``missing`` carries exactly the (element, dot, value) keys
    the requester's set-clock has never seen — located by folding only the
    responder's diverged digest subranges (``keys_scanned`` counts that
    fold work).
    """

    set_name: bytes
    clock: Clock
    survivors: Clock
    missing: List[Tuple[bytes, Dot, bytes]] = field(default_factory=list)
    skipped: bool = False
    keys_scanned: int = 0

    def digest_bytes(self) -> int:
        return (len(self.set_name) + self.clock.size_bytes()
                + self.survivors.size_bytes())

    def payload_bytes(self) -> int:
        return sum(len(e) + 16 + len(v) for e, _, v in self.missing)

    def size_bytes(self) -> int:
        return self.digest_bytes() + self.payload_bytes()


@dataclass
class SyncReply:
    """Legacy full-fold reply (:func:`full_sync`, :func:`handoff`)."""

    set_name: bytes
    clock: Clock
    survivors: Clock
    # (element, dot, value): the value rides along so the receiver's
    # replica_insert can re-derive index postings (posting liveness == dot
    # liveness requires the posting's extractor input, not just the key)
    missing: List[Tuple[bytes, Dot, bytes]]

    def size_bytes(self) -> int:
        return (
            self.clock.size_bytes()
            + self.survivors.size_bytes()
            + sum(len(e) + 16 + len(v) for e, _, v in self.missing)
        )


def survivors_digest(vnode: BigsetVnode, set_name: bytes) -> Clock:
    """Clock digest of the dots of all surviving element-keys.

    Delegates to the vnode's maintained :class:`~repro.core.bigset.
    SetDigest` — O(causal metadata), never a fold.  Every protocol below
    uses this one definition, so the digest the scheduler's skip decision
    depends on cannot drift from the digest replies are built from.
    """
    return vnode.survivors_digest(set_name)


# ------------------------------------------------------- digest-first sync
def build_digest_reply(
    vnode: BigsetVnode,
    set_name: bytes,
    remote_clock: Clock,
    remote_survivors: Clock,
) -> DigestReply:
    """Answer a pull: skip when converged, else ship the diverged keys."""
    sc = vnode.read_clock(set_name)
    dig = survivors_digest(vnode, set_name)
    if sc == remote_clock and dig == remote_survivors:
        return DigestReply(set_name, sc, dig, skipped=True)
    need = dig.diff_dots(remote_clock)
    missing: List[Tuple[bytes, Dot, bytes]] = []
    scanned = 0
    if need:
        need_set = set(need)
        for lo, hi in vnode.digest_ranges(set_name, need):
            for element, dot, value in vnode.fold_raw(
                    set_name, start=lo, end=hi):
                scanned += 1
                if dot in need_set:
                    missing.append((element, dot, value))
    return DigestReply(set_name, sc, dig, missing, False, scanned)


def apply_digest_reply(vnode: BigsetVnode, reply: DigestReply) -> int:
    """Apply a pull's reply.  Returns #element-keys written.

    Idempotent under duplicate delivery: inserts dedup on the dot-seen
    check, removal inference re-derives an empty set once the tombstone
    covers the removed dots, and clock joins are joins.
    """
    if reply.skipped:
        return 0
    set_name = reply.set_name
    written = 0
    for element, dot, value in reply.missing:
        if vnode.replica_insert(InsertDelta(set_name, element, dot,
                                            value=value)):
            written += 1
    # removal inference by digest subtraction: surviving here, seen but not
    # surviving at the peer -> the peer removed it (no fold, no tombstone
    # exchange; safe even after the peer compacted the removal away).
    # Pure run merges: (mine \ peer-survivors) ∩ peer-clock, O(runs).
    mine = survivors_digest(vnode, set_name)
    removed = mine.subtract_clock(reply.survivors).intersect(reply.clock)
    sc0 = vnode.read_clock(set_name)
    sc = sc0.join(reply.clock)
    ts0 = vnode.read_tombstone(set_name)
    ts = ts0.add_runs(removed.iter_runs())
    if sc != sc0 or ts is not ts0:
        from ..core.bigset import clock_key, tombstone_key, _clock_to_bytes

        vnode.store.put_batch(
            [
                (clock_key(set_name), _clock_to_bytes(sc)),
                (tombstone_key(set_name), _clock_to_bytes(ts)),
            ]
        )
    if ts is not ts0:
        trim_tombstone(vnode, set_name)
    return written


def sync_pull(dst: BigsetVnode, src: BigsetVnode, set_name: bytes
              ) -> DigestReply:
    """One direction of the digest ladder: ``dst`` pulls from ``src``."""
    reply = build_digest_reply(
        src, set_name, dst.read_clock(set_name),
        survivors_digest(dst, set_name))
    apply_digest_reply(dst, reply)
    return reply


def sync(a: BigsetVnode, b: BigsetVnode, set_name: bytes) -> None:
    """Bidirectional digest-first sync of one set between two replicas.

    Converged pairs cost O(causal metadata) — digest bytes only, zero
    element-key folds; diverged pairs fold only the diverged subranges.
    """
    sync_pull(a, b, set_name)
    sync_pull(b, a, set_name)


# ------------------------------------------------------- legacy full sync
def build_reply(
    vnode: BigsetVnode, set_name: bytes, remote_clock: Clock
) -> SyncReply:
    """Full-fold reply: every surviving key unseen by ``remote_clock``."""
    missing = [
        (element, dot, value)
        for element, dot, value in vnode.fold_values(set_name)
        if not remote_clock.seen(dot)
    ]
    return SyncReply(set_name, vnode.read_clock(set_name),
                     survivors_digest(vnode, set_name), missing)


def apply_reply(vnode: BigsetVnode, reply: SyncReply) -> int:
    """Apply a sync reply at the requesting replica.  Returns #keys written.

    One raw fold computes *both* removal inference and the tombstone
    backing trim needs (it used to take two more full scans), and the trim
    is skipped outright when the tombstone did not change.
    """
    set_name = reply.set_name
    written = 0
    for element, dot, value in reply.missing:
        if vnode.replica_insert(InsertDelta(set_name, element, dot,
                                            value=value)):
            written += 1
    ts0 = vnode.read_tombstone(set_name)
    removed: List[Dot] = []
    backed: Set[Dot] = set()
    for _element, dot, _v in vnode.fold_raw(set_name):
        if ts0.seen(dot):
            backed.add(dot)      # covered key still on disk backs its dot
        elif reply.clock.seen(dot) and not reply.survivors.seen(dot):
            removed.append(dot)  # surviving here, removed at the peer
            backed.add(dot)      # the key we are tombstoning backs it
    sc = vnode.read_clock(set_name).join(reply.clock)
    ts = ts0.add_dots(removed)
    from ..core.bigset import clock_key, tombstone_key, _clock_to_bytes

    vnode.store.put_batch(
        [
            (clock_key(set_name), _clock_to_bytes(sc)),
            (tombstone_key(set_name), _clock_to_bytes(ts)),
        ]
    )
    if ts is not ts0:
        trim_tombstone(vnode, set_name, backed=backed)
    return written


def trim_tombstone(vnode: BigsetVnode, set_name: bytes,
                   backed: Optional[Set[Dot]] = None) -> int:
    """Subtract tombstone dots that no longer shadow any element-key.

    ``backed`` (the dots known to have physical keys) can be handed in by
    a caller that just folded; otherwise backing comes from the vnode's
    maintained raw digest.  Either way the trim is a run intersection —
    O(tombstone runs), no scan, no per-dot enumeration.

    Returns the number of tombstone *events* trimmed.
    """
    ts = vnode.read_tombstone(set_name)
    if ts.is_zero():
        return 0
    if backed is None:
        backing = vnode._digest(set_name).raw_total()
    else:
        backing = Clock.zero().add_dots(backed)
    trimmed = ts.intersect(backing)
    if trimmed == ts:
        return 0
    from ..core.bigset import tombstone_key, _clock_to_bytes

    vnode.store.put(tombstone_key(set_name), _clock_to_bytes(trimmed))
    return ts.n_events() - trimmed.n_events()


def full_sync(a: BigsetVnode, b: BigsetVnode, set_name: bytes) -> None:
    """Bidirectional *full-fold* sync — the pre-digest baseline.

    Semantically identical to :func:`sync`; costs two O(n) element folds
    per direction regardless of divergence (it used to be three before
    ``apply_reply`` fused inference and trim backing).  Kept for
    benchmarks and as the simplest statement of the protocol.
    """
    apply_reply(a, build_reply(b, set_name, a.read_clock(set_name)))
    apply_reply(b, build_reply(a, set_name, b.read_clock(set_name)))


def handoff(src: BigsetVnode, dst: BigsetVnode, set_name: bytes) -> int:
    """Transfer a set to a new owner (ring change): sync with empty clock.

    The full-fold baseline.  Scheduled ring-change handoff uses the
    digest ladder instead (:class:`HandoffTask` pulls pumped by
    ``BigsetCluster.tick``), which ships only what the new owner's clock
    has not seen — for a fresh owner that is everything, but for a
    crash-restarted or partially-caught-up owner it is the diverged tail.
    """
    reply = build_reply(src, set_name, Clock.zero())
    return apply_reply(dst, reply)


# ----------------------------------------------------------- ring handoff
@dataclass
class HandoffTask:
    """One digest-ladder pull a ring change requires: ``dst`` (a gaining
    owner) pulls partition-set ``pset`` from ``src`` (a surviving old
    owner, or the leaver itself when nobody else holds the partition).

    ``done`` flips once :func:`handoff_complete` proves domination — the
    pull is re-scheduled every tick until then, so dropped request or
    reply messages only delay completion, never lose it.
    """

    set_name: bytes   # logical set (for spans / stats attribution)
    pset: bytes       # partition storage set being moved
    pid: int
    dst: str
    src: str
    done: bool = False


@dataclass
class RetireTask:
    """Retire ``leaver``'s copy of ``pset`` once every vnode in
    ``waits_on`` (the partition's gaining owners — or, when nobody
    joined, its surviving owners) causally dominates the leaver.

    Domination means the waiter's set-clock descends the leaver's: every
    dot the leaver acknowledged is either a surviving key at the waiter
    or was legitimately removed there — deleting the leaver's copy can
    lose nothing (invariant 13).
    """

    set_name: bytes
    pset: bytes
    pid: int
    leaver: str
    waits_on: Tuple[str, ...]
    done: bool = False


def handoff_complete(src: BigsetVnode, dst: BigsetVnode,
                     set_name: bytes) -> bool:
    """Has ``dst`` causally caught up with ``src`` for ``set_name``?

    Clock descent is the whole check: the digest ladder joins ``src``'s
    set-clock into ``dst``'s with the reply, so descent certifies every
    dot ``src`` ever acknowledged is accounted for at ``dst`` (present,
    or removed by an observed remove).  O(causal metadata), no fold.
    """
    return dst.read_clock(set_name).descends(src.read_clock(set_name))


# ------------------------------------------------------------- scheduling
@dataclass
class AntiEntropyStats:
    """Cost ledger of scheduled anti-entropy, surfaced by
    ``BigsetCluster.ae_stats()`` next to ``io_stats()``.

    Counters are message-level events, so at-least-once delivery (dup
    networks) can count a pull's reply twice — the ledger reflects work
    actually performed, which is what the cost claims are about.
    """

    rounds: int = 0           # pair rounds scheduled (two pulls each)
    pulls: int = 0            # pull requests sent
    rounds_skipped: int = 0   # pulls answered "already converged"
    rounds_synced: int = 0    # pulls whose reply shipped keys / clocks
    digest_bytes: int = 0     # clock + survivors-digest wire volume
    payload_bytes: int = 0    # (element, dot, value) wire volume
    keys_shipped: int = 0     # element-keys replayed by anti-entropy
    keys_scanned: int = 0     # raw keys folded locating diverged subranges
    repair_hits: int = 0      # read-repair replays observed by the query path
    repair_misses: int = 0    # quorum checks where every replica had the dot
    repair_no_donor: int = 0  # repairs skipped: no replica could supply a value
    rounds_crashed: int = 0   # rounds not attempted: a member was crashed
    handoff_rounds: int = 0   # ring-change digest pulls pumped by tick()
    handoff_retired: int = 0  # partition copies retired after domination
    hints_recorded: int = 0   # sloppy writes parked on a fallback vnode
    hints_resolved: int = 0   # hints promoted to handoff pulls (owner back)


class AntiEntropyScheduler:
    """Repair-hit-fed prioritisation of (set, replica-pair) sync rounds.

    The query path's read repair is a free divergence detector: every
    element-key it replays names a set and a replica pair that demonstrably
    disagree.  ``record_repair_hit`` bumps that pair's score;
    ``next_rounds`` drains the hottest pairs first and *decays* all scores,
    so sets that stop missing data stop being synced.  A round-robin
    baseline over every known (set, pair) — ``baseline`` rounds per tick —
    guarantees replicas outside every read quorum converge too.
    """

    def __init__(self, actors: Iterable[str], decay: float = 0.5,
                 baseline: int = 1, hot_threshold: float = 0.5):
        self.actors = list(actors)
        self.decay = decay
        self.baseline = baseline
        self.hot_threshold = hot_threshold
        self.stats = AntiEntropyStats()
        self._scores: Dict[Tuple[bytes, Tuple[str, str]], float] = {}
        self._sets: List[bytes] = []
        self._known: Set[bytes] = set()
        # per-set owner lists (partitioned placement): a partition set only
        # syncs among its preference list, never across the whole cluster
        self._owners: Dict[bytes, Tuple[str, ...]] = {}
        self._rr = 0

    # ------------------------------------------------------------- signals
    def note_set(self, set_name: bytes,
                 owners: Optional[Iterable[str]] = None) -> None:
        """Register a set for the round-robin baseline (cluster write path).

        ``owners`` scopes the set's sync pairs to its preference list;
        omitted (the full-replication default), every actor pair gossips
        the set.  Re-noting with new owners (a ring change) re-scopes the
        pairs, so retired owners stop being synced against.
        """
        if set_name not in self._known:
            self._known.add(set_name)
            self._sets.append(set_name)
        if owners is not None:
            self._owners[set_name] = tuple(owners)

    def record_repair_hit(self, set_name: bytes, target: str,
                          donor: str) -> None:
        """A read repair replayed a key from ``donor`` to ``target``."""
        self.note_set(set_name)
        self.stats.repair_hits += 1
        key = (set_name, self._pair(target, donor))
        self._scores[key] = self._scores.get(key, 0.0) + 1.0

    def record_repair_miss(self, set_name: bytes) -> None:
        self.stats.repair_misses += 1

    def record_no_donor(self, set_name: bytes) -> None:
        self.stats.repair_no_donor += 1

    # ----------------------------------------------------------- schedule
    @staticmethod
    def _pair(a: str, b: str) -> Tuple[str, str]:
        return (a, b) if a <= b else (b, a)

    def _all_pairs(self) -> List[Tuple[str, str]]:
        return [
            (a, b)
            for i, a in enumerate(self.actors)
            for b in self.actors[i + 1:]
        ]

    def _pairs_for(self, set_name: bytes) -> List[Tuple[str, str]]:
        owners = self._owners.get(set_name)
        if owners is None:
            return self._all_pairs()
        owners = sorted(owners)
        return [
            (a, b)
            for i, a in enumerate(owners)
            for b in owners[i + 1:]
        ]

    def hot_pairs(self) -> List[Tuple[bytes, Tuple[str, str], float]]:
        """(set, pair, score) above threshold, hottest first."""
        hot = [(k[0], k[1], s) for k, s in self._scores.items()
               if s >= self.hot_threshold]
        hot.sort(key=lambda t: (-t[2], t[0], t[1]))
        return hot

    def next_rounds(self, budget: Optional[int] = None
                    ) -> List[Tuple[bytes, str, str]]:
        """Drain up to ``budget`` (set, a, b) rounds; decay all scores.

        Default budget: every hot pair plus ``baseline`` round-robin
        rounds, so a quiescent cluster still gossips slowly and a hot one
        is serviced fully.
        """
        hot = self.hot_pairs()
        if budget is None:
            budget = len(hot) + self.baseline
        rounds: List[Tuple[bytes, str, str]] = []
        chosen: Set[Tuple[bytes, Tuple[str, str]]] = set()
        for set_name, pair, _score in hot:
            if len(rounds) >= budget:
                break
            rounds.append((set_name, pair[0], pair[1]))
            chosen.add((set_name, pair))
        universe = [(s, p) for s in self._sets for p in self._pairs_for(s)]
        for _ in range(len(universe)):
            if len(rounds) >= budget:
                break
            s, p = universe[self._rr % len(universe)]
            self._rr += 1
            if (s, p) in chosen:
                continue
            rounds.append((s, p[0], p[1]))
            chosen.add((s, p))
        self._scores = {
            k: v * self.decay
            for k, v in self._scores.items()
            if v * self.decay >= 0.05
        }
        return rounds
