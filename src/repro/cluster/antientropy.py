"""Anti-entropy and handoff for bigset replicas.

The paper (§6) defers its anti-entropy design to future work ("key processes
we have developed including anti-entropy and hand-off").  We implement a
correct protocol here, built from the paper's own primitives:

A full sync of set S from replica B to replica A:

1. A sends its set-clock ``SC_A`` to B.
2. B replies with ``(SC_B, survivors_B, missing)`` where ``survivors_B`` is
   a *clock digest* of the dots of B's surviving element-keys (contiguous
   runs compress into the base VV, so in the common case this is
   VV-sized), and ``missing`` is the list of surviving element-keys whose
   dots ``SC_A`` has not seen.
3. A applies:
   * each missing key via Algorithm 2 (dot-seen check + append);
   * **removal inference**: any local surviving key whose dot is seen by
     ``SC_B`` but absent from ``survivors_B`` was removed at B — its dot
     joins A's set-tombstone (B may have already *compacted* the removal
     away; this rule needs no tombstone exchange, which is what makes
     subtraction-after-compaction safe);
   * ``SC_A := SC_A ⊔ SC_B`` — pre-empts superseded adds A never saw.
4. A trims its tombstone: dots with no backing element-key are subtracted
   (they can never discard anything again).

Run in both directions, the protocol makes both replicas' read values equal
(tested under drop/dup/reorder in tests/test_antientropy.py).  Handoff is
the same machinery with the ``missing`` filter removed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

from ..core.bigset import BigsetVnode, InsertDelta
from ..core.clock import Clock
from ..core.dots import Dot


@dataclass
class SyncReply:
    set_name: bytes
    clock: Clock
    survivors: Clock
    # (element, dot, value): the value rides along so the receiver's
    # replica_insert can re-derive index postings (posting liveness == dot
    # liveness requires the posting's extractor input, not just the key)
    missing: List[Tuple[bytes, Dot, bytes]]

    def size_bytes(self) -> int:
        return (
            self.clock.size_bytes()
            + self.survivors.size_bytes()
            + sum(len(e) + 16 + len(v) for e, _, v in self.missing)
        )


def survivors_digest(vnode: BigsetVnode, set_name: bytes) -> Clock:
    """Clock digest of the dots of all surviving element-keys."""
    return Clock.zero().add_dots(d for _e, d in vnode.fold(set_name))


def build_reply(
    vnode: BigsetVnode, set_name: bytes, remote_clock: Clock
) -> SyncReply:
    survivors = Clock.zero()
    missing: List[Tuple[bytes, Dot, bytes]] = []
    dots = []
    for element, dot, value in vnode.fold_values(set_name):
        dots.append(dot)
        if not remote_clock.seen(dot):
            missing.append((element, dot, value))
    survivors = survivors.add_dots(dots)
    return SyncReply(set_name, vnode.read_clock(set_name), survivors, missing)


def apply_reply(vnode: BigsetVnode, reply: SyncReply) -> int:
    """Apply a sync reply at the requesting replica.  Returns #keys written."""
    set_name = reply.set_name
    written = 0
    for element, dot, value in reply.missing:
        if vnode.replica_insert(InsertDelta(set_name, element, dot,
                                            value=value)):
            written += 1
    # removal inference: local surviving keys removed remotely
    removed: List[Dot] = []
    for _element, dot in vnode.fold(set_name):
        if reply.clock.seen(dot) and not reply.survivors.seen(dot):
            removed.append(dot)
    sc = vnode.read_clock(set_name).join(reply.clock)
    ts = vnode.read_tombstone(set_name).add_dots(removed)
    from ..core.bigset import clock_key, tombstone_key, _clock_to_bytes

    vnode.store.put_batch(
        [
            (clock_key(set_name), _clock_to_bytes(sc)),
            (tombstone_key(set_name), _clock_to_bytes(ts)),
        ]
    )
    trim_tombstone(vnode, set_name)
    return written


def trim_tombstone(vnode: BigsetVnode, set_name: bytes) -> int:
    """Subtract tombstone dots that no longer shadow any element-key."""
    ts = vnode.read_tombstone(set_name)
    if ts.is_zero():
        return 0
    backed = set()
    from ..core.bigset import element_range, decode_element_key

    lo, hi = element_range(set_name)
    for k, _v in vnode.store.scan(lo, hi):
        _s, _e, dot = decode_element_key(k)
        if ts.seen(dot):
            backed.add(dot)
    unbacked = [d for d in ts.all_dots() if d not in backed]
    if not unbacked:
        return 0
    ts = ts.subtract(unbacked)
    from ..core.bigset import tombstone_key, _clock_to_bytes

    vnode.store.put(tombstone_key(set_name), _clock_to_bytes(ts))
    return len(unbacked)


def sync(a: BigsetVnode, b: BigsetVnode, set_name: bytes) -> None:
    """Bidirectional full sync of one set between two replicas."""
    apply_reply(a, build_reply(b, set_name, a.read_clock(set_name)))
    apply_reply(b, build_reply(a, set_name, b.read_clock(set_name)))


def handoff(src: BigsetVnode, dst: BigsetVnode, set_name: bytes) -> int:
    """Transfer a set to a new owner (ring change): sync with empty clock."""
    reply = build_reply(src, set_name, Clock.zero())
    return apply_reply(dst, reply)
