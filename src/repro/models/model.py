"""Public Model API: init / train_step / prefill_step / decode_step.

The cross-entropy is computed **chunked over the sequence** so the
[B, T, vocab] logits tensor never materialises (gemma's 256k vocab at 4k
seq would otherwise dominate HBM); prefill computes logits for the final
position only.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..train.optimizer import AdamWConfig, adamw_update, init_opt_state
from .layers import dtype_of, rmsnorm, softcap
from .sharding import constrain
from .transformer import forward, init_decode_cache, init_params

CE_CHUNK = 512


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


def _logits(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", h, params["embed"]["tok"])
    else:
        logits = h @ params["lm_head"]
    return softcap(logits, cfg.logit_softcap)


def _hidden(params, cfg, tokens, **kw):
    """Forward trunk returning final hidden states (no logits)."""
    # forward() computes logits; to avoid the [B,T,V] tensor we call the
    # trunk pieces directly via a thin shim flag.
    return forward(params, cfg, tokens, _return_hidden=True, **kw)


def cross_entropy(params, cfg: ModelConfig, hidden: jax.Array,
                  targets: jax.Array, mask: Optional[jax.Array] = None
                  ) -> jax.Array:
    """Chunked CE over the sequence.  hidden [B,T,D], targets int32[B,T]."""
    B, T, D = hidden.shape
    chunk = min(CE_CHUNK, T)
    n = T // chunk
    rem = T - n * chunk

    def chunk_loss(h, t, m):
        # shard the chunk's sequence dim over the model axis so the
        # [B, chunk, V] logits tensor is fully distributed even when the
        # vocab does not divide the mesh (e.g. granite/whisper vocabs)
        h = constrain(h, "batch", "ce_seq", "embed")
        logits = _logits(params, cfg, h).astype(jnp.float32)
        logits = constrain(logits, "batch", "ce_seq", None)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return (((logz - gold) * m).sum(), m.sum())

    if mask is None:
        mask = jnp.ones((B, T), jnp.float32)

    total, cnt = 0.0, 0.0
    for i in range(n):  # python loop: dry-run cost_analysis sees every chunk
        sl = slice(i * chunk, (i + 1) * chunk)
        l, c = chunk_loss(hidden[:, sl], targets[:, sl], mask[:, sl])
        total, cnt = total + l, cnt + c
    if rem:
        l2, c2 = chunk_loss(hidden[:, n * chunk:], targets[:, n * chunk:],
                            mask[:, n * chunk:])
        total, cnt = total + l2, cnt + c2
    return total / jnp.maximum(cnt, 1.0)


class Model(NamedTuple):
    cfg: ModelConfig
    opt_cfg: AdamWConfig

    # ------------------------------------------------------------------ init
    def init(self, rng) -> Any:
        return init_params(rng, self.cfg)

    def init_train_state(self, rng) -> TrainState:
        params = self.init(rng)
        opt = init_opt_state(params, self.opt_cfg)
        return TrainState(params, opt, jnp.zeros((), jnp.int32))

    def init_cache(self, batch: int, length: int):
        return init_decode_cache(self.cfg, batch, length)

    # ------------------------------------------------------------ train step
    def loss_fn(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        tokens = batch["tokens"]
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        hidden, _, aux = forward(
            params, cfg, inp, mode="train",
            patch_embeds=batch.get("patch_embeds"),
            encoder_frames=batch.get("encoder_frames"),
            _return_hidden=True)
        ce = cross_entropy(params, cfg, hidden, tgt, batch.get("mask"))
        return ce + 0.01 * aux

    def train_step(self, state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        mbs = self.cfg.n_microbatches
        if mbs <= 1:
            loss, grads = jax.value_and_grad(self.loss_fn)(state.params, batch)
        else:
            # gradient accumulation: scan over microbatches, f32 accumulators
            # sharded like the grads (halves/quarters activation peaks)
            params = state.params

            def split(leaf):
                b = leaf.shape[0]
                if b % mbs != 0:
                    raise ValueError(
                        f"batch {b} not divisible by {mbs} microbatches")
                return leaf.reshape((mbs, b // mbs) + leaf.shape[1:])

            mb_batch = jax.tree_util.tree_map(split, batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            loss_sum, gsum = jnp.zeros((), jnp.float32), g0
            for i in range(mbs):  # unrolled: exact cost_analysis accounting
                mb = jax.tree_util.tree_map(lambda x: x[i], mb_batch)
                l, grads = jax.value_and_grad(self.loss_fn)(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                loss_sum = loss_sum + l
            loss = loss_sum / mbs
            grads = jax.tree_util.tree_map(lambda g: g / mbs, gsum)
        new_params, new_opt = adamw_update(
            grads, state.opt, state.params, self.opt_cfg)
        metrics = {"loss": loss, "step": state.step + 1}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    def grad_step(self, params, batch) -> Tuple[jax.Array, Any]:
        """Loss + grads only (for delta-sync / accumulation drivers)."""
        return jax.value_and_grad(self.loss_fn)(params, batch)

    # ------------------------------------------------------------ serve steps
    def prefill_step(self, params, batch: Dict[str, jax.Array],
                     max_len: Optional[int] = None) -> Tuple[jax.Array, Any]:
        cfg = self.cfg
        hidden, cache, _ = forward(
            params, cfg, batch["tokens"], mode="prefill",
            patch_embeds=batch.get("patch_embeds"),
            encoder_frames=batch.get("encoder_frames"),
            _return_hidden=True,
            max_cache_len=max_len or batch["tokens"].shape[1] + 64)
        logits = _logits(params, cfg, hidden[:, -1:, :])[:, 0, :]
        return logits, cache

    def decode_step(self, params, cache, tokens: jax.Array,
                    cache_len: jax.Array) -> Tuple[jax.Array, Any]:
        cfg = self.cfg
        hidden, new_cache, _ = forward(
            params, cfg, tokens, mode="decode", cache=cache,
            cache_len=cache_len, _return_hidden=True)
        logits = _logits(params, cfg, hidden)[:, 0, :]
        return logits, new_cache


def build_model(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None) -> Model:
    if opt_cfg is None:
        opt_cfg = AdamWConfig(moments=cfg.optimizer_moments)
    return Model(cfg, opt_cfg)
