"""FFN blocks: gated dense MLP (SwiGLU/GeGLU/relu²) and capacity-routed MoE.

MoE dispatch is the GShard capacity scheme implemented with cumsum +
scatter (no [T, E, C] one-hot dispatch tensor — that would dominate HBM at
the assigned shapes).  Tokens are dispatched *per batch row*, whose axis is
data-sharded, so the cumsum/scatter stays device-local under GSPMD.
Baseline expert placement is tensor-parallel (``ff`` dim over the model
axis, experts replicated) — correct for any expert count vs mesh; true
expert-parallel all-to-all placement is the §Perf hillclimb for the MoE
cells (granite: 32 experts / 16-way axis).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import activation, dense_init
from .sharding import constrain


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _permute_rows(x, idx, inv):
    """Out-of-bounds-dropping row permutation: ``out[j] = x[idx[j]]`` with
    ``idx[j] == x.shape[0]`` producing a zero row.

    Both directions are GATHERS: the VJP gathers the cotangent through the
    inverse map ``inv`` (``inv[i]`` = where row i landed, or ``len(idx)``
    if dropped).  This keeps the MoE dispatch/combine free of D-wide
    scatter ops, which (a) XLA:CPU expands into f32/u32 sort pipelines that
    triple HBM, and (b) TPUs execute far slower than gathers.
    """
    xp = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)], axis=0)
    return jnp.take(xp, idx, axis=0)


def _permute_rows_fwd(x, idx, inv):
    sentinel = jnp.zeros((0,), x.dtype)  # carries dtype (a dtype object is
    return _permute_rows(x, idx, inv), (inv, sentinel)  # not a pytree leaf)


def _permute_rows_bwd(res, ct):
    inv, sentinel = res
    ctp = jnp.concatenate([ct, jnp.zeros((1, ct.shape[1]), ct.dtype)], axis=0)
    dx = jnp.take(ctp, inv, axis=0).astype(sentinel.dtype)
    return dx, None, None


_permute_rows.defvjp(_permute_rows_fwd, _permute_rows_bwd)


def init_dense_ffn(key, cfg: ModelConfig, dtype) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "wi_gate": dense_init(ks[0], d, f, dtype),
        "wo_ff": dense_init(ks[2], f, d, dtype),
    }
    if cfg.hidden_act != "relu2":        # gated activations need the up proj
        p["wi_up"] = dense_init(ks[1], d, f, dtype)
    return p


def dense_ffn(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    gate = x @ p["wi_gate"]
    gate = constrain(gate, "batch", "seq", "ff")
    up = x @ p["wi_up"] if "wi_up" in p else None
    h = activation(cfg.hidden_act, gate, up)
    y = h @ p["wo_ff"]
    return constrain(y, "batch", "seq", "embed")


# ------------------------------------------------------------------------ MoE
def init_moe_ffn(key, cfg: ModelConfig, dtype) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "e_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(dtype),
        "e_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(dtype),
        "e_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   / math.sqrt(f)).astype(dtype),
    }


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = math.ceil(tokens * cfg.experts_per_token / cfg.n_experts
                  * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # sublane-aligned


def moe_ffn(p: Dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (y, load_balance_loss)."""
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    C = capacity(cfg, T)
    dt = x.dtype

    logits = x.astype(jnp.float32) @ p["router"]           # [B, T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, sel = jax.lax.top_k(probs, K)                  # [B, T, K]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch/GShard form); bincount instead of
    # a [B,T,K,E] one-hot
    me = probs.mean(axis=(0, 1))                           # [E]
    counts = jax.vmap(lambda r: jnp.bincount(r.reshape(-1), length=E))(
        sel.reshape(B, -1))                                # [B, E]
    ce = counts.astype(jnp.float32).mean(0) / (T * K)      # routed fraction
    aux = E * jnp.sum(me * ce)

    # ---- capacity dispatch (per batch row; batch is data-sharded)
    # position-in-expert WITHOUT a [B, TK, E] one-hot (that tensor would be
    # ~1 TB at the train_4k cells): stable-sort slots by expert, rank within
    # each expert run, scatter ranks back.  O(TK log TK), O(B·TK) memory.
    sel_flat = sel.reshape(B, T * K)                       # token-slot -> expert
    TK = T * K

    def pos_in_expert(row):                                # row: int32[TK]
        order = jnp.argsort(row, stable=True)
        sorted_e = row[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
        rank = jnp.arange(TK) - starts[sorted_e]
        return jnp.zeros((TK,), jnp.int32).at[order].set(rank.astype(jnp.int32))

    pos = jax.vmap(pos_in_expert)(sel_flat)                # [B, TK]
    keep = pos < C
    dest = jnp.where(keep, sel_flat * C + pos, E * C)      # E*C -> dropped

    tok_idx = jnp.arange(T * K) // K
    x_slots = x[:, tok_idx, :]                             # [B, TK, D]
    x_slots = constrain(x_slots, "batch", "moe_slots", "embed")

    # invert dest (an int-only scatter, no D dimension): src[s] = which
    # token-slot fills expert slot s (TK if empty)
    def invert_row(dr):
        return jnp.full((E * C,), TK, jnp.int32).at[dr].set(
            jnp.arange(TK, dtype=jnp.int32), mode="drop")

    src = jax.vmap(invert_row)(dest)                       # [B, E*C]

    x_disp = jax.vmap(_permute_rows)(x_slots, src, dest)   # [B, E*C, D]
    x_disp = x_disp.reshape(B, E, C, D)
    x_disp = constrain(x_disp, "batch", "experts", "moe_cap", "embed")

    gate = jnp.einsum("becd,edf->becf", x_disp, p["e_gate"])
    gate = constrain(gate, "batch", "experts", None, "ff")
    up = jnp.einsum("becd,edf->becf", x_disp, p["e_up"]) \
        if cfg.hidden_act != "relu2" else None
    h = activation(cfg.hidden_act, gate, up)
    y_disp = jnp.einsum("becf,efd->becd", h, p["e_down"])
    y_disp = constrain(y_disp, "batch", "experts", "moe_cap", "embed")
    y_flat = y_disp.reshape(B, E * C, D)

    y_slots = jax.vmap(_permute_rows)(y_flat, dest, src)   # [B, TK, D]
    y_slots = jnp.where(keep[..., None], y_slots, 0)
    y = (y_slots.reshape(B, T, K, D)
         * gate_w[..., None].astype(dt)).sum(axis=2)
    return constrain(y, "batch", "seq", "embed"), aux
