"""Attention blocks: GQA/MHA, causal, sliding-window, cross, KV caching.

Three execution modes share one parameter set:
* ``train``/``prefill``: full-sequence flash attention (ref-jnp by default so
  dry-run HLO compiles on any backend; Pallas kernel on real TPU);
* ``decode``: one token against a cache — a contiguous buffer for global
  layers, a **ring buffer of size window** for sliding-window layers (keys
  are RoPE-rotated before caching, so slot order is irrelevant to the
  softmax — set semantics);
* optional int8-quantised cache (per-token per-head scales) for the
  ≥100B-param cells (see DESIGN.md §5 memory table).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.decode_attention import decode_attention
from ..kernels.flash_attention import flash_attention
from .layers import dense_init, rope
from .sharding import constrain


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> Dict:
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * dh, dtype),
        "wk": dense_init(ks[1], d, hk * dh, dtype),
        "wv": dense_init(ks[2], d, hk * dh, dtype),
        "wo": dense_init(ks[3], h * dh, d, dtype),
    }


# ----------------------------------------------------------- cache handling
def quantize_kv(x: jax.Array, dtype: str) -> Tuple[jax.Array, Optional[jax.Array]]:
    """[B, Hkv, S, Dh] -> (stored, scale) with per-(token, head) scales."""
    if dtype != "int8":
        return x, None
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: Optional[jax.Array], dtype) -> jax.Array:
    if scale is None:
        return q
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_cache(cfg: ModelConfig, batch: int, length: int, *, window: bool,
               dtype) -> Dict:
    """ShapeDtype-compatible cache for one attention layer."""
    size = min(length, cfg.sliding_window) if (window and cfg.sliding_window) else length
    hk, dh = cfg.n_kv_heads, cfg.head_dim
    store_dtype = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
    c = {
        "k": jnp.zeros((batch, hk, size, dh), store_dtype),
        "v": jnp.zeros((batch, hk, size, dh), store_dtype),
    }
    if cfg.kv_cache_dtype == "int8":
        c["k_scale"] = jnp.zeros((batch, hk, size, 1), jnp.float32)
        c["v_scale"] = jnp.zeros((batch, hk, size, 1), jnp.float32)
    return c


# ------------------------------------------------------------------ forward
def attention_forward(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,                 # [B, T, D]
    *,
    positions: jax.Array,         # [B, T] absolute positions
    mode: str,                    # train | prefill | decode
    causal: bool = True,
    window: Optional[int] = None,
    cache: Optional[Dict] = None,
    cache_len: Optional[jax.Array] = None,   # int32[B]
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
    use_pallas: bool = False,
    max_cache_len: Optional[int] = None,     # prefill: cache capacity
) -> Tuple[jax.Array, Optional[Dict]]:
    B, T, D = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype

    q = (x @ p["wq"]).reshape(B, T, h, dh)
    if kv_override is None:
        k = (x @ p["wk"]).reshape(B, T, hk, dh)
        v = (x @ p["wv"]).reshape(B, T, hk, dh)
        if cfg.pos_embedding == "rope":
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
    else:
        enc = kv_override[0]  # [B, S_enc, D]
        S_enc = enc.shape[1]
        k = (enc @ p["wk"]).reshape(B, S_enc, hk, dh)
        v = (enc @ p["wv"]).reshape(B, S_enc, hk, dh)
        causal, window = False, None

    q = q.transpose(0, 2, 1, 3)  # [B, H, T, Dh]
    q = constrain(q, "batch", "heads", None, None)

    new_cache = None
    if mode == "decode" and kv_override is None:
        if cache is None or cache_len is None or T != 1:
            raise ValueError(
                f"decode mode needs a cache, cache_len, and T == 1 "
                f"(got cache={cache is not None}, "
                f"cache_len={cache_len is not None}, T={T})")
        k1 = k.transpose(0, 2, 1, 3)  # [B, Hkv, 1, Dh]
        v1 = v.transpose(0, 2, 1, 3)
        size = cache["k"].shape[2]
        # ring-buffer slot: absolute position p lives at slot p % size
        # (for global layers size == max length, so slot == cache_len)
        slot = cache_len % size
        kq, ks = quantize_kv(k1, cfg.kv_cache_dtype)
        vq, vs = quantize_kv(v1, cfg.kv_cache_dtype)

        def upd(buf, val):
            # per-batch dynamic slot update
            def one(b_buf, b_val, b_slot):
                return jax.lax.dynamic_update_slice_in_dim(
                    b_buf, b_val, b_slot, axis=1)
            return jax.vmap(one)(buf, val, slot)

        new_cache = dict(cache)
        new_cache["k"] = upd(cache["k"], kq)
        new_cache["v"] = upd(cache["v"], vq)
        if cfg.kv_cache_dtype == "int8":
            new_cache["k_scale"] = upd(cache["k_scale"], ks)
            new_cache["v_scale"] = upd(cache["v_scale"], vs)

        k_full = dequantize_kv(new_cache["k"], new_cache.get("k_scale"), dt)
        v_full = dequantize_kv(new_cache["v"], new_cache.get("v_scale"), dt)
        valid = jnp.minimum(cache_len + 1, size)  # ring: whole buffer once wrapped
        out = decode_attention(
            q[:, :, 0, :], k_full, v_full, valid,
            scale=dh ** -0.5, use_pallas=use_pallas)  # [B, H, Dh]
        out = out[:, :, None, :]
    else:
        k = k.transpose(0, 2, 1, 3)  # [B, Hkv, S, Dh]
        v = v.transpose(0, 2, 1, 3)
        out = flash_attention(
            q, k, v, causal=causal, window=window, scale=dh ** -0.5,
            use_pallas=use_pallas)
        if mode == "prefill" and kv_override is None:
            cap = max_cache_len or T
            size = min(cap, window) if window else cap
            keep = min(T, size)
            kc = k[:, :, T - keep:, :]
            vc = v[:, :, T - keep:, :]
            if keep < T or (window and size == window):
                # ring invariant: absolute position p lives at slot p % size
                shift = (T - keep) % size
                kc = jnp.roll(jnp.pad(
                    kc, ((0, 0), (0, 0), (0, size - keep), (0, 0))), shift, axis=2)
                vc = jnp.roll(jnp.pad(
                    vc, ((0, 0), (0, 0), (0, size - keep), (0, 0))), shift, axis=2)
            elif size > keep:
                kc = jnp.pad(kc, ((0, 0), (0, 0), (0, size - keep), (0, 0)))
                vc = jnp.pad(vc, ((0, 0), (0, 0), (0, size - keep), (0, 0)))
            kq, ks = quantize_kv(kc, cfg.kv_cache_dtype)
            vq, vs = quantize_kv(vc, cfg.kv_cache_dtype)
            new_cache = {"k": kq, "v": vq}
            if cfg.kv_cache_dtype == "int8":
                new_cache["k_scale"] = ks
                new_cache["v_scale"] = vs

    out = out.transpose(0, 2, 1, 3).reshape(B, T, h * dh)
    y = out @ p["wo"]
    return constrain(y, "batch", "seq", "embed"), new_cache
