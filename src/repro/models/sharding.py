"""Logical-axis sharding: MaxText-style rules mapping logical names to mesh axes.

Model code annotates activations with *logical* axes (``batch``, ``seq``,
``embed``, ``heads``, ``ff``, ``vocab``, ``kv_seq``, ``experts``…); the
launcher installs a :class:`ShardingRules` context binding them to physical
mesh axes per cell (e.g. ``batch → ('pod','data')`` for training,
``kv_seq → 'data'`` for long-context decode).  With no context installed
(CPU smoke tests) every annotation is a no-op, so the same model code runs
everywhere.

Parameter shardings use the same rules via :func:`param_pspec`, which maps
leaf *path names* to logical axis tuples and degrades gracefully when a
dimension does not divide the mesh axis (falls back to replication for that
dim — e.g. whisper's 51865 vocab over a 16-way model axis).
"""
from __future__ import annotations

import re
import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]


@dataclass
class ShardingRules:
    mesh: Mesh
    rules: Dict[str, Axis] = field(default_factory=dict)

    def axis(self, logical: Optional[str]) -> Axis:
        if logical is None:
            return None
        return self.rules.get(logical)

    def spec(self, *logical: Optional[str]) -> P:
        return P(*[self.axis(l) for l in logical])

    def mesh_axis_size(self, axis: Axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[axis]


_CTX = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_CTX, "rules", None)


@contextmanager
def sharding_rules(rules: Optional[ShardingRules]):
    prev = getattr(_CTX, "rules", None)
    _CTX.rules = rules
    try:
        yield rules
    finally:
        _CTX.rules = prev


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op without a context)."""
    r = current_rules()
    if r is None:
        return x
    if x.ndim != len(logical):
        raise ValueError(
            f"axis annotation arity mismatch: {x.shape} vs {logical}")
    spec = []
    used: set = set()
    for dim, l in zip(x.shape, logical):
        a = r.axis(l)
        if a is not None and dim % r.mesh_axis_size(a) != 0:
            a = None  # non-divisible: leave unconstrained
        flat = a if isinstance(a, tuple) else (a,) if a else ()
        if any(f in used for f in flat):
            a = None  # a mesh axis may shard only one dim
        used.update(flat)
        spec.append(a)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, P(*spec)))


# --------------------------------------------------------------- param rules
# leaf-name -> logical axes of the LAST ndim dims (leading stack dims -> None)
PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "tok": ("vocab", "embed_shard"),
    "pos": (None, None),
    "lm_head": ("embed_shard", "vocab"),
    "wq": ("embed_shard", "heads"),
    "wk": ("embed_shard", "heads"),
    "wv": ("embed_shard", "heads"),
    "wo": ("heads", "embed_shard"),
    "q_norm": (None,),
    "k_norm": (None,),
    "wi_gate": ("embed_shard", "ff"),
    "wi_up": ("embed_shard", "ff"),
    "wo_ff": ("ff", "embed_shard"),
    "router": ("embed_shard", None),
    "e_gate": ("experts", "embed_shard", "ff"),
    "e_up": ("experts", "embed_shard", "ff"),
    "e_down": ("experts", "ff", "embed_shard"),
    "in_proj": ("embed_shard", "ff"),
    "conv_w": (None, "ff"),
    "conv_b": ("ff",),
    "x_proj": ("ff", None),
    "dt_w": (None, "ff"),
    "dt_b": ("ff",),
    "A_log": ("ff", None),
    "Dp": ("ff",),
    "out_proj": ("ff", "embed_shard"),
    "scale": (None,),
    "bias": (None,),
}

# default logical -> physical binding used by the launcher; per-cell overrides
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    # Megatron-style sequence parallelism: residuals / norms / elementwise
    # work and the scan-saved activations are seq-sharded over 'model';
    # GSPMD all-gathers around attention and reduce-scatters after (the
    # collective cost shows up in the roofline's collective term).
    "seq": "model",
    "embed": None,            # activation embed dim: replicated
    "embed_shard": "data",    # parameter embed dim: FSDP-sharded over data
    "vocab": "model",
    "heads": "model",
    "ff": "model",
    "experts": None,          # TP-MoE baseline: experts replicated, ff sharded
    "kv_heads": "model",
    "kv_seq": None,
    "ssm_state": None,
    "ce_seq": "model",        # CE chunk sequence dim (distributes logits)
    "attn_q": "model",        # attention q-chunk dim (fallback when heads
                              # don't divide the axis; deduped otherwise)
    "moe_cap": "model",       # MoE expert-capacity dim (dispatch buffers)
    "moe_slots": "model",     # MoE token-slot dim ([B, T·K, D] tensors)
}


def make_rules(mesh: Mesh, **overrides: Axis) -> ShardingRules:
    rules = dict(DEFAULT_RULES)
    # drop axes the mesh doesn't have (e.g. 'pod' on the single-pod mesh)
    def filter_axis(a: Axis) -> Axis:
        if a is None:
            return None
        if isinstance(a, tuple):
            kept = tuple(x for x in a if x in mesh.shape)
            return kept if kept else None
        return a if a in mesh.shape else None

    rules.update(overrides)
    rules = {k: filter_axis(v) for k, v in rules.items()}
    return ShardingRules(mesh=mesh, rules=rules)


def param_pspec(path: str, ndim: int, shape: Tuple[int, ...],
                rules: ShardingRules) -> P:
    """PartitionSpec for a parameter leaf by its path name."""
    name = path.split("/")[-1]
    logical = PARAM_RULES.get(name)
    if logical is None:
        return P()
    spec: list = [None] * (ndim - len(logical)) + [
        rules.axis(l) for l in logical
    ]
    # replicate non-divisible dims; a mesh axis shards at most one dim
    # (earlier logical axes win — e.g. EP: experts take 'model', ff yields)
    used: set = set()
    for i, (dim, a) in enumerate(zip(shape[-len(spec):], spec)):
        if a is not None and dim % rules.mesh_axis_size(a) != 0:
            a = None
        flat = a if isinstance(a, tuple) else (a,) if a else ()
        if any(f in used for f in flat):
            a = None
        used.update(flat)
        spec[i] = a
    return P(*spec)


def tree_pspecs(params, rules: ShardingRules):
    """Map a parameter pytree to a same-structure tree of PartitionSpecs."""
    def visit(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        return param_pspec(name, leaf.ndim, leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(visit, params)


def tree_shardings(params, rules: ShardingRules):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(rules.mesh, s), tree_pspecs(params, rules))
