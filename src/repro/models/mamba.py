"""Mamba-1 block (falcon-mamba, jamba mixer layers).

in_proj → depthwise causal conv1d → SiLU → selective scan (Pallas kernel /
jnp ref) → gate → out_proj.  Decode mode carries (conv window, ssm state)
per layer; both are O(1) in sequence length — this is why the SSM/hybrid
archs run the ``long_500k`` cell that dense attention cannot.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.mamba_scan import mamba_scan, mamba_step
from .layers import dense_init
from .sharding import constrain


def init_mamba(key, cfg: ModelConfig, dtype) -> Dict:
    d, di, n, r, kw = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                       cfg.ssm_conv)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (kw, di), jnp.float32)
                   / math.sqrt(kw)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, r + 2 * n, dtype),
        "dt_w": dense_init(ks[3], r, di, dtype),
        "dt_b": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A),                   # fp32
        "Dp": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 history: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv.  x [B, T, Di], w [K, Di]."""
    K = w.shape[0]
    pad = history if history is not None else jnp.zeros(
        (x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # [B, T+K-1, Di]
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return out + b[None, None, :]


def mamba_forward(
    p: Dict, cfg: ModelConfig, x: jax.Array, *, mode: str,
    cache: Optional[Dict] = None, use_pallas: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    B, T, D = x.shape
    di, n, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    dt = x.dtype

    xz = x @ p["in_proj"]                              # [B, T, 2Di]
    xz = constrain(xz, "batch", "seq", "ff")
    xi, z = jnp.split(xz, 2, axis=-1)

    new_cache = None
    if mode == "decode":
        if cache is None or T != 1:
            raise ValueError(
                f"decode mode needs a conv cache and T == 1 "
                f"(got cache={cache is not None}, T={T})")
        hist = cache["conv"].astype(dt)
        conv_out = _causal_conv(xi, p["conv_w"].astype(dt), p["conv_b"].astype(dt), hist)
        new_conv = jnp.concatenate([hist, xi], axis=1)[:, 1:, :].astype(dt)
        u = jax.nn.silu(conv_out)                      # [B, 1, Di]
        bcd = u @ p["x_proj"]                          # [B, 1, r+2n]
        dt_in, Bm, Cm = jnp.split(bcd, [r, r + n], axis=-1)
        delta = jax.nn.softplus(dt_in @ p["dt_w"] + p["dt_b"])
        A = -jnp.exp(p["A_log"])
        y, h_new = mamba_step(
            u[:, 0].astype(jnp.float32), delta[:, 0].astype(jnp.float32), A,
            Bm[:, 0].astype(jnp.float32), Cm[:, 0].astype(jnp.float32),
            p["Dp"], cache["h"])
        y = y[:, None, :].astype(dt)
        new_cache = {"conv": new_conv, "h": h_new}
    else:
        conv_out = _causal_conv(xi, p["conv_w"].astype(dt), p["conv_b"].astype(dt))
        u = jax.nn.silu(conv_out)
        bcd = u @ p["x_proj"]
        dt_in, Bm, Cm = jnp.split(bcd, [r, r + n], axis=-1)
        delta = jax.nn.softplus(dt_in @ p["dt_w"] + p["dt_b"])
        A = -jnp.exp(p["A_log"])
        if mode == "prefill":
            from ..kernels.mamba_scan import mamba_scan_ref
            y, hT = mamba_scan_ref(
                u.astype(jnp.float32), delta.astype(jnp.float32), A,
                Bm.astype(jnp.float32), Cm.astype(jnp.float32), p["Dp"])
            y = y.astype(dt)
            kw = cfg.ssm_conv
            new_cache = {"conv": xi[:, -(kw - 1):, :].astype(dt), "h": hT}
        else:
            y = mamba_scan(
                u.astype(jnp.float32), delta.astype(jnp.float32), A,
                Bm.astype(jnp.float32), Cm.astype(jnp.float32), p["Dp"],
                use_pallas=use_pallas).astype(dt)

    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return constrain(out, "batch", "seq", "embed"), new_cache
