"""Model assembly: decoder-only LM (dense/ssm/moe/hybrid/vlm) + enc-dec (audio).

Layer stacks run as ``jax.lax.scan`` over *repeating groups* (one group =
the architecture's layer pattern: 1 layer for uniform stacks, 6 for
gemma3's 5-local:1-global, 8 for jamba's 7-mamba:1-attn) so HLO size and
compile time are O(group), not O(n_layers) — essential for the 62-88 layer
archs on the 512-device dry-run.  Layers that don't fill a whole group
("tail") and the whisper enc-dec run unrolled.

Modes: ``train`` (logits for loss), ``prefill`` (logits + cache),
``decode`` (one token + cache update).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import attention_forward, init_attention, init_cache
from .layers import (dtype_of, embed_init, init_rmsnorm, learned_positions,
                     rmsnorm, softcap)
from .mamba import init_mamba, init_mamba_cache, mamba_forward
from .mlp import dense_ffn, init_dense_ffn, init_moe_ffn, moe_ffn
from .sharding import constrain


# ---------------------------------------------------------------------- init
def init_layer(key, cfg: ModelConfig, mixer: str, ffn: str, dtype,
               cross: bool = False) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if mixer.startswith("attn"):
        p["attn"] = init_attention(ks[0], cfg, dtype)
    else:
        p["mamba"] = init_mamba(ks[0], cfg, dtype)
    if cross:
        p["norm_cross"] = init_rmsnorm(cfg.d_model, dtype)
        p["cross"] = init_attention(ks[3], cfg, dtype, cross=True)
    if ffn != "none":
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        p["ffn"] = (init_moe_ffn(ks[1], cfg, dtype) if ffn == "moe"
                    else init_dense_ffn(ks[1], cfg, dtype))
    return p


def _plan(cfg: ModelConfig) -> Tuple[int, int, List[Tuple[str, str]]]:
    """(n_groups, n_tail, kinds-per-group-position)."""
    g = cfg.group_len if cfg.scan_layers else cfg.n_layers
    if not cfg.scan_layers:
        return 0, cfg.n_layers, [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    n_groups = cfg.n_layers // g
    n_tail = cfg.n_layers - n_groups * g
    kinds = [cfg.layer_kind(j) for j in range(g)]
    return n_groups, n_tail, kinds


def init_params(key, cfg: ModelConfig) -> Dict:
    dtype = dtype_of(cfg)
    n_groups, n_tail, kinds = _plan(cfg)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": {"tok": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)},
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.pos_embedding == "learned":
        length = cfg.decoder_positions or 2048
        params["embed"]["pos"] = embed_init(keys[1], length, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        from .layers import dense_init
        params["lm_head"] = dense_init(keys[2], cfg.d_model, cfg.vocab_size, dtype)

    cross = cfg.is_encoder_decoder
    if n_groups:
        gkeys = jax.random.split(keys[3], n_groups)
        stacked = []
        for j, (mixer, ffn) in enumerate(kinds):
            def one(k, j=j, mixer=mixer, ffn=ffn):
                return init_layer(jax.random.fold_in(k, j), cfg, mixer, ffn,
                                  dtype, cross=cross)
            stacked.append(jax.vmap(one)(gkeys))
        params["groups"] = stacked
    tail = []
    tail_kinds = ([cfg.layer_kind(n_groups * cfg.group_len + i)
                   for i in range(n_tail)] if cfg.scan_layers else kinds)
    for i, (mixer, ffn) in enumerate(tail_kinds):
        tail.append(init_layer(jax.random.fold_in(keys[4], i), cfg, mixer,
                               ffn, dtype, cross=cross))
    params["tail"] = tail

    if cfg.is_encoder_decoder:
        enc_layers = []
        for i in range(cfg.n_encoder_layers):
            enc_layers.append(init_layer(
                jax.random.fold_in(keys[5], i), cfg, "attn", "dense", dtype))
        params["encoder"] = {
            "layers": enc_layers,
            "pos": embed_init(keys[6], cfg.encoder_positions, cfg.d_model, dtype),
            "final_norm": init_rmsnorm(cfg.d_model, dtype),
        }
    return params


# --------------------------------------------------------------------- cache
def init_layer_cache(cfg: ModelConfig, mixer: str, batch: int, length: int,
                     dtype) -> Dict:
    if mixer == "mamba":
        return init_mamba_cache(cfg, batch, dtype)
    return init_cache(cfg, batch, length, window=(mixer == "attn_local"),
                      dtype=dtype)


def init_decode_cache(cfg: ModelConfig, batch: int, length: int) -> Dict:
    """Whole-model cache pytree (used concretely and as ShapeDtypeStructs)."""
    dtype = dtype_of(cfg)
    n_groups, n_tail, kinds = _plan(cfg)
    cache: Dict[str, Any] = {}
    if n_groups:
        stacked = []
        for mixer, _ in kinds:
            one = init_layer_cache(cfg, mixer, batch, length, dtype)
            stacked.append(jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), one))
        cache["groups"] = stacked
    tail_kinds = ([cfg.layer_kind(n_groups * cfg.group_len + i)
                   for i in range(n_tail)] if cfg.scan_layers else kinds)
    cache["tail"] = [init_layer_cache(cfg, m, batch, length, dtype)
                     for m, _ in tail_kinds]
    if cfg.is_encoder_decoder:
        cache["enc_out"] = jnp.zeros(
            (batch, cfg.encoder_positions, cfg.d_model), dtype)
    return cache


# ------------------------------------------------------------------- forward
def apply_layer(p: Dict, cfg: ModelConfig, x: jax.Array, mixer: str, ffn: str,
                *, positions, mode, cache, cache_len, enc_out,
                use_pallas: bool, max_cache_len: Optional[int] = None,
                ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if mixer.startswith("attn"):
        window = cfg.sliding_window if mixer == "attn_local" else None
        causal = not (cfg.is_encoder_decoder and mode == "encode")
        att, new_cache = attention_forward(
            p["attn"], cfg, h, positions=positions, mode=mode, causal=causal,
            window=window, cache=cache, cache_len=cache_len,
            use_pallas=use_pallas, max_cache_len=max_cache_len)
    else:
        att, new_cache = mamba_forward(
            p["mamba"], cfg, h, mode=mode, cache=cache, use_pallas=use_pallas)
    x = x + att
    if "cross" in p and enc_out is not None:
        hc = rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        catt, _ = attention_forward(
            p["cross"], cfg, hc, positions=positions, mode="train",
            kv_override=(enc_out, enc_out), use_pallas=use_pallas)
        x = x + catt
    if ffn != "none":
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if ffn == "moe":
            y, aux = moe_ffn(p["ffn"], cfg, h2)
        else:
            y = dense_ffn(p["ffn"], cfg, h2)
        x = x + y
    return x, new_cache, aux


def encoder_forward(params: Dict, cfg: ModelConfig, frames: jax.Array,
                    use_pallas: bool = False) -> jax.Array:
    """Whisper encoder over precomputed (stub-frontend) frame embeddings."""
    enc = params["encoder"]
    S = frames.shape[1]
    x = frames + enc["pos"][None, :S, :]
    pos = jnp.broadcast_to(jnp.arange(S)[None], frames.shape[:2])
    for lp in enc["layers"]:
        x, _, _ = apply_layer(
            lp, cfg, x, "attn", "dense", positions=pos, mode="encode",
            cache=None, cache_len=None, enc_out=None, use_pallas=use_pallas)
    return rmsnorm(enc["final_norm"], x, cfg.norm_eps)


def forward(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,                    # [B, T]
    *,
    mode: str = "train",                  # train | prefill | decode
    cache: Optional[Dict] = None,
    cache_len: Optional[jax.Array] = None,  # int32[B]
    patch_embeds: Optional[jax.Array] = None,
    encoder_frames: Optional[jax.Array] = None,
    use_pallas: bool = False,
    _return_hidden: bool = False,
    max_cache_len: Optional[int] = None,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (logits | hidden, new_cache, aux_loss)."""
    dtype = dtype_of(cfg)
    B, T = tokens.shape
    n_groups, n_tail, kinds = _plan(cfg)

    x = jnp.take(params["embed"]["tok"], tokens, axis=0).astype(dtype)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    if patch_embeds is not None and cfg.frontend == "vision":
        P_ = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(dtype), x[:, P_:, :]], axis=1)
    if mode == "decode":
        positions = cache_len[:, None]                     # [B, 1]
    else:
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    if cfg.pos_embedding == "learned":
        x = x + learned_positions(params["embed"]["pos"], positions).astype(dtype)
    x = constrain(x, "batch", "seq", "embed")

    enc_out = None
    if cfg.is_encoder_decoder:
        if encoder_frames is not None:
            enc_out = encoder_forward(params, cfg, encoder_frames, use_pallas)
        elif cache is not None:
            enc_out = cache["enc_out"]

    aux_total = jnp.zeros((), jnp.float32)

    def run_layer(lp, x, mixer, ffn, lcache):
        return apply_layer(
            lp, cfg, x, mixer, ffn, positions=positions, mode=mode,
            cache=lcache, cache_len=cache_len, enc_out=enc_out,
            use_pallas=use_pallas, max_cache_len=max_cache_len)

    if n_groups:
        has_cache_in = cache is not None
        builds_cache = mode in ("prefill", "decode")

        def group_step(carry, xs):
            x, aux = carry
            gparams = xs[0] if has_cache_in else xs
            gcache = xs[1] if has_cache_in else None
            new_gcache = []
            for j, (mixer, ffn) in enumerate(kinds):
                lc = gcache[j] if gcache is not None else None
                x, nc, a = run_layer(gparams[j], x, mixer, ffn, lc)
                aux = aux + a
                if builds_cache:
                    new_gcache.append(nc if nc is not None else lc)
            return (x, aux), (new_gcache if builds_cache else 0)

        step = group_step
        if cfg.remat:
            step = jax.checkpoint(
                group_step,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        xs = (params["groups"], cache["groups"]) if has_cache_in \
            else params["groups"]
        (x, aux_total), new_group_cache = jax.lax.scan(
            step, (x, aux_total), xs)
    else:
        new_group_cache = None

    tail_kinds = ([cfg.layer_kind(n_groups * cfg.group_len + i)
                   for i in range(n_tail)] if cfg.scan_layers else kinds)
    new_tail_cache = []
    for i, (mixer, ffn) in enumerate(tail_kinds):
        lc = cache["tail"][i] if cache is not None else None
        layer_fn = run_layer
        if cfg.remat:
            layer_fn = jax.checkpoint(
                run_layer,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                static_argnums=(2, 3))
        x, nc, a = layer_fn(params["tail"][i], x, mixer, ffn, lc)
        aux_total = aux_total + a
        new_tail_cache.append(nc if nc is not None else lc)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if _return_hidden:
        logits = x
    else:
        if cfg.tie_embeddings:
            logits = jnp.einsum("btd,vd->btv", x, params["embed"]["tok"])
        else:
            logits = x @ params["lm_head"]
        logits = softcap(logits, cfg.logit_softcap)
        logits = constrain(logits, "batch", "seq", "vocab")

    new_cache = None
    if cache is not None or mode == "prefill":
        new_cache = {}
        if n_groups:
            new_cache["groups"] = new_group_cache
        new_cache["tail"] = new_tail_cache
        if cfg.is_encoder_decoder and enc_out is not None:
            new_cache["enc_out"] = enc_out
    return logits, new_cache, aux_total
