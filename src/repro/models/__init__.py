"""Model substrate: layers, attention, mamba, MoE, transformer assembly."""
from .model import Model, TrainState, build_model
from .sharding import ShardingRules, make_rules, sharding_rules, tree_pspecs

__all__ = ["Model", "TrainState", "build_model", "ShardingRules",
           "make_rules", "sharding_rules", "tree_pspecs"]
