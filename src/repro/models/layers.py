"""Primitive layers: norms, embeddings, RoPE, activations, dense projections.

Functional style: ``init_*`` builds param dicts (named leaves drive the
sharding rules in :mod:`repro.models.sharding`), ``apply`` functions are
pure.  Norm/softmax statistics accumulate in fp32 regardless of the compute
dtype.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[cfg.dtype]


# ------------------------------------------------------------------- inits
def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32)).astype(dtype)


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


# ------------------------------------------------------------------ applies
def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def activation(name: str, gate: jax.Array, up: Optional[jax.Array]) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(gate) * up
    if name == "gelu":
        return jax.nn.gelu(gate, approximate=True) * up
    if name == "relu2":
        r = jax.nn.relu(gate)
        return r * r  # squared-ReLU, ungated (nemotron)
    raise ValueError(name)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (jnp.tanh(x.astype(jnp.float32) / cap) * cap).astype(x.dtype)


# --------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x [..., T, H, Dh]; positions [..., T] (absolute)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., T, half]
    sin = jnp.sin(ang)[..., None, :]  # broadcast over heads
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def learned_positions(table: jax.Array, positions: jax.Array) -> jax.Array:
    # extend-by-wraparound beyond the published table (DESIGN.md §4 note)
    return jnp.take(table, positions % table.shape[0], axis=0)
