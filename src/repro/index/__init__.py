"""Secondary indexes over bigset element values, under the CRDT clocks.

The paper's read trade-off is "mitigated by enabling queries on sets"
(§4.4); PR 1's query engine filters by element *order* only.  This package
adds payload filtering: per-set named indexes whose postings live in the
same ordered keyspace as the element-keys they mirror —

    ``(set, KIND_INDEX, index_name, index_key, element, actor, counter)``

— and under the same set-clock / set-tombstone.  The consistency argument
is one sentence: **a posting is live iff its dot is live.**  Postings are
written in the same atomic batch as their element-key (coordinator and
downstream replica re-derive them from the delta), filtered by the same
batched ``dot_seen`` visibility pass at query time, and discarded by the
same compaction filter in the same pass — so a concurrent remove makes a
posting invisible without any index write, and there is no separate index
GC or index replication.

* :mod:`repro.index.spec`     — :class:`IndexSpec` + standard extractors;
* :mod:`repro.index.postings` — posting key codec and range bounds.

Query plans (`IndexLookup` / `IndexRange`) live in :mod:`repro.query.plan`;
the quorum-merged cluster path in
:meth:`repro.cluster.clusters.BigsetCluster.query`.
"""
from .postings import (decode_posting_key, index_bounds, index_range,
                       lookup_span, posting_key)
from .spec import (IndexSpec, by_element_prefix, by_element_suffix, by_field,
                   by_length, by_value, by_value_prefix)

__all__ = [
    "IndexSpec", "by_element_prefix", "by_element_suffix", "by_field",
    "by_length", "by_value", "by_value_prefix",
    "decode_posting_key", "index_bounds", "index_range", "lookup_span",
    "posting_key",
]
