"""Index specs — pluggable extractors from element/value to index keys.

An :class:`IndexSpec` names a secondary index and supplies its extractor:
``extract(element, value) -> iterable of index keys``.  The extractor must
be **deterministic** — downstream replicas re-derive postings from the
replicated :class:`~repro.core.bigset.InsertDelta` (which carries element
and value), so no index data ever travels on the wire.  An extractor that
yields nothing leaves the insert unindexed under that index; yielding
several keys builds a multi-valued index.

Extractors run on the write path (and during backfill), so they should be
cheap and must never raise: malformed payloads yield no keys.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, Tuple

import msgpack

Extractor = Callable[[bytes, bytes], Iterable[bytes]]


@dataclass(frozen=True)
class IndexSpec:
    """A named secondary index over one bigset.

    ``name`` scopes the index's posting range inside the set's keyspace;
    two specs with the same name on one set are the same index (last
    registration wins).
    """

    name: bytes
    extract: Extractor

    def keys(self, element: bytes, value: bytes) -> Tuple[bytes, ...]:
        """Extractor call with the never-raise contract enforced."""
        try:
            return tuple(self.extract(element, value))
        except Exception:
            return ()


# ------------------------------------------------------- standard extractors
def by_value(name: bytes = b"value") -> IndexSpec:
    """Index each insert under its whole value payload (empty values skip)."""
    return IndexSpec(name, lambda el, v: (v,) if v else ())


def by_value_prefix(n: int, name: bytes | None = None) -> IndexSpec:
    """Index under the first ``n`` bytes of the value (empty values skip)."""
    return IndexSpec(
        name or b"value_prefix:%d" % n,
        lambda el, v: (v[:n],) if v else ())


def by_element_prefix(n: int, name: bytes | None = None) -> IndexSpec:
    """Index under the first ``n`` bytes of the element itself."""
    return IndexSpec(name or b"element_prefix:%d" % n, lambda el, v: (el[:n],))


def by_element_suffix(n: int, name: bytes | None = None) -> IndexSpec:
    """Index under the last ``n`` bytes of the element (hash-bucket style)."""
    return IndexSpec(name or b"element_suffix:%d" % n, lambda el, v: (el[-n:],))


def by_length(name: bytes = b"length") -> IndexSpec:
    """Index under the value length, fixed-width so keys sort numerically."""
    return IndexSpec(name, lambda el, v: (b"%012d" % len(v),))


def by_field(field: bytes, name: bytes | None = None) -> IndexSpec:
    """Index under one field of a msgpack-map value (absent/bad -> no keys).

    The field's value is indexed as bytes (str values are utf-8 encoded);
    non-scalar fields are skipped.
    """

    def extract(el: bytes, v: bytes) -> Iterable[bytes]:
        obj = msgpack.unpackb(v, strict_map_key=False)
        if not isinstance(obj, dict):
            return ()
        got = obj.get(field, obj.get(field.decode("utf-8", "replace")))
        if isinstance(got, bytes):
            return (got,)
        if isinstance(got, str):
            return (got.encode("utf-8"),)
        if isinstance(got, int) and 0 <= got < 1 << 63:
            return (b"%020d" % got,)
        return ()

    return IndexSpec(name or b"field:" + field, extract)
