"""Posting keys — the secondary-index keyspace inside a set's key range.

A posting is ``(set, KIND_INDEX, index_name, index_key, element, actor,
counter) -> b""``: the element-key of an insert, re-sorted by the index key
its extractor produced.  Postings live in the *same* LSM keyspace as the
element-keys they mirror and under the same set-clock / set-tombstone:

* written in the same atomic batch as the element-key (coordinator and
  downstream replica alike, re-derived from the delta's element + value);
* live iff their dot is live — visibility is the same batched
  ``tombstone.seen(dot)`` filter the element scan uses;
* discarded by the same compaction filter, in the same pass, as the
  element-key that shares their dot.  There is no separate index GC.

``KIND_INDEX`` sorts immediately after ``KIND_ELEMENT``, so element scans
(`element_bounds`) and posting scans never overlap, and a set remains one
contiguous key range.
"""
from __future__ import annotations

from typing import Optional, Tuple

from ..core.dots import Dot, dot_from_key
from ..storage.keycodec import (KIND_INDEX, decode_key, encode_key,
                                prefix_bounds, successor_bytes)

# (index_key, element): the sort position of one posting group
Position = Tuple[bytes, bytes]


def posting_key(
    set_name: bytes, index_name: bytes, index_key: bytes,
    element: bytes, dot: Dot,
) -> bytes:
    return encode_key((set_name, KIND_INDEX, index_name, index_key,
                       element, dot.actor, dot.counter))


def decode_posting_key(key: bytes) -> Tuple[bytes, bytes, bytes, bytes, Dot]:
    """Decode ``(set_name, index_name, index_key, element, dot)``.

    Raises :class:`ValueError` for any other key kind — postings share the
    keyspace with clocks and element-keys, and a silent mis-decode would
    fabricate a garbage dot.
    """
    parts = decode_key(key)
    if len(parts) != 7 or parts[1] != KIND_INDEX:
        raise ValueError(f"not an index posting key: {parts!r}")
    set_name, _kind, index_name, index_key, element, actor, counter = parts
    return set_name, index_name, index_key, element, dot_from_key(
        actor, counter)


def index_range(set_name: bytes, index_name: bytes) -> Tuple[bytes, bytes]:
    """Bounds of one whole index's posting range."""
    return prefix_bounds((set_name, KIND_INDEX, index_name))


def index_bounds(
    set_name: bytes,
    index_name: bytes,
    start: Optional[bytes] = None,
    end: Optional[bytes] = None,
    at: Optional[Position] = None,
    after: Optional[Position] = None,
) -> Tuple[bytes, bytes]:
    """Encoded posting bounds for index keys in ``[start, end)``.

    ``at``/``after`` position the scan at a ``(index_key, element)`` group
    boundary for cursor resumption: ``at`` starts *at* the group (a page
    that emitted nothing), ``after`` strictly past every posting of the
    group (``element + b"\\x00"`` upper-bounds the group, exactly as the
    element-keyspace cursor does).  They win over ``start``.
    """
    if after is not None:
        ik, el = after
        lo = encode_key(
            (set_name, KIND_INDEX, index_name, ik, successor_bytes(el)))
    elif at is not None:
        ik, el = at
        lo = encode_key((set_name, KIND_INDEX, index_name, ik, el))
    elif start is not None:
        lo = encode_key((set_name, KIND_INDEX, index_name, start))
    else:
        lo = encode_key((set_name, KIND_INDEX, index_name))
    if end is not None:
        hi = encode_key((set_name, KIND_INDEX, index_name, end))
    else:
        hi = index_range(set_name, index_name)[1]
    return lo, hi


def lookup_span(key: bytes) -> Tuple[bytes, bytes]:
    """The ``[start, end)`` index-key span matching exactly ``key``."""
    return key, successor_bytes(key)
