"""Core CRDT library - the paper's primary contribution.

* :mod:`repro.core.clock` - BaseVV + DotCloud logical clocks (paper 4.1)
* :mod:`repro.core.orswot` - state-based ORSWOT (Riak Sets baseline, paper 2)
* :mod:`repro.core.delta_orswot` - delta-replication baseline (paper 3)
* :mod:`repro.core.bigset` - the decomposed bigset (paper 4, Algorithms 1 & 2)
* :mod:`repro.core.streaming` - streaming ORSWOT join / quorum reads (paper 4.4)
* :mod:`repro.core.vclock` - dense JAX clock arrays backing the Pallas
  dot-seen / clock-join kernels used by the framework's checkpoint and
  membership planes
"""
from .clock import Clock
from .dots import Dot
from .orswot import Orswot
from .bigset import BigsetVnode, InsertDelta, RemoveDelta

__all__ = ["Clock", "Dot", "Orswot", "BigsetVnode", "InsertDelta", "RemoveDelta"]
