"""Logical clocks for bigset: ``{BaseVV(), DotCloud()}`` (paper §4.1).

Both the *set-clock* and the *set-tombstone* are instances of this structure:

* ``base`` — a version vector: ``actor -> max contiguous counter`` (events
  ``1..base[actor]`` have all been seen).
* ``runs`` — the dot-cloud, *interval-compressed*: ``actor -> tuple of
  (lo, hi) runs`` of counters seen beyond the contiguous base.  Invariants:
  runs are sorted, disjoint, non-adjacent (``next.lo > prev.hi + 1``), and
  the first run starts at ``base[a] + 2`` or later (a run touching the base
  would have been folded into it).

This is Riak's bigset clock-ranges idea: a removal below the base used to
fragment the summary into one cloud entry *per retained counter* (the old
frozenset cloud's documented "hole" problem); with runs the cost of any
clock is O(actors + interval runs) — causal metadata — never O(dots).  The
legacy per-dot cloud is still available as the read-only :attr:`cloud`
property (O(events); tests and legacy codecs only).

A replica **never** has an entry for itself in the DotCloud (paper §4.1): a
coordinator only mints contiguous events for itself via :meth:`increment`.

The clock is a join-semilattice under :meth:`join`; :meth:`seen` is the
membership test used by Algorithms 1 & 2 and by compaction.  The tombstone
additionally *shrinks* via :meth:`subtract` / :meth:`subtract_clock` once
compaction discards keys (paper §4.3.3), and digest comparison ships
diverged *ranges* via :meth:`diff_runs` — all O(runs) run merges.

The implementation is purely functional: every operation returns a new clock.
"""
from __future__ import annotations

from bisect import bisect_right
from typing import Dict, FrozenSet, Iterable, List, Mapping, Sequence, Tuple

from .dots import ActorId, Dot, as_dot

Run = Tuple[int, int]

_EMPTY: "Clock | None" = None


# ---------------------------------------------------------------- run algebra
def runs_from_counters(counters: Iterable[int]) -> Tuple[Run, ...]:
    """Sorted-unique counters -> coalesced (lo, hi) runs."""
    cs = sorted(set(int(c) for c in counters))
    out: List[Run] = []
    for c in cs:
        if out and c == out[-1][1] + 1:
            out[-1] = (out[-1][0], c)
        else:
            out.append((c, c))
    return tuple(out)


def canonical_runs(runs: Iterable[Sequence[int]]) -> Tuple[Run, ...]:
    """Arbitrary (lo, hi) pairs -> sorted, coalesced, non-empty runs."""
    rs = sorted((int(lo), int(hi)) for lo, hi in runs if int(lo) <= int(hi))
    out: List[Run] = []
    for lo, hi in rs:
        if out and lo <= out[-1][1] + 1:
            if hi > out[-1][1]:
                out[-1] = (out[-1][0], hi)
        else:
            out.append((lo, hi))
    return tuple(out)


def union_runs(x: Sequence[Run], y: Sequence[Run]) -> Tuple[Run, ...]:
    """Union of two canonical run lists — O(|x| + |y|) merge."""
    if not x:
        return tuple(y)
    if not y:
        return tuple(x)
    out: List[Run] = []
    i = j = 0
    while i < len(x) or j < len(y):
        if j >= len(y) or (i < len(x) and x[i][0] <= y[j][0]):
            lo, hi = x[i]
            i += 1
        else:
            lo, hi = y[j]
            j += 1
        if out and lo <= out[-1][1] + 1:
            if hi > out[-1][1]:
                out[-1] = (out[-1][0], hi)
        else:
            out.append((lo, hi))
    return tuple(out)


def difference_runs(x: Sequence[Run], y: Sequence[Run]) -> Tuple[Run, ...]:
    """Events in ``x`` not in ``y`` — O(|x| + |y|) merge."""
    if not x or not y:
        return tuple(x)
    out: List[Run] = []
    j = 0
    for lo, hi in x:
        cur = lo
        while j < len(y) and y[j][1] < cur:
            j += 1
        k = j
        while k < len(y) and y[k][0] <= hi:
            ylo, yhi = y[k]
            if ylo > cur:
                out.append((cur, ylo - 1))
            cur = max(cur, yhi + 1)
            if yhi > hi:
                break
            k += 1
        if cur <= hi:
            out.append((cur, hi))
    return tuple(out)


def intersect_runs(x: Sequence[Run], y: Sequence[Run]) -> Tuple[Run, ...]:
    """Events in both ``x`` and ``y`` — O(|x| + |y|) merge."""
    out: List[Run] = []
    i = j = 0
    while i < len(x) and j < len(y):
        lo = max(x[i][0], y[j][0])
        hi = min(x[i][1], y[j][1])
        if lo <= hi:
            out.append((lo, hi))
        if x[i][1] < y[j][1]:
            i += 1
        else:
            j += 1
    return tuple(out)


def runs_contain(runs: Sequence[Run], c: int) -> bool:
    """Point membership — O(log runs) bisect on run starts."""
    i = bisect_right(runs, (c, float("inf")))
    return i > 0 and runs[i - 1][1] >= c


def covers_runs(x: Sequence[Run], y: Sequence[Run]) -> bool:
    """Is every event of ``y`` inside ``x``?  O(|x| + |y|).

    Because canonical runs are coalesced, a covered ``y`` run must sit
    within a *single* ``x`` run (a gap between x runs is a real gap).
    """
    i = 0
    for lo, hi in y:
        while i < len(x) and x[i][1] < lo:
            i += 1
        if i >= len(x) or x[i][0] > lo or x[i][1] < hi:
            return False
    return True


def count_runs_events(runs: Sequence[Run]) -> int:
    return sum(hi - lo + 1 for lo, hi in runs)


def _split_full(full: Tuple[Run, ...]) -> Tuple[int, Tuple[Run, ...]]:
    """Full run list -> (base, beyond-base runs)."""
    if full and full[0][0] == 1:
        return full[0][1], full[1:]
    return 0, full


class Clock:
    __slots__ = ("base", "runs")

    def __init__(
        self,
        base: Mapping[ActorId, int] | None = None,
        cloud: Mapping[ActorId, Iterable[int]] | None = None,
        runs: Mapping[ActorId, Iterable[Sequence[int]]] | None = None,
        _normalise: bool = True,  # kept for signature compat; always normalises
    ):
        b: Dict[ActorId, int] = {
            a: int(n) for a, n in (base or {}).items() if int(n) > 0
        }
        r: Dict[ActorId, Tuple[Run, ...]] = {}
        for a, rs in (runs or {}).items():
            cr = canonical_runs(rs)
            if cr:
                r[a] = cr
        for a, s in (cloud or {}).items():
            cr = runs_from_counters(s)
            if cr:
                r[a] = union_runs(r[a], cr) if a in r else cr
        # fold runs contiguous with the base into the base VV (normalisation)
        for a in list(r):
            full = union_runs(((1, b[a]),) if a in b else (), r[a])
            bb, rr = _split_full(full)
            if bb:
                b[a] = bb
            if rr:
                r[a] = rr
            else:
                del r[a]
        self.base: Mapping[ActorId, int] = b
        self.runs: Mapping[ActorId, Tuple[Run, ...]] = r

    @classmethod
    def _make(
        cls,
        base: Dict[ActorId, int],
        runs: Dict[ActorId, Tuple[Run, ...]],
    ) -> "Clock":
        """Trusted fast path: parts already satisfy the run invariants."""
        c = object.__new__(cls)
        c.base = base
        c.runs = runs
        return c

    # ---------------------------------------------------------------- basics
    @staticmethod
    def zero() -> "Clock":
        global _EMPTY
        if _EMPTY is None:
            _EMPTY = Clock._make({}, {})
        return _EMPTY

    def is_zero(self) -> bool:
        return not self.base and not self.runs

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, Clock):
            return NotImplemented
        return self.base == other.base and self.runs == other.runs

    def __hash__(self) -> int:
        return hash(
            (
                tuple(sorted(self.base.items())),
                tuple(sorted(self.runs.items())),
            )
        )

    def __repr__(self) -> str:
        runs = {a: list(rs) for a, rs in sorted(self.runs.items())}
        return f"Clock(base={dict(sorted(self.base.items()))}, runs={runs})"

    @property
    def cloud(self) -> Mapping[ActorId, FrozenSet[int]]:
        """Legacy per-dot view of the run cloud — O(events beyond base).

        Compatibility/oracle accessor only: production layers outside
        ``core/`` must stay O(runs) (lint rule BS008 enforces this).
        """
        return {
            a: frozenset(c for lo, hi in rs for c in range(lo, hi + 1))
            for a, rs in self.runs.items()
        }

    def _full(self, a: ActorId) -> Tuple[Run, ...]:
        """Canonical run list over *all* events seen for actor ``a``."""
        b = self.base.get(a, 0)
        rs = self.runs.get(a, ())
        return ((1, b),) + rs if b else rs

    # ----------------------------------------------------------------- seen
    def seen(self, dot: Dot) -> bool:
        """Has this clock observed ``dot``?  (Algorithms 1 & 2's test.)"""
        dot = as_dot(dot)
        if dot.counter <= self.base.get(dot.actor, 0):
            return True
        return runs_contain(self.runs.get(dot.actor, ()), dot.counter)

    def seen_all(self, dots: Iterable[Dot]) -> bool:
        return all(self.seen(d) for d in dots)

    # ----------------------------------------------------------- coordinator
    def increment(self, actor: ActorId) -> Tuple["Clock", Dot]:
        """Mint the next contiguous event for ``actor`` (coordinator-side).

        Returns ``(clock', dot)`` where ``dot`` is the freshly minted event.
        Only ever called by a replica for *itself*, hence it extends the base
        VV and never touches the run cloud (a replica has no cloud entry for
        itself, §4.1).
        """
        if actor in self.runs:
            # §4.1 invariant: "A replica will never have an entry for itself
            # in the DotCloud" — minting below a gap would reuse/skip events.
            raise ValueError(f"actor {actor!r} has its own dots in the cloud")
        base = dict(self.base)
        nxt = base.get(actor, 0) + 1
        base[actor] = nxt
        return Clock._make(base, dict(self.runs)), Dot(actor, nxt)

    def latest_dot(self, actor: ActorId) -> Dot:
        return Dot(actor, self.base.get(actor, 0))

    # ------------------------------------------------------------------ add
    def add(self, dot: Dot) -> "Clock":
        """Add one observed event (replica-side delta apply)."""
        dot = as_dot(dot)
        if self.seen(dot):
            return self
        return self.add_dots((dot,))

    def add_dots(self, dots: Iterable[Dot]) -> "Clock":
        by_actor: Dict[ActorId, List[int]] = {}
        for d in dots:
            d = as_dot(d)
            if not self.seen(d):
                by_actor.setdefault(d.actor, []).append(d.counter)
        if not by_actor:
            return self
        base = dict(self.base)
        runs = dict(self.runs)
        for a, cs in by_actor.items():
            full = union_runs(self._full(a), runs_from_counters(cs))
            self._set_actor(base, runs, a, full)
        return Clock._make(base, runs)

    def add_runs(self, ranges: Iterable[Tuple[ActorId, int, int]]) -> "Clock":
        """Observe whole ``(actor, lo, hi)`` ranges — O(runs) bulk apply.

        This is how digest-sync results are absorbed: diverged *ranges* from
        :meth:`diff_runs` apply without ever enumerating counters.
        """
        by_actor: Dict[ActorId, List[Run]] = {}
        for a, lo, hi in ranges:
            if int(lo) <= int(hi):
                by_actor.setdefault(a, []).append((int(lo), int(hi)))
        if not by_actor:
            return self
        base = dict(self.base)
        runs = dict(self.runs)
        changed = False
        for a, rs in by_actor.items():
            full0 = self._full(a)
            full = union_runs(full0, canonical_runs(rs))
            if full != full0:
                changed = True
                self._set_actor(base, runs, a, full)
        return Clock._make(base, runs) if changed else self

    # ----------------------------------------------------------------- join
    def join(self, other: "Clock") -> "Clock":
        """Least upper bound of two clocks (semilattice join) — O(runs)."""
        if self is other:
            return self
        base: Dict[ActorId, int] = {}
        runs: Dict[ActorId, Tuple[Run, ...]] = {}
        for a in self.actors() | other.actors():
            full = union_runs(self._full(a), other._full(a))
            self._set_actor(base, runs, a, full)
        return Clock._make(base, runs)

    # ------------------------------------------------------------- subtract
    def subtract(self, dots: Iterable[Dot]) -> "Clock":
        """Remove ``dots`` from this clock (tombstone trimming, §4.3.3).

        Only meaningful for clocks that describe *sets of dots* (the
        set-tombstone, survivors digests): after compaction discards an
        element-key, its dot is subtracted so the summary stays minimal.
        Subtracting a dot below the base splits the base run into interval
        runs for the retained ranges — the hole is permanent (counters are
        never re-minted), but the cost stays O(interval runs), never
        O(retained counters) as in the old frozenset cloud.
        """
        by_actor: Dict[ActorId, List[int]] = {}
        for d in dots:
            d = as_dot(d)
            by_actor.setdefault(d.actor, []).append(d.counter)
        if not by_actor:
            return self
        base = dict(self.base)
        runs = dict(self.runs)
        changed = False
        for a, cs in by_actor.items():
            full0 = self._full(a)
            full = difference_runs(full0, runs_from_counters(cs))
            if full != full0:
                changed = True
                self._set_actor(base, runs, a, full)
        return Clock._make(base, runs) if changed else self

    def subtract_clock(self, other: "Clock") -> "Clock":
        """Set-minus of dot sets: events seen by self but not by other.

        The O(runs) replacement for ``subtract(o.all_dots())`` — used by
        survivors digests (raw total minus tombstone) and tombstone trims.
        """
        base: Dict[ActorId, int] = {}
        runs: Dict[ActorId, Tuple[Run, ...]] = {}
        changed = False
        for a in self.actors():
            full0 = self._full(a)
            full = difference_runs(full0, other._full(a))
            if full != full0:
                changed = True
            self._set_actor(base, runs, a, full)
        return Clock._make(base, runs) if changed else self

    def intersect(self, other: "Clock") -> "Clock":
        """Events seen by both clocks — O(runs).

        Tombstone trimming uses this to drop entries with no backing
        element-key: ``ts.intersect(raw)`` keeps only removals the raw
        total actually covers.
        """
        base: Dict[ActorId, int] = {}
        runs: Dict[ActorId, Tuple[Run, ...]] = {}
        changed = False
        for a in self.actors():
            full0 = self._full(a)
            full = intersect_runs(full0, other._full(a))
            if full != full0:
                changed = True
            self._set_actor(base, runs, a, full)
        return Clock._make(base, runs) if changed else self

    # ------------------------------------------------------------- ordering
    def descends(self, other: "Clock") -> bool:
        """True iff self has seen every event other has (self >= other)."""
        for a in other.actors():
            if not covers_runs(self._full(a), other._full(a)):
                return False
        return True

    def dominates(self, other: "Clock") -> bool:
        return self.descends(other) and self != other

    # ---------------------------------------------------------------- dots
    def diff_runs(self, other: "Clock") -> Tuple[Tuple[ActorId, int, int], ...]:
        """Ranges seen by ``self`` but not ``other`` — O(runs).

        This is the digest subtraction at the heart of digest-driven
        anti-entropy: two survivors digests (clock summaries of surviving
        element-key dots) yield the exact diverged *ranges* without touching
        a single element-key, and without enumerating a single counter.
        """
        out: List[Tuple[ActorId, int, int]] = []
        for a in sorted(self.actors(), key=repr):
            for lo, hi in difference_runs(self._full(a), other._full(a)):
                out.append((a, lo, hi))
        return tuple(out)

    def diff_dots(self, other: "Clock") -> Tuple[Dot, ...]:
        """Dots seen by ``self`` but not by ``other`` — O(diff).

        Enumerated form of :meth:`diff_runs`, for callers that need the
        individual diverged dots (the diff itself is materialised, so cost
        is O(actual divergence), not O(cloud fragmentation)).
        """
        out = []
        for a, lo, hi in self.diff_runs(other):
            out.extend(Dot(a, c) for c in range(lo, hi + 1))
        return tuple(sorted(out))

    def all_dots(self) -> Tuple[Dot, ...]:
        """Every dot this clock has seen (O(total events) — for tests/small clocks)."""
        out = []
        for a in self.actors():
            for lo, hi in self._full(a):
                out.extend(Dot(a, c) for c in range(lo, hi + 1))
        return tuple(sorted(out))

    def iter_runs(self) -> Tuple[Tuple[ActorId, int, int], ...]:
        """Every (actor, lo, hi) run this clock has seen, base included."""
        out: List[Tuple[ActorId, int, int]] = []
        for a in sorted(self.actors(), key=repr):
            out.extend((a, lo, hi) for lo, hi in self._full(a))
        return tuple(out)

    def actors(self) -> FrozenSet[ActorId]:
        return frozenset(self.base) | frozenset(self.runs)

    def n_runs(self) -> int:
        """Total interval runs (a base entry counts as one run)."""
        return len(self.base) + sum(len(rs) for rs in self.runs.values())

    def n_events(self) -> int:
        """Total events covered — O(runs) to compute."""
        return sum(self.base.values()) + sum(
            count_runs_events(rs) for rs in self.runs.values()
        )

    def size_bytes(self) -> int:
        """Approximate serialized size — the metric the paper optimises for.

        O(actors + interval runs): each run is (actor, lo, hi) ~ three
        8-byte words, regardless of how many events it spans.
        """
        return 24 * self.n_runs()

    # ---------------------------------------------------------- (de)coding
    def to_obj(self):
        """Run-length codec (version 2): ``{"b": base, "r": runs}``."""
        return {
            "b": sorted(self.base.items()),
            "r": sorted(
                (a, [list(r) for r in rs]) for a, rs in self.runs.items()
            ),
        }

    @staticmethod
    def from_obj(o) -> "Clock":
        """Decode a clock object — new run-length or legacy per-dot codecs.

        Accepts (newest first):
        * ``{"b": [[a, n]...], "r": [[a, [[lo, hi]...]]...]}`` — run-length,
        * ``{"b": [[a, n]...], "c": [[a, [c...]]...]}`` — legacy msgpack
          per-dot cloud (pre-interval ``KIND_CLOCK`` / orswot payloads),
        * ``{"base": ..., "cloud": ...}`` — legacy ``to_obj`` form.
        """
        if "r" in o:
            return Clock(dict(o["b"]), runs={a: rs for a, rs in o["r"]})
        if "c" in o:
            return Clock(dict(o["b"]), {a: set(s) for a, s in o["c"]})
        return Clock(dict(o["base"]), {a: set(s) for a, s in o["cloud"]})

    # ------------------------------------------------------------- internals
    @staticmethod
    def _set_actor(
        base: Dict[ActorId, int],
        runs: Dict[ActorId, Tuple[Run, ...]],
        a: ActorId,
        full: Tuple[Run, ...],
    ) -> None:
        """Install a canonical full run list for actor ``a`` into parts."""
        b, rs = _split_full(full)
        if b:
            base[a] = b
        else:
            base.pop(a, None)
        if rs:
            runs[a] = rs
        else:
            runs.pop(a, None)
