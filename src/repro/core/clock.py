"""Logical clocks for bigset: ``{BaseVV(), DotCloud()}`` (paper §4.1).

Both the *set-clock* and the *set-tombstone* are instances of this structure:

* ``base`` — a version vector: ``actor -> max contiguous counter`` (events
  ``1..base[actor]`` have all been seen).
* ``cloud`` — the dot-cloud: ``actor -> set of counters`` seen *beyond* the
  contiguous base (gaps exist below them).  Invariant: every counter in
  ``cloud[a]`` is ``> base[a] + 1`` or not contiguous; after normalisation no
  counter in the cloud extends the base.

A replica **never** has an entry for itself in the DotCloud (paper §4.1): a
coordinator only mints contiguous events for itself via :meth:`increment`.

The clock is a join-semilattice under :meth:`join`; :meth:`seen` is the
membership test used by Algorithms 1 & 2 and by compaction.  The tombstone
additionally *shrinks* via :meth:`subtract` once compaction discards keys
(paper §4.3.3) — subtraction is safe for the tombstone because it is a
record of *pending* removals, not a grow-only summary.

The implementation is purely functional: every operation returns a new clock.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Iterable, Mapping, Tuple

from .dots import ActorId, Dot, as_dot

_EMPTY: "Clock | None" = None


class Clock:
    __slots__ = ("base", "cloud")

    def __init__(
        self,
        base: Mapping[ActorId, int] | None = None,
        cloud: Mapping[ActorId, FrozenSet[int]] | None = None,
        _normalise: bool = True,
    ):
        b: Dict[ActorId, int] = dict(base or {})
        c: Dict[ActorId, FrozenSet[int]] = {
            a: frozenset(s) for a, s in (cloud or {}).items() if s
        }
        if _normalise:
            b, c = _normalise_parts(b, c)
        self.base: Mapping[ActorId, int] = b
        self.cloud: Mapping[ActorId, FrozenSet[int]] = c

    # ---------------------------------------------------------------- basics
    @staticmethod
    def zero() -> "Clock":
        global _EMPTY
        if _EMPTY is None:
            _EMPTY = Clock({}, {}, _normalise=False)
        return _EMPTY

    def is_zero(self) -> bool:
        return not self.base and not self.cloud

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, Clock):
            return NotImplemented
        return self.base == other.base and self.cloud == other.cloud

    def __hash__(self) -> int:
        return hash(
            (
                tuple(sorted(self.base.items())),
                tuple(sorted((a, tuple(sorted(s))) for a, s in self.cloud.items())),
            )
        )

    def __repr__(self) -> str:
        cloud = {a: sorted(s) for a, s in sorted(self.cloud.items())}
        return f"Clock(base={dict(sorted(self.base.items()))}, cloud={cloud})"

    # ----------------------------------------------------------------- seen
    def seen(self, dot: Dot) -> bool:
        """Has this clock observed ``dot``?  (Algorithms 1 & 2's test.)"""
        dot = as_dot(dot)
        if dot.counter <= self.base.get(dot.actor, 0):
            return True
        return dot.counter in self.cloud.get(dot.actor, frozenset())

    def seen_all(self, dots: Iterable[Dot]) -> bool:
        return all(self.seen(d) for d in dots)

    # ----------------------------------------------------------- coordinator
    def increment(self, actor: ActorId) -> Tuple["Clock", Dot]:
        """Mint the next contiguous event for ``actor`` (coordinator-side).

        Returns ``(clock', dot)`` where ``dot`` is the freshly minted event.
        Only ever called by a replica for *itself*, hence it extends the base
        VV and never touches the cloud (a replica has no cloud entry for
        itself, §4.1).
        """
        base = dict(self.base)
        nxt = base.get(actor, 0) + 1
        if actor in self.cloud:
            # §4.1 invariant: "A replica will never have an entry for itself
            # in the DotCloud" — minting below a gap would reuse/skip events.
            raise ValueError(f"actor {actor!r} has its own dots in the cloud")
        base[actor] = nxt
        return Clock(base, self.cloud, _normalise=False), Dot(actor, nxt)

    def latest_dot(self, actor: ActorId) -> Dot:
        return Dot(actor, self.base.get(actor, 0))

    # ------------------------------------------------------------------ add
    def add(self, dot: Dot) -> "Clock":
        """Add one observed event (replica-side delta apply)."""
        dot = as_dot(dot)
        if self.seen(dot):
            return self
        base = dict(self.base)
        cloud = {a: set(s) for a, s in self.cloud.items()}
        cloud.setdefault(dot.actor, set()).add(dot.counter)
        b, c = _normalise_parts(base, cloud)
        return Clock(b, c, _normalise=False)

    def add_dots(self, dots: Iterable[Dot]) -> "Clock":
        base = dict(self.base)
        cloud = {a: set(s) for a, s in self.cloud.items()}
        changed = False
        for d in dots:
            d = as_dot(d)
            if d.counter <= base.get(d.actor, 0):
                continue
            s = cloud.setdefault(d.actor, set())
            if d.counter not in s:
                s.add(d.counter)
                changed = True
        if not changed:
            return self
        b, c = _normalise_parts(base, cloud)
        return Clock(b, c, _normalise=False)

    # ----------------------------------------------------------------- join
    def join(self, other: "Clock") -> "Clock":
        """Least upper bound of two clocks (semilattice join)."""
        if self is other:
            return self
        base: Dict[ActorId, int] = dict(self.base)
        for a, n in other.base.items():
            if n > base.get(a, 0):
                base[a] = n
        cloud: Dict[ActorId, set] = {a: set(s) for a, s in self.cloud.items()}
        for a, s in other.cloud.items():
            cloud.setdefault(a, set()).update(s)
        b, c = _normalise_parts(base, cloud)
        return Clock(b, c, _normalise=False)

    # ------------------------------------------------------------- subtract
    def subtract(self, dots: Iterable[Dot]) -> "Clock":
        """Remove ``dots`` from this clock (tombstone trimming, §4.3.3).

        Only meaningful for clocks that describe *sets of dots* (the
        set-tombstone, survivors digests): after compaction discards an
        element-key, its dot is subtracted so the summary stays minimal.
        Subtracting a dot below the base fragments the base into cloud
        entries for the retained counters — and the hole is permanent
        (counters are never re-minted), so a digest over a set with holes
        costs O(fragmentation) to store/compare, not O(actors).  ROADMAP
        lists interval-compressed clouds as the structural fix.
        """
        by_actor: Dict[ActorId, set] = {}
        for d in dots:
            d = as_dot(d)
            by_actor.setdefault(d.actor, set()).add(d.counter)
        if not by_actor:
            return self
        base = dict(self.base)
        cloud: Dict[ActorId, set] = {a: set(s) for a, s in self.cloud.items()}
        for a, gone in by_actor.items():
            b = base.get(a, 0)
            keep_low = min(gone)
            if keep_low <= b:
                # fragment base: retain 1..keep_low-1 contiguously, the rest
                # (minus `gone`) as cloud entries
                retained = set(range(keep_low, b + 1)) - gone
                base[a] = keep_low - 1
                if base[a] == 0:
                    base.pop(a, None)
                cloud.setdefault(a, set()).update(retained)
            if a in cloud:
                cloud[a] -= gone
                if not cloud[a]:
                    del cloud[a]
        b2, c2 = _normalise_parts(base, cloud)
        return Clock(b2, c2, _normalise=False)

    # ------------------------------------------------------------- ordering
    def descends(self, other: "Clock") -> bool:
        """True iff self has seen every event other has (self >= other)."""
        for a, n in other.base.items():
            if n > self.base.get(a, 0):
                # other's base may still be covered by self's cloud
                cl = self.cloud.get(a, frozenset())
                lo = self.base.get(a, 0)
                if not all(k in cl for k in range(lo + 1, n + 1)):
                    return False
        for a, s in other.cloud.items():
            lo = self.base.get(a, 0)
            cl = self.cloud.get(a, frozenset())
            for k in s:
                if k > lo and k not in cl:
                    return False
        return True

    def dominates(self, other: "Clock") -> bool:
        return self.descends(other) and self != other

    # ---------------------------------------------------------------- dots
    def diff_dots(self, other: "Clock") -> Tuple[Dot, ...]:
        """Dots seen by ``self`` but not by ``other`` — O(diff + metadata).

        This is the digest subtraction at the heart of digest-driven
        anti-entropy: two survivors digests (clock summaries of surviving
        element-key dots) yield the exact diverged dot set without touching
        a single element-key.  Contiguous shared prefixes are skipped
        wholesale (base-vs-base is one comparison); cloud entries are
        enumerated, so the cost is O(diff + cloud fragmentation) — see the
        fragmentation note on :meth:`subtract`.
        """
        out = []
        for a in set(self.base) | set(self.cloud):
            lo = self.base.get(a, 0)
            o_lo = other.base.get(a, 0)
            o_cloud = other.cloud.get(a, frozenset())
            for c in range(o_lo + 1, lo + 1):
                if c not in o_cloud:
                    out.append(Dot(a, c))
            for c in self.cloud.get(a, frozenset()):
                if c > o_lo and c not in o_cloud:
                    out.append(Dot(a, c))
        return tuple(sorted(out))

    def all_dots(self) -> Tuple[Dot, ...]:
        """Every dot this clock has seen (O(total events) — for tests/small clocks)."""
        out = []
        for a, n in self.base.items():
            out.extend(Dot(a, k) for k in range(1, n + 1))
        for a, s in self.cloud.items():
            out.extend(Dot(a, k) for k in sorted(s))
        return tuple(sorted(out))

    def actors(self) -> FrozenSet[ActorId]:
        return frozenset(self.base) | frozenset(self.cloud)

    def size_bytes(self) -> int:
        """Approximate serialized size — the metric the paper optimises for."""
        n_entries = len(self.base) + sum(len(s) for s in self.cloud.values())
        return 16 * n_entries  # (actor, counter) ~ two 8-byte words each

    # ---------------------------------------------------------- (de)coding
    def to_obj(self):
        return {
            "base": sorted(self.base.items()),
            "cloud": sorted((a, sorted(s)) for a, s in self.cloud.items()),
        }

    @staticmethod
    def from_obj(o) -> "Clock":
        return Clock(dict(o["base"]), {a: frozenset(s) for a, s in o["cloud"]})


def _normalise_parts(
    base: Dict[ActorId, int], cloud: Dict[ActorId, Iterable[int]]
) -> Tuple[Dict[ActorId, int], Dict[ActorId, FrozenSet[int]]]:
    """Compress cloud counters contiguous with the base into the base VV."""
    out_cloud: Dict[ActorId, FrozenSet[int]] = {}
    for a, s in cloud.items():
        s = set(s)
        b = base.get(a, 0)
        s = {k for k in s if k > b}
        while b + 1 in s:
            b += 1
            s.remove(b)
        if b:
            base[a] = b
        if s:
            out_cloud[a] = frozenset(s)
    # drop zero entries in base
    for a in [a for a, n in base.items() if n <= 0]:
        del base[a]
    return base, out_cloud
