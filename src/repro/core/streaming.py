"""Streaming ORSWOT join (paper §4.4).

    "Bigset has a novel streaming ORSWOT CRDT Join operation, that is able
     to perform a merge on subsets of an ORSWOT.  This is enabled by the
     fact that the set element keys are stored and therefore streamed in
     lexicographical element order."

Given R replica streams — each a :class:`~repro.core.bigset.ReadStream`
(a fixed clock plus entries in lexicographic element order) — the merge is a
k-way ordered merge.  For each element the surviving dots are computed with
the standard optimized-OR-set rule against the *other* streams' clocks:

    keep(d from stream i) = d present in every stream that has the element,
                            OR d unseen by the clock of every stream missing d

Because each stream's clock is fixed for the whole read, a window of one
element suffices: the merge is O(1) memory and can paginate / early-exit —
this is what makes membership and range queries on a quorum possible
without materialising the full set.
"""
from __future__ import annotations

import heapq
from typing import Dict, FrozenSet, Iterable, Iterator, List, Sequence, Tuple

from .clock import Clock
from .dots import Dot
from .orswot import Orswot


class _PeekStream:
    __slots__ = ("clock", "_it", "head")

    def __init__(self, clock: Clock, entries: Iterable[Tuple[bytes, Tuple[Dot, ...]]]):
        self.clock = clock
        self._it = iter(entries)
        self.head = next(self._it, None)

    def pop(self):
        h = self.head
        self.head = next(self._it, None)
        return h


def merge_entry(
    per_stream_dots: Sequence[FrozenSet[Dot] | None], clocks: Sequence[Clock]
) -> FrozenSet[Dot]:
    """Surviving dots for one element across R streams.

    ``per_stream_dots[i]`` is None when stream i did not list the element
    (equivalently: it has no surviving dots for it).
    """
    survivors = set()
    all_dots = set()
    for ds in per_stream_dots:
        if ds:
            all_dots |= ds
    for d in all_dots:
        ok = True
        for ds, ck in zip(per_stream_dots, clocks):
            if ds is not None and d in ds:
                continue
            # stream lacks d: d survives only if that stream never saw it
            if ck.seen(d):
                ok = False
                break
        if ok:
            survivors.add(d)
    return frozenset(survivors)


def streaming_join(
    streams: Sequence[Tuple[Clock, Iterable[Tuple[bytes, Tuple[Dot, ...]]]]],
) -> Iterator[Tuple[bytes, FrozenSet[Dot]]]:
    """K-way streaming merge of replica read streams.

    Yields (element, surviving dots) for surviving elements, in element
    order.  Never holds more than one element per stream in memory.
    """
    ps = [_PeekStream(c, e) for c, e in streams]
    clocks = [p.clock for p in ps]
    heap: List[Tuple[bytes, int]] = [
        (p.head[0], i) for i, p in enumerate(ps) if p.head is not None
    ]
    heapq.heapify(heap)
    while heap:
        element = heap[0][0]
        per_stream: List[FrozenSet[Dot] | None] = [None] * len(ps)
        while heap and heap[0][0] == element:
            _, i = heapq.heappop(heap)
            per_stream[i] = frozenset(ps[i].pop()[1])
            if ps[i].head is not None:
                heapq.heappush(heap, (ps[i].head[0], i))
        dots = merge_entry(per_stream, clocks)
        if dots:
            yield element, dots


def quorum_read(
    streams: Sequence[Tuple[Clock, Iterable[Tuple[bytes, Tuple[Dot, ...]]]]],
) -> Orswot:
    """Materialise a quorum read as a classic ORSWOT (clock = join of clocks)."""
    clock = Clock.zero()
    for c, _ in streams:
        clock = clock.join(c)
    entries: Dict[bytes, FrozenSet[Dot]] = {}
    for element, dots in streaming_join(streams):
        entries[element] = dots
    return Orswot(clock, entries)


def quorum_is_member(
    probes: Sequence[Tuple[Clock, FrozenSet[Dot] | None]],
) -> Tuple[bool, Tuple[Dot, ...]]:
    """Membership across a quorum from per-replica ``is_member`` probes."""
    clocks = [c for c, _ in probes]
    per_stream = [ds for _, ds in probes]
    dots = merge_entry(per_stream, clocks)
    return bool(dots), tuple(sorted(dots))
