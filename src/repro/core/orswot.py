"""State-based ORSWOT — the Riak Sets baseline (paper §2).

An Observe-Remove Set WithOut Tombstones (Bieniusa et al., "An optimized
conflict-free replicated set").  State is ``(clock, entries)`` where
``entries`` maps each present element to its minimal set of surviving dots.
Riak stores this whole structure as one opaque blob inside a riak-object —
which is exactly the O(n)-per-write behaviour the paper's bigset removes.

The ``entries`` clock here is generalised to gappy :class:`~repro.core.clock.Clock`
values so that the same ``merge`` implements both full-state joins and
delta-state joins (a delta is simply a small ORSWOT whose clock covers only
the dots it mentions) — see :mod:`repro.core.delta_orswot`.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Iterable, Mapping, Tuple

from .clock import Clock
from .dots import ActorId, Dot


class Orswot:
    __slots__ = ("clock", "entries")

    def __init__(
        self,
        clock: Clock | None = None,
        entries: Mapping[object, FrozenSet[Dot]] | None = None,
    ):
        self.clock: Clock = clock or Clock.zero()
        self.entries: Mapping[object, FrozenSet[Dot]] = {
            e: frozenset(ds) for e, ds in (entries or {}).items() if ds
        }

    # ----------------------------------------------------------------- api
    @staticmethod
    def new() -> "Orswot":
        return Orswot()

    def value(self) -> FrozenSet[object]:
        return frozenset(self.entries)

    def __contains__(self, element: object) -> bool:
        return element in self.entries

    def __len__(self) -> int:
        return len(self.entries)

    def context_of(self, element: object) -> Tuple[Dot, ...]:
        """The causal context a client would supply to remove/re-add element."""
        return tuple(sorted(self.entries.get(element, frozenset())))

    # ------------------------------------------------------------- mutators
    def add(self, actor: ActorId, element: object) -> "Orswot":
        """Coordinator-side add: mint a dot, replace all prior dots of element.

        The replaced dots stay covered by the clock, so merges at other
        replicas discard them (add-wins, no tombstones).
        """
        clock, dot = self.clock.increment(actor)
        entries = dict(self.entries)
        entries[element] = frozenset((dot,))
        return Orswot(clock, entries)

    def remove(self, element: object, ctx: Iterable[Dot] | None = None) -> "Orswot":
        """Remove the element's *observed* dots (those in ``ctx``; all if None)."""
        cur = self.entries.get(element)
        if cur is None:
            return self
        drop = frozenset(ctx) if ctx is not None else cur
        keep = cur - drop
        entries = dict(self.entries)
        if keep:
            entries[element] = keep
        else:
            del entries[element]
        return Orswot(self.clock, entries)

    # ---------------------------------------------------------------- merge
    def merge(self, other: "Orswot") -> "Orswot":
        """Join two ORSWOT states (also joins deltas; clocks may be gappy).

        An element's surviving dots are: dots present on both sides, plus
        dots present on exactly one side that the *other* side's clock has
        not seen (i.e. adds the other side has not yet observed).
        """
        clock = self.clock.join(other.clock)
        entries: Dict[object, FrozenSet[Dot]] = {}
        for e in set(self.entries) | set(other.entries):
            da = self.entries.get(e, frozenset())
            db = other.entries.get(e, frozenset())
            keep = (
                (da & db)
                | {d for d in da - db if not other.clock.seen(d)}
                | {d for d in db - da if not self.clock.seen(d)}
            )
            if keep:
                entries[e] = keep
        return Orswot(clock, entries)

    # ------------------------------------------------------------ accounting
    def size_bytes(self) -> int:
        """Approximate serialized size (the paper's cost metric, §2.1)."""
        total = self.clock.size_bytes()
        for e, ds in self.entries.items():
            total += _elem_bytes(e) + 16 * len(ds)
        return total

    # -------------------------------------------------------------- helpers
    def __eq__(self, other: object) -> bool:
        if not isinstance(other, Orswot):
            return NotImplemented
        return self.clock == other.clock and self.entries == other.entries

    def __repr__(self) -> str:
        return f"Orswot(n={len(self.entries)}, clock={self.clock!r})"


def _elem_bytes(e: object) -> int:
    if isinstance(e, bytes):
        return len(e)
    if isinstance(e, str):
        return len(e.encode())
    return 8
