"""Dense JAX logical clocks — the TPU-native form of BaseVV + DotCloud.

The paper's clocks are sparse maps; their hot operations (dot-seen filtering
of element-key streams, clock joins, tombstone subtraction) are the write
and read path of every bigset op.  On TPU we hold a *dense* clock per actor
universe:

* ``origin : int32[A]``   — per-actor contiguous horizon: every event
  ``1..origin[a]`` has been seen (the BaseVV, epoch-aligned),
* ``bits : uint32[A, W]`` — a bitmap windowing events
  ``origin[a]+1 .. origin[a]+32·W`` (the DotCloud).

With a *shared origin* (the framework re-bases clocks at checkpoint epochs)
the lattice ops become data-parallel bitwise kernels:

    join      = bitwise OR            (set-clock ⊔ delta)
    subtract  = AND NOT               (tombstone shrink, §4.3.3)
    seen      = counter ≤ origin  OR  bit-test        (Algorithms 1 & 2)
    compress  = count contiguous prefix of ones → fold into origin

``dots_seen`` — the per-element-key filter applied millions of times during
a read fold — is the Pallas kernel in :mod:`repro.kernels.dot_seen`; the
bit-gather is expressed as one-hot matmuls so it runs on the MXU instead of
a scatter/gather unit TPUs don't have.  This module is the pure-jnp oracle
(``ref``) for those kernels and the conversion layer to/from the sparse
:class:`repro.core.clock.Clock`.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .clock import Clock
from .dots import Dot


class DenseClock(NamedTuple):
    origin: jax.Array  # int32[A]
    bits: jax.Array    # uint32[A, W]

    @property
    def n_actors(self) -> int:
        return self.origin.shape[0]

    @property
    def window_events(self) -> int:
        return self.bits.shape[1] * 32


def zero(n_actors: int, n_words: int) -> DenseClock:
    return DenseClock(
        jnp.zeros((n_actors,), jnp.int32),
        jnp.zeros((n_actors, n_words), jnp.uint32),
    )


# ------------------------------------------------------------------- seen
def dots_seen(clock: DenseClock, actors: jax.Array, counters: jax.Array) -> jax.Array:
    """Vectorised Algorithm-1/2 membership test.

    actors : int32[N] (indices into the actor universe)
    counters : int32[N] (event numbers, 1-based)
    returns bool[N]
    """
    origin = clock.origin[actors]                      # [N]
    below = counters <= origin
    rel = counters - origin - 1                        # 0-based window offset
    word = jnp.clip(rel // 32, 0, clock.bits.shape[1] - 1)
    bit = (rel % 32).astype(jnp.uint32)
    words = clock.bits[actors, word]                   # [N]
    in_window = (rel >= 0) & (rel < clock.window_events)
    hit = ((words >> bit) & jnp.uint32(1)).astype(bool)
    return below | (in_window & hit)


# ------------------------------------------------------------------ lattice
def _require_aligned(a: DenseClock, b: DenseClock) -> None:
    if a.origin.shape != b.origin.shape or a.bits.shape != b.bits.shape:
        raise ValueError("dense clocks must share actor universe and window")


def join(a: DenseClock, b: DenseClock) -> DenseClock:
    """⊔ of two *origin-aligned* dense clocks (bitwise OR)."""
    _require_aligned(a, b)
    return DenseClock(jnp.maximum(a.origin, b.origin), a.bits | b.bits)


def subtract(a: DenseClock, b: DenseClock) -> DenseClock:
    """Remove b's window events from a (tombstone shrink).  Origins must
    match: events at/below the shared origin cannot be subtracted densely."""
    _require_aligned(a, b)
    return DenseClock(a.origin, a.bits & ~b.bits)


def add_dots(clock: DenseClock, actors: jax.Array, counters: jax.Array) -> DenseClock:
    """Scatter-OR events into the window (delta apply).

    XLA has no scatter-OR, and scatter-set loses bits when several dots land
    in the same word.  OR is emulated exactly with 32 per-bit scatter-max
    ops on 0/1 planes (duplicate dots are idempotent under max).
    """
    A, W = clock.bits.shape
    rel = counters - clock.origin[actors] - 1
    word = rel // 32
    bit = rel % 32
    ok = (rel >= 0) & (rel < clock.window_events)
    flat = jnp.where(ok, actors * W + word, A * W)  # out-of-range -> dropped
    bits_flat = clock.bits.reshape(-1)
    for b in range(32):
        plane = ((bits_flat >> jnp.uint32(b)) & jnp.uint32(1)).astype(jnp.int32)
        idx_b = jnp.where(bit == b, flat, A * W)
        plane = plane.at[idx_b].max(1, mode="drop")
        if b == 0:
            acc = plane.astype(jnp.uint32)
        else:
            acc = acc | (plane.astype(jnp.uint32) << jnp.uint32(b))
    return DenseClock(clock.origin, acc.reshape(A, W))


def compress(clock: DenseClock) -> DenseClock:
    """Fold the contiguous all-ones prefix of each window into the origin.

    Mirrors :func:`repro.core.clock._normalise_parts`: events contiguous
    with the base VV leave the dot cloud.
    """
    A, W = clock.bits.shape
    full = jnp.uint32(0xFFFFFFFF)
    is_full = clock.bits == full                        # [A, W]
    # number of leading full words per actor
    prefix_full = jnp.cumprod(is_full.astype(jnp.int32), axis=1)  # 1 while full
    n_full_words = prefix_full.sum(axis=1)              # [A]
    # bits in the first non-full word: count trailing ones
    first_partial = jnp.take_along_axis(
        clock.bits, jnp.minimum(n_full_words, W - 1)[:, None], axis=1
    )[:, 0]
    # trailing ones of w = ctz(~w)
    inv = ~first_partial
    tz = _ctz32(inv)
    extra = jnp.where(n_full_words < W, tz, 0)
    advance = n_full_words * 32 + extra                  # events to absorb
    new_origin = clock.origin + advance.astype(jnp.int32)
    # shift windows left by `advance` bits (per actor) — done in numpy-free
    # jnp via per-actor roll on words + bit shifts
    new_bits = _shift_left_bits(clock.bits, advance)
    return DenseClock(new_origin, new_bits)


def _ctz32(x: jax.Array) -> jax.Array:
    """Count trailing zeros of uint32 (32 for x == 0)."""
    x = x.astype(jnp.uint32)
    lsb = x & (~x + jnp.uint32(1))
    f = lsb.astype(jnp.float32)
    e = jnp.where(lsb == 0, jnp.int32(32), (jnp.log2(f)).astype(jnp.int32))
    return e


def _shift_left_bits(bits: jax.Array, n: jax.Array) -> jax.Array:
    """Per-row left-shift of a multi-word little-endian bitfield by n bits."""
    A, W = bits.shape
    word_shift = (n // 32)[:, None]                      # [A,1]
    bit_shift = (n % 32).astype(jnp.uint32)[:, None]     # [A,1]
    idx = jnp.arange(W)[None, :] + word_shift            # source word index
    lo = jnp.where(idx < W, jnp.take_along_axis(
        bits, jnp.minimum(idx, W - 1), axis=1), jnp.uint32(0))
    idx2 = idx + 1
    hi = jnp.where(idx2 < W, jnp.take_along_axis(
        bits, jnp.minimum(idx2, W - 1), axis=1), jnp.uint32(0))
    shifted = jnp.where(
        bit_shift == 0,
        lo,
        (lo >> bit_shift) | (hi << (jnp.uint32(32) - bit_shift)),
    )
    return shifted


def base_vv(clock: DenseClock) -> jax.Array:
    """Effective version vector (origin + contiguous window prefix)."""
    return compress(clock).origin


# ------------------------------------------------------------- conversions
def from_clock(
    clock: Clock, actor_index: Dict[object, int], n_actors: int, n_words: int,
    origin: np.ndarray | None = None,
) -> DenseClock:
    """Sparse → dense.  ``origin`` defaults to zeros (epoch start)."""
    og = np.zeros((n_actors,), np.int32) if origin is None else np.asarray(origin, np.int32).copy()
    bits = np.zeros((n_actors, n_words), np.uint32)
    for a, n in clock.base.items():
        i = actor_index[a]
        for c in range(og[i] + 1, n + 1):
            rel = c - og[i] - 1
            if rel >= n_words * 32:
                raise ValueError("window too small for clock base")
            bits[i, rel // 32] |= np.uint32(1) << np.uint32(rel % 32)
    for a, s in clock.cloud.items():
        i = actor_index[a]
        for c in s:
            rel = c - og[i] - 1
            if rel < 0:
                continue
            if rel >= n_words * 32:
                raise ValueError("window too small for dot cloud")
            bits[i, rel // 32] |= np.uint32(1) << np.uint32(rel % 32)
    return DenseClock(jnp.asarray(og), jnp.asarray(bits))


def to_clock(clock: DenseClock, actors: Sequence[object]) -> Clock:
    """Dense → sparse (normalised BaseVV + DotCloud)."""
    og = np.asarray(clock.origin)
    bits = np.asarray(clock.bits)
    base: Dict[object, int] = {}
    cloud: Dict[object, set] = {}
    A, W = bits.shape
    for i, a in enumerate(actors):
        if og[i]:
            base[a] = int(og[i])
        s = set()
        for w in range(W):
            v = int(bits[i, w])
            while v:
                b = (v & -v).bit_length() - 1
                s.add(int(og[i]) + w * 32 + b + 1)
                v &= v - 1
        if s:
            cloud[a] = frozenset(s)
    return Clock(base, cloud)
