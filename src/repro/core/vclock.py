"""Dense JAX logical clocks — the TPU-native form of BaseVV + DotCloud.

The paper's clocks are sparse maps; their hot operations (dot-seen filtering
of element-key streams, clock joins, tombstone subtraction) are the write
and read path of every bigset op.  On TPU we hold a *dense interval* clock
per actor universe:

* ``starts : int32[A, R]`` — per-actor run start counters,
* ``ends   : int32[A, R]`` — per-actor run end counters (inclusive).

Row ``a`` holds the actor's seen events as sorted, disjoint, coalesced
``(lo, hi)`` runs — the base VV is simply the first run when it starts at 1.
Empty slots are the sentinel ``(1, 0)`` (``lo > hi``), which no membership
test can hit.  This mirrors :class:`repro.core.clock.Clock`'s run cloud:
cost is O(interval runs) — causal metadata — with **no window cap** (the old
``uint32`` bitmap silently could not represent dots beyond its
``window_events`` spread at all, and subtraction required matching origins).

The lattice ops become data-parallel interval merges over fixed shapes:

    join      = run union            (set-clock ⊔ delta)
    subtract  = run difference       (tombstone shrink, §4.3.3) — origin-free
    intersect = run intersection     (tombstone ∩ raw trim)
    seen      = any(lo ≤ c ≤ hi)     (Algorithms 1 & 2)
    popcount  = Σ (hi - lo + 1)      (events per actor)

The merges use a boundary sweep: a counter ``p`` starts an output run iff it
is live under the op's predicate and ``p - 1`` is not; ``p`` ends one iff it
is live and ``p + 1`` is not.  Candidate boundaries come only from input run
edges, so the sweep is O(P²) dense compares over P = Ra + Rb candidates —
fixed-shape, branch-free work that maps straight onto the VPU.

``dots_seen`` — the per-element-key filter applied millions of times during
a read fold — is the Pallas kernel in :mod:`repro.kernels.dot_seen`; the
per-dot row gather is expressed as one-hot matmuls so it runs on the MXU
instead of a scatter/gather unit TPUs don't have.  This module is the
pure-jnp oracle (``ref``) for those kernels and the conversion layer to/from
the sparse :class:`repro.core.clock.Clock`.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .clock import Clock

_INT32_MAX = np.int32(2**31 - 1)


class DenseClock(NamedTuple):
    starts: jax.Array  # int32[A, R] (empty slot: starts=1, ends=0)
    ends: jax.Array    # int32[A, R]

    @property
    def n_actors(self) -> int:
        return self.starts.shape[0]

    @property
    def n_runs(self) -> int:
        return self.starts.shape[1]


def zero(n_actors: int, n_runs: int = 1) -> DenseClock:
    return DenseClock(
        jnp.ones((n_actors, n_runs), jnp.int32),
        jnp.zeros((n_actors, n_runs), jnp.int32),
    )


# ------------------------------------------------------------------- seen
def dots_seen(clock: DenseClock, actors: jax.Array, counters: jax.Array) -> jax.Array:
    """Vectorised Algorithm-1/2 membership test.

    actors : int32[N] (indices into the actor universe)
    counters : int32[N] (event numbers, 1-based)
    returns bool[N]

    A dot is seen iff some run of its actor's row contains its counter —
    a broadcast interval test over all R runs, no window cap.
    """
    s = clock.starts[actors]                     # [N, R]
    e = clock.ends[actors]                       # [N, R]
    c = counters[:, None]                        # [N, 1]
    return jnp.any((s <= c) & (c <= e), axis=1)


# ------------------------------------------------------------------ lattice
def _require_same_universe(a: DenseClock, b: DenseClock) -> None:
    if a.starts.shape[0] != b.starts.shape[0]:
        raise ValueError("dense clocks must share the actor universe")


def _interval_merge(a_s, a_e, b_s, b_e, mode: str):
    """Boundary-sweep run merge — shared math for join/subtract/intersect.

    Inputs are int32[A, Ra] / int32[A, Rb] run arrays; output is the
    *unsorted* int32[A, Ra+Rb] run arrays of the result (empty slots
    ``(1, 0)``).  ``mode``: ``"or"`` (union), ``"andnot"`` (difference),
    ``"and"`` (intersection).

    A counter ``p`` is *live* when the mode's predicate over (in-A, in-B)
    holds.  Output runs start at live points whose predecessor is dead and
    end at live points whose successor is dead; every such boundary is an
    edge of an input run (shifted by one for the B side of ``andnot``), so
    the candidate set has fixed size P = Ra + Rb.
    """
    a_valid = a_s <= a_e
    b_valid = b_s <= b_e

    def in_a(x):  # x: int32[A, P] -> bool[A, P]
        return jnp.any(
            (a_s[:, None, :] <= x[:, :, None]) & (x[:, :, None] <= a_e[:, None, :]),
            axis=-1,
        )

    def in_b(x):
        return jnp.any(
            (b_s[:, None, :] <= x[:, :, None]) & (x[:, :, None] <= b_e[:, None, :]),
            axis=-1,
        )

    if mode == "or":
        def live(x):
            return in_a(x) | in_b(x)
        cand_s = jnp.concatenate([a_s, b_s], axis=1)
        s_valid = jnp.concatenate([a_valid, b_valid], axis=1)
        cand_e = jnp.concatenate([a_e, b_e], axis=1)
        e_valid = s_valid
    elif mode == "andnot":
        def live(x):
            return in_a(x) & ~in_b(x)
        # a difference run starts at an A start or just after a B end,
        # and ends at an A end or just before a B start
        cand_s = jnp.concatenate([a_s, b_e + 1], axis=1)
        s_valid = jnp.concatenate([a_valid, b_valid], axis=1)
        cand_e = jnp.concatenate([a_e, b_s - 1], axis=1)
        e_valid = s_valid
    elif mode == "and":
        def live(x):
            return in_a(x) & in_b(x)
        cand_s = jnp.concatenate([a_s, b_s], axis=1)
        s_valid = jnp.concatenate([a_valid, b_valid], axis=1)
        cand_e = jnp.concatenate([a_e, b_e], axis=1)
        e_valid = s_valid
    else:  # pragma: no cover
        raise ValueError(f"unknown merge mode {mode!r}")

    is_start = s_valid & live(cand_s) & ~live(cand_s - 1)
    # two candidates can carry the same start value (e.g. identical runs in
    # both inputs under "or") — keep only the first occurrence per row
    p = cand_s.shape[1]
    same = cand_s[:, :, None] == cand_s[:, None, :]            # [A, P, P]
    earlier = jnp.tril(jnp.ones((p, p), bool), k=-1)           # [P, P] q < p
    dup = jnp.any(same & earlier[None, :, :] & is_start[:, None, :], axis=-1)
    is_start = is_start & ~dup

    is_end = e_valid & live(cand_e) & ~live(cand_e + 1)
    # each output run ends at the smallest end-boundary >= its start
    reach = is_end[:, None, :] & (cand_e[:, None, :] >= cand_s[:, :, None])
    ends_for = jnp.min(
        jnp.where(reach, cand_e[:, None, :], _INT32_MAX), axis=-1)

    out_s = jnp.where(is_start, cand_s, 1).astype(jnp.int32)
    out_e = jnp.where(is_start, ends_for, 0).astype(jnp.int32)
    return out_s, out_e


def sort_runs(starts: jax.Array, ends: jax.Array):
    """Canonicalise run arrays: sort rows by start, empties ``(1, 0)`` last."""
    valid = starts <= ends
    key = jnp.where(valid, starts, _INT32_MAX)
    order = jnp.argsort(key, axis=1)
    s = jnp.take_along_axis(starts, order, axis=1)
    e = jnp.take_along_axis(ends, order, axis=1)
    ok = s <= e
    return jnp.where(ok, s, 1), jnp.where(ok, e, 0)


def join(a: DenseClock, b: DenseClock) -> DenseClock:
    """⊔ of two dense clocks (run union) — no alignment requirements."""
    _require_same_universe(a, b)
    s, e = _interval_merge(a.starts, a.ends, b.starts, b.ends, "or")
    return DenseClock(*sort_runs(s, e))


def subtract(a: DenseClock, b: DenseClock) -> DenseClock:
    """Remove b's events from a (tombstone shrink, §4.3.3).

    Origin-free: runs below either clock's contiguous horizon subtract the
    same as any other runs (the old bitmap form required matching origins
    and silently could not subtract events at/below them).
    """
    _require_same_universe(a, b)
    s, e = _interval_merge(a.starts, a.ends, b.starts, b.ends, "andnot")
    return DenseClock(*sort_runs(s, e))


def intersect(a: DenseClock, b: DenseClock) -> DenseClock:
    """Events seen by both clocks (run intersection)."""
    _require_same_universe(a, b)
    s, e = _interval_merge(a.starts, a.ends, b.starts, b.ends, "and")
    return DenseClock(*sort_runs(s, e))


def add_dots(clock: DenseClock, actors: jax.Array, counters: jax.Array) -> DenseClock:
    """Observe a batch of dots (delta apply) — one run build + one merge.

    Sorts the dots, detects run breaks, segment-reduces each run's bounds,
    scatters the runs into per-actor rows and unions them with the clock.
    No per-bit planes, no scatter-OR emulation: duplicate dots land in the
    same run and adjacent counters coalesce before the merge.
    """
    n = int(actors.shape[0])
    if n == 0:
        return clock
    n_a = clock.n_actors
    order = jnp.lexsort((counters, actors))
    a = jnp.asarray(actors, jnp.int32)[order]
    c = jnp.asarray(counters, jnp.int32)[order]
    prev_a = jnp.concatenate([a[:1] - 1, a[:-1]])
    prev_c = jnp.concatenate([c[:1], c[:-1]])
    new_run = (a != prev_a) | (c > prev_c + 1)
    gid = jnp.cumsum(new_run.astype(jnp.int32)) - 1             # [n]
    run_lo = jax.ops.segment_min(c, gid, num_segments=n)
    run_hi = jax.ops.segment_max(c, gid, num_segments=n)
    run_actor = jax.ops.segment_max(a, gid, num_segments=n)
    run_ids = jnp.arange(n, dtype=jnp.int32)
    valid = run_ids <= gid[-1]
    run_actor = jnp.where(valid, run_actor, n_a)                # drop pads
    # rank of each run within its actor row (runs are actor-grouped)
    first = jax.ops.segment_min(run_ids, run_actor, num_segments=n_a + 1)
    rank = run_ids - first[run_actor]
    delta_s = jnp.ones((n_a, n), jnp.int32)
    delta_e = jnp.zeros((n_a, n), jnp.int32)
    delta_s = delta_s.at[run_actor, rank].set(run_lo, mode="drop")
    delta_e = delta_e.at[run_actor, rank].set(run_hi, mode="drop")
    return join(clock, DenseClock(delta_s, delta_e))


def compact(clock: DenseClock) -> DenseClock:
    """Trim trailing all-empty run columns (host-side width reduction).

    Merges widen arrays to Ra + Rb; after coalescing most columns are the
    empty sentinel.  Call between chained merges to keep widths O(runs).
    """
    s = np.asarray(clock.starts)
    e = np.asarray(clock.ends)
    used = (s <= e).any(axis=0)
    width = max(1, int(used.nonzero()[0].max()) + 1 if used.any() else 1)
    return DenseClock(jnp.asarray(s[:, :width]), jnp.asarray(e[:, :width]))


def popcount(clock: DenseClock) -> jax.Array:
    """Events per actor — Σ (hi - lo + 1) over valid runs (int32[A])."""
    return jnp.maximum(clock.ends - clock.starts + 1, 0).sum(axis=1)


def base_vv(clock: DenseClock) -> jax.Array:
    """Effective version vector: the contiguous horizon per actor.

    Requires canonical (sorted) rows — true for anything built by
    :func:`from_clock` or returned by the merge ops.
    """
    return jnp.where(clock.starts[:, 0] == 1, clock.ends[:, 0], 0)


# ------------------------------------------------------------- conversions
def from_clock(
    clock: Clock, actor_index: Dict[object, int], n_actors: int,
    n_runs: int | None = None,
) -> DenseClock:
    """Sparse → dense: O(runs), one row slot per interval run.

    ``n_runs`` pads the run axis to a fixed width (for shape-stable jit);
    defaults to the widest row.  Raises if a row needs more than ``n_runs``.
    """
    rows: Dict[int, list] = {}
    for a, lo, hi in clock.iter_runs():
        rows.setdefault(actor_index[a], []).append((lo, hi))
    widest = max((len(r) for r in rows.values()), default=0)
    width = max(1, widest) if n_runs is None else n_runs
    if widest > width:
        raise ValueError(
            f"clock has {widest} runs in a row; n_runs={width} too narrow")
    starts = np.ones((n_actors, width), np.int32)
    ends = np.zeros((n_actors, width), np.int32)
    for i, rs in rows.items():
        for k, (lo, hi) in enumerate(rs):
            starts[i, k] = lo
            ends[i, k] = hi
    return DenseClock(jnp.asarray(starts), jnp.asarray(ends))


def to_clock(clock: DenseClock, actors: Sequence[object]) -> Clock:
    """Dense → sparse (normalised BaseVV + run cloud)."""
    s = np.asarray(clock.starts)
    e = np.asarray(clock.ends)
    runs: Dict[object, list] = {}
    for i, a in enumerate(actors):
        rs = [(int(lo), int(hi)) for lo, hi in zip(s[i], e[i]) if lo <= hi]
        if rs:
            runs[a] = rs
    return Clock(runs=runs)
