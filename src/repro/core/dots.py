"""Dots — the unit of causality in bigset.

A *dot* is a pair ``(actor, counter)`` naming the ``counter``-th event performed
by ``actor`` (Almeida et al., "Scalable and accurate causality tracking").  Every
insert of an element into a bigset is tagged with a fresh dot minted by the
coordinating vnode; the dot is the element-key's causal identity and the unit
that set-clocks and set-tombstones track.
"""
from __future__ import annotations

from typing import Any, Iterable, NamedTuple, Tuple

ActorId = Any  # opaque, hashable, totally ordered (bytes/str/int)


class Dot(NamedTuple):
    actor: ActorId
    counter: int

    def __repr__(self) -> str:  # compact debugging
        return f"{self.actor}:{self.counter}"


DotList = Tuple[Dot, ...]


def as_dot(x: "Dot | Tuple[ActorId, int]") -> Dot:
    if isinstance(x, Dot):
        return x
    a, c = x
    if not isinstance(c, int) or c < 1:
        raise ValueError(f"dot counter must be a positive int, got {c!r}")
    return Dot(a, c)


def sort_dots(dots: Iterable[Dot]) -> DotList:
    return tuple(sorted(as_dot(d) for d in dots))


def dot_from_key(actor: ActorId, counter: int) -> Dot:
    """Dot from decoded storage-key components.

    The key codec round-trips string actors as utf-8 bytes; this is the one
    place that mapping is undone, shared by element-key and posting-key
    decoding so the two can never drift.
    """
    return Dot(actor.decode() if isinstance(actor, bytes) else actor, counter)
