"""Delta-CRDT ORSWOT — the paper's §3 baseline (riak_dt delta_data_types).

A delta-mutator returns, instead of the full post-state, a small ORSWOT
fragment that other replicas can join with the generic
:meth:`repro.core.orswot.Orswot.merge`.  The paper's observation (§3) is that
this *alone* barely helps a durable store: the delta is small on the wire,
but the downstream replica must still **read + deserialize + merge + write
the full state** for every delta ("an incoming delta never supersedes the
local state, even without concurrency").  The byte accounting in
:mod:`benchmarks.bench_writes` makes this visible.
"""
from __future__ import annotations

from typing import Iterable, Tuple

from .clock import Clock
from .dots import ActorId, Dot
from .orswot import Orswot


def delta_add(state: Orswot, actor: ActorId, element: object) -> Tuple[Orswot, Orswot]:
    """Coordinator add.  Returns ``(new_state, delta)``.

    The delta's clock covers the new dot *and* the replaced dots of the
    element (the causal context of the add), so that joining it elsewhere
    removes the superseded adds.
    """
    replaced = state.entries.get(element, frozenset())
    clock, dot = state.clock.increment(actor)
    new_entries = dict(state.entries)
    new_entries[element] = frozenset((dot,))
    new_state = Orswot(clock, new_entries)

    delta_clock = Clock.zero().add_dots((dot, *replaced))
    delta = Orswot(delta_clock, {element: frozenset((dot,))})
    return new_state, delta


def delta_remove(
    state: Orswot, element: object, ctx: Iterable[Dot] | None = None
) -> Tuple[Orswot, Orswot]:
    """Coordinator remove.  Returns ``(new_state, delta)``.

    The delta is entry-less: its clock covers exactly the removed dots, so a
    join discards them everywhere (observed-remove).
    """
    cur = state.entries.get(element, frozenset())
    drop = frozenset(ctx) if ctx is not None else cur
    new_state = state.remove(element, drop)
    delta = Orswot(Clock.zero().add_dots(drop), {})
    return new_state, delta


def join_delta(state: Orswot, delta: Orswot) -> Orswot:
    """Downstream delta apply — a full-state merge, per §3's complaint."""
    return state.merge(delta)


def group_deltas(deltas: Iterable[Orswot]) -> Orswot:
    """Delta-group composition: deltas are themselves joinable."""
    acc = Orswot.new()
    for d in deltas:
        acc = acc.merge(d)
    return acc
