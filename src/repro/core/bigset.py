"""Bigset — the paper's decomposed delta CRDT Set (§4, Algorithms 1 & 2).

A bigset vnode stores, per set, in one ordered KV store:

* ``(set, KIND_CLOCK)``      -> serialized set-clock (BaseVV + DotCloud)
* ``(set, KIND_TOMBSTONE)``  -> serialized set-tombstone
* ``(set, KIND_ELEMENT, element, actor, counter)`` -> b""   (one per insert)
* ``(set, KIND_INDEX, index_name, index_key, element, actor, counter)``
  -> b""  (secondary-index postings; see :mod:`repro.index`)

Writes read **only the clocks** (O(causal metadata)), append element keys —
plus one posting per registered-index key, derived deterministically from
(element, value) so replicas rebuild them from the delta — and ship the
element-key as the replication delta.  Removes are clock-only (no element
or index writes).  Compaction (storage hook) discards element-keys *and*
postings covered by the tombstone in the same pass and then subtracts the
discarded element dots so the tombstone shrinks (§4.3.3).  Reads are a
streaming fold over the element-key range in lexicographic element order,
which also enables membership/range queries and the §4.4 streaming join.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, Iterator, List, Optional, Tuple

import msgpack

from ..index.postings import (decode_posting_key, index_bounds, index_range,
                              posting_key)
from ..index.spec import IndexSpec
from ..storage.keycodec import (KIND_CLOCK, KIND_ELEMENT, KIND_INDEX,
                                KIND_TOMBSTONE, decode_key, encode_key)
from ..storage.lsm import TOMBSTONE as STORE_TOMBSTONE
from ..storage.lsm import LsmIterator, LsmStore
from .clock import Clock
from .dots import ActorId, Dot, dot_from_key
from .orswot import Orswot


# ------------------------------------------------------------------ codecs
def _clock_to_bytes(c: Clock) -> bytes:
    """Run-length clock codec: ``{"b": base VV, "r": interval runs}``.

    O(runs) on the wire regardless of how many events each run spans.
    """
    return msgpack.packb(c.to_obj())


def _clock_from_bytes(b: Optional[bytes]) -> Clock:
    """Decode a ``KIND_CLOCK``/``KIND_TOMBSTONE`` payload.

    Accepts both the run-length codec and the legacy per-dot ``{"b", "c"}``
    cloud form, so records written before the interval refactor (including
    WAL-replayed state) still decode and round-trip through recovery.
    """
    if b is None:
        return Clock.zero()
    o = msgpack.unpackb(b, strict_map_key=False)
    return Clock.from_obj(o)


def clock_key(set_name: bytes) -> bytes:
    return encode_key((set_name, KIND_CLOCK))

def tombstone_key(set_name: bytes) -> bytes:
    return encode_key((set_name, KIND_TOMBSTONE))

def element_key(set_name: bytes, element: bytes, dot: Dot) -> bytes:
    return encode_key((set_name, KIND_ELEMENT, element, dot.actor, dot.counter))

def element_range(set_name: bytes) -> Tuple[bytes, bytes]:
    lo = encode_key((set_name, KIND_ELEMENT))
    hi = encode_key((set_name, KIND_ELEMENT + 1))
    return lo, hi

def decode_element_key(key: bytes) -> Tuple[bytes, bytes, Dot]:
    parts = decode_key(key)
    if len(parts) != 5 or parts[1] != KIND_ELEMENT:
        # a real exception, not an assert: under ``python -O`` an assert
        # vanishes and a clock/tombstone/posting key would silently decode
        # into a garbage Dot
        raise ValueError(f"not an element key: {parts!r}")
    set_name, _kind, element, _actor, _counter = parts
    return set_name, element, _dot_from_parts(parts)


def _dot_from_parts(parts: Tuple) -> Dot:
    """The trailing ``(actor, counter)`` of an element or posting key."""
    return dot_from_key(parts[-2], parts[-1])


def element_bounds(
    set_name: bytes,
    start: Optional[bytes] = None,
    end: Optional[bytes] = None,
    after: Optional[bytes] = None,
) -> Tuple[bytes, bytes]:
    """Encoded key bounds for the element range ``[start, end)`` of a set.

    ``after`` seeks *strictly past* every key of that element (cursor
    resumption): in the order-preserving codec ``element + b"\\x00"`` is the
    immediate successor element, so its encoded prefix upper-bounds all of
    ``after``'s keys.  ``after`` wins over ``start`` when both are given.
    """
    if after is not None:
        lo = encode_key((set_name, KIND_ELEMENT, after + b"\x00"))
    elif start is not None:
        lo = encode_key((set_name, KIND_ELEMENT, start))
    else:
        lo = encode_key((set_name, KIND_ELEMENT))
    if end is not None:
        hi = encode_key((set_name, KIND_ELEMENT, end))
    else:
        hi = encode_key((set_name, KIND_ELEMENT + 1))
    return lo, hi


# ------------------------------------------------------------------ deltas
@dataclass(frozen=True)
class InsertDelta:
    """The replicated delta for an insert: the new element-key + op context.

    ``value`` rides along with the key (empty for plain sets; checkpoint
    shards store their tensor bytes here — the CRDT governs key liveness,
    the value is immutable payload under that key).
    """

    set_name: bytes
    element: bytes
    dot: Dot
    ctx: Tuple[Dot, ...] = ()
    value: bytes = b""

    def size_bytes(self) -> int:
        return (len(self.set_name) + len(self.element) + 16
                + 16 * len(self.ctx) + len(self.value))


@dataclass(frozen=True)
class RemoveDelta:
    """The replicated delta for a remove: context dots only (clock-sized)."""

    set_name: bytes
    ctx: Tuple[Dot, ...]

    def size_bytes(self) -> int:
        return len(self.set_name) + 16 * len(self.ctx)


Delta = InsertDelta  # union alias for typing docs; removes use RemoveDelta


# ------------------------------------------------------------ element cursor
class ElementCursor:
    """Positional ``(element, dot, value)`` cursor over one set's element
    range.

    Wraps a :class:`~repro.storage.lsm.LsmIterator`: iterating streams
    decoded element-keys in order; :meth:`seek` repositions at the first
    key of ``element`` in O(log n) per level.  Keys skipped by a seek are
    never touched — no ``bytes_read``, no scan work — which is what makes
    a gallop join's probes cost O(probe), not O(gap).
    """

    __slots__ = ("_set", "_it")

    def __init__(
        self,
        store: LsmStore,
        set_name: bytes,
        start: Optional[bytes] = None,
        end: Optional[bytes] = None,
        after: Optional[bytes] = None,
    ):
        self._set = set_name
        lo, hi = element_bounds(set_name, start, end, after)
        self._it = LsmIterator(store, lo, hi)

    def seek(self, element: bytes) -> None:
        """Reposition at the first key of ``element`` (or the next one)."""
        self._it.seek(encode_key((self._set, KIND_ELEMENT, element)))

    def __iter__(self) -> "ElementCursor":
        return self

    def __next__(self) -> Tuple[bytes, Dot, bytes]:
        k, v = next(self._it)
        _s, element, dot = decode_element_key(k)
        return element, dot, v


# ------------------------------------------------------------- set digests
class SetDigest:
    """Incrementally maintained digest of one set's *physical* element-keys.

    Two structures, both fed by the write path (never by folds):

    * a **total** raw digest — a :class:`~repro.core.clock.Clock` over the
      dots of every element-key physically in storage (tombstone-covered or
      not).  Updates are buffered and applied lazily, so a write costs one
      list append and a digest read after ``w`` writes costs one batched
      ``add_dots``/``subtract`` — O(w + causal metadata), never a fold.
    * **subrange buckets** — the element keyspace fenced into contiguous
      subranges, each holding the mutable dot-set of its keys.  A bucket
      that outgrows ``bucket_limit`` is split at its median element
      (B-tree style, amortised O(log) per key), so locating the element
      range that holds any given dot set stays bounded: anti-entropy folds
      only the subranges whose buckets intersect the diverged dots.

    The **survivors digest** (dots of keys *visible* under the tombstone —
    the anti-entropy currency) is derived on demand: ``raw − (ts ∩ raw)``,
    O(tombstone) clock math.  Compaction keeps the tombstone small
    (invariant 3), so this is causal-metadata-sized in steady state.

    Memory: the total digest compresses contiguous runs into the base VV;
    buckets cannot (a bucket sees an element-ordered, hence dot-scattered,
    slice) and cost O(keys) ints overall — the in-memory analogue of
    Riak's on-disk AAE hashtree.
    """

    __slots__ = ("bucket_limit", "fences", "buckets", "counts", "limits",
                 "_total", "_pend_add", "_pend_sub", "_surv")

    def __init__(self, bucket_limit: int = 2048):
        self.bucket_limit = bucket_limit
        self.fences: List[bytes] = []        # element boundaries, sorted
        self.buckets: List[Dict[ActorId, set]] = [{}]
        self.counts: List[int] = [0]
        # per-bucket split thresholds: raised (backoff) when a bucket turns
        # out to be un-splittable — all keys one element — so it is not
        # re-folded on every subsequent write
        self.limits: List[int] = [bucket_limit]
        self._total: Clock = Clock.zero()
        self._pend_add: List[Dot] = []
        self._pend_sub: List[Dot] = []
        # (raw, tombstone, survivors) of the last survivors() computation
        self._surv: Optional[Tuple[Clock, Clock, Clock]] = None

    # ------------------------------------------------------------- updates
    def _bucket_of(self, element: bytes) -> int:
        return bisect.bisect_right(self.fences, element)

    def add(self, element: bytes, dot: Dot) -> Optional[int]:
        """Record a written element-key.  Returns a bucket index to split
        (caller folds that subrange and calls :meth:`split`) or None.

        Idempotent: re-adding a dot already in its bucket (store adoption
        racing a split's disk fold) never double-counts.
        """
        i = self._bucket_of(element)
        s = self.buckets[i].setdefault(dot.actor, set())
        if dot.counter in s:
            # a split's disk fold placed it in the bucket already, but the
            # total may not have it yet (adoption reaches keys the fold ran
            # ahead of) — add_dots is idempotent, so always feed the total
            self._pend_add.append(dot)
            return None
        s.add(dot.counter)
        self.counts[i] += 1
        self._pend_add.append(dot)
        return i if self.counts[i] > self.limits[i] else None

    def discard(self, element: bytes, dot: Dot) -> None:
        """Record a compaction-discarded element-key."""
        i = self._bucket_of(element)
        s = self.buckets[i].get(dot.actor)
        if s is not None and dot.counter in s:
            s.remove(dot.counter)
            if not s:
                del self.buckets[i][dot.actor]
            self.counts[i] -= 1
            self._pend_sub.append(dot)

    def bucket_bounds(self, i: int) -> Tuple[Optional[bytes], Optional[bytes]]:
        """Element-range ``[lo, hi)`` of bucket ``i`` (None = unbounded)."""
        lo = self.fences[i - 1] if i > 0 else None
        hi = self.fences[i] if i < len(self.fences) else None
        return lo, hi

    def split(self, i: int, items: List[Tuple[bytes, Dot]]) -> bool:
        """Split bucket ``i`` at the median element of its folded ``items``.

        ``items`` is the (element, dot) list of every physical key in the
        bucket's range, in element order.  When every key shares one
        element there is nothing to fence on: the bucket's split threshold
        doubles instead (backoff), so hot single-element buckets — e.g. a
        shard re-saved thousands of times between compactions — are not
        re-folded on every write.  Returns whether a fence was added.
        """
        if not items:
            return False
        mid = items[len(items) // 2][0]
        if mid == items[0][0]:
            # median equals the low edge: fence at the next element change
            for el, _d in items:
                if el > mid:
                    mid = el
                    break
            else:
                self.limits[i] = max(self.counts[i], self.limits[i]) * 2
                return False
        left: Dict[ActorId, set] = {}
        right: Dict[ActorId, set] = {}
        n_left = 0
        for el, d in items:
            tgt = left if el < mid else right
            tgt.setdefault(d.actor, set()).add(d.counter)
            if el < mid:
                n_left += 1
        self.fences.insert(i, mid)
        self.buckets[i: i + 1] = [left, right]
        self.counts[i: i + 1] = [n_left, len(items) - n_left]
        self.limits[i: i + 1] = [self.bucket_limit, self.bucket_limit]
        return True

    # --------------------------------------------------------------- reads
    def raw_total(self) -> Clock:
        """Digest of every physical element-key's dot (pending applied)."""
        if self._pend_add:
            self._total = self._total.add_dots(self._pend_add)
            self._pend_add = []
        if self._pend_sub:
            self._total = self._total.subtract(self._pend_sub)
            self._pend_sub = []
        return self._total

    def survivors(self, tombstone: Clock) -> Clock:
        """Digest of *visible* element-key dots: raw minus ts-covered.

        An O(runs) run-difference (:meth:`Clock.subtract_clock`) — never a
        per-dot enumeration.  Computed only when the state actually
        changed: the result is cached against (raw identity, tombstone
        equality), and anti-entropy reads this several times per round per
        set, all between state changes.
        """
        raw = self.raw_total()
        if tombstone.is_zero():
            return raw
        cached = self._surv
        if cached is not None and cached[0] is raw and cached[1] == tombstone:
            return cached[2]
        out = raw.subtract_clock(tombstone)
        self._surv = (raw, tombstone, out)
        return out

    def ranges_containing(
        self, dots: Iterable[Dot]
    ) -> List[Tuple[Optional[bytes], Optional[bytes]]]:
        """Coalesced element ranges of the buckets holding any of ``dots``.

        This is the location half of divergence-bounded sync: the caller
        folds only these subranges instead of the whole set.
        """
        want = list(dots)
        hit: List[int] = []
        for i, bucket in enumerate(self.buckets):
            for d in want:
                s = bucket.get(d.actor)
                if s is not None and d.counter in s:
                    hit.append(i)
                    break
        out: List[Tuple[Optional[bytes], Optional[bytes]]] = []
        for i in hit:
            lo, hi = self.bucket_bounds(i)
            if out and out[-1][1] is not None and out[-1][1] == lo:
                out[-1] = (out[-1][0], hi)  # adjacent buckets: one fold
            else:
                out.append((lo, hi))
        return out

    def key_count(self) -> int:
        return sum(self.counts)


# ---------------------------------------------------------------- the vnode
class BigsetVnode:
    """One replica (vnode) hosting many bigsets in a single ordered store."""

    def __init__(self, actor: ActorId, store: Optional[LsmStore] = None,
                 digest_bucket_limit: int = 2048):
        self.actor = actor
        # `store or LsmStore()` would silently discard an injected *empty*
        # store (LsmStore defines __len__, and a fresh store is falsy) —
        # fatal for durable stores injected before their first write
        self.store = store if store is not None else LsmStore()
        self.store.compaction_filter = self._compaction_filter
        self.store.on_discard = self._on_discard
        self._discarded: Dict[bytes, List[Dot]] = {}
        self._ts_cache: Dict[bytes, Clock] = {}  # valid only within one compaction
        self._indexes: Dict[bytes, Dict[bytes, IndexSpec]] = {}
        # per-set maintained digests of physical element-keys (anti-entropy
        # reads these instead of folding; see SetDigest)
        self._digests: Dict[bytes, SetDigest] = {}
        self._digest_bucket_limit = digest_bucket_limit

    # -------------------------------------------------------------- digests
    def _fold_background(
        self, lo: bytes, hi: bytes
    ) -> List[Tuple[bytes, bytes]]:
        """Raw scan metered as *background* volume (``bytes_compacted``).

        Digest maintenance (adoption of a pre-populated store, bucket
        splits) reads element-keys the way compaction does — as background
        upkeep, not foreground query IO — so it must not pollute the
        foreground ``bytes_read``/``num_seeks`` the paper's cost claims are
        asserted against.
        """
        st = self.store.stats
        seeks0, read0 = st.num_seeks, st.bytes_read
        items = list(self.store.seek(lo, hi))
        st.num_seeks = seeks0
        st.bytes_compacted += st.bytes_read - read0
        st.bytes_read = read0
        return items

    def _digest(self, set_name: bytes) -> SetDigest:
        """The set's maintained digest, adopting pre-existing keys once.

        All write paths in this repo create keys through this vnode, so in
        practice adoption sees an empty range and the digest is maintained
        incrementally from the set's first insert — zero folds ever.  A
        vnode handed an already-populated store pays one background fold
        here and is exact from then on.
        """
        dig = self._digests.get(set_name)
        if dig is None:
            dig = SetDigest(self._digest_bucket_limit)
            self._digests[set_name] = dig
            lo, hi = element_range(set_name)
            for k, _v in self._fold_background(lo, hi):
                _s, element, dot = decode_element_key(k)
                self._digest_add(dig, set_name, element, dot)
        return dig

    def _digest_add(self, dig: SetDigest, set_name: bytes, element: bytes,
                    dot: Dot) -> None:
        overflow = dig.add(element, dot)
        if overflow is not None:
            b_lo, b_hi = dig.bucket_bounds(overflow)
            lo, hi = element_bounds(set_name, start=b_lo, end=b_hi)
            items = []
            for k, _v in self._fold_background(lo, hi):
                _s, el, d = decode_element_key(k)
                items.append((el, d))
            dig.split(overflow, items)

    def survivors_digest(self, set_name: bytes) -> Clock:
        """Clock digest of the dots of all surviving element-keys.

        O(causal metadata): derived from the maintained digest, never a
        fold.  This is the anti-entropy currency — two replicas whose
        set-clocks and survivors digests match are converged.
        """
        return self._digest(set_name).survivors(self.read_tombstone(set_name))

    def digest_ranges(
        self, set_name: bytes, dots: Iterable[Dot]
    ) -> List[Tuple[Optional[bytes], Optional[bytes]]]:
        """Element subranges whose keys could carry any of ``dots``.

        The divergence-bounded sync primitive: a peer that needs specific
        dots folds only these fenced subranges, so sync scan cost tracks
        the diverged subranges, not set cardinality.
        """
        return self._digest(set_name).ranges_containing(dots)

    # ------------------------------------------------------------ sec. indexes
    def register_index(
        self, set_name: bytes, spec: IndexSpec, backfill: bool = True
    ) -> int:
        """Register a secondary index on one set; returns postings written.

        Extractors must be registered identically on every replica (they run
        downstream too).  ``backfill`` reconciles the index's posting range
        against every element-key already in storage — including
        tombstone-covered ones, preserving the invariant that a posting
        exists exactly for the element-keys that physically exist, so both
        compact away in the same pass.  Reconciliation makes re-registration
        "last wins" for real: postings a previous extractor produced that
        the new one does not are storage-deleted (their dots are live, so
        no tombstone would ever discard them), and re-registering the same
        extractor is a no-op.
        """
        self._indexes.setdefault(set_name, {})[spec.name] = spec
        if not backfill:
            return 0
        lo, hi = index_range(set_name, spec.name)
        stale = {k for k, _ in self.store.seek(lo, hi)}
        fresh: List[Tuple[bytes, bytes]] = []
        for element, dot, value in self.fold_raw(set_name):
            for ik in spec.keys(element, value):
                k = posting_key(set_name, spec.name, ik, element, dot)
                if k in stale:
                    stale.discard(k)  # already correct under this extractor
                else:
                    fresh.append((k, b""))
        batch = fresh + [(k, STORE_TOMBSTONE) for k in sorted(stale)]
        if batch:
            self.store.put_batch(batch)
        return len(fresh)

    def indexes(self, set_name: bytes) -> Tuple[IndexSpec, ...]:
        return tuple(self._indexes.get(set_name, {}).values())

    def _posting_writes(
        self, set_name: bytes, element: bytes, dot: Dot, value: bytes
    ) -> List[Tuple[bytes, bytes]]:
        specs = self._indexes.get(set_name)
        if not specs:
            return []
        return [
            (posting_key(set_name, spec.name, ik, element, dot), b"")
            for spec in specs.values()
            for ik in spec.keys(element, value)
        ]

    def fold_postings(
        self,
        set_name: bytes,
        index_name: bytes,
        start: Optional[bytes] = None,
        end: Optional[bytes] = None,
        at: Optional[Tuple[bytes, bytes]] = None,
        after: Optional[Tuple[bytes, bytes]] = None,
    ) -> Iterator[Tuple[bytes, bytes, Dot]]:
        """Unfiltered ``(index_key, element, dot)`` posting stream.

        The index analogue of :meth:`fold_raw`: a storage seek to the first
        relevant posting (or a ``(index_key, element)`` cursor boundary via
        ``at``/``after``) plus a bounded lazy scan.  Tombstone visibility is
        applied by the query executor's batched dot filter, exactly as for
        element-keys.
        """
        lo, hi = index_bounds(set_name, index_name, start, end, at, after)
        for k, _v in self.store.seek(lo, hi):
            _s, _i, ik, element, dot = decode_posting_key(k)
            yield ik, element, dot

    # ------------------------------------------------------------- clock io
    def read_clock(self, set_name: bytes) -> Clock:
        return _clock_from_bytes(self.store.get(clock_key(set_name)))

    def read_tombstone(self, set_name: bytes) -> Clock:
        return _clock_from_bytes(self.store.get(tombstone_key(set_name)))

    # ----------------------------------------------------------- Algorithm 1
    def coordinate_insert(
        self, set_name: bytes, element: bytes, ctx: Iterable[Dot] = (),
        value: bytes = b"",
    ) -> InsertDelta:
        """Coordinator-side insert (paper Algorithm 1).

        Reads clocks only; context dots unseen by the set-clock are added to
        it (so superseded adds can never materialise later), seen ones go to
        the tombstone (so their element-keys compact away).  Mints a fresh
        dot, atomically writes [set-clock, set-tombstone, element-key] and
        returns the delta to send downstream.
        """
        ctx = tuple(ctx)
        sc = self.read_clock(set_name)
        ts = self.read_tombstone(set_name)
        for dot in ctx:
            if not sc.seen(dot):
                sc = sc.add(dot)
            else:
                ts = ts.add(dot)
        sc, dot = sc.increment(self.actor)
        dig = self._digest(set_name)  # adopt pre-state before the key lands
        self.store.put_batch(
            [
                (clock_key(set_name), _clock_to_bytes(sc)),
                (tombstone_key(set_name), _clock_to_bytes(ts)),
                (element_key(set_name, element, dot), value),
            ]
            + self._posting_writes(set_name, element, dot, value)
        )
        self._digest_add(dig, set_name, element, dot)
        return InsertDelta(set_name, element, dot, ctx, value)

    # ----------------------------------------------------------- Algorithm 2
    def replica_insert(self, delta: InsertDelta) -> bool:
        """Downstream delta apply (paper Algorithm 2).

        Never merges full state: a dot-seen check, a clock add and an append.
        Returns True if the element-key was written (False -> duplicate no-op).
        """
        set_name = delta.set_name
        sc0 = sc = self.read_clock(set_name)
        ts0 = ts = self.read_tombstone(set_name)
        for dot in delta.ctx:
            if not sc.seen(dot):
                sc = sc.add(dot)
            else:
                ts = ts.add(dot)
        if not sc.seen(delta.dot):
            sc = sc.add(delta.dot)
            dig = self._digest(set_name)  # adopt pre-state before the write
            self.store.put_batch(
                [
                    (clock_key(set_name), _clock_to_bytes(sc)),
                    (tombstone_key(set_name), _clock_to_bytes(ts)),
                    (element_key(set_name, delta.element, delta.dot), delta.value),
                ]
                + self._posting_writes(
                    set_name, delta.element, delta.dot, delta.value)
            )
            self._digest_add(dig, set_name, delta.element, delta.dot)
            return True
        # seen: write clocks only if the ctx changed them — a redelivered
        # delta whose ctx is already absorbed must be byte-for-byte free
        # under at-least-once delivery (Clock.add returns self on no-ops,
        # so identity is an exact change test)
        if sc is not sc0 or ts is not ts0:
            self.store.put_batch(
                [
                    (clock_key(set_name), _clock_to_bytes(sc)),
                    (tombstone_key(set_name), _clock_to_bytes(ts)),
                ]
            )
        return False

    # -------------------------------------------------------------- removes
    def coordinate_remove(
        self, set_name: bytes, ctx: Iterable[Dot]
    ) -> RemoveDelta:
        """Remove (§4.3.2): clock-only write; the ctx **must** come from a read."""
        ctx = tuple(ctx)
        self._apply_remove(set_name, ctx)
        return RemoveDelta(set_name, ctx)

    def replica_remove(self, delta: RemoveDelta) -> None:
        self._apply_remove(delta.set_name, delta.ctx)

    def _apply_remove(self, set_name: bytes, ctx: Tuple[Dot, ...]) -> None:
        sc0 = sc = self.read_clock(set_name)
        ts0 = ts = self.read_tombstone(set_name)
        for dot in ctx:
            if sc.seen(dot):
                ts = ts.add(dot)  # key exists (or existed): compact it away
            else:
                sc = sc.add(dot)  # unseen add: pre-empt it ever materialising
        if sc is sc0 and ts is ts0:
            return  # redelivered remove already absorbed: zero writes
        self.store.put_batch(
            [
                (clock_key(set_name), _clock_to_bytes(sc)),
                (tombstone_key(set_name), _clock_to_bytes(ts)),
            ]
        )

    # ---------------------------------------------------------------- reads
    def fold(
        self, set_name: bytes
    ) -> Iterator[Tuple[bytes, Dot]]:
        """Stream surviving (element, dot) pairs in lexicographic element order."""
        for element, dot, _v in self.fold_values(set_name):
            yield element, dot

    def fold_values(
        self, set_name: bytes
    ) -> Iterator[Tuple[bytes, Dot, bytes]]:
        """Fold including element values (checkpoint-shard payloads)."""
        ts = self.read_tombstone(set_name)
        for element, dot, v in self.fold_raw(set_name):
            if not ts.seen(dot):
                yield element, dot, v

    def fold_raw(
        self,
        set_name: bytes,
        start: Optional[bytes] = None,
        end: Optional[bytes] = None,
        after: Optional[bytes] = None,
    ) -> Iterator[Tuple[bytes, Dot, bytes]]:
        """Unfiltered element-key stream over a bounded range.

        This is the fold hook the query executor drives: a storage *seek* to
        the range start (or strictly past the cursor element via ``after``)
        followed by a bounded lazy scan, so a range query touches
        O(result + causal metadata) bytes instead of the whole set.
        Tombstone visibility is **not** applied here — the executor filters
        dots in batches (see :mod:`repro.query.batch`).
        """
        lo, hi = element_bounds(set_name, start, end, after)
        for k, v in self.store.seek(lo, hi):
            _s, element, dot = decode_element_key(k)
            yield element, dot, v

    def element_cursor(
        self,
        set_name: bytes,
        start: Optional[bytes] = None,
        end: Optional[bytes] = None,
        after: Optional[bytes] = None,
    ) -> ElementCursor:
        """Like :meth:`fold_raw`, but positional: the returned cursor can
        :meth:`~ElementCursor.seek` to any element without paying for the
        keys in between (the storage half of gallop joins and cursor
        resumption)."""
        return ElementCursor(self.store, set_name, start, end, after)

    def read(self, set_name: bytes, batch_size: int = 10_000) -> "ReadStream":
        """Streaming read (§4.4): batches of a partial ORSWOT, default 10k."""
        return ReadStream(self, set_name, batch_size)

    def read_full(self, set_name: bytes) -> Orswot:
        """Materialise the whole set as a traditional ORSWOT (for tests/merge)."""
        sc = self.read_clock(set_name)
        entries: Dict[bytes, set] = {}
        for element, dot in self.fold(set_name):
            entries.setdefault(element, set()).add(dot)
        return Orswot(sc, {e: frozenset(s) for e, s in entries.items()})

    def value(self, set_name: bytes) -> FrozenSet[bytes]:
        return frozenset(e for e, _ in self.fold(set_name))

    def is_member(self, set_name: bytes, element: bytes) -> Tuple[bool, Tuple[Dot, ...]]:
        """Membership query without reading the whole set (a seek, §4.4).

        Returns (present, surviving dots) — the dots double as the causal
        context for a subsequent remove or replacing add.
        """
        ts = self.read_tombstone(set_name)
        dots = [
            dot
            for el, dot, _v in self.fold_raw(
                set_name, start=element, end=element + b"\x00")
            if el == element and not ts.seen(dot)
        ]
        return (len(dots) > 0), tuple(sorted(dots))

    def range_query(
        self, set_name: bytes, start: bytes, limit: int
    ) -> List[bytes]:
        """Seek to ``start`` and stream up to ``limit`` members (pagination)."""
        ts = self.read_tombstone(set_name)
        out: List[bytes] = []
        last = None
        for el, dot, _v in self.fold_raw(set_name, start=start):
            if ts.seen(dot):
                continue
            if el != last:
                if len(out) == limit:
                    break
                out.append(el)
                last = el
        return out

    def context_of(self, set_name: bytes, element: bytes) -> Tuple[Dot, ...]:
        return self.is_member(set_name, element)[1]

    # ----------------------------------------------------------- retirement
    def drop_set(self, set_name: bytes) -> int:
        """Delete every key of one set — clock, tombstone, elements,
        postings — and drop its maintained digest.  Returns keys deleted.

        The ring-handoff retirement primitive: after a new owner's clock
        provably dominates this replica's, the moved partition's local
        copy is dead weight.  Deletion is storage-tombstone writes (the
        keys physically leave on the next compaction); the set reads as
        empty immediately.  Index specs stay registered, so a straggler
        replication delta delivered after retirement still derives its
        postings — it becomes a harmless orphan the next ring change or
        anti-entropy round will not resurrect into queries, because
        queries only ever cover owner vnodes.
        """
        lo = encode_key((set_name, KIND_CLOCK))
        hi = encode_key((set_name, KIND_INDEX + 1))
        batch = [(k, STORE_TOMBSTONE) for k, _v in self.store.seek(lo, hi)]
        if batch:
            self.store.put_batch(batch)
        self._digests.pop(set_name, None)
        return len(batch)

    # ----------------------------------------------------------- compaction
    def _compaction_filter(self, key: bytes, value: bytes) -> bool:
        """The modified-leveldb hook: drop element-keys **and** index
        postings seen by the tombstone.

        Both kinds carry their dot in the trailing ``(actor, counter)``
        components and both are tested against the same tombstone snapshot
        in the same pass, so a dead element-key and its postings always
        leave storage together — no separate index GC.
        """
        parts = decode_key(key)
        if len(parts) < 3 or parts[1] not in (KIND_ELEMENT, KIND_INDEX):
            return False
        set_name = parts[0]
        ts = self._ts_cache.get(set_name)
        if ts is None:
            ts = _clock_from_bytes(self._peek(tombstone_key(set_name)))
            self._ts_cache[set_name] = ts
        return ts.seen(_dot_from_parts(parts))

    def _peek(self, key: bytes) -> Optional[bytes]:
        # un-metered read used inside compaction (compaction volume is metered
        # separately by the store)
        v = self.store.memtable.get(key)
        if v is None:
            for run in self.store.runs:
                v = run.get(key)
                if v is not None:
                    break
        from ..storage.lsm import TOMBSTONE as _T

        return None if v is None or v == _T else v

    def _on_discard(self, key: bytes, value: bytes) -> None:
        parts = decode_key(key)
        if parts[1] != KIND_ELEMENT:
            return  # postings ride along; only element dots shrink the tombstone
        set_name, dot = parts[0], _dot_from_parts(parts)
        self._discarded.setdefault(set_name, []).append(dot)
        dig = self._digests.get(set_name)
        if dig is not None:  # uninitialised digests adopt post-compaction state
            dig.discard(parts[2], dot)

    def compact(self) -> Dict[bytes, List[Dot]]:
        """Run storage compaction; shrink tombstones by the discarded dots.

        Returns {set_name: [discarded dots]} (§4.3.3: "Once a key is removed
        the set-tombstone subtracts the deleted dot").
        """
        self._discarded = {}
        self._ts_cache = {}
        self.store.compact()
        discarded = self._discarded
        self._discarded = {}
        self._ts_cache = {}
        batch = []
        for set_name in set(discarded) | set(self._digests):
            ts0 = ts = self.read_tombstone(set_name)
            if set_name in discarded:
                ts = ts.subtract(discarded[set_name])
            # hygiene: a tombstone dot with no physical key left (e.g. a
            # redelivered remove re-added it after its key compacted away)
            # can never discard anything again — drop it here, since sync
            # skips its trim when a reply leaves the tombstone unchanged
            dig = self._digests.get(set_name)
            if dig is not None and not ts.is_zero():
                # O(runs) run-intersection: keep only removals the raw
                # total actually covers
                ts = ts.intersect(dig.raw_total())
            if ts is not ts0:
                batch.append((tombstone_key(set_name), _clock_to_bytes(ts)))
        if batch:
            self.store.put_batch(batch)
        return discarded


# ------------------------------------------------------------ streaming read
class ReadStream:
    """Batched streaming read of a bigset (§4.4), preserving element order.

    Each batch is a *partial* ORSWOT (the set-clock plus a slice of entries)
    suitable for the streaming quorum join in :mod:`repro.core.streaming`.
    """

    def __init__(self, vnode: BigsetVnode, set_name: bytes, batch_size: int):
        self.clock = vnode.read_clock(set_name)
        self._vnode = vnode
        self._set = set_name
        self._batch = batch_size

    def batches(self) -> Iterator[List[Tuple[bytes, Tuple[Dot, ...]]]]:
        out: List[Tuple[bytes, Tuple[Dot, ...]]] = []
        cur_el: Optional[bytes] = None
        cur_dots: List[Dot] = []
        for element, dot in self._vnode.fold(self._set):
            if element != cur_el:
                if cur_el is not None:
                    out.append((cur_el, tuple(cur_dots)))
                    if len(out) >= self._batch:
                        yield out
                        out = []
                cur_el, cur_dots = element, [dot]
            else:
                cur_dots.append(dot)
        if cur_el is not None:
            out.append((cur_el, tuple(cur_dots)))
        if out:
            yield out

    def entries(self) -> Iterator[Tuple[bytes, Tuple[Dot, ...]]]:
        for batch in self.batches():
            yield from batch
