"""Assigned input-shape cells and ShapeDtypeStruct input specs.

Four cells per LM arch (paper-assignment block):
  train_4k    — seq 4096,  global_batch 256  -> train_step
  prefill_32k — seq 32768, global_batch 32   -> prefill_step
  decode_32k  — seq 32768, global_batch 128  -> decode_step (1 new token)
  long_500k   — seq 524288, global_batch 1   -> decode_step

``long_500k`` requires sub-quadratic attention: it runs for the SSM /
hybrid / sliding-window archs and is skipped for pure full-attention archs
and the enc-dec (DESIGN.md §4 records each skip).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .base import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs with a sub-quadratic long-context path
LONG_OK_FAMILIES = ("ssm", "hybrid")


def long_context_capable(cfg: ModelConfig) -> bool:
    if cfg.family in LONG_OK_FAMILIES:
        return True
    # sliding-window archs: the windowed layers bound the KV cache; the
    # sparse global layers are linear-in-S at decode (one token per step)
    if cfg.sliding_window and cfg.local_per_global:
        return True
    return False


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not long_context_capable(cfg):
        return False, "no sub-quadratic attention path (DESIGN.md §4)"
    if cfg.is_encoder_decoder and shape.name == "long_500k":
        return False, "enc-dec: 500k decode undefined (max source 30s audio)"
    return True, ""


def shape_cells(cfg: ModelConfig) -> Iterator[ShapeSpec]:
    for s in SHAPES.values():
        ok, _ = cell_applicable(cfg, s)
        if ok:
            yield s


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the *batch* of one step (weak-type
    correct, shardable, no allocation).  Caches/state specs come from
    ``Model.init_cache`` under ``jax.eval_shape``."""
    B, S = shape.global_batch, shape.seq_len
    dt = _dtype(cfg)
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sd((B, S + 1), jnp.int32)}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = sd((B, cfg.n_patches, cfg.d_model), dt)
        if cfg.is_encoder_decoder:
            batch["encoder_frames"] = sd((B, cfg.encoder_positions, cfg.d_model), dt)
    elif shape.kind == "prefill":
        batch = {"tokens": sd((B, S), jnp.int32)}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = sd((B, cfg.n_patches, cfg.d_model), dt)
        if cfg.is_encoder_decoder:
            batch["encoder_frames"] = sd((B, cfg.encoder_positions, cfg.d_model), dt)
    else:  # decode: one new token against a cache of seq_len
        batch = {
            "tokens": sd((B, 1), jnp.int32),
            "cache_len": sd((B,), jnp.int32),
        }
    return batch
