"""pixtral-12b [vlm] — hf:mistralai/Pixtral-12B-2409 (unverified).

Decoder backbone (mistral-nemo): 40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072, head_dim=128.  The Pixtral-ViT frontend is
STUBBED: ``input_specs()`` provides precomputed patch embeddings that the
backbone splices over the leading positions.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    source="hf:mistralai/Pixtral-12B-2409; unverified",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    hidden_act="silu",
    rope_theta=1_000_000.0,
    frontend="vision",
    n_patches=256,
    tie_embeddings=False,
    optimizer_moments="fp32",
)
